"""Docs smoke checker: every fenced ``python`` code block in README.md
and docs/*.md must run cleanly (PYTHONPATH=src, fresh subprocess per
block, asserts and prints included). Fences tagged anything else
(``bash``, ``text``) are skipped — label a snippet ``python`` only if
it is meant to be executable documentation.

    PYTHONPATH=src python scripts/check_docs.py

Exit code 0 = all blocks ran; 1 = at least one failed (stderr shows
the file, block index, and traceback). CI runs this as the docs job.
"""
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FENCE = re.compile(r"^```(\w*)\s*$")


def extract_python_blocks(path):
    blocks, cur, lang = [], None, None
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            m = FENCE.match(line)
            if m and cur is None:
                lang, cur, start = m.group(1), [], lineno + 1
            elif m:
                if lang == "python":
                    blocks.append((start, "".join(cur)))
                cur, lang = None, None
            elif cur is not None:
                cur.append(line)
    return blocks


def main() -> int:
    docs = [os.path.join(REPO, "README.md")]
    docs_dir = os.path.join(REPO, "docs")
    if os.path.isdir(docs_dir):
        docs += sorted(os.path.join(docs_dir, n)
                       for n in os.listdir(docs_dir)
                       if n.endswith(".md"))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    failures = []                 # (file, line, reason) — ALL of them
    total = 0
    for doc in docs:
        rel = os.path.relpath(doc, REPO)
        for start, code in extract_python_blocks(doc):
            total += 1
            try:
                proc = subprocess.run([sys.executable, "-c", code],
                                      env=env, capture_output=True,
                                      text=True, timeout=600)
            except subprocess.TimeoutExpired:
                # a hanging block must not abort the run — record it
                # and keep checking the rest
                failures.append((rel, start, "timed out after 600s"))
                sys.stderr.write(
                    f"FAIL {rel}: block at line {start} timed out\n")
                continue
            if proc.returncode != 0:
                failures.append((rel, start,
                                 f"exit code {proc.returncode}"))
                sys.stderr.write(
                    f"FAIL {rel}: block at line {start}\n"
                    f"{proc.stdout}{proc.stderr}\n")
            else:
                print(f"ok   {rel}: block at line {start}")
    print(f"{total - len(failures)}/{total} doc blocks ran cleanly")
    if failures:
        sys.stderr.write("failing blocks:\n" + "".join(
            f"  {rel}:{start}  ({reason})\n"
            for rel, start, reason in failures))
    return 1 if failures or total == 0 else 0


if __name__ == "__main__":
    sys.exit(main())
