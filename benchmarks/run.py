"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [table3 table4 ...]

Emits ``name,us_per_call,derived`` CSV rows:
  table3    — frozen-aware vs -unaware pipeline partitioning (§6.4)
  table2    — modality parallelism vs colocated/replicated (§6.2/§6.3)
  table4    — CP token distribution: LPT/random/ring/zigzag (§6.5)
  kernel    — BAM Pallas kernel block-sparsity & memory wins
  roofline  — §Roofline terms from the dry-run artifacts
  schedmem  — simulator-vs-executor peak-activation validation for
              every pipeline schedule (fails loudly on divergence)
"""
import sys


def main() -> None:
    want = set(sys.argv[1:])

    def on(name):
        return not want or name in want

    print("name,us_per_call,derived", flush=True)
    if on("table3"):
        from benchmarks import bench_frozen_aware_pp
        bench_frozen_aware_pp.run()
    if on("table2"):
        from benchmarks import bench_modality_parallel
        bench_modality_parallel.run()
    if on("table4"):
        from benchmarks import bench_cp_distribution
        bench_cp_distribution.run()
    if on("kernel"):
        from benchmarks import bench_bam_kernel
        bench_bam_kernel.run()
    if on("roofline"):
        from benchmarks import bench_roofline
        bench_roofline.run()
    if on("schedmem"):
        from benchmarks import bench_schedule_memory
        bench_schedule_memory.run()


if __name__ == '__main__':
    main()
