"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--smoke] [table3 table4 ...]

Emits ``name,us_per_call,derived`` CSV rows:
  table3    — frozen-aware vs -unaware pipeline partitioning (§6.4)
  table2    — modality parallelism vs colocated/replicated (§6.2/§6.3)
  table4    — CP token distribution: LPT/random/ring/zigzag (§6.5)
  kernel    — BAM Pallas kernel block-sparsity & memory wins
  roofline  — §Roofline terms from the dry-run artifacts
  schedmem  — simulator-vs-executor peak-activation validation for
              every pipeline schedule (fails loudly on divergence)
  spmd      — distributed shard_map executor vs sequential replay
              (multi-device subprocess; fails loudly on grad or
              peak divergence)
  spmdtrain — real-MLLM SPMD train step (stage bundle through the
              wave program) + rolled-vs-switch dispatch compile
              scaling; writes BENCH_spmd_train.json
  serve     — paged-cache serving throughput: tokens/sec vs batch
              size, xla gather vs paged flash-decode kernel, plus
              the multimodal page-skip fraction
  resil     — fault-tolerance runtime cost: in-jit health-monitor
              overhead per train step (guarded vs plain), atomic
              checkpoint save/restore MB/s

``--smoke`` shrinks every benchmark to a tiny grid with one repeat —
seconds, not minutes — so CI can execute all of them on every push and
the scripts cannot rot silently when the API moves under them. The
figures a smoke run emits are NOT the paper's numbers; only the full
grids are.
"""
import sys


def main() -> None:
    argv = list(sys.argv[1:])
    smoke = "--smoke" in argv
    if smoke:
        argv = [a for a in argv if a != "--smoke"]
    want = set(argv)

    def on(name):
        return not want or name in want

    print("name,us_per_call,derived", flush=True)
    if on("table3"):
        from benchmarks import bench_frozen_aware_pp
        bench_frozen_aware_pp.run(smoke=smoke)
    if on("table2"):
        from benchmarks import bench_modality_parallel
        bench_modality_parallel.run(smoke=smoke)
    if on("table4"):
        from benchmarks import bench_cp_distribution
        bench_cp_distribution.run(smoke=smoke)
    if on("kernel"):
        from benchmarks import bench_bam_kernel
        bench_bam_kernel.run(smoke=smoke)
    if on("roofline"):
        from benchmarks import bench_roofline
        bench_roofline.run(smoke=smoke)
    if on("schedmem"):
        from benchmarks import bench_schedule_memory
        bench_schedule_memory.run(smoke=smoke)
    if on("spmd"):
        from benchmarks import bench_spmd_executor
        bench_spmd_executor.run(smoke=smoke)
    if on("spmdtrain"):
        from benchmarks import bench_spmd_train
        bench_spmd_train.run(smoke=smoke)
    if on("serve"):
        from benchmarks import bench_serve
        bench_serve.run(smoke=smoke)
    if on("resil"):
        from benchmarks import bench_resilience
        bench_resilience.run(smoke=smoke)


if __name__ == '__main__':
    main()
