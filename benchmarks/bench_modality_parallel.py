"""Paper Table 2 / §6.3 + Fig. 9/10 (§6.2): modality parallelism vs
encoders-colocated vs encoders-replicated, VALM grid (vision × audio
S/M/L with a medium LLM).

``derived`` = normalized throughput/device for each scheme + the
cornstarch/colocated speedup (paper: up to 1.57x end-to-end; Table 2
shows modality parallelism matches or beats colocated while being more
flexible)."""
import time

import numpy as np

from repro.configs.paper_mllm import (audio_encoder_config, llm_config,
                                      vision_encoder_config)
from repro.core import pipeline as pp
from repro.models.mllm import AUDIO_TOKENS, VISION_TOKENS
from repro.parallel import ClusterSpec, WorkloadShape, search_plan

from .common import emit

TEXT_LEN = 1024
MICROBATCHES = 24


def valm_profiles(v_size: str, a_size: str, llm_size: str = "M"):
    vis = pp.profile_from_config(vision_encoder_config(v_size),
                                 VISION_TOKENS, frozen=True, name="vision")
    aud = pp.profile_from_config(audio_encoder_config(a_size),
                                 AUDIO_TOKENS, frozen=True, name="audio")
    llm = pp.profile_from_config(
        llm_config(llm_size), TEXT_LEN + VISION_TOKENS + AUDIO_TOKENS,
        frozen=True, name="llm")
    llm.trainable_upstream = True   # trainable projectors before the LLM
    return [vis, aud], llm


def tput_per_device(sim, devices, microbatches):
    return microbatches / (sim["iteration_time"] * devices)


def run(llm_size: str = "M", smoke: bool = False):
    rows = []
    sizes = ("S",) if smoke else ("S", "M", "L")
    microbatches = 8 if smoke else MICROBATCHES
    for v in sizes:
        for a in sizes:
            encs, llm = valm_profiles(v, a, llm_size)
            t0 = time.perf_counter()
            # Cornstarch: Algorithm-1 auto-parallelized modality-parallel
            # through the typed API (1F1B only here so the device
            # accounting matches the colocated/replicated baselines
            # below, which run 1F1B)
            plan = search_plan(encs, llm, ClusterSpec(num_devices=12),
                               WorkloadShape(
                                   text_len=TEXT_LEN,
                                   num_microbatches=microbatches),
                               schedules=("1f1b",))
            devices = plan.pp_devices
            corn = plan.schedule.tput_per_device
            # encoders-colocated: fused encoder chain + llm chain, split
            # chosen by forward-time balance (frozen-unaware baseline)
            best_colo = None
            for enc_stages in range(1, 8):
                llm_stages = devices - enc_stages
                if llm_stages < 1:
                    continue
                g = pp.build_colocated(encs, llm, enc_stages, llm_stages,
                                       frozen_aware=False)
                sim = pp.simulate_1f1b(g, microbatches)
                t = tput_per_device(sim, devices, microbatches)
                if best_colo is None or t > best_colo:
                    best_colo = t
            # encoders-replicated (Meta-Llama style)
            g = pp.build_replicated(encs, llm, devices,
                                    frozen_aware=False)
            sim = pp.simulate_1f1b(g, microbatches)
            repl = tput_per_device(sim, devices, microbatches)
            us = (time.perf_counter() - t0) * 1e6
            name = f"table2/valm-{v}{a}-llm{llm_size}"
            emit(name, us,
                 f"corn={corn:.3e};colocated={best_colo:.3e};"
                 f"replicated={repl:.3e};"
                 f"speedup_vs_colo={corn / best_colo:.3f};"
                 f"speedup_vs_repl={corn / repl:.3f};"
                 f"stages=llm{plan.stage.llm_stages}+enc"
                 f"{list(plan.stage.encoder_stages)};"
                 f"sched={plan.schedule.name}")
            rows.append((name, corn / best_colo, corn / repl))
    return rows


if __name__ == "__main__":
    run()
