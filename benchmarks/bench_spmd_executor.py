"""SPMD schedule executor: distributed shard_map execution vs the
sequential replay, per schedule.

The bench process itself keeps the host's single real device (like the
test suite), so each scenario runs in a subprocess with a forced host
device count — the same harness the multi-device tests use. Per
schedule the child

* compiles the timeline to the wave/ppermute program
  (``repro.parallel.spmd.compile_spmd_program``),
* runs the shard_map executor once (trace + XLA compile) and then to
  steady state, and
* replays the identical timeline on the sequential executor
  (``core.modality_parallel.execute_schedule``),

and reports steady-state microseconds per distributed iteration with
``derived`` carrying the compile/first-call costs, the replay time,
the program shape (waves/rounds), and the max elementwise grad
difference against the replay — which the child ASSERTS is tiny, so a
row only ever appears for a program that computed the right thing.
"""
import os
import subprocess
import sys

from .common import emit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_CHILD = """
import time
import numpy as np
import jax
import jax.numpy as jnp
from repro.core import schedule as sch
from repro.core.modality_parallel import execute_schedule
from repro.parallel.spmd import (build_spmd_runner, compile_spmd_program,
                                 toy_stage_model)

scheds = {scheds!r}
iters = {iters}
M, d = {M}, 16
CHUNKED = ("interleaved", "zb-v")
for sched in scheds:
    stages = [sch.Stage(f"s{{s}}", 1.0, 2.0, bwd_w=1.0)
              for s in range(4)]
    g = sch.chain_graph(stages)
    if sched in CHUNKED:
        g = sch.refine_chain(sch.chain_graph(stages[:2]), 2)
    kwargs = {{"virtual_chunks": 2}} if sched in CHUNKED else {{}}
    sim = sch.get_scheduler(sched, **kwargs).simulate(g, M)
    t0 = time.perf_counter()
    prog = compile_spmd_program(g, sim)
    compile_us = (time.perf_counter() - t0) * 1e6
    fn, params = toy_stage_model(len(g.stages), d)
    mbs = jax.random.normal(jax.random.PRNGKey(1), (M, 1, 4, d))
    runner = build_spmd_runner(fn, g, sim, program=prog)
    t0 = time.perf_counter()
    res = runner(params, mbs)
    first_us = (time.perf_counter() - t0) * 1e6
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        res = runner(params, mbs)
        times.append(time.perf_counter() - t0)
    times.sort()
    us = times[len(times) // 2] * 1e6
    t0 = time.perf_counter()
    ref = execute_schedule(fn, params, mbs, g, sim)
    replay_us = (time.perf_counter() - t0) * 1e6
    diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree.leaves(res["param_grads"]),
        jax.tree.leaves(ref["param_grads"])))
    assert diff < 1e-4, (sched, diff)
    assert res["peak_activations_per_device"] == \\
        ref["peak_activations_per_device"], sched
    c = prog.counts()
    print(f"ROW spmd/{{sched}}-d{{c['devices']}} {{us:.1f}} "
          f"compile_us={{compile_us:.0f}};first_us={{first_us:.0f}};"
          f"replay_us={{replay_us:.0f}};waves={{c['waves']}};"
          f"rounds={{c['rounds']}};items={{c['items']}};"
          f"grad_diff={{diff:.1e}};match=1", flush=True)
"""


def run(smoke: bool = False):
    scheds = ("1f1b", "zb-v") if smoke else tuple(
        __import__("repro.core.schedule",
                   fromlist=["SCHEDULES"]).SCHEDULES)
    code = _CHILD.format(scheds=tuple(scheds), iters=2 if smoke else 5,
                         M=4 if smoke else 8)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=1200,
                          cwd=REPO)
    assert proc.returncode == 0, \
        f"spmd bench child failed:\n{proc.stdout}\n{proc.stderr}"
    rows = []
    for line in proc.stdout.splitlines():
        if not line.startswith("ROW "):
            continue
        _tag, name, us, derived = line.split(" ", 3)
        emit(name, float(us), derived)
        rows.append((name, float(us), derived))
    assert len(rows) == len(scheds), proc.stdout
    return rows


if __name__ == "__main__":
    run()
