"""Paper Table 4 / §6.5: context-parallel attention time under the four
token distributions (LPT, random, naive ring, zigzag) × three mask
types (EP, EE, MP) × sequence lengths.

Two measurement levels (CPU container, per DESIGN.md):
  * full scale (16k/32k/64k): per-rank attention *workload model*
    (row-sums of the BAM mask, the exact quantity all-gather CP time is
    proportional to) — ``pred_ms`` = max-rank workload / v5e attention
    throughput;
  * reduced scale (2k, "control"): wall-clock of the worst-loaded
    rank through the DENSE XLA path. These come out ~equal by design —
    a dense kernel computes every masked entry anyway, which is exactly
    why the workload win requires a mask-skipping kernel (our Pallas
    BAM kernel's block-skip; see bench_bam_kernel).

``derived`` reports imbalance + LPT speedup over zigzag/ring — the
paper's Table 4 shows LPT/random ≥ zigzag > naive ring for EE/MP.

Since CP went differentiable, ``cp-bwd/*`` rows time a full
forward+backward through ``cp_attention`` per method × per-step body
(dense XLA vs the Pallas stats kernel, interpret mode — ordering check
on CPU, not TPU perf) and report the analytic backward-memory term
(dense logits vs (out, lse) flash residuals). Mirrored into
``BENCH_cp_bwd.json``.
"""
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import context_parallel as cp
from repro.data.synthetic import random_multimodal_bits
from repro.launch.mesh import PEAK_FLOPS_BF16
from repro.parallel import plan_context

from .common import emit, timeit

RANKS = 8
BLOCK = 128
PLANNERS = ["lpt", "random", "ring", "zigzag"]
HEADS, HEAD_DIM = 8, 128   # one Llama-70B attention layer slice

CP_BWD_JSON = os.environ.get("BENCH_CP_BWD_JSON", "BENCH_cp_bwd.json")


def full_scale(seq_len: int, mode: str, seeds=range(3)):
    loads = {m: [] for m in PLANNERS}
    for seed in seeds:
        bits, pos = random_multimodal_bits(seq_len, mode, seed=seed)
        for m in PLANNERS:
            kw = {"seed": seed} if m == "random" else {}
            plan = plan_context(bits, pos, RANKS, block_size=BLOCK,
                                method=m, **kw)
            loads[m].append(plan.makespan)
    out = {}
    for m in PLANNERS:
        mean_makespan = float(np.mean(loads[m]))
        flops = 4.0 * mean_makespan * HEADS * HEAD_DIM  # scores + AV
        out[m] = flops / PEAK_FLOPS_BF16 * 1e3          # ms on one chip
    return out


def reduced_scale_measured(mode: str, seq_len: int = 2048):
    bits_np, pos_np = random_multimodal_bits(seq_len, mode, seed=0)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(jax.random.fold_in(key, 0),
                          (1, seq_len, 4, 64), jnp.float32)
    k, v = q, q
    bits = jnp.asarray(bits_np)[None]
    pos = jnp.asarray(pos_np)[None]

    @jax.jit
    def rank_attn(q_r, b_r, p_r):
        return cp.cp_reference(q_r, k, v, b_r, bits, p_r, pos)

    out = {}
    for m in PLANNERS:
        plan = plan_context(bits_np, pos_np, RANKS,
                            block_size=BLOCK // 4, method=m)
        loads = cp.simulate_rank_workloads(plan.core_plan(), bits_np,
                                           pos_np)
        worst = int(np.argmax(loads))
        sl = plan.rank_token_slices()[worst]
        sl = jnp.asarray(sl[:seq_len // RANKS])
        q_r = jnp.take(q, sl, axis=1)
        b_r = jnp.take(bits, sl, axis=1)
        p_r = jnp.take(pos, sl, axis=1)
        out[m] = timeit(rank_attn, q_r, b_r, p_r, iters=3, warmup=1) / 1e3
    return out   # ms


def cp_fwd_bwd(smoke: bool = False):
    """Differentiable-CP rows: forward and forward+backward wall time
    through ``cp_attention`` for each method × per-step body, plus the
    analytic backward-memory term. Single-rank mesh (the bodies and
    their custom_vjps are what is being timed; collectives are
    identity), reduced scale, interpret-mode kernels."""
    T = 64 if smoke else 128
    B, H, hd = 1, 2, 32
    bits_np, pos_np = random_multimodal_bits(T, "ee", seed=0)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, T, H, hd), jnp.float32)
    bits = jnp.asarray(bits_np)[None]
    pos = jnp.asarray(pos_np)[None]
    mesh = jax.make_mesh((1,), ("cp",))
    iters = 1 if smoke else 2
    if os.path.exists(CP_BWD_JSON):
        os.remove(CP_BWD_JSON)
    # backward-memory term per rank: the XLA body re-materializes the
    # [B,H,Tq,Tk] f32 logits per step; the kernel body saves only the
    # (out, lse) flash residuals
    mem_xla = B * H * T * T * 4
    mem_kernel = B * H * T * 4 + B * T * H * hd * 4
    for method in ("allgather", "ring"):
        for impl in ("xla", "bam_interpret"):
            def fwd(q):
                return cp.cp_attention(
                    mesh, "cp", q, q, q, bits, bits, pos, pos,
                    method=method, impl=impl, block_q=32, block_k=32)

            grad_fn = jax.jit(jax.grad(lambda q: jnp.sum(fwd(q) ** 2)))
            us_f = timeit(jax.jit(fwd), q, iters=iters, warmup=1)
            us_b = timeit(grad_fn, q, iters=iters, warmup=1)
            mem = mem_xla if impl == "xla" else mem_kernel
            emit(f"cp-bwd/{method}-{impl}-T{T}", us_b,
                 f"fwd_us={us_f:.1f};bwd_bytes={mem};"
                 f"mem_vs_xla={mem_xla / mem:.1f}x",
                 json_path=CP_BWD_JSON, method=method, impl=impl,
                 seq_len=T, fwd_us=round(us_f, 1), bwd_bytes=mem)


def run(smoke: bool = False):
    rows = []
    seq_lens = (4096,) if smoke else (16384, 32768, 65536)
    modes = ("ee",) if smoke else ("ep", "ee", "mp")
    seeds = range(1) if smoke else range(3)
    for seq_len in seq_lens:
        for mode in modes:
            t0 = time.perf_counter()
            pred = full_scale(seq_len, mode, seeds=seeds)
            us = (time.perf_counter() - t0) * 1e6
            name = f"table4/T{seq_len}-{mode}"
            emit(name, us,
                 ";".join(f"{m}_pred_ms={pred[m]:.3f}" for m in PLANNERS)
                 + f";lpt_vs_zigzag={pred['zigzag'] / pred['lpt']:.3f}"
                 + f";lpt_vs_ring={pred['ring'] / pred['lpt']:.3f}")
            rows.append((name, pred))
    # reduced-scale wall-clock confirmation (one setting per mask type)
    ctrl_seq = 1024 if smoke else 2048
    for mode in modes:
        t0 = time.perf_counter()
        ms = reduced_scale_measured(mode, seq_len=ctrl_seq)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"table4-densecontrol/T{ctrl_seq}-{mode}", us,
             ";".join(f"{m}_ms={ms[m]:.2f}" for m in PLANNERS))
    cp_fwd_bwd(smoke=smoke)
    return rows


if __name__ == "__main__":
    run()
