"""Serving throughput: tokens/sec vs batch size over the paged cache.

Two decode attention paths through the same ``ServingEngine``:

  * ``xla``    — dense page-table gather + reference masked softmax;
  * ``kernel`` — the paged flash-decode Pallas kernel in interpret
    mode (CPU container; ordering/shape check, not TPU perf — the
    compacted grid's step counts ARE the TPU-relevant figure).

Each row reports wall time per generated token and tokens/sec for one
(batch size, path) cell, continuous batching included (requests admit
as rows free up). A final row reports the decode grid's page-skip
fraction on a multimodal batch — the fraction of resident KV pages the
kernel never visits (no grid step, no DMA), which is the serving twin
of the training kernel's block-sparsity win. Rows are mirrored into
``BENCH_serve.json``.
"""
import os
import time

import numpy as np

import jax

from repro.configs.base import ModelConfig
from repro.core import bam
from repro.models import api
from repro.serving import ServingEngine

from .common import emit

SERVE_JSON = os.environ.get("BENCH_SERVE_JSON", "BENCH_serve.json")


def _cfg(smoke: bool) -> ModelConfig:
    if smoke:
        return ModelConfig(name="serve-smoke", family="dense",
                           num_layers=2, d_model=32, num_heads=4,
                           num_kv_heads=2, d_ff=64, vocab_size=64,
                           dtype="float32", remat=False,
                           seq_shard_activations=False, attn_softcap=10.0)
    return ModelConfig(name="serve-bench", family="dense", num_layers=4,
                       d_model=128, num_heads=8, num_kv_heads=2,
                       d_ff=256, vocab_size=256, dtype="float32",
                       remat=False, seq_shard_activations=False,
                       attn_softcap=10.0)


def _drive(params, cfg, *, batch, attn, prompt_len, max_new, page_size=8):
    rng = np.random.default_rng(0)
    pool = 1 + batch * (-(-(prompt_len + max_new) // page_size) + 1)
    eng = ServingEngine(params, cfg, num_pages=pool, page_size=page_size,
                        max_batch=batch, attn=attn)
    rids = [eng.submit(rng.integers(1, cfg.vocab_size, size=prompt_len),
                       max_new_tokens=max_new) for _ in range(batch)]
    out = eng.run()
    return sum(len(out[r]) for r in rids), eng


def run(smoke: bool = False):
    cfg = _cfg(smoke)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batches = (1, 2) if smoke else (1, 2, 4)
    prompt_len = 8 if smoke else 32
    max_new = 3 if smoke else 16
    if os.path.exists(SERVE_JSON):
        os.remove(SERVE_JSON)

    for attn, label in (("xla", "xla"), ("interpret", "kernel")):
        for B in batches:
            kw = dict(batch=B, attn=attn, prompt_len=prompt_len,
                      max_new=max_new)
            _drive(params, cfg, **kw)          # warm the jit caches
            t0 = time.perf_counter()
            toks, _ = _drive(params, cfg, **kw)
            dt = time.perf_counter() - t0
            tps = toks / dt
            emit(f"serve/{label}-B{B}", dt * 1e6 / toks,
                 f"tokens_per_s={tps:.1f}", json_path=SERVE_JSON,
                 path=label, batch=B, tokens_per_s=round(tps, 1),
                 tokens=toks)

    # grid compaction on a multimodal batch: text-only continuations
    # over image-heavy prompts never visit the image pages
    ps = 8
    segs = [("text", 0, ps), ("mod", 1, 2 * ps), ("text", 0, ps)]
    bits, pos = bam.build_sample_bits(segs, 4 * ps)
    eng = ServingEngine(params, cfg, num_pages=32, page_size=ps,
                        max_batch=2, attn="interpret")
    t0 = time.perf_counter()
    for _ in range(2):
        eng.submit(np.arange(1, 4 * ps + 1), bits=bits, positions=pos,
                   max_new_tokens=2)
    eng.run()
    us = (time.perf_counter() - t0) * 1e6
    grid = eng.last_grid
    emit("serve/grid-skip-mm", us,
         f"skip_fraction={grid.skip_fraction:.3f};"
         f"steps={grid.n_active_steps}/{grid.n_dense_steps}",
         json_path=SERVE_JSON, path="kernel",
         skip_fraction=round(grid.skip_fraction, 3))


if __name__ == "__main__":
    run()
