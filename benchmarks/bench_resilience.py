"""Resilience runtime cost: monitor overhead per step + checkpoint I/O.

Three questions, one row each (mirrored into ``BENCH_resilience.json``):

  * ``resil/step-plain`` vs ``resil/step-guarded`` — the same LM train
    step with and without the in-jit health gate
    (``make_resilient_train_step``: NaN/Inf flags, global grad norm,
    EMA loss-spike score, gated update, fused f32 bundle). The guarded
    row's derived field is the overhead in percent — the price of
    never letting a NaN touch params. It should be a few percent: the
    bundle is one tiny stacked vector and the gate is a tree of
    ``jnp.where`` selects XLA fuses into the update.
  * ``resil/ckpt-save`` — ``CheckpointManager.save`` of a full
    {params, optimizer, health} state tree (atomic temp-dir+rename,
    per-shard crc32), derived = MB/s to disk.
  * ``resil/ckpt-restore`` — ``CheckpointManager.restore`` of the same
    tree with checksum verification on, derived = MB/s back.
"""
import os
import shutil
import tempfile
import time

import numpy as np

import jax

from repro.configs.base import ModelConfig
from repro.data.synthetic import TextLMDataset
from repro.models import api
from repro.optim import optimizer as opt
from repro.resilience import (CheckpointManager, default_controls,
                              init_health, make_resilient_train_step)
from repro.training import steps

from .common import emit, timeit

RESIL_JSON = os.environ.get("BENCH_RESIL_JSON", "BENCH_resilience.json")


def _cfg(smoke: bool) -> ModelConfig:
    if smoke:
        return ModelConfig(name="resil-smoke", family="dense",
                           num_layers=2, d_model=32, num_heads=4,
                           num_kv_heads=2, d_ff=64, vocab_size=64,
                           dtype="float32", remat=False,
                           seq_shard_activations=False, attn_softcap=10.0)
    return ModelConfig(name="resil-bench", family="dense", num_layers=4,
                       d_model=256, num_heads=8, num_kv_heads=2,
                       d_ff=512, vocab_size=512, dtype="float32",
                       remat=False, seq_shard_activations=False,
                       attn_softcap=10.0)


def run(smoke: bool = False):
    cfg = _cfg(smoke)
    seq, batch = (16, 2) if smoke else (64, 4)
    iters = 3 if smoke else 10
    params = api.init(jax.random.PRNGKey(0), cfg)
    ocfg = opt.AdamWConfig(warmup_steps=0, schedule="constant")
    state = opt.init(ocfg, params)
    batch_data = next(iter(TextLMDataset(cfg.vocab_size, seq, batch,
                                         seed=0)))
    if os.path.exists(RESIL_JSON):
        os.remove(RESIL_JSON)

    # -- monitor overhead: plain step vs guarded step (no donation so
    # -- the same buffers can be timed repeatedly)
    plain = jax.jit(steps.make_train_step(cfg, ocfg))
    guarded = jax.jit(make_resilient_train_step(
        steps.make_loss_fn(cfg), ocfg))
    health, controls = init_health(), default_controls()
    us_plain = timeit(plain, params, state, batch_data, iters=iters)
    us_guard = timeit(guarded, params, state, health, batch_data,
                      controls, iters=iters)
    over = 100.0 * (us_guard - us_plain) / us_plain
    emit("resil/step-plain", us_plain, "baseline",
         json_path=RESIL_JSON)
    emit("resil/step-guarded", us_guard, f"overhead_pct={over:.1f}",
         json_path=RESIL_JSON, overhead_pct=round(over, 1))

    # -- checkpoint save / restore latency over the full state tree
    tree = {"params": params, "opt": state, "health": health}
    nbytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(tree))
    mb = nbytes / 1e6
    root = tempfile.mkdtemp(prefix="bench_resil_")
    try:
        mgr = CheckpointManager(root, keep=2)
        t0 = time.perf_counter()
        for i in range(iters):
            mgr.save(i, tree, meta={"cursor": i})
        save_us = (time.perf_counter() - t0) * 1e6 / iters
        t0 = time.perf_counter()
        for _ in range(iters):
            mgr.restore(tree)                  # crc32-verified load
        load_us = (time.perf_counter() - t0) * 1e6 / iters
    finally:
        shutil.rmtree(root, ignore_errors=True)
    emit("resil/ckpt-save", save_us,
         f"mb_per_s={mb / (save_us / 1e6):.1f}", json_path=RESIL_JSON,
         mbytes=round(mb, 2), mb_per_s=round(mb / (save_us / 1e6), 1))
    emit("resil/ckpt-restore", load_us,
         f"mb_per_s={mb / (load_us / 1e6):.1f}", json_path=RESIL_JSON,
         mbytes=round(mb, 2), mb_per_s=round(mb / (load_us / 1e6), 1))


if __name__ == "__main__":
    run()
