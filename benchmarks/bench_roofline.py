"""§Roofline report: read the dry-run artifacts and emit one row per
(arch × shape × mesh): the three roofline terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs ratio, and per-device memory."""
import glob
import json
import os


def run(out_dir: str = "experiments/dryrun", smoke: bool = False):
    files = sorted(glob.glob(os.path.join(out_dir, "*.json")))
    # plan-mode artifacts (plan__*.json) are MLLMParallelPlans, not
    # lowering reports — they have no roofline terms to read
    files = [p for p in files
             if not os.path.basename(p).startswith("plan__")]
    if not files:
        print("roofline/none,0.0,run `python -m repro.launch.dryrun --all`"
              " first", flush=True)
        return
    if smoke:
        files = files[:3]
    for p in files:
        d = json.load(open(p))
        tag = f"{d['arch']}__{d['shape']}__{d['mesh']}"
        if "skipped" in d:
            print(f"roofline/{tag},0.0,skipped={d['skipped']}", flush=True)
            continue
        r = d["roofline"]
        print(
            f"roofline/{tag},{d.get('wall_s', 0) * 1e6:.0f},"
            f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
            f"collective_s={r['collective_s']:.4f};"
            f"dominant={r['dominant']};"
            f"useful={r['useful_flops_ratio']:.3f};"
            f"GB_per_dev={d['per_device_bytes'] / 1e9:.2f};"
            f"fits={d['fits_16GB']}", flush=True)


if __name__ == "__main__":
    run()
