"""Shared benchmark utilities: wall-clock timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived =
table-specific figure of merit, e.g. speedup or imbalance). ``emit``
optionally mirrors a row into a ``BENCH_<x>.json``-style record file
(one JSON object per row, accumulated into a list) for machine
consumers — pass ``json_path`` plus any extra keyword fields."""
import json
import os
import time

import jax


def timeit(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (blocking)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived, json_path: str = None,
         **fields) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
    if json_path is None:
        return
    records = []
    if os.path.exists(json_path):
        with open(json_path, encoding="utf-8") as f:
            records = json.load(f)
    records.append({"name": name, "us_per_call": round(us, 1),
                    "derived": str(derived), **fields})
    with open(json_path, "w", encoding="utf-8") as f:
        json.dump(records, f, indent=1)
        f.write("\n")
