"""Shared benchmark utilities: wall-clock timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (derived =
table-specific figure of merit, e.g. speedup or imbalance)."""
import time

import jax


def timeit(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (blocking)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
