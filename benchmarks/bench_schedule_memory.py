"""Simulator-vs-executor activation-memory validation, per schedule.

For every scheduler in ``core.schedule.SCHEDULES`` this bench builds a
small pipeline (2 and 4 ranks; chunked schedules get the 2x-refined
chain folded onto the same ranks), simulates the schedule, replays the
emitted item timeline on the real executor
(``core.modality_parallel.execute_schedule`` — real forwards, real
input-grad and weight-grad VJPs, explicit activation store), and
cross-checks three claims:

* executor-measured peak live activations == simulator's peak, EXACTLY
  and per device (``validate_schedule_memory`` raises
  ``MemoryModelMismatch`` otherwise — the bench fails loudly rather
  than emitting a row);
* measured peaks stay inside the ``depth_from_end`` cap envelope;
* the timeline is executable as emitted (dependency or double-free
  bugs die with a KeyError inside the executor).

Two freeze scenarios per size: ``train`` (every stage trainable, W
passes everywhere) and ``frozen`` (first half of the chain frozen with
nothing trainable upstream — zero-duration B items, no W, no
cotangents flow into the frozen prefix; the paper's frozen-encoder
shape). ``derived`` reports sim/exec/cap peaks and the W-residual
peak, the zero-bubble memory-vs-bubble trade-off measured.

A final scenario goes through the typed API: ``repro.parallel.
search_plan`` picks the joint winner for a small frozen-encoder MLLM
and its pinned (schedule, virtual_chunks) pair is validated the same
way — the memory harness covers exactly what ``parallelize`` emits.
That scenario also times ``repro.analysis.schedlint`` over the same
plan + timeline (the static gate the launcher runs before step 0) and
asserts it comes back clean.
"""
import time

import numpy as np

from repro.analysis import schedlint
from repro.core import pipeline as pp
from repro.core.schedule import (SCHEDULES, Stage, chain_graph,
                                 refine_chain, validate_schedule_memory)
from repro.parallel import ClusterSpec, WorkloadShape, search_plan

from .common import emit

MICROBATCHES = 8
CHUNKED = ("interleaved", "zb-v")     # run on the 2x-refined chain


def build_chain(ranks: int, scenario: str):
    """One stage per rank; ``frozen`` freezes the first half (bwd = 0:
    frozen module with nothing trainable upstream)."""
    stages = []
    for s in range(ranks):
        if scenario == "frozen" and s < ranks // 2:
            stages.append(Stage(f"enc{s}", 1.0, 0.0))
        else:
            stages.append(Stage(f"llm{s}", 1.0, 2.0, bwd_w=1.0))
    return chain_graph(stages)


def validate_searched_plan():
    """End-to-end through the typed API: search the joint winner for a
    small frozen-encoder MLLM (``repro.parallel.search_plan``), rebuild
    the winner's simulation graph at its pinned (schedule, v), and
    cross-check the memory model on the real executor. One row; raises
    on divergence like every other scenario."""
    enc = pp.ModuleProfile("vision", np.ones(4) * 2.0, frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(8) * 1.5, frozen=False,
                           trainable_upstream=True)
    plan = search_plan([enc], llm, ClusterSpec(num_devices=4),
                       WorkloadShape(num_microbatches=MICROBATCHES))
    graph, sim = pp.simulate_plan(
        [enc], llm, list(plan.stage.encoder_stages),
        plan.stage.llm_stages, MICROBATCHES,
        schedule=plan.schedule.name,
        virtual_chunks=(plan.schedule.virtual_chunks,))
    kwargs = {"virtual_chunks": plan.schedule.virtual_chunks} \
        if plan.schedule.name in CHUNKED else {}
    t0 = time.perf_counter()
    rep = validate_schedule_memory(graph, MICROBATCHES,
                                   plan.schedule.name, **kwargs)
    us = (time.perf_counter() - t0) * 1e6
    emit(f"schedmem/plan-{plan.schedule.name}"
         f"-d{plan.pp_devices}", us,
         f"sim_peak={max(rep['simulated_peaks'])};"
         f"exec_peak={max(rep['executor_peaks'])};"
         f"cap={max(rep['caps'])};"
         f"plan_bubble={plan.schedule.bubble_fraction:.3f};match=1")
    # the static gate over the same artifacts: how long the launcher's
    # pre-step-0 schedlint pass costs, and that the winner is clean
    t0 = time.perf_counter()
    found = schedlint.lint_plan(plan) + schedlint.lint_timeline(graph,
                                                                sim)
    lint_us = (time.perf_counter() - t0) * 1e6
    assert not found, [str(f) for f in found]
    emit(f"schedlint/plan-{plan.schedule.name}-d{plan.pp_devices}",
         lint_us,
         f"findings=0;items={len(sim['items'])};clean=1")
    return rep


def run(smoke: bool = False):
    rows = []
    for ranks in ((2,) if smoke else (2, 4)):
        for scenario in ("train", "frozen"):
            coarse = build_chain(ranks, scenario)
            fine = refine_chain(coarse, 2)
            for sched in SCHEDULES:
                g = fine if sched in CHUNKED else coarse
                kwargs = {"virtual_chunks": 2} if sched in CHUNKED \
                    else {}
                t0 = time.perf_counter()
                rep = validate_schedule_memory(
                    g, MICROBATCHES, sched, **kwargs)
                us = (time.perf_counter() - t0) * 1e6
                assert rep["num_devices"] == ranks, \
                    (sched, rep["num_devices"], ranks)
                name = f"schedmem/{sched}-d{ranks}-{scenario}"
                derived = (
                    f"sim_peak={max(rep['simulated_peaks'])};"
                    f"exec_peak={max(rep['executor_peaks'])};"
                    f"cap={max(rep['caps'])};"
                    f"w_residual_peak={max(rep['peak_w_residuals'])};"
                    f"match=1")
                emit(name, us, derived)
                rows.append((name, rep))
    rows.append(("schedmem/plan", validate_searched_plan()))
    return rows


if __name__ == "__main__":
    run()
