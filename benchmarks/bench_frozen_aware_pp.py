"""Paper Table 3 / §6.4: frozen-status-aware vs -unaware pipeline
partitioning, over the paper's VLM/ALM model grid (Table 1 sizes).

Cost oracle: analytic per-layer FLOPs at the paper's workload (1k text
+ modality tokens, microbatch 1); schedule: the deterministic 1F1B
simulator. ``derived`` = iteration-time speedup of frozen-aware over
frozen-unaware partitioning (paper reports up to 1.53x)."""
import time

import numpy as np

from repro.configs.paper_mllm import (audio_encoder_config, llm_config,
                                      vision_encoder_config)
from repro.core import pipeline as pp
from repro.models.mllm import AUDIO_TOKENS, VISION_TOKENS

from .common import emit

TEXT_LEN = 1024
MICROBATCHES = 24
STAGES = 8


def profiles(kind: str, enc_size: str, llm_size: str = "M"):
    llm_cfg = llm_config(llm_size)
    if kind == "vlm":
        enc_cfg = vision_encoder_config(enc_size)
        n_tok = VISION_TOKENS
    else:
        enc_cfg = audio_encoder_config(enc_size)
        n_tok = AUDIO_TOKENS
    enc = pp.profile_from_config(enc_cfg, n_tok, frozen=True,
                                 name=f"{kind}-{enc_size}")
    llm = pp.profile_from_config(llm_cfg, TEXT_LEN + n_tok, frozen=True,
                                 name="llm")
    # frozen encoders + frozen LLM + trainable projectors (paper §6)
    pp.analyze_chain([enc, llm], projector_trainable=[True, False])
    return enc, llm


def run(llm_size: str = "M"):
    rows = []
    for kind in ("vlm", "alm"):
        for enc_size in ("S", "M", "L"):
            enc, llm = profiles(kind, enc_size, llm_size)
            t0 = time.perf_counter()
            res = {}
            for aware in (True, False):
                g = pp.build_chain_fused([enc, llm], STAGES,
                                         frozen_aware=aware)
                sim = pp.simulate_1f1b(g, MICROBATCHES)
                res[aware] = sim
            us = (time.perf_counter() - t0) * 1e6
            speedup = res[False]["iteration_time"] / \
                res[True]["iteration_time"]
            name = f"table3/{kind}-{enc_size}-llm{llm_size}"
            emit(name, us,
                 f"speedup={speedup:.3f};bubble_aware="
                 f"{res[True]['bubble_fraction']:.3f};bubble_unaware="
                 f"{res[False]['bubble_fraction']:.3f}")
            rows.append((name, speedup))
    return rows


if __name__ == "__main__":
    run()
