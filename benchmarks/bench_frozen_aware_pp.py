"""Paper Table 3 / §6.4: frozen-status-aware vs -unaware pipeline
partitioning, over the paper's VLM/ALM model grid (Table 1 sizes) —
plus the schedule comparison the B/W split enables: per config, bubble
fractions for all four schedulers (1F1B, interleaved-1F1B with its
virtual-chunk count swept over {4, 2, 1}, ZB-H1, ZB-V).

Cost oracle: analytic per-layer FLOPs at the paper's workload (1k text
+ modality tokens, microbatch 1); schedules: the deterministic
core.schedule simulator at a FIXED device budget (chunked schedules
fold their finer partitions back onto the same devices). ``derived`` =
iteration-time speedup of frozen-aware over frozen-unaware
partitioning (paper reports up to 1.53x) +
bubble_{1f1b,interleaved,zbh1,zbv} + the winning chunk counts. Two
freeze settings per config: ``ft0`` = fully frozen backbone
(projector-only tuning, paper §6) and ``ft1`` = frozen encoder with
trainable LLM (the common fine-tune where the zero-bubble schedules'
deferred W passes actually have work to defer)."""
import time

from repro.configs.paper_mllm import (audio_encoder_config, llm_config,
                                      vision_encoder_config)
from repro.core import pipeline as pp
from repro.core.schedule import get_scheduler
from repro.models.mllm import AUDIO_TOKENS, VISION_TOKENS
from repro.parallel import ClusterSpec, WorkloadShape, search_plan

from .common import emit

TEXT_LEN = 1024
MICROBATCHES = 24
STAGES = 8


def profiles(kind: str, enc_size: str, llm_size: str = "M", *,
             llm_trainable: bool = False):
    llm_cfg = llm_config(llm_size)
    if kind == "vlm":
        enc_cfg = vision_encoder_config(enc_size)
        n_tok = VISION_TOKENS
    else:
        enc_cfg = audio_encoder_config(enc_size)
        n_tok = AUDIO_TOKENS
    enc = pp.profile_from_config(enc_cfg, n_tok, frozen=True,
                                 name=f"{kind}-{enc_size}")
    llm = pp.profile_from_config(llm_cfg, TEXT_LEN + n_tok,
                                 frozen=not llm_trainable, name="llm")
    # frozen encoders + trainable projectors (paper §6); the LLM is
    # frozen (projector-only) or trainable (fine-tune) per the flag
    pp.analyze_chain([enc, llm], projector_trainable=[True, False])
    return enc, llm


def run(llm_size: str = "M", smoke: bool = False):
    rows = []
    kinds = ("vlm",) if smoke else ("vlm", "alm")
    enc_sizes = ("S",) if smoke else ("S", "M", "L")
    microbatches = 8 if smoke else MICROBATCHES
    for kind in kinds:
        for enc_size in enc_sizes:
            for llm_trainable in (False, True):
                enc, llm = profiles(kind, enc_size, llm_size,
                                    llm_trainable=llm_trainable)
                t0 = time.perf_counter()
                res = {}
                g_aware = None
                for aware in (True, False):
                    g = pp.build_chain_fused([enc, llm], STAGES,
                                             frozen_aware=aware)
                    res[aware] = pp.simulate_1f1b(g, microbatches)
                    if aware:
                        g_aware = g
                # schedule comparison at a FIXED device budget (STAGES
                # devices): chunked schedules search their chunk count
                # (finer partitions folded onto the same devices, or
                # the v=1 degenerate) — interleaved sweeps v over
                # {4, 2, 1}, zb-v its inherent {2, 1}
                scheds = {
                    "1f1b": res[True],
                    "interleaved": pp.simulate_fused_chain(
                        [enc, llm], STAGES, microbatches,
                        schedule="interleaved",
                        virtual_chunks=(4, 2, 1))[1],
                    "zb-h1": get_scheduler("zb-h1").simulate(
                        g_aware, microbatches),
                    "zb-v": pp.simulate_fused_chain(
                        [enc, llm], STAGES, microbatches,
                        schedule="zb-v")[1],
                }
                # the typed joint winner for the same modules at the
                # same budget (modality-parallel topology, Algorithm 1
                # + schedule + chunk search through repro.parallel)
                plan = search_plan(
                    [enc], llm, ClusterSpec(num_devices=STAGES),
                    WorkloadShape(text_len=TEXT_LEN,
                                  num_microbatches=microbatches))
                assert all(r["num_devices"] == STAGES
                           for r in scheds.values())
                us = (time.perf_counter() - t0) * 1e6
                speedup = res[False]["iteration_time"] / \
                    res[True]["iteration_time"]
                assert scheds["zb-h1"]["bubble_fraction"] <= \
                    scheds["1f1b"]["bubble_fraction"] + 1e-9, \
                    "ZB-H1 must not bubble more than 1F1B"
                assert scheds["zb-v"]["bubble_fraction"] <= \
                    scheds["zb-h1"]["bubble_fraction"] + 1e-9, \
                    "ZB-V must not bubble more than ZB-H1 (v=1 is " \
                    "the ZB-H1 placement)"
                name = (f"table3/{kind}-{enc_size}-llm{llm_size}"
                        f"-ft{int(llm_trainable)}")
                emit(name, us,
                     f"speedup={speedup:.3f};bubble_aware="
                     f"{res[True]['bubble_fraction']:.3f};bubble_unaware="
                     f"{res[False]['bubble_fraction']:.3f};"
                     f"bubble_1f1b={scheds['1f1b']['bubble_fraction']:.3f};"
                     f"bubble_interleaved="
                     f"{scheds['interleaved']['bubble_fraction']:.3f};"
                     f"bubble_zbh1={scheds['zb-h1']['bubble_fraction']:.3f};"
                     f"bubble_zbv={scheds['zb-v']['bubble_fraction']:.3f};"
                     f"il_chunks={scheds['interleaved']['virtual_chunks']};"
                     f"zbv_chunks={scheds['zb-v']['virtual_chunks']};"
                     f"plan_sched={plan.schedule.name};"
                     f"plan_v={plan.schedule.virtual_chunks};"
                     f"plan_bubble="
                     f"{plan.schedule.bubble_fraction:.3f};"
                     f"plan_ranks={plan.pp_devices}")
                rows.append((name, speedup,
                             {s: r["bubble_fraction"]
                              for s, r in scheds.items()}))
    return rows


if __name__ == "__main__":
    run()
