"""BAM Pallas kernel characterization (beyond-paper kernel layer):

  * block-sparsity ratio: fraction of [128,128] tiles the kernel skips
    per mask type (the compute-term win vs a dense-mask kernel);
  * memory win: BAM bytes vs materialized-mask bytes at each seq len
    (the paper's C3 — O(T) vs O(T^2));
  * interpret-mode wall time with/without block skipping at reduced
    scale (ordering check only — CPU interpret, not TPU perf).
"""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bam
from repro.data.synthetic import random_multimodal_bits
from repro.kernels.bam_attention import bam_flash_attention

from .common import emit, timeit


def tile_skip_fraction(bits, pos, bq=128, bk=128):
    T = len(bits)
    nq, nk = T // bq, T // bk
    m = bam.allowed_mask(jnp.asarray(bits)[None], jnp.asarray(bits)[None],
                         jnp.asarray(pos)[None], jnp.asarray(pos)[None])[0]
    m = np.asarray(m)
    skipped = 0
    for i in range(nq):
        for j in range(nk):
            if not m[i * bq:(i + 1) * bq, j * bk:(j + 1) * bk].any():
                skipped += 1
    return skipped / (nq * nk)


def run(smoke: bool = False):
    modes = ("mp",) if smoke else ("ep", "ee", "mp")
    seq_lens = (1024,) if smoke else (2048, 4096)
    for mode in modes:
        for T in seq_lens:
            t0 = time.perf_counter()
            bits, pos = random_multimodal_bits(T, mode, seed=0)
            frac = tile_skip_fraction(bits, pos)
            us = (time.perf_counter() - t0) * 1e6
            bam_bytes = T * 4
            mask_bytes = T * T
            emit(f"kernel/skip-{mode}-T{T}", us,
                 f"tiles_skipped={frac:.3f};"
                 f"mask_mem_ratio={mask_bytes / bam_bytes:.0f}x")

    # interpret-mode ordering check (reduced scale)
    T = 128 if smoke else 256
    bits_np, pos_np = random_multimodal_bits(T, "mp", seed=0)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, T, 2, 32), jnp.float32)
    bits = jnp.asarray(bits_np)[None]
    pos = jnp.asarray(pos_np)[None]

    def f(skip):
        return bam_flash_attention(q, q, q, bits, bits, pos, pos,
                                   block_q=32, block_k=32,
                                   block_skip=skip, interpret=True)
    iters = 1 if smoke else 2
    us_skip = timeit(f, True, iters=iters, warmup=1)
    us_dense = timeit(f, False, iters=iters, warmup=1)
    emit(f"kernel/interpret-T{T}-mp", us_skip,
         f"skip_vs_dense={us_dense / us_skip:.2f}x")


if __name__ == "__main__":
    run()
