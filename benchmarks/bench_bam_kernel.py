"""BAM Pallas kernel characterization (beyond-paper kernel layer):

  * block-sparsity ratio: fraction of [128,128] tiles the kernel skips
    per mask type (the compute-term win vs a dense-mask kernel);
  * grid compaction: dense vs compacted grid step counts (the
    scalar-prefetch block map drops fully-masked tiles from the grid
    itself — no grid step, no K/V DMA);
  * memory win: BAM bytes vs materialized-mask bytes at each seq len
    (the paper's C3 — O(T) vs O(T^2));
  * backward pass: fused-kernel vs XLA-recompute wall time at reduced
    scale (interpret mode — ordering check, not TPU perf) plus the
    analytic residual-memory win (LSE row stats vs [T,T] logits);
    rows are mirrored into ``BENCH_bam_bwd.json``;
  * interpret-mode wall time with/without block skipping at reduced
    scale (ordering check only — CPU interpret, not TPU perf).
"""
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bam
from repro.data.synthetic import random_multimodal_bits
from repro.kernels.bam_attention import bam_flash_attention
from repro.kernels.ops import bam_attention

from .common import emit, timeit

BWD_JSON = os.environ.get("BENCH_BAM_BWD_JSON", "BENCH_bam_bwd.json")


def tile_skip_fraction(bits, pos, bq=128, bk=128):
    """Fraction of [bq,bk] tiles with no allowed pair — the blockwise
    ``any`` reduction shared with the kernel's grid compaction (strip
    at a time; no O(T^2/bq/bk) python loop, no dense [T,T] mask)."""
    return bam.build_block_map(bits, bits, pos, pos, bq, bk).skip_fraction


def run(smoke: bool = False):
    modes = ("mp",) if smoke else ("ep", "ee", "mp")
    seq_lens = (1024,) if smoke else (2048, 4096)
    for mode in modes:
        for T in seq_lens:
            t0 = time.perf_counter()
            bits, pos = random_multimodal_bits(T, mode, seed=0)
            # one block-level reduction yields both the skip fraction
            # and the compacted grid (dense vs remaining steps)
            bm = bam.build_block_map(bits, bits, pos, pos, 128, 128)
            frac = bm.skip_fraction
            us = (time.perf_counter() - t0) * 1e6
            bam_bytes = T * 4
            mask_bytes = T * T
            emit(f"kernel/skip-{mode}-T{T}", us,
                 f"tiles_skipped={frac:.3f};"
                 f"grid_steps={bm.n_steps}/{bm.n_dense_steps};"
                 f"mask_mem_ratio={mask_bytes / bam_bytes:.0f}x")

    # backward: fused kernel vs XLA-recompute (reduced scale, interpret)
    T = 64 if smoke else 128
    B, H, hd = 1, 2, 32
    bits_np, pos_np = random_multimodal_bits(T, "mp", seed=0)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, T, H, hd), jnp.float32)
    bits = jnp.asarray(bits_np)[None]
    pos = jnp.asarray(pos_np)[None]

    def grad_fn(impl):
        def loss(q):
            return jnp.sum(bam_attention(q, q, q, bits, bits, pos, pos,
                                         impl=impl, block_q=32,
                                         block_k=32) ** 2)
        return jax.jit(jax.grad(loss))

    iters = 1 if smoke else 2
    us_fused = timeit(grad_fn("bam_interpret"), q, iters=iters, warmup=1)
    us_xla = timeit(grad_fn("xla"), q, iters=iters, warmup=1)
    # analytic backward-memory term: XLA-recompute re-materializes the
    # [B,H,T,T] f32 logits; the fused path saves only (out, lse) rows.
    mem_xla = B * H * T * T * 4
    mem_fused = B * H * T * 4 + B * T * H * hd * 4
    if os.path.exists(BWD_JSON):
        os.remove(BWD_JSON)
    emit(f"kernel/bwd-fused-T{T}-mp", us_fused,
         f"resid_bytes={mem_fused}", json_path=BWD_JSON,
         impl="bam_interpret", seq_len=T, bwd_bytes=mem_fused)
    emit(f"kernel/bwd-xla-T{T}-mp", us_xla,
         f"logits_bytes={mem_xla};mem_ratio={mem_xla / mem_fused:.1f}x",
         json_path=BWD_JSON, impl="xla", seq_len=T, bwd_bytes=mem_xla)

    # interpret-mode ordering check (reduced scale)
    T = 128 if smoke else 256
    bits_np, pos_np = random_multimodal_bits(T, "mp", seed=0)
    q = jax.random.normal(key, (1, T, 2, 32), jnp.float32)
    bits = jnp.asarray(bits_np)[None]
    pos = jnp.asarray(pos_np)[None]

    def f(skip):
        return bam_flash_attention(q, q, q, bits, bits, pos, pos,
                                   block_q=32, block_k=32,
                                   block_skip=skip, interpret=True)
    iters = 1 if smoke else 2
    us_skip = timeit(f, True, iters=iters, warmup=1)
    us_dense = timeit(f, False, iters=iters, warmup=1)
    emit(f"kernel/interpret-T{T}-mp", us_skip,
         f"skip_vs_dense={us_dense / us_skip:.2f}x")

    # compacted grid vs dense grid (same kernel math, fewer steps)
    bm = bam.build_block_map(bits_np, bits_np, pos_np, pos_np, 32, 32)

    def g(block_map):
        return bam_flash_attention(q, q, q, bits, bits, pos, pos,
                                   block_q=32, block_k=32,
                                   block_map=block_map, interpret=True)
    us_compact = timeit(g, bm, iters=iters, warmup=1)
    us_dense_grid = timeit(g, None, iters=iters, warmup=1)
    emit(f"kernel/compact-T{T}-mp", us_compact,
         f"grid_steps={bm.n_steps}/{bm.n_dense_steps};"
         f"compact_vs_dense={us_dense_grid / us_compact:.2f}x")


if __name__ == "__main__":
    run()
