"""Real-model SPMD training: distributed step time + dispatch compile
scaling.

Two childs, each in a subprocess with forced host devices (the same
harness the multi-device tests use):

* **train** — partitions the reduced paper VLM into its stage bundle
  (``repro.models.stages``), runs the plan's compiled wave program
  through the ``shard_map`` runner to steady state, and replays the
  identical timeline + stage fns on the sequential executor. The child
  ASSERTS the distributed loss matches the replay, so a row only ever
  appears for a run that computed the right thing.

* **compile** — times the first (trace + XLA compile) call of the
  rolled instruction-table dispatch against the fully-unrolled switch
  dispatch as the wave count grows with the microbatch count. The
  rolled loop's compile time scales with *distinct* instructions, not
  timeline length — the derived fields carry the wave counts so the
  sublinear growth is visible in ``BENCH_spmd_train.json``.
"""
import os
import subprocess
import sys

from .common import emit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(REPO, "BENCH_spmd_train.json")

_CHILD_TRAIN = """
import time
import numpy as np
import jax
from repro.core.modality_parallel import execute_schedule
from repro.data.synthetic import MultimodalDataset
from repro.models.mllm import build_paper_mllm
from repro.parallel import ClusterSpec, WorkloadShape, parallelize
from repro.parallel.spmd import build_spmd_runner, mesh_from_plan

TEXT, M, BATCH = 16, 2, 2
iters = {iters}
mllm = build_paper_mllm("vlm", reduced=True, text_len=TEXT)
plan = parallelize(mllm, ClusterSpec(num_devices=3),
                   WorkloadShape(text_len=TEXT, num_microbatches=M,
                                 microbatch_size=1, block_size=8))
ex = plan.apply(mllm, text_len=TEXT, mode="spmd")
bundle = ex["stage_bundle"]
D = int(ex["schedule"]["num_devices"])
runner = build_spmd_runner(
    bundle.stage_fns, ex["sim_graph"], ex["schedule"],
    mesh=mesh_from_plan(plan, mllm, D),
    microbatch_loss=bundle.microbatch_loss,
    program=ex["spmd_program"], trainable=list(bundle.trainable))
params = mllm.init(jax.random.PRNGKey(0))
sp = bundle.partition(params)
ds = MultimodalDataset(
    vocab_size=mllm.llm_cfg.vocab_size, text_len=TEXT, batch_size=BATCH,
    encoder_dims={{n: e.cfg.d_model for n, e in mllm.encoders.items()}},
    encoder_tokens={{n: e.num_tokens for n, e in mllm.encoders.items()}},
    modality_ids={{n: e.modality_id for n, e in mllm.encoders.items()}},
    seed=0)
mbs = bundle.encode_microbatches(next(iter(ds)), M)
t0 = time.perf_counter()
res = runner(sp, mbs)
jax.block_until_ready(res["loss"])
first_us = (time.perf_counter() - t0) * 1e6
times = []
for _ in range(iters):
    t0 = time.perf_counter()
    res = runner(sp, mbs)
    jax.block_until_ready(res["loss"])
    times.append(time.perf_counter() - t0)
times.sort()
us = times[len(times) // 2] * 1e6
t0 = time.perf_counter()
ref = execute_schedule(bundle.stage_fns, sp, mbs, ex["sim_graph"],
                       ex["schedule"],
                       microbatch_loss=bundle.microbatch_loss,
                       trainable=list(bundle.trainable))
replay_us = (time.perf_counter() - t0) * 1e6
diff = abs(float(res["loss"]) - float(ref["loss"]))
assert diff < 1e-4 * max(1.0, abs(float(ref["loss"]))), diff
c = ex["spmd_program"].counts()
n_params = sum(int(x.size) for x in jax.tree.leaves(sp))
print(f"ROW spmdtrain/vlm-d{{D}} {{us:.1f}} "
      f"first_us={{first_us:.0f}};replay_us={{replay_us:.0f}};"
      f"waves={{c['waves']}};items={{c['items']}};"
      f"params={{n_params}};loss_diff={{diff:.1e}};match=1", flush=True)
"""

_CHILD_COMPILE = """
import time
import jax
from repro.core import schedule as sch
from repro.parallel.spmd import (build_spmd_runner, compile_spmd_program,
                                 toy_stage_model)

Ms = {Ms!r}
d = 16
for M in Ms:
    g = sch.chain_graph([sch.Stage(f"s{{i}}", 1.0, 2.0, bwd_w=1.0)
                         for i in range(4)])
    sim = sch.get_scheduler("zb-h1").simulate(g, M)
    prog = compile_spmd_program(g, sim)
    fn, params = toy_stage_model(4, d)
    mbs = jax.random.normal(jax.random.PRNGKey(1), (M, 1, 4, d))
    for dispatch in ("rolled", "switch"):
        runner = build_spmd_runner(fn, g, sim, program=prog,
                                   dispatch=dispatch)
        t0 = time.perf_counter()
        res = runner(params, mbs)
        jax.block_until_ready(res["loss"])
        us = (time.perf_counter() - t0) * 1e6
        print(f"ROW spmdtrain/compile-{{dispatch}}-M{{M}} {{us:.1f}} "
              f"dispatch={{dispatch}};microbatches={{M}};"
              f"waves={{prog.counts()['waves']}}", flush=True)
"""


def _child(code: str, n_devices: int):
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=1200,
                          cwd=REPO)
    assert proc.returncode == 0, \
        f"spmdtrain bench child failed:\n{proc.stdout}\n{proc.stderr}"
    rows = []
    for line in proc.stdout.splitlines():
        if not line.startswith("ROW "):
            continue
        _tag, name, us, derived = line.split(" ", 3)
        emit(name, float(us), derived, json_path=JSON_PATH)
        rows.append((name, float(us), derived))
    return rows


def run(smoke: bool = False):
    if os.path.exists(JSON_PATH):
        os.remove(JSON_PATH)
    ms = (4, 8) if smoke else (4, 8, 16, 32)
    rows = _child(_CHILD_TRAIN.format(iters=2 if smoke else 5), 3)
    rows += _child(_CHILD_COMPILE.format(Ms=tuple(ms)), 4)
    assert len(rows) == 1 + 2 * len(ms), rows
    return rows


if __name__ == "__main__":
    run()
