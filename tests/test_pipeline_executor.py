"""shard_map pipeline executor + modality islands (subprocess,
multi-device) — the SPMD realizations of the paper's schedules."""
import pytest

from .helpers import run_with_devices


def test_pipeline_forward_and_grads_4_stages():
    code = """
import jax, jax.numpy as jnp
from repro.core import modality_parallel as mp
mesh = jax.make_mesh((4,), ("stage",))
key = jax.random.PRNGKey(0)
d = 32
per_stage = [{"w": jax.random.normal(jax.random.fold_in(key, s),
                                     (d, d)) * 0.1} for s in range(4)]
sp = mp.stack_stage_params(per_stage)
def stage_fn(lp, x):
    return x + jnp.tanh(x @ lp["w"])
mbs = jax.random.normal(jax.random.fold_in(key, 9), (6, 2, 8, d))
out = mp.pipeline_forward(mesh, "stage", stage_fn, sp, mbs, num_stages=4)
ref = mp.pipeline_reference(stage_fn, sp, mbs, num_stages=4)
assert float(jnp.abs(out - ref).max()) < 1e-5
def loss(sp):
    return jnp.mean(mp.pipeline_forward(mesh, "stage", stage_fn, sp, mbs,
                                        num_stages=4) ** 2)
def loss_ref(sp):
    return jnp.mean(mp.pipeline_reference(stage_fn, sp, mbs,
                                          num_stages=4) ** 2)
g1 = jax.grad(loss)(sp); g2 = jax.grad(loss_ref)(sp)
assert float(jnp.abs(g1["w"] - g2["w"]).max()) < 1e-6
print("OK")
"""
    assert "OK" in run_with_devices(code, 4)


def test_pipeline_transformer_stages():
    """Real transformer blocks as pipeline stages (paper's LLM chain)."""
    code = """
import jax, jax.numpy as jnp
from repro.core import modality_parallel as mp
from repro.configs.base import get_config
from repro.models import transformer as T
from repro.models import layers as L
cfg = get_config("qwen3-1.7b", reduced=True).replace(num_layers=4)
mesh = jax.make_mesh((4,), ("stage",))
key = jax.random.PRNGKey(0)
full = T.init(key, cfg)
per_stage = [jax.tree.map(lambda a: a[s], full["layers"]) for s in range(4)]
sp = mp.stack_stage_params(per_stage)
B, T_ = 2, 16
pos = jnp.broadcast_to(jnp.arange(T_, dtype=jnp.int32)[None], (B, T_))
batch = {"positions": pos}
def stage_fn(lp, x):
    out, _, _ = T._block(cfg, lp, x, batch, jnp.int32(0), None)
    return out
mbs = jax.random.normal(jax.random.fold_in(key, 7), (4, B, T_, cfg.d_model))
out = mp.pipeline_forward(mesh, "stage", stage_fn, sp, mbs, num_stages=4)
ref = mp.pipeline_reference(stage_fn, sp, mbs, num_stages=4)
assert float(jnp.abs(out - ref).max()) < 1e-4
print("OK")
"""
    assert "OK" in run_with_devices(code, 4)


def test_modality_islands_match_monolithic():
    code = """
import jax, jax.numpy as jnp
from repro.core import modality_parallel as mp
from repro.models.mllm import build_paper_mllm
mllm = build_paper_mllm("valm", reduced=True)
params = mllm.init(jax.random.PRNGKey(0))
batch = {
    "text_tokens": jnp.ones((2, 64), jnp.int32),
    "vision_embeds": jax.random.normal(jax.random.PRNGKey(1), (2, 16, 128)),
    "audio_embeds": jax.random.normal(jax.random.PRNGKey(2), (2, 16, 128)),
}
split = mp.split_devices(mllm, jax.devices())
isl = mp.ModalityIslands(mllm, split)
logits, aux = isl.run(params, batch)
(ref_logits, _), _ = mllm.forward(params, batch)
assert float(jnp.abs(logits - ref_logits).max()) == 0.0
# encoders really live on disjoint devices
assert set(d.id for d in split["vision"]).isdisjoint(
    d.id for d in split["audio"])
print("OK")
"""
    assert "OK" in run_with_devices(code, 8)


def test_islands_device_split_respects_plan():
    code = """
import jax
from repro.core import modality_parallel as mp
from repro.models.mllm import build_paper_mllm
mllm = build_paper_mllm("valm", reduced=True)
split = mp.split_devices(mllm, jax.devices(), plan={"vision": 2, "audio": 1})
assert len(split["vision"]) == 2 and len(split["audio"]) == 1
assert len(split["llm"]) == 5
print("OK")
"""
    assert "OK" in run_with_devices(code, 8)


def test_shardmap_moe_dispatch_matches_gspmd():
    """Perf-A4 path: the shard_map expert-parallel dispatch must be
    numerically identical to the plain capacity dispatch."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_config, MoEConfig
from repro.models import moe, api
from repro.launch import sharding as shd
cfg = get_config("qwen2-moe-a2.7b", reduced=True).replace(
    moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                  d_expert=128, backend="capacity", capacity_factor=4.0,
                  expert_pad_to=4))
params = api.init(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
B, T = 4, 16
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)),
                               jnp.int32),
         "positions": jnp.broadcast_to(
             jnp.arange(T, dtype=jnp.int32)[None], (B, T))}
l_plain, _ = moe.forward(params, cfg, batch)
mesh = jax.make_mesh((2, 2), ("data", "model"))
shd.set_rules(shd.Rules(seq_parallel=False))
shd.set_mesh(mesh)
try:
    with mesh:
        l_sm, _ = jax.jit(lambda p, b: moe.forward(p, cfg, b))(params, batch)
finally:
    shd.set_rules(None); shd.set_mesh(None)
d = float(jnp.abs(l_sm - l_plain).max())
assert d < 1e-5, d
print("OK", d)
"""
    assert "OK" in run_with_devices(code, 4)
