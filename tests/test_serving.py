"""Serving subsystem tests: paged flash-decode kernel parity vs the XLA
references (GQA x softcap x window x multimodal bitfields), masked-page
grid compaction, the continuous batching engine's determinism, the
ContextPlan prefill handoff, and the ragged dense decode_step fix."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import bam
from repro.models import api
from repro.models import transformer as T
from repro.parallel import plan_context
from repro.serving import (NULL_PAGE, PageTable, ServingEngine,
                           build_decode_grid, decode_grid_bucket,
                           init_paged_cache)
from repro.kernels.paged_decode import (paged_decode_attention,
                                        paged_decode_ref)


def tiny_cfg(**kw):
    base = dict(name="tiny-serve", family="dense", num_layers=2, d_model=32,
                num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                dtype="float32", remat=False, seq_shard_activations=False)
    base.update(kw)
    return ModelConfig(**base)


# ---------------------------------------------------------------------------
# Kernel parity: paged_decode_attention (interpret) vs paged_decode_ref
# ---------------------------------------------------------------------------

def _paged_fixture(page_size, Hkv, hd, layouts, seed=0):
    """Build a page pool holding one request per multimodal layout.
    Returns (table, k_pages, v_pages, rids). ``layouts`` are
    build_sample_bits segment lists."""
    rng = np.random.default_rng(seed)
    total_pages = 1 + sum(
        -(-sum(s[2] for s in segs) // page_size) for segs in layouts)
    table = PageTable(total_pages + 2, page_size)
    for rid, segs in enumerate(layouts):
        n = sum(s[2] for s in segs)
        bits, pos = bam.build_sample_bits(segs, n)
        table.alloc(rid, n)
        table.write(rid, np.arange(n), bits, pos)
    P = table.num_pages
    k_pages = jnp.asarray(rng.normal(size=(P, page_size, Hkv, hd)),
                          jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(P, page_size, Hkv, hd)),
                          jnp.float32)
    return table, k_pages, v_pages, list(range(len(layouts)))


LAYOUTS = [
    [("text", 0, 5), ("mod", 1, 8), ("text", 0, 6)],
    [("text", 0, 9), ("newdoc", 0, 0), ("text", 0, 4)],
]


@pytest.mark.parametrize("H,Hkv", [(4, 4), (4, 2), (8, 2)])
@pytest.mark.parametrize("softcap", [0.0, 20.0])
@pytest.mark.parametrize("window", [0, 4])
def test_kernel_parity(H, Hkv, softcap, window):
    page_size, hd = 8, 16
    table, k_pages, v_pages, rids = _paged_fixture(
        page_size, Hkv, hd, LAYOUTS)
    rng = np.random.default_rng(1)
    B = len(rids) + 1                      # + one empty batch row
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.float32)
    # text queries continuing each request (window semantics only
    # constrain text queries, so text is the apples-to-apples case);
    # request 0's query also attends its modality-1 stream; request 1's
    # continues its second document (instance 1, positions restart)
    q_bits = np.array([bam.text_token((1,)), bam.text_token(instance=1), 0],
                      np.uint32)[:, None]
    q_pos = np.array([[19], [4], [0]], np.int32)

    grid = build_decode_grid(table, rids + [None], q_bits[:, 0],
                             q_pos[:, 0], window=window,
                             pad_to=decode_grid_bucket(16))
    kv_bits = jnp.asarray(table.bits)
    kv_pos = jnp.asarray(table.pos)
    out_k = paged_decode_attention(
        q, k_pages, v_pages, jnp.asarray(q_bits), jnp.asarray(q_pos),
        kv_bits, kv_pos, grid.arrays(), softcap=softcap, window=window,
        interpret=True)
    mp = max(len(table.pages_of(r)) for r in rids)
    pt = np.stack([table.page_table_row(r, mp) for r in rids]
                  + [np.full(mp, NULL_PAGE, np.int32)])
    out_r = paged_decode_ref(
        q, k_pages, v_pages, jnp.asarray(q_bits), jnp.asarray(q_pos),
        kv_bits, kv_pos, jnp.asarray(pt), softcap=softcap, window=window)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-5)
    assert np.asarray(out_k[0]).any()               # row 0 nonzero
    assert np.asarray(out_k[2] == 0).all()          # empty row exactly 0


def test_masked_pages_skipped():
    """A text query that does not attend the modality stream must not
    visit the image-only pages: the grid provably drops those steps and
    the kernel still matches the dense-gather reference."""
    page_size, Hkv, hd = 8, 2, 16
    # one image-heavy request: 8 text + 16 image + 8 text = 2 pure
    # image pages out of 4
    table, k_pages, v_pages, rids = _paged_fixture(
        page_size, Hkv, hd,
        [[("text", 0, 8), ("mod", 1, 16), ("text", 0, 8)]])
    q_bits_blind = np.array([[bam.text_token()]], np.uint32)
    q_bits_vis = np.array([[bam.text_token((1,))]], np.uint32)
    q_pos = np.array([[32]], np.int32)

    g_blind = build_decode_grid(table, rids, q_bits_blind[:, 0],
                                q_pos[:, 0])
    g_vis = build_decode_grid(table, rids, q_bits_vis[:, 0], q_pos[:, 0])
    assert g_vis.n_active_steps == 4          # every resident page
    assert g_blind.n_active_steps == 2        # image pages compacted out
    assert g_blind.n_dense_steps == 4
    assert g_blind.skip_fraction == pytest.approx(0.5)

    q = jnp.asarray(np.random.default_rng(2).normal(size=(1, 4, hd)),
                    jnp.float32)
    for qb, grid in ((q_bits_blind, g_blind), (q_bits_vis, g_vis)):
        out_k = paged_decode_attention(
            q, k_pages, v_pages, jnp.asarray(qb), jnp.asarray(q_pos),
            jnp.asarray(table.bits), jnp.asarray(table.pos),
            grid.arrays(), interpret=True)
        pt = table.page_table_row(rids[0], 4)[None]
        out_r = paged_decode_ref(
            q, k_pages, v_pages, jnp.asarray(qb), jnp.asarray(q_pos),
            jnp.asarray(table.bits), jnp.asarray(table.pos),
            jnp.asarray(pt))
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   atol=2e-5)


# ---------------------------------------------------------------------------
# Engine vs dense decode_step, determinism, CP handoff
# ---------------------------------------------------------------------------

def _dense_generate(params, cfg, prompt, max_new, Tmax=64):
    cache = T.init_cache(cfg, 1, Tmax)
    logits = None
    for t, tok in enumerate(prompt):
        batch = {"tokens": jnp.asarray([[int(tok)]], jnp.int32),
                 "positions": jnp.asarray([[t]], jnp.int32)}
        logits, cache = T.decode_step(params, cfg, cache, batch)
    out = [int(jnp.argmax(logits[0, 0]))]
    for i in range(max_new - 1):
        batch = {"tokens": jnp.asarray([[out[-1]]], jnp.int32),
                 "positions": jnp.asarray([[len(prompt) + i]], jnp.int32)}
        logits, cache = T.decode_step(params, cfg, cache, batch)
        out.append(int(jnp.argmax(logits[0, 0])))
    return out


@pytest.mark.parametrize("cfg_kw", [
    dict(attn_softcap=10.0),
    dict(decode_kv_replicate=4),
    dict(sliding_window=6, local_global_pattern=2, attn_softcap=10.0),
])
def test_engine_matches_dense_decode(cfg_kw):
    cfg = tiny_cfg(**cfg_kw)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, 64, size=n) for n in (7, 12)]
    ref = [_dense_generate(params, cfg, p, 4) for p in prompts]
    for attn in ("xla", "interpret"):
        eng = ServingEngine(params, cfg, num_pages=24, page_size=8,
                            max_batch=3, attn=attn)
        rids = [eng.submit(p, max_new_tokens=4) for p in prompts]
        got = eng.run()
        assert [got[r] for r in rids] == ref, attn


def test_engine_determinism_continuous_vs_sequential():
    """Continuous batching must be composition-invariant: the tokens a
    request generates do not depend on which other requests share its
    batch. Batched engine == one-request-at-a-time engine."""
    cfg = tiny_cfg(attn_softcap=10.0)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, 64, size=n) for n in (5, 11, 3, 8)]

    eng = ServingEngine(params, cfg, num_pages=48, page_size=8,
                        max_batch=4, attn="xla")
    rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
    batched = [eng.run()[r] for r in rids]

    solo = []
    for p in prompts:
        e1 = ServingEngine(params, cfg, num_pages=48, page_size=8,
                           max_batch=1, attn="xla")
        r = e1.submit(p, max_new_tokens=5)
        solo.append(e1.run()[r])
    assert batched == solo


def test_engine_multimodal_and_page_reuse():
    """Multimodal prompts decode through the kernel path, and pages
    freed by finished requests are reused with scrubbed metadata (a
    later request over recycled pages matches a fresh engine)."""
    cfg = tiny_cfg(attn_softcap=10.0)
    params = api.init(jax.random.PRNGKey(0), cfg)
    segs = [("text", 0, 4), ("mod", 1, 8), ("text", 0, 4)]
    bits, pos = bam.build_sample_bits(segs, 16)
    prompt = np.arange(1, 17, dtype=np.int32)

    def run(engine, gen_bits):
        rid = engine.submit(prompt, bits=bits, positions=pos,
                            max_new_tokens=4, gen_bits=gen_bits)
        return engine.run()[rid]

    gb = bam.text_token((1,))
    eng = ServingEngine(params, cfg, num_pages=8, page_size=8,
                        max_batch=2, attn="interpret")
    first = run(eng, gb)
    # pool is 7 allocatable pages; the first request used 3 and freed
    # them — the rerun must land on recycled pages and match exactly
    second = run(eng, gb)
    fresh = run(ServingEngine(params, cfg, num_pages=8, page_size=8,
                              max_batch=2, attn="interpret"), gb)
    assert first == second == fresh
    assert eng.table.num_free == 7


def test_cp_plan_prefill_layout_equivalence():
    """A ContextPlan-permuted prefill writes the same decode state:
    generation from a plan-laid-out cache matches the identity layout,
    and the request's pages carry rank ownership."""
    cfg = tiny_cfg(attn_softcap=10.0)
    params = api.init(jax.random.PRNGKey(0), cfg)
    Tp = 16
    prompt = np.arange(1, Tp + 1, dtype=np.int32)
    bits = np.full(Tp, bam.text_token(), np.uint32)
    pos = np.arange(Tp, dtype=np.int32)
    plan = plan_context(bits, pos, num_ranks=2, block_size=4)

    outs = {}
    for key, p in (("plain", None), ("plan", plan)):
        eng = ServingEngine(params, cfg, num_pages=16, page_size=4,
                            max_batch=1, attn="xla")
        rid = eng.submit(prompt, max_new_tokens=4, plan=p)
        eng.step()                       # prefill only
        if p is not None:
            owners = eng.table.page_owner[eng.table.pages_of(rid)[:4]]
            assert set(owners.tolist()) == {0, 1}
        outs[key] = eng.run()[rid]
    assert outs["plan"] == outs["plain"]


# ---------------------------------------------------------------------------
# Satellites: ragged dense decode_step + _cache_cfg ValueError
# ---------------------------------------------------------------------------

def test_decode_step_ragged_rows():
    """Regression: decode_step used row 0's position for every row's
    cache insert. Two requests at staggered lengths batched together
    must produce the same logits as each decoded alone."""
    cfg = tiny_cfg(attn_softcap=10.0)
    params = api.init(jax.random.PRNGKey(0), cfg)
    Tmax = 16
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, 64, size=3), rng.integers(1, 64, size=7)]

    caches, solo_logits = [], []
    for p in prompts:
        cache = T.init_cache(cfg, 1, Tmax)
        for t, tok in enumerate(p):
            batch = {"tokens": jnp.asarray([[int(tok)]], jnp.int32),
                     "positions": jnp.asarray([[t]], jnp.int32)}
            logits, cache = T.decode_step(params, cfg, cache, batch)
        caches.append(cache)
        solo_logits.append(logits)

    stacked = jax.tree.map(
        lambda a, b: jnp.concatenate([a, b],
                                     axis=1 if a.ndim == 5 else 0),
        caches[0], caches[1])
    # replay the *last* token of each prompt batched at ragged rows:
    # rewind each row's final insert by scrubbing its bits slot
    cur = jnp.asarray([[len(prompts[0]) - 1], [len(prompts[1]) - 1]],
                      jnp.int32)
    stacked["bits"] = stacked["bits"].at[
        jnp.arange(2), cur[:, 0]].set(jnp.uint32(0))
    batch = {"tokens": jnp.asarray([[int(prompts[0][-1])],
                                    [int(prompts[1][-1])]], jnp.int32),
             "positions": cur}
    logits, new_cache = T.decode_step(params, cfg, stacked, batch)
    np.testing.assert_allclose(np.asarray(logits[0]),
                               np.asarray(solo_logits[0][0]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(logits[1]),
                               np.asarray(solo_logits[1][0]), atol=1e-5)
    # and each row's K/V landed at its own offset: bits restored
    for i, p in enumerate(prompts):
        got = np.asarray(new_cache["bits"][i, :len(p)])
        assert (got != 0).all()
        assert not np.asarray(new_cache["bits"][i, len(p):]).any()


def test_cache_cfg_divisibility_valueerror():
    cfg = tiny_cfg(num_heads=4, num_kv_heads=2, decode_kv_replicate=3)
    with pytest.raises(ValueError) as e:
        T.init_cache(cfg, 1, 8)
    assert "decode_kv_replicate=3" in str(e.value)
    assert "num_heads=4" in str(e.value)


def test_paged_cache_guards():
    table = PageTable(4, 4)
    table.alloc(0, 12)                    # all 3 allocatable pages
    with pytest.raises(RuntimeError, match="exhausted"):
        table.alloc(1, 4)
    with pytest.raises(IndexError):
        table.coords(0, [12])
    table.free(0)
    assert table.num_free == 3
    cfg = tiny_cfg()
    cache = init_paged_cache(cfg, 4, 4)
    assert cache["k"].shape == (2, 4, 4, 2, 8)
    assert int(cache["bits"].sum()) == 0


def test_submit_rejects_infeasible_page_budget():
    """A request whose prompt+max_new page budget exceeds the whole
    pool must be rejected AT SUBMIT with a structured error — not sit
    at the head of the FIFO forever waiting for pages that can never
    free up (the engine would spin to max_ticks)."""
    from repro.serving import InfeasibleRequest
    cfg = tiny_cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(params, cfg, num_pages=4, page_size=4,
                        max_batch=2)
    # capacity = 3 pages (page 0 is the null page) = 12 tokens;
    # 8 prompt tokens + 15 generated - 1 = 22 cached tokens -> 6 pages
    with pytest.raises(InfeasibleRequest) as e:
        eng.submit(np.arange(8) % cfg.vocab_size, max_new_tokens=15)
    err = e.value
    assert err.needed_pages == 6 and err.capacity == 3
    assert err.prompt_len == 8 and err.max_new_tokens == 15
    assert "never" in str(err)
    # nothing was queued, no rid leaked, and the engine still serves
    # feasible work afterwards
    assert not eng.queue and not eng.requests
    rid = eng.submit(np.arange(6) % cfg.vocab_size, max_new_tokens=4)
    assert rid == 0
    out = eng.run()
    assert len(out[rid]) == 4
