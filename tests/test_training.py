"""Training substrate tests: optimizer, schedules, chunked CE,
checkpointing, memorization convergence (integration)."""
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.checkpoint import checkpoint as ckpt
from repro.models import api, transformer as T
from repro.optim import optimizer as opt
from repro.training import steps


def test_lr_schedule():
    c = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(opt.lr_at(c, 0)) == 0.0
    assert abs(float(opt.lr_at(c, 10)) - 1.0) < 1e-6
    assert float(opt.lr_at(c, 110)) < 1e-6
    assert 0.4 < float(opt.lr_at(c, 60)) < 0.6


def test_grad_clip_applied():
    c = opt.AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0,
                        warmup_steps=0, schedule="constant")
    params = {"w": jnp.ones((4,))}
    state = opt.init(c, params)
    grads = {"w": jnp.full((4,), 100.0)}
    _, _, m = opt.update(c, grads, state, params)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_frozen_leaves_no_state_no_update():
    c = opt.AdamWConfig(lr=0.1, warmup_steps=0, schedule="constant")
    params = {"a": jnp.ones((4,)), "b": jnp.ones((4,))}
    mask = {"a": True, "b": False}
    state = opt.init(c, params, mask)
    assert state["m"]["a"].size == 0 and state["m"]["b"].size == 4
    grads = {"a": jnp.ones((4,)), "b": jnp.ones((4,))}
    p2, _, _ = opt.update(c, grads, state, params, mask)
    assert float(jnp.abs(p2["a"] - params["a"]).max()) == 0.0
    assert float(jnp.abs(p2["b"] - params["b"]).max()) > 0.0


@pytest.mark.parametrize("chunk", [4, 8])
def test_chunked_ce_matches_plain(chunk):
    cfg = get_config("qwen3-1.7b", reduced=True).replace(loss_chunk=chunk)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B, T_ = 2, 16
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T_)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T_)),
                              jnp.int32),
        "positions": jnp.broadcast_to(
            jnp.arange(T_, dtype=jnp.int32)[None], (B, T_)),
    }
    h, _ = T.hidden(params, cfg, batch)
    l1 = steps.chunked_cross_entropy(h, params, cfg, batch["labels"])
    l2 = steps.cross_entropy(T.unembed(params, cfg, h), batch["labels"])
    assert abs(float(l1) - float(l2)) < 1e-5
    # gradients agree too
    g1 = jax.grad(lambda h: steps.chunked_cross_entropy(
        h, params, cfg, batch["labels"]))(h)
    g2 = jax.grad(lambda h: steps.cross_entropy(
        T.unembed(params, cfg, h), batch["labels"]))(h)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


def test_memorization_converges():
    """Integration: a tiny model memorizes a fixed batch (loss must
    drop well below the uniform baseline ln(V))."""
    cfg = get_config("qwen3-1.7b", reduced=True).replace(
        num_layers=2, vocab_size=64)
    params = api.init(jax.random.PRNGKey(0), cfg)
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=200,
                           weight_decay=0.0)
    state = opt.init(ocfg, params)
    step = jax.jit(steps.make_train_step(cfg, ocfg))
    rng = np.random.default_rng(0)
    B, T_ = 4, 16
    batch = {
        "tokens": jnp.asarray(rng.integers(0, 64, (B, T_)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, 64, (B, T_)), jnp.int32),
        "positions": jnp.broadcast_to(
            jnp.arange(T_, dtype=jnp.int32)[None], (B, T_)),
    }
    first = None
    for i in range(120):
        params, state, m = step(params, state, batch)
        if first is None:
            first = float(m["loss"])
    last = float(m["loss"])
    assert last < first * 0.5, (first, last)
    assert last < np.log(64), (last, np.log(64))


def test_checkpoint_roundtrip_and_frozen_reuse():
    cfg = get_config("xlstm-125m", reduced=True)
    params = api.init(jax.random.PRNGKey(1), cfg)
    with tempfile.TemporaryDirectory() as d:
        man1 = ckpt.save(d, params, step=1)
        restored, s = ckpt.load(d, like=params)
        assert s == 1
        for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
            assert float(jnp.abs(jnp.asarray(a, jnp.float32) -
                                 jnp.asarray(b, jnp.float32)).max()) == 0.0
        # frozen-path reuse: second save skips rewriting frozen files
        man2 = ckpt.save(d, params, step=2, frozen_paths={"embed"},
                         prev_manifest=man1)
        reuse = [e for e in man2["entries"] if e["path"].startswith("embed")]
        prev = {e["path"]: e["file"] for e in man1["entries"]}
        assert all(e["file"] == prev[e["path"]] for e in reuse)


def test_serve_step_greedy_token():
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    cache = api.init_cache(cfg, 2, 8)
    serve = jax.jit(steps.make_serve_step(cfg))
    batch = {"tokens": jnp.ones((2, 1), jnp.int32),
             "positions": jnp.zeros((2, 1), jnp.int32)}
    tok, cache = serve(params, cache, batch)
    assert tok.shape == (2,) and tok.dtype == jnp.int32
