"""Multimodality-aware context parallelism tests (paper §4.3/§5.3).

Single-device paths run in-process; multi-rank equivalence runs in a
subprocess with a forced host device count."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bam, context_parallel as cp
from repro.core import distribution as dist
from repro.models.layers import sdpa

from .helpers import host_mesh, subprocess_test


def make_case(seed=0, B=2, T=64, H=4, hd=16):
    key = jax.random.PRNGKey(seed)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, T, H, hd))
               for i in range(3))
    segs = [("text", 0, T // 4), ("mod", 1, T // 4), ("text", 0, T // 4),
            ("mod", 2, T // 8), ("text", 0, T - 7 * (T // 8))]
    bits_np, pos_np = bam.build_sample_bits(segs, T)
    bits = jnp.broadcast_to(jnp.asarray(bits_np)[None], (B, T))
    pos = jnp.broadcast_to(jnp.asarray(pos_np)[None], (B, T))
    return q, k, v, bits, pos, bits_np, pos_np


@pytest.mark.parametrize("method", ["allgather", "ring"])
def test_cp_single_rank_equals_sdpa(method):
    q, k, v, bits, pos, *_ = make_case()
    mask = bam.allowed_mask(bits, bits, pos, pos)[:, None]
    ref = sdpa(q, k, v, mask)
    mesh = jax.make_mesh((1,), ("cp",))
    out = cp.cp_attention(mesh, "cp", q, k, v, bits, bits, pos, pos,
                          method=method)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


@pytest.mark.parametrize("method", ["allgather", "ring"])
def test_cp_kernel_stats_path_equals_reference(method):
    """CP bodies on the Pallas stats kernel (impl="bam_interpret"):
    the per-step [B,H,Tq,Tk] logits never materialize, the combined
    output must still equal the dense oracle."""
    q, k, v, bits, pos, *_ = make_case()
    ref = cp.cp_reference(q, k, v, bits, bits, pos, pos)
    mesh = jax.make_mesh((1,), ("cp",))
    out = cp.cp_attention(mesh, "cp", q, k, v, bits, bits, pos, pos,
                          method=method, impl="bam_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_cp_reference_equals_sdpa():
    q, k, v, bits, pos, *_ = make_case(1)
    mask = bam.allowed_mask(bits, bits, pos, pos)[:, None]
    np.testing.assert_allclose(
        np.asarray(cp.cp_reference(q, k, v, bits, bits, pos, pos)),
        np.asarray(sdpa(q, k, v, mask)), atol=2e-6)


def test_plan_permutation_roundtrip():
    _, _, _, _, _, bits_np, pos_np = make_case(2)
    plan = dist.plan_tokens(bits_np, pos_np, 4, block_size=8, method="lpt")
    perm = cp.plan_permutation(plan, 64)
    inv = cp.invert_perm(perm)
    x = np.arange(64)
    np.testing.assert_array_equal(x[perm][inv], x)
    assert sorted(perm) == list(range(64))


@pytest.mark.parametrize("T,G,bs", [(60, 8, 8), (50, 4, 8), (33, 2, 4),
                                    (70, 3, 8)])
@pytest.mark.parametrize("planner", ["lpt", "zigzag", "ring"])
def test_plan_permutation_nondivisible_seq(T, G, bs, planner):
    """Regression: the rebalance path used to DROP up to G-1 trailing
    tokens whenever seq_len % num_ranks != 0 (target = seq_len // G and
    the leftover `extra` blocks were never re-appended). The result
    must always be a true permutation of arange(seq_len)."""
    bits_np, pos_np = bam.build_sample_bits([("text", 0, T)], T)
    plan = dist.plan_tokens(bits_np, pos_np, G, block_size=bs,
                            method=planner)
    perm = cp.plan_permutation(plan, T)
    assert sorted(perm.tolist()) == list(range(T))
    inv = cp.invert_perm(perm)
    np.testing.assert_array_equal(np.arange(T)[perm][inv], np.arange(T))
    # the layout is rank-contiguous with counts differing by at most
    # one (extras on the leading ranks), and each rank's segment keeps
    # its own assigned tokens first — rebalancing only trims tails and
    # appends other ranks' leftovers
    base, rem = divmod(T, G)
    targets = [base + (1 if g < rem else 0) for g in range(G)]
    own = [s[s < T] for s in plan.rank_token_slices()]
    off = 0
    for g in range(G):
        seg = perm[off:off + targets[g]]
        keep = min(targets[g], len(own[g]))
        np.testing.assert_array_equal(seg[:keep], own[g][:keep])
        off += targets[g]
    assert off == T


def test_plan_permutation_uncovered_seq_raises():
    """seq_len beyond the plan's block coverage must fail loudly."""
    bits_np, pos_np = bam.build_sample_bits([("text", 0, 32)], 32)
    plan = dist.plan_tokens(bits_np, pos_np, 2, block_size=8)
    with pytest.raises(ValueError, match="covers 32 tokens"):
        cp.plan_permutation(plan, 48)


def test_cp_attention_unknown_method_raises():
    q, k, v, bits, pos, *_ = make_case()
    mesh = jax.make_mesh((1,), ("cp",))
    with pytest.raises(ValueError, match="allgather.*ring"):
        cp.cp_attention(mesh, "cp", q, k, v, bits, bits, pos, pos,
                        method="butterfly")


def test_simulate_rank_workloads_matches_loop():
    """The vectorized scatter-add must equal the per-block Python loop
    it replaced — including a partial trailing block."""
    from repro.data.synthetic import random_multimodal_bits
    for T, G, bs, window in [(300, 4, 32, 0), (256, 8, 16, 7)]:
        bits, pos = random_multimodal_bits(T, "ee", seed=1)
        bits, pos = bits[:T], pos[:T]
        plan = dist.plan_tokens(bits, pos, G, block_size=bs)
        W = bam.token_workload(bits, pos, window)
        loop = np.zeros(plan.num_ranks)
        for g, blocks in enumerate(plan.per_rank_blocks):
            for b in blocks:
                loop[g] += W[b * bs:(b + 1) * bs].sum()
        np.testing.assert_allclose(
            cp.simulate_rank_workloads(plan, bits, pos, window), loop)


@pytest.mark.parametrize("method", ["allgather", "ring"])
@pytest.mark.parametrize("planner", ["lpt", "zigzag", "random"])
@subprocess_test(4)
def test_cp_multirank_equivalence(method, planner):
    """4 CP ranks × every planner must reproduce full attention exactly
    (the distribution is a permutation, never an approximation)."""
    q, k, v, bits, pos, bits_np, pos_np = make_case()
    mask = bam.allowed_mask(bits, bits, pos, pos)[:, None]
    ref = sdpa(q, k, v, mask)
    plan = dist.plan_tokens(bits_np, pos_np, 4, block_size=8,
                            method=planner)
    perm = cp.plan_permutation(plan, 64)
    inv = cp.invert_perm(perm)
    with host_mesh(4, ("cp",)) as mesh:
        args = [jnp.take(a, perm, axis=1) for a in (q, k, v)]
        bp = jnp.take(bits, perm, axis=1)
        pp_ = jnp.take(pos, perm, axis=1)
        out = cp.cp_attention(mesh, "cp", *args, bp, bp, pp_, pp_,
                              method=method)
    out = jnp.take(out, inv, axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-6)


@pytest.mark.parametrize("method", ["allgather", "ring"])
@subprocess_test(2)
def test_cp_multirank_kernel_stats_path(method):
    """Multi-rank CP on the kernel stats path: ring-step / all-gather
    combination of Pallas partials reproduces full attention."""
    q, k, v, bits, pos, bits_np, pos_np = make_case(B=1, H=2)
    ref = cp.cp_reference(q, k, v, bits, bits, pos, pos)
    plan = dist.plan_tokens(bits_np, pos_np, 2, block_size=8,
                            method="lpt")
    perm = cp.plan_permutation(plan, 64)
    inv = cp.invert_perm(perm)
    with host_mesh(2, ("cp",)) as mesh:
        args = [jnp.take(a, perm, axis=1) for a in (q, k, v)]
        bp = jnp.take(bits, perm, axis=1)
        pp_ = jnp.take(pos, perm, axis=1)
        out = cp.cp_attention(mesh, "cp", *args, bp, bp, pp_, pp_,
                              method=method, impl="bam_interpret")
    out = jnp.take(out, inv, axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5)


# ---------------------------------------------------------------------------
# Differentiable CP: jax.grad through the bodies must match jax.grad of
# the collective-free oracle (combining-aware custom_vjp; the kernel
# path runs the fused per-chunk flash backward, allgather reduce-
# scatters dK/dV, ring runs the reverse ring)
# ---------------------------------------------------------------------------

def _grads_of(fn, q, k, v):
    return jax.grad(lambda q, k, v: jnp.sum(fn(q, k, v) ** 2),
                    argnums=(0, 1, 2))(q, k, v)


def _gqa_case(seed=0, B=1, T=64, H=4, Hkv=2, hd=16):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, T, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, hd))
    segs = [("text", 0, T // 4), ("mod", 1, T // 4), ("text", 0, T // 4),
            ("mod", 2, T // 8), ("text", 0, T - 7 * (T // 8))]
    bits_np, pos_np = bam.build_sample_bits(segs, T)
    bits = jnp.broadcast_to(jnp.asarray(bits_np)[None], (B, T))
    pos = jnp.broadcast_to(jnp.asarray(pos_np)[None], (B, T))
    return q, k, v, bits, pos


@pytest.mark.parametrize("method", ["allgather", "ring"])
@pytest.mark.parametrize("impl", ["xla", "bam_interpret"])
def test_cp_grads_match_reference(method, impl):
    q, k, v, bits, pos, *_ = make_case()
    mesh = jax.make_mesh((1,), ("cp",))
    g_cp = _grads_of(
        lambda q, k, v: cp.cp_attention(mesh, "cp", q, k, v, bits, bits,
                                        pos, pos, method=method, impl=impl,
                                        block_q=16, block_k=16), q, k, v)
    g_ref = _grads_of(
        lambda q, k, v: cp.cp_reference(q, k, v, bits, bits, pos, pos),
        q, k, v)
    for a, b in zip(g_cp, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("method", ["allgather", "ring"])
@pytest.mark.parametrize("variant", ["softcap", "window", "gqa"])
def test_cp_grads_variants(method, variant):
    """softcap chain rule, sliding window, and GQA head-folding all
    survive the CP backward on the kernel path."""
    Hkv = 2 if variant == "gqa" else 4
    kw = {"softcap": {"softcap": 30.0}, "window": {"window": 9},
          "gqa": {}}[variant]
    q, k, v, bits, pos = _gqa_case(seed=1, Hkv=Hkv)
    mesh = jax.make_mesh((1,), ("cp",))
    g_cp = _grads_of(
        lambda q, k, v: cp.cp_attention(mesh, "cp", q, k, v, bits, bits,
                                        pos, pos, method=method,
                                        impl="bam_interpret", block_q=16,
                                        block_k=16, **kw), q, k, v)
    g_ref = _grads_of(
        lambda q, k, v: cp.cp_reference(q, k, v, bits, bits, pos, pos,
                                        **kw), q, k, v)
    for a, b in zip(g_cp, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("method", ["allgather", "ring"])
@pytest.mark.parametrize("impl", ["xla", "bam_interpret"])
def test_cp_grads_padding_exact_zero(method, impl):
    """bits=0 tokens must receive exactly-zero dQ/dK/dV through CP."""
    B, T, H, hd = 1, 64, 2, 16
    key = jax.random.PRNGKey(3)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, T, H, hd))
               for i in range(3))
    bits_np, pos_np = bam.build_sample_bits(
        [("text", 0, 24), ("mod", 1, 8), ("text", 0, 16)], T)  # 16 padded
    bits = jnp.asarray(bits_np)[None]
    pos = jnp.asarray(pos_np)[None]
    mesh = jax.make_mesh((1,), ("cp",))
    dq, dk, dv = _grads_of(
        lambda q, k, v: cp.cp_attention(mesh, "cp", q, k, v, bits, bits,
                                        pos, pos, method=method, impl=impl,
                                        block_q=16, block_k=16), q, k, v)
    assert not np.asarray(dq)[:, 48:].any()
    assert not np.asarray(dk)[:, 48:].any()
    assert not np.asarray(dv)[:, 48:].any()
    g_ref = _grads_of(
        lambda q, k, v: cp.cp_reference(q, k, v, bits, bits, pos, pos),
        q, k, v)
    for a, b in zip((dq, dk, dv), g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("method", ["allgather", "ring"])
def test_cp_backward_no_quadratic_intermediate(method):
    """The traced CP backward on the kernel path must not allocate any
    O(Tq·Tk) f32 array — residuals are (out, lse) rows and the fused
    chunk backwards only ever hold [block_q, block_k] tiles. (The
    jaxpr walk lives in repro.analysis.jaxprlint, promoted from this
    file.)"""
    from repro.analysis.jaxprlint import quadratic_f32 as _quadratic_f32
    T = 64
    q, k, v, bits, pos, *_ = make_case(B=1, H=2)
    mesh = jax.make_mesh((1,), ("cp",))

    def loss(impl):
        def f(q, k, v):
            return jnp.sum(cp.cp_attention(
                mesh, "cp", q, k, v, bits, bits, pos, pos, method=method,
                impl=impl, block_q=16, block_k=16) ** 2)
        return f

    jaxpr = jax.make_jaxpr(jax.grad(loss("bam_interpret"),
                                    argnums=(0, 1, 2)))(q, k, v)
    assert not _quadratic_f32(jaxpr, T), _quadratic_f32(jaxpr, T)
    # sanity: the XLA body DOES trace a [T,T] intermediate, so the
    # assertion above is actually discriminating
    jaxpr_x = jax.make_jaxpr(jax.grad(loss("xla"),
                                      argnums=(0, 1, 2)))(q, k, v)
    assert _quadratic_f32(jaxpr_x, T)


@pytest.mark.parametrize("method", ["allgather", "ring"])
@subprocess_test(2)
def test_cp_multirank_grads_kernel_path(method):
    """2 CP ranks on the kernel path: grads through the plan-permuted
    CP attention (reduce-scatter / reverse-ring backward collectives)
    must match the single-device oracle's grads."""
    q, k, v, bits, pos, bits_np, pos_np = make_case(B=1, H=2)
    plan = dist.plan_tokens(bits_np, pos_np, 2, block_size=8,
                            method="lpt")
    perm = jnp.asarray(cp.plan_permutation(plan, 64))
    bp = jnp.take(bits, perm, axis=1)
    pp_ = jnp.take(pos, perm, axis=1)
    with host_mesh(2, ("cp",)) as mesh:

        def loss_cp(q, k, v):
            qp, kp, vp = (jnp.take(a, perm, axis=1) for a in (q, k, v))
            out = cp.cp_attention(mesh, "cp", qp, kp, vp, bp, bp, pp_,
                                  pp_, method=method,
                                  impl="bam_interpret",
                                  block_q=16, block_k=16)
            return jnp.sum(out ** 2)   # permutation-invariant scalar

        def loss_ref(q, k, v):
            return jnp.sum(cp.cp_reference(q, k, v, bits, bits, pos,
                                           pos) ** 2)

        g1 = jax.grad(loss_cp, (0, 1, 2))(q, k, v)
        g2 = jax.grad(loss_ref, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4)


def test_cp_train_step_contextplan_layout():
    """End-to-end: a typed ContextPlan drives a CP train step — loss
    and parameter grads match the plain (unpermuted, non-CP) step."""
    from repro.configs.base import get_config
    from repro.models import api
    from repro.optim import optimizer as opt
    from repro.parallel import plan_context
    from repro.training import steps

    cfg = get_config("qwen3-1.7b", reduced=True)
    T, B = 32, 2
    bits_np, pos_np = bam.build_sample_bits(
        [("text", 0, 8), ("mod", 1, 8), ("text", 0, 16)], T)
    ctx = plan_context(bits_np, pos_np, 2, block_size=4, method="lpt")
    layout = ctx.apply(T)
    assert sorted(layout["perm"].tolist()) == list(range(T))
    mesh = jax.make_mesh((1,), ("cp",))

    params = api.init(jax.random.PRNGKey(0), cfg)
    ocfg = opt.AdamWConfig(lr=1e-2, warmup_steps=0, schedule="constant")
    state = opt.init(ocfg, params)
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T)),
                              jnp.int32),
        "positions": jnp.broadcast_to(jnp.asarray(pos_np)[None], (B, T)),
        "bits": jnp.broadcast_to(jnp.asarray(bits_np)[None], (B, T)),
        "valid": jnp.broadcast_to(jnp.asarray(bits_np != 0)[None], (B, T)),
    }
    # a 2-rank plan on a 1-device mesh is exact but unbalanced — the
    # step must say so
    with pytest.warns(UserWarning, match="balanced for 2 ranks"):
        step_cp = jax.jit(steps.make_cp_train_step(cfg, layout, mesh,
                                                   ocfg))
    _, _, m_cp = step_cp(params, state, batch)
    _, _, m_ref = jax.jit(steps.make_train_step(cfg, ocfg))(
        params, state, batch)
    assert abs(float(m_cp["loss"]) - float(m_ref["loss"])) < 1e-4
    assert abs(float(m_cp["grad_norm"]) - float(m_ref["grad_norm"])) < 1e-3

    # grads themselves agree leaf-by-leaf (the step's value_and_grad,
    # re-derived here; Adam's 1/sqrt(v) would amplify float noise)
    cp_cfg = cfg.replace(cp_mesh=mesh, cp_axis="cp")
    perm = jnp.asarray(layout["perm"])
    pb = {k: jnp.take(x, perm, axis=1) for k, x in batch.items()}
    g_cp = jax.grad(lambda p: steps.make_loss_fn(cp_cfg)(p, pb)[0])(params)
    g_ref = jax.grad(lambda p: steps.make_loss_fn(cfg)(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g_cp), jax.tree.leaves(g_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-4, rtol=5e-3)


def test_cp_train_step_missing_bits_raises():
    """A CP step on a bits-less batch would silently replicate dense
    attention on every device — it must refuse at trace time."""
    from repro.configs.base import get_config
    from repro.optim import optimizer as opt
    from repro.parallel import plan_context
    from repro.training import steps
    cfg = get_config("qwen3-1.7b", reduced=True)
    T, B = 32, 1
    bits_np, pos_np = bam.build_sample_bits([("text", 0, T)], T)
    layout = plan_context(bits_np, pos_np, 1, block_size=4).apply(T)
    mesh = jax.make_mesh((1,), ("cp",))
    step = steps.make_cp_train_step(cfg, layout, mesh)
    params = {}
    batch = {"tokens": jnp.zeros((B, T), jnp.int32),
             "labels": jnp.zeros((B, T), jnp.int32),
             "positions": jnp.broadcast_to(jnp.asarray(pos_np)[None],
                                           (B, T))}
    with pytest.raises(ValueError, match="batch\\['bits'\\]"):
        step(params, {}, batch)


def test_cp_train_step_indivisible_mesh_raises():
    from repro.configs.base import get_config
    from repro.parallel import plan_context
    from repro.training import steps
    cfg = get_config("qwen3-1.7b", reduced=True)
    T = 30
    bits_np, pos_np = bam.build_sample_bits([("text", 0, T)], T)
    ctx = plan_context(bits_np, pos_np, 4, block_size=4, method="lpt")
    layout = ctx.apply(T)

    class FakeMesh:
        shape = {"cp": 4}

    with pytest.raises(ValueError, match="not divisible"):
        steps.make_cp_train_step(cfg, layout, FakeMesh())


def test_rank_workload_balance_lpt_vs_zigzag():
    """The §6.5 claim at planner level: LPT's max-rank workload is no
    worse than zigzag's on multimodal masks (usually strictly better)."""
    from repro.data.synthetic import random_multimodal_bits
    worse = 0
    for seed in range(6):
        bits, pos = random_multimodal_bits(2048, "ee", seed=seed)
        pl_l = dist.plan_tokens(bits, pos, 8, 32, method="lpt")
        pl_z = dist.plan_tokens(bits, pos, 8, 32, method="zigzag")
        l_max = cp.simulate_rank_workloads(pl_l, bits, pos).max()
        z_max = cp.simulate_rank_workloads(pl_z, bits, pos).max()
        if l_max > z_max + 1e-6:
            worse += 1
    assert worse == 0
