"""Multimodality-aware context parallelism tests (paper §4.3/§5.3).

Single-device paths run in-process; multi-rank equivalence runs in a
subprocess with a forced host device count."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bam, context_parallel as cp
from repro.core import distribution as dist
from repro.models.layers import sdpa

from .helpers import run_with_devices


def make_case(seed=0, B=2, T=64, H=4, hd=16):
    key = jax.random.PRNGKey(seed)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, T, H, hd))
               for i in range(3))
    segs = [("text", 0, T // 4), ("mod", 1, T // 4), ("text", 0, T // 4),
            ("mod", 2, T // 8), ("text", 0, T - 7 * (T // 8))]
    bits_np, pos_np = bam.build_sample_bits(segs, T)
    bits = jnp.broadcast_to(jnp.asarray(bits_np)[None], (B, T))
    pos = jnp.broadcast_to(jnp.asarray(pos_np)[None], (B, T))
    return q, k, v, bits, pos, bits_np, pos_np


@pytest.mark.parametrize("method", ["allgather", "ring"])
def test_cp_single_rank_equals_sdpa(method):
    q, k, v, bits, pos, *_ = make_case()
    mask = bam.allowed_mask(bits, bits, pos, pos)[:, None]
    ref = sdpa(q, k, v, mask)
    mesh = jax.make_mesh((1,), ("cp",))
    out = cp.cp_attention(mesh, "cp", q, k, v, bits, bits, pos, pos,
                          method=method)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


@pytest.mark.parametrize("method", ["allgather", "ring"])
def test_cp_kernel_stats_path_equals_reference(method):
    """CP bodies on the Pallas stats kernel (impl="bam_interpret"):
    the per-step [B,H,Tq,Tk] logits never materialize, the combined
    output must still equal the dense oracle."""
    q, k, v, bits, pos, *_ = make_case()
    ref = cp.cp_reference(q, k, v, bits, bits, pos, pos)
    mesh = jax.make_mesh((1,), ("cp",))
    out = cp.cp_attention(mesh, "cp", q, k, v, bits, bits, pos, pos,
                          method=method, impl="bam_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_cp_reference_equals_sdpa():
    q, k, v, bits, pos, *_ = make_case(1)
    mask = bam.allowed_mask(bits, bits, pos, pos)[:, None]
    np.testing.assert_allclose(
        np.asarray(cp.cp_reference(q, k, v, bits, bits, pos, pos)),
        np.asarray(sdpa(q, k, v, mask)), atol=2e-6)


def test_plan_permutation_roundtrip():
    _, _, _, _, _, bits_np, pos_np = make_case(2)
    plan = dist.plan_tokens(bits_np, pos_np, 4, block_size=8, method="lpt")
    perm = cp.plan_permutation(plan, 64)
    inv = cp.invert_perm(perm)
    x = np.arange(64)
    np.testing.assert_array_equal(x[perm][inv], x)
    assert sorted(perm) == list(range(64))


@pytest.mark.parametrize("method", ["allgather", "ring"])
@pytest.mark.parametrize("planner", ["lpt", "zigzag", "random"])
def test_cp_multirank_equivalence(method, planner):
    """4 CP ranks × every planner must reproduce full attention exactly
    (the distribution is a permutation, never an approximation)."""
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import bam, context_parallel as cp, distribution as dist
from repro.models.layers import sdpa
B, T, H, hd = 2, 64, 4, 16
key = jax.random.PRNGKey(0)
q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, T, H, hd))
           for i in range(3))
segs = [("text", 0, 16), ("mod", 1, 16), ("text", 0, 16), ("mod", 2, 8),
        ("text", 0, 8)]
bits_np, pos_np = bam.build_sample_bits(segs, T)
bits = jnp.broadcast_to(jnp.asarray(bits_np)[None], (B, T))
pos = jnp.broadcast_to(jnp.asarray(pos_np)[None], (B, T))
mask = bam.allowed_mask(bits, bits, pos, pos)[:, None]
ref = sdpa(q, k, v, mask)
plan = dist.plan_tokens(bits_np, pos_np, 4, block_size=8,
                        method={planner!r})
perm = cp.plan_permutation(plan, T)
inv = cp.invert_perm(perm)
mesh = jax.make_mesh((4,), ("cp",))
args = [jnp.take(a, perm, axis=1) for a in (q, k, v)]
bp = jnp.take(bits, perm, axis=1); pp_ = jnp.take(pos, perm, axis=1)
out = cp.cp_attention(mesh, "cp", *args, bp, bp, pp_, pp_,
                      method={method!r})
out = jnp.take(out, inv, axis=1)
d = float(jnp.abs(out - ref).max())
assert d < 5e-6, d
print("OK", d)
"""
    out = run_with_devices(code, 4)
    assert "OK" in out


@pytest.mark.parametrize("method", ["allgather", "ring"])
def test_cp_multirank_kernel_stats_path(method):
    """Multi-rank CP on the kernel stats path: ring-step / all-gather
    combination of Pallas partials reproduces full attention."""
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import bam, context_parallel as cp, distribution as dist
B, T, H, hd = 1, 64, 2, 16
key = jax.random.PRNGKey(0)
q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, T, H, hd))
           for i in range(3))
segs = [("text", 0, 16), ("mod", 1, 16), ("text", 0, 16), ("mod", 2, 8),
        ("text", 0, 8)]
bits_np, pos_np = bam.build_sample_bits(segs, T)
bits = jnp.broadcast_to(jnp.asarray(bits_np)[None], (B, T))
pos = jnp.broadcast_to(jnp.asarray(pos_np)[None], (B, T))
ref = cp.cp_reference(q, k, v, bits, bits, pos, pos)
plan = dist.plan_tokens(bits_np, pos_np, 2, block_size=8, method="lpt")
perm = cp.plan_permutation(plan, T)
inv = cp.invert_perm(perm)
mesh = jax.make_mesh((2,), ("cp",))
args = [jnp.take(a, perm, axis=1) for a in (q, k, v)]
bp = jnp.take(bits, perm, axis=1); pp_ = jnp.take(pos, perm, axis=1)
out = cp.cp_attention(mesh, "cp", *args, bp, bp, pp_, pp_,
                      method={method!r}, impl="bam_interpret")
out = jnp.take(out, inv, axis=1)
d = float(jnp.abs(out - ref).max())
assert d < 2e-5, d
print("OK", d)
"""
    out = run_with_devices(code, 2)
    assert "OK" in out


def test_rank_workload_balance_lpt_vs_zigzag():
    """The §6.5 claim at planner level: LPT's max-rank workload is no
    worse than zigzag's on multimodal masks (usually strictly better)."""
    from repro.data.synthetic import random_multimodal_bits
    worse = 0
    for seed in range(6):
        bits, pos = random_multimodal_bits(2048, "ee", seed=seed)
        pl_l = dist.plan_tokens(bits, pos, 8, 32, method="lpt")
        pl_z = dist.plan_tokens(bits, pos, 8, 32, method="zigzag")
        l_max = cp.simulate_rank_workloads(pl_l, bits, pos).max()
        z_max = cp.simulate_rank_workloads(pl_z, bits, pos).max()
        if l_max > z_max + 1e-6:
            worse += 1
    assert worse == 0
