"""Sharding-rule tests: divisibility-aware candidate selection for
every assigned architecture against the production mesh geometry
(no devices needed — specs are pure functions of shapes)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, get_config, list_archs
from repro.launch import sharding as shd
from repro.launch import specs as S


class FakeMesh:
    """Geometry-only stand-in for the (16,16)/(2,16,16) meshes."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.zeros(shape)


MESH_1POD = FakeMesh((16, 16), ("data", "model"))
MESH_2POD = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def axis_size(mesh, entry):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for e in entry:
            n *= sizes[e]
        return n
    return sizes[entry]


def check_divisible(tree_specs, tree_shapes, mesh):
    flat_sp = jax.tree.leaves(tree_specs,
                              is_leaf=lambda x: isinstance(x, P))
    flat_sh = jax.tree.leaves(tree_shapes)
    assert len(flat_sp) == len(flat_sh)
    for spec, leaf in zip(flat_sp, flat_sh):
        for dim, entry in zip(leaf.shape[len(leaf.shape) - len(spec):],
                              spec):
            n = axis_size(mesh, entry)
            assert dim % n == 0, (spec, leaf.shape)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("mesh", [MESH_1POD, MESH_2POD],
                         ids=["1pod", "2pod"])
def test_param_specs_divisible(arch, mesh):
    cfg = get_config(arch)
    p_spec = S.param_specs(cfg)
    specs = shd.param_pspecs(p_spec, mesh)
    check_divisible(specs, p_spec, mesh)


@pytest.mark.parametrize("arch", ["starcoder2-7b", "qwen2-moe-a2.7b",
                                  "zamba2-2.7b", "xlstm-125m"])
@pytest.mark.parametrize("shape", ["decode_32k"])
def test_cache_specs_divisible(arch, shape):
    cfg = get_config(arch)
    c_spec = S.cache_specs(cfg, SHAPES[shape])
    rules = shd.Rules(seq_parallel=False)
    specs = shd.cache_pspecs(rules, c_spec, MESH_1POD)
    check_divisible(specs, c_spec, MESH_1POD)


def test_moe_expert_fallback_to_tp():
    """60 unpadded experts % 16 != 0 -> falls back to TP-within-expert;
    the shipped config pads to 64 (expert-parallel, next test)."""
    from repro.configs.base import MoEConfig
    import dataclasses
    cfg = get_config("qwen2-moe-a2.7b")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, expert_pad_to=0))
    p_spec = S.param_specs(cfg)
    specs = shd.param_pspecs(p_spec, MESH_1POD)
    wg = specs["layers"]["mlp"]["w_gate"]     # [L, E, d, f]
    assert wg == P(None, None, None, "model")  # f=1408 sharded


def test_moe_expert_pad_enables_ep():
    """expert_pad_to=64 (shipped qwen2-moe config) -> expert-parallel."""
    cfg = get_config("qwen2-moe-a2.7b")
    assert cfg.moe.num_experts_padded == 64
    p_spec = S.param_specs(cfg)
    specs = shd.param_pspecs(p_spec, MESH_1POD)
    assert specs["layers"]["mlp"]["w_gate"] == P(None, "model", None, None)


def test_moe_expert_parallel_when_divisible():
    cfg = get_config("deepseek-moe-16b")      # 64 experts % 16 == 0
    p_spec = S.param_specs(cfg)
    specs = shd.param_pspecs(p_spec, MESH_1POD)
    wg = specs["layers"]["mlp"]["w_gate"]
    assert wg == P(None, "model", None, None)  # expert-parallel


def test_whisper_vocab_fallback():
    """51865 % 16 != 0 -> embedding shards d_model instead."""
    cfg = get_config("whisper-base")
    p_spec = S.param_specs(cfg)
    specs = shd.param_pspecs(p_spec, MESH_1POD)
    assert specs["embed"] == P(None, "model")  # d=512 sharded, not vocab


def test_kv_cache_seq_fallback_for_narrow_gqa():
    """kv heads 4 % 16 != 0 -> cache seq dim takes the model axis."""
    cfg = get_config("starcoder2-7b")
    c_spec = S.cache_specs(cfg, SHAPES["decode_32k"])
    specs = shd.cache_pspecs(shd.Rules(seq_parallel=False), c_spec,
                             MESH_1POD)
    assert specs["k"] == P(None, ("data",), "model", None, None)


def test_long500k_cache_seq_over_data():
    cfg = get_config("zamba2-2.7b")
    c_spec = S.cache_specs(cfg, SHAPES["long_500k"])
    rules = shd.Rules(seq_parallel=False, shard_cache_seq=True)
    specs = shd.cache_pspecs(rules, c_spec, MESH_1POD)
    assert specs["attn_k"][2] in ("data", ("data",))


def test_zero_opt_sharding():
    cfg = get_config("qwen3-1.7b")
    p_spec = S.param_specs(cfg)
    base = shd.opt_state_pspecs(shd.Rules(), p_spec, MESH_1POD)
    zero = shd.opt_state_pspecs(shd.Rules(zero_sharded_opt=True), p_spec,
                                MESH_1POD)
    # ZeRO shards the first replicated dim that divides (L=28 does not
    # divide 16, so the d_model dim takes the data axis)
    w = zero["layers"]["attn"]["wq"]
    assert any(e in ("data", ("data",)) for e in w)
    assert not any(e in ("data", ("data",))
                   for e in base["layers"]["attn"]["wq"])


def test_constrain_residual_noop_without_rules():
    x = jnp.ones((2, 4, 8))
    shd.set_rules(None)
    y = shd.constrain_residual(x)
    assert y.shape == x.shape


@pytest.mark.parametrize("arch", list_archs())
def test_input_specs_constructible(arch):
    cfg = get_config(arch)
    for name, sh in SHAPES.items():
        if sh.kind in ("train", "prefill"):
            b = S.train_input_specs(cfg, sh)
            assert b["tokens"].shape == (sh.global_batch, sh.seq_len)
        else:
            b = S.decode_input_specs(cfg, sh)
            assert b["tokens"].shape == (sh.global_batch, 1)
