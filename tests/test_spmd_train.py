"""Real-model SPMD training (repro.models.stages + launch/train
--spmd): the MLLM partitioned into typed per-stage callables must
compute — through the sequential replay AND the distributed shard_map
runner — exactly what the single-process ``make_mllm_train_step``
trainer computes, train only what the freeze config says is trainable,
and round-trip checkpoints across spmd/replay modes.

Multi-device tests re-exec themselves in a subprocess with a forced
host device count (tests/helpers.subprocess_test); under the
multi-device CI job (global XLA_FLAGS) they run in-process."""
import argparse
import functools
import json
import warnings

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import schedule as sch
from repro.core.modality_parallel import execute_schedule
from repro.data.synthetic import MultimodalDataset
from repro.optim import optimizer as opt
from repro.training import steps

from .helpers import subprocess_test

TEXT = 16
M = 2
BATCH = 2

LAUNCH_ARGS = ["--mllm", "vlm", "--reduced", "--steps", "4",
               "--seq", str(TEXT), "--batch", str(BATCH),
               "--microbatches", str(M), "--plan-devices", "3",
               "--log-every", "0"]


@functools.lru_cache(maxsize=None)
def tiny_case():
    """A real (reduced) VLM + a searched plan + its SPMD executor
    contract — the fixture every test here partitions. Cached per
    process: the plan search and stage build are deterministic."""
    from repro.models.mllm import build_paper_mllm
    from repro.parallel import ClusterSpec, WorkloadShape, parallelize
    mllm = build_paper_mllm("vlm", reduced=True, text_len=TEXT)
    plan = parallelize(
        mllm, ClusterSpec(num_devices=3),
        WorkloadShape(text_len=TEXT, num_microbatches=M,
                      microbatch_size=1, block_size=8))
    ex = plan.apply(mllm, text_len=TEXT, mode="spmd")
    return mllm, plan, ex


def tiny_batch(mllm, seed=0):
    ds = MultimodalDataset(
        vocab_size=mllm.llm_cfg.vocab_size, text_len=TEXT,
        batch_size=BATCH,
        encoder_dims={n: e.cfg.d_model
                      for n, e in mllm.encoders.items()},
        encoder_tokens={n: e.num_tokens
                        for n, e in mllm.encoders.items()},
        modality_ids={n: e.modality_id
                      for n, e in mllm.encoders.items()},
        seed=seed)
    return next(iter(ds))


def reference_loss_grads(mllm, params, batch):
    """The single-process oracle: full-batch mean CE + autodiff grads
    from ``make_mllm_train_step``'s loss_fn."""
    _, loss_fn = steps.make_mllm_train_step(mllm, opt.AdamWConfig())
    (loss, _aux), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, batch)
    return float(loss), grads


def assert_frozen_zero(bundle, stage_grads):
    """Every leaf the frozen masks mark must be EXACTLY zero — frozen
    modules get no grads by schedule construction, not by masking."""
    masks = bundle.frozen_masks(stage_grads)
    checked = [0]

    def chk(m, g):
        if m:
            checked[0] += 1
            assert not np.asarray(g).any()
    for mk, gr in zip(masks, stage_grads):
        jax.tree.map(chk, mk, gr)
    assert checked[0] > 0          # the masks are not vacuous


# ---------------------------------------------------------------------------
# stage bundle contract (single device)
# ---------------------------------------------------------------------------

def test_stage_bundle_partition_roundtrip():
    """partition/unpartition is an exact bijection, stage specs tile
    the model, and trainable flags agree with the frozen masks."""
    mllm, _plan, ex = tiny_case()
    bundle = ex["stage_bundle"]
    assert len(bundle.specs) == len(ex["sim_graph"].stages)
    params = mllm.init(jax.random.PRNGKey(0))
    sp = bundle.partition(params)
    back = bundle.unpartition(sp)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), params, back)
    # the paper's freeze config: something trains (projectors),
    # something doesn't (encoder modules + LLM)
    assert any(bundle.trainable) and not all(bundle.trainable)
    masks = bundle.frozen_masks(sp)
    for s, mk in enumerate(masks):
        all_frozen = all(jax.tree.leaves(mk))
        assert bundle.trainable[s] == (not all_frozen)


def test_replay_matches_single_process_trainer():
    """Tentpole oracle, sequential half: the stage fns replayed
    through ``execute_schedule`` reproduce the single-process
    trainer's loss and grads (scaled by 1/M), with frozen-module
    grads exactly zero."""
    mllm, _plan, ex = tiny_case()
    bundle = ex["stage_bundle"]
    params = mllm.init(jax.random.PRNGKey(0))
    batch = tiny_batch(mllm)
    ref_loss, ref_grads = reference_loss_grads(mllm, params, batch)

    sp = bundle.partition(params)
    mbs = bundle.encode_microbatches(batch, M)
    res = execute_schedule(bundle.stage_fns, sp, mbs,
                           ex["sim_graph"], ex["schedule"],
                           microbatch_loss=bundle.microbatch_loss,
                           trainable=list(bundle.trainable))
    np.testing.assert_allclose(float(res["loss"]) / M, ref_loss,
                               rtol=2e-5)
    stage_grads = [jax.tree.map(lambda g: g / M, gs)
                   for gs in res["param_grads"]]
    assert_frozen_zero(bundle, stage_grads)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-6),
        bundle.unpartition(stage_grads), ref_grads)


def test_encode_microbatches_rejects_indivisible_batch():
    mllm, _plan, ex = tiny_case()
    batch = tiny_batch(mllm)
    with pytest.raises(ValueError, match="divisible"):
        ex["stage_bundle"].encode_microbatches(batch, 3)


# ---------------------------------------------------------------------------
# distributed runner + train step (multi-device)
# ---------------------------------------------------------------------------

@subprocess_test(3)
def test_spmd_runner_trains_real_mllm():
    """Tentpole oracle, distributed half: the shard_map runner on the
    real stage partition matches the single-process trainer, and one
    ``make_spmd_train_step`` update moves ONLY the trainable params."""
    from repro.parallel.spmd import build_spmd_runner, mesh_from_plan
    mllm, plan, ex = tiny_case()
    bundle = ex["stage_bundle"]
    D = int(ex["schedule"]["num_devices"])
    mesh = mesh_from_plan(plan, mllm, D)
    params = mllm.init(jax.random.PRNGKey(0))
    batch = tiny_batch(mllm)
    ref_loss, ref_grads = reference_loss_grads(mllm, params, batch)

    sp = bundle.partition(params)
    mbs = bundle.encode_microbatches(batch, M)
    runner = build_spmd_runner(
        bundle.stage_fns, ex["sim_graph"], ex["schedule"], mesh=mesh,
        microbatch_loss=bundle.microbatch_loss,
        program=ex["spmd_program"], trainable=list(bundle.trainable))
    res = runner(sp, mbs)
    np.testing.assert_allclose(float(res["loss"]) / M, ref_loss,
                               rtol=2e-5)
    stage_grads = [jax.tree.map(lambda g: g / M, gs)
                   for gs in res["param_grads"]]
    assert_frozen_zero(bundle, stage_grads)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-6),
        bundle.unpartition(stage_grads), ref_grads)

    # one optimizer step through the full distributed path
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    masks = bundle.frozen_masks(sp)
    step = steps.make_spmd_train_step(
        bundle.stage_fns, ex["sim_graph"], ex["schedule"], ocfg,
        mesh=mesh, microbatch_loss=bundle.microbatch_loss,
        frozen_mask=masks, trainable=list(bundle.trainable),
        grad_scale=1.0 / M, program=ex["spmd_program"])
    state = opt.init(ocfg, sp, masks)
    new_sp, _state, metrics = step(sp, state, mbs)
    np.testing.assert_allclose(float(metrics["loss"]), ref_loss,
                               rtol=2e-5)
    moved = [0]

    def check_move(m, a, b):
        if m:        # frozen: bit-identical
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        elif np.asarray(a).size and not np.array_equal(
                np.asarray(a), np.asarray(b)):
            moved[0] += 1
    for mk, old, new in zip(masks, sp, new_sp):
        jax.tree.map(check_move, mk, old, new)
    assert moved[0] > 0            # the projectors actually trained


@subprocess_test(4)
def test_rolled_dispatch_matches_switch_dispatch():
    """The compacted rolled loop and the unrolled switch program are
    the same executor: identical trace/peaks, equal loss and grads."""
    from repro.parallel.spmd import run_schedule_spmd, toy_stage_model
    stages = [sch.Stage(f"s{i}", 1.0, 2.0, bwd_w=1.0) for i in range(4)]
    g = sch.chain_graph(stages)
    sim = sch.get_scheduler("zb-h1").simulate(g, 8)
    fn, params = toy_stage_model(4, 16)
    mbs = jax.random.normal(jax.random.PRNGKey(7), (8, 1, 4, 16))
    rolled = run_schedule_spmd(fn, params, mbs, g, sim,
                               dispatch="rolled")
    switch = run_schedule_spmd(fn, params, mbs, g, sim,
                               dispatch="switch")
    np.testing.assert_allclose(float(rolled["loss"]),
                               float(switch["loss"]), rtol=1e-6)
    assert rolled["activation_trace"] == switch["activation_trace"]
    assert rolled["peak_activations_per_device"] == \
        switch["peak_activations_per_device"]
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        rolled["param_grads"], switch["param_grads"])


@subprocess_test(3)
def test_run_schedule_spmd_toy_fallback_is_explicit():
    """Satellite contract: ``stage_fn=None`` on the plan form warns
    that the TOY model (not the MLLM) will run; ``stage_fn="toy"``
    opts in silently."""
    from repro.parallel.spmd import run_schedule_spmd
    mllm, plan, ex = tiny_case()
    n_mb = int(plan.schedule.num_microbatches)
    mbs = jax.random.normal(jax.random.PRNGKey(5), (n_mb, 1, 4, 16))
    with pytest.warns(UserWarning, match="TOY stage model"):
        run_schedule_spmd(plan, mllm, mbs)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        got = run_schedule_spmd(plan, mllm, mbs, stage_fn="toy")
    assert np.isfinite(float(got["loss"]))


# ---------------------------------------------------------------------------
# launch-level: --spmd trains the real model, loss-matches the
# single-process path, and resumes bit-exactly (tier1-multidevice)
# ---------------------------------------------------------------------------

@subprocess_test(3)
def test_launch_spmd_matches_replay_and_resumes(tmp_path):
    """``launch/train --spmd`` end to end: per-step losses match the
    non-spmd run of the same seed/stream, and a crash + ``--resume``
    reproduces the uninterrupted run's tail losses exactly."""
    from repro.launch.train import main
    ref = main(LAUNCH_ARGS)
    full = main(LAUNCH_ARGS + ["--spmd", "--ckpt-dir",
                               str(tmp_path / "a"), "--ckpt-every", "2"])
    np.testing.assert_allclose(np.asarray(ref["losses"]),
                               np.asarray(full["losses"]),
                               rtol=2e-4, atol=1e-5)
    fp = tmp_path / "faults.json"
    fp.write_text(json.dumps([{"kind": "crash", "step": 3}]))
    from repro.resilience.faults import CrashInjected
    with pytest.raises(CrashInjected):
        main(LAUNCH_ARGS + ["--spmd", "--ckpt-dir",
                            str(tmp_path / "b"), "--ckpt-every", "2",
                            "--fault-plan", str(fp)])
    rest = main(LAUNCH_ARGS + ["--spmd", "--ckpt-dir",
                               str(tmp_path / "b"), "--resume"])
    full_losses = full["resilience"]["losses"]
    rest_losses = rest["resilience"]["losses"]
    assert rest_losses                       # it actually resumed
    for s, v in rest_losses.items():
        assert abs(full_losses[s] - v) < 1e-6, (s, full_losses[s], v)


@subprocess_test(3)
def test_launch_cross_mode_resume(tmp_path):
    """A replay-mode checkpoint resumes an ``--spmd`` run (params
    re-partitioned through the StageBundle) and the resulting spmd
    checkpoint resumes a replay run — both continue at the saved
    step, never restart."""
    from repro.launch.train import main
    ck = str(tmp_path / "x")
    short = [a if a != "4" else "2" for a in LAUNCH_ARGS]
    main(short + ["--ckpt-dir", ck, "--ckpt-every", "1"])
    up = main(LAUNCH_ARGS + ["--spmd", "--ckpt-dir", ck, "--resume"])
    assert sorted(up["resilience"]["losses"]) == [2, 3]
    back = main([a if a != "4" else "6" for a in LAUNCH_ARGS]
                + ["--ckpt-dir", ck, "--resume"])
    assert sorted(back["resilience"]["losses"]) == [4, 5]


# ---------------------------------------------------------------------------
# the lint gate guards the --spmd resolve path (single device)
# ---------------------------------------------------------------------------

def test_resolve_plan_lint_gate_blocks_corrupt_program(monkeypatch):
    """Satellite contract: a corrupted wave program (comm rounds
    stripped, so cross-device recvs are never delivered) must die in
    ``resolve_plan``'s schedlint gate before any device is touched."""
    from repro.launch.train import resolve_plan
    from repro.parallel import MLLMParallelPlan
    mllm, _plan, _ex = tiny_case()
    orig = MLLMParallelPlan.apply

    def corrupt(self, target, **kw):
        ex = orig(self, target, **kw)
        for wave in ex["spmd_program"].waves:
            wave.rounds = []
        return ex
    monkeypatch.setattr(MLLMParallelPlan, "apply", corrupt)
    ns = argparse.Namespace(
        plan=None, plan_out=None, plan_devices=3, cp_size=1,
        microbatches=M, batch=BATCH, seq=TEXT, spmd=True, lint=True)
    with pytest.raises(SystemExit, match="schedule lint"):
        resolve_plan(mllm, ns)
