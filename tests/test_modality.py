"""MultimodalModule / ModalityModule composition tests: execution DAG,
merge policy, frozen masking, callbacks (paper §3.2, Listing 1/2)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bam
from repro.core.modality import (ModalityModule, MultimodalModule,
                                 MultimodalParallelSpec, ParallelSpec)
from repro.models.mllm import build_paper_mllm
from repro.optim import optimizer as opt
from repro.training import steps


@pytest.fixture(scope="module")
def valm():
    return build_paper_mllm("valm", reduced=True)


@pytest.fixture(scope="module")
def valm_params(valm):
    return valm.init(jax.random.PRNGKey(0))


def make_batch(valm, seed=0, B=2, Tt=64):
    rng = np.random.default_rng(seed)
    batch = {
        "text_tokens": jnp.asarray(
            rng.integers(0, valm.llm_cfg.vocab_size, (B, Tt)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, valm.llm_cfg.vocab_size, (B, Tt)), jnp.int32),
    }
    for name, enc in valm.encoders.items():
        batch[f"{name}_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, enc.num_tokens, enc.cfg.d_model)),
            jnp.float32)
    return batch


def test_execution_dag_no_false_deps(valm):
    g = valm.execution_graph()
    assert not g.has_edge("vision", "audio")
    assert not g.has_edge("audio", "vision")
    assert g.has_edge("vision", "llm") and g.has_edge("audio", "llm")
    gens = valm.independent_sets()
    assert gens[0] == ["audio", "vision"]   # parallel-executable antichain
    assert gens[1] == ["llm"]


def test_merge_layout_and_bits(valm, valm_params):
    batch = make_batch(valm)
    (_, _), merged = valm.forward(valm_params, batch)
    B, Tm = merged["tokens"].shape
    assert Tm == valm.merged_length(64)
    bits = np.asarray(merged["bits"][0])
    # modality ids present exactly num_tokens times each
    mods = (bits >> bam.MOD_SHIFT) & 0x7F
    for name, enc in valm.encoders.items():
        assert (mods == enc.modality_id).sum() == enc.num_tokens
    # embed_mask marks exactly the modality positions
    emask = np.asarray(merged["embed_mask"][0])
    np.testing.assert_array_equal(emask, mods != bam.TEXT)
    # text tokens preserved in order
    toks = np.asarray(merged["tokens"][0])[mods == bam.TEXT]
    np.testing.assert_array_equal(toks, np.asarray(batch["text_tokens"][0]))


def test_frozen_mask_matches_flags(valm, valm_params):
    mask = valm.frozen_mask(valm_params)
    assert all(jax.tree.leaves(mask["llm"]))
    assert all(jax.tree.leaves(mask["encoders"]["vision"]["module"]))
    assert not any(jax.tree.leaves(mask["encoders"]["vision"]["projector"]))


def test_frozen_grads_exactly_zero(valm, valm_params):
    batch = make_batch(valm, seed=3)
    _, loss_fn = steps.make_mllm_train_step(valm)
    grads = jax.grad(lambda p: loss_fn(p, batch)[0])(valm_params)
    enc_g = jax.tree.leaves(grads["encoders"]["vision"]["module"])
    assert max(float(jnp.abs(g).max()) for g in enc_g) == 0.0
    llm_g = jax.tree.leaves(grads["llm"])
    assert max(float(jnp.abs(g).max()) for g in llm_g) == 0.0
    proj_g = jax.tree.leaves(grads["encoders"]["vision"]["projector"])
    assert max(float(jnp.abs(g).max()) for g in proj_g) > 0.0


def test_train_step_updates_only_trainable(valm, valm_params):
    batch = make_batch(valm, seed=4)
    step, _ = steps.make_mllm_train_step(
        valm, opt.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10))
    fmask = valm.frozen_mask(valm_params)
    state = opt.init(opt.AdamWConfig(), valm_params, fmask)
    p2, _, metrics = jax.jit(step)(valm_params, state, batch)
    # frozen llm unchanged bit-for-bit
    for a, b in zip(jax.tree.leaves(p2["llm"]),
                    jax.tree.leaves(valm_params["llm"])):
        assert float(jnp.abs(a - b).max()) == 0.0
    # projector moved
    moved = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree.leaves(p2["encoders"]["vision"]["projector"]),
        jax.tree.leaves(valm_params["encoders"]["vision"]["projector"])))
    assert moved > 0.0
    # frozen leaves carry no optimizer memory
    frozen_m = jax.tree.leaves(state["m"]["llm"])
    assert all(x.size == 0 for x in frozen_m)


def test_callbacks_call_order():
    calls = []
    enc_cfg = build_paper_mllm("vlm", reduced=True).encoders["vision"].cfg

    def cb_pre(inputs):
        calls.append("pre")
        return inputs

    def cb_post_mod(inputs, out):
        calls.append("post_mod")
        return out

    def cb_post_proj(inputs, out):
        calls.append("post_proj")
        return out

    enc = ModalityModule("vision", enc_cfg, modality_id=1, num_tokens=16,
                         preprocess_callback=cb_pre,
                         postprocess_module_callback=cb_post_mod,
                         postprocess_projector_callback=cb_post_proj)
    params = enc.init(jax.random.PRNGKey(0), llm_d_model=256)
    enc.forward(params, {"vision_embeds": jnp.ones((1, 16, enc_cfg.d_model))})
    assert calls == ["pre", "post_mod", "post_proj"]


def test_parallel_spec_apply(valm):
    spec = MultimodalParallelSpec(
        encoder_specs={"vision": ParallelSpec(pp_size=1),
                       "audio": ParallelSpec(pp_size=2)},
        llm_spec=ParallelSpec(pp_size=2), num_microbatches=6)
    plan = spec.apply(valm, text_len=64)
    assert plan["devices"] == 5
    assert len(plan["graph"].stages) == 5
    assert plan["schedule"]["iteration_time"] > 0


def test_modality_id_uniqueness_enforced():
    cfg = build_paper_mllm("vlm", reduced=True).encoders["vision"].cfg
    with pytest.raises(AssertionError):
        MultimodalModule(
            encoders={
                "a": ModalityModule("a", cfg, modality_id=1, num_tokens=4),
                "b": ModalityModule("b", cfg, modality_id=1, num_tokens=4),
            },
            llm_cfg=build_paper_mllm("vlm", reduced=True).llm_cfg)
