"""repro.analysis: every rule must catch its seeded violation and stay
quiet on the real repo.

The seeded mutations are the falsifiability half of the subsystem: a
valid schedule is doctored one invariant at a time (B before F, two
items overlapping on a device, a W pass on a frozen stage, a
program-order inversion that cross-waits two devices) and the matching
rule — and only a relevant set of rules — must fire. The kernel lint
rules get deliberately-bad source snippets; jaxprlint gets the XLA
attention path as its tripping control (see test_kernels /
test_context_parallel for the kernel-side controls, which import the
promoted helpers from here)."""
import dataclasses
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import __main__ as cli
from repro.analysis import entrypoints, jaxprlint, kernellint, schedlint
from repro.analysis.findings import (Finding, RULES, Severity,
                                     filter_findings, finding, gate)
from repro.core import schedule as sch
from repro.core.modality_parallel import execute_schedule
from repro.core.schedule.memory import (MemoryModelMismatch,
                                        diff_activation_traces,
                                        simulated_activation_trace,
                                        validate_schedule_memory)
from repro.core.schedule.simulator import item_id

M = 4


def two_stage(frozen_head=False):
    return sch.chain_graph(
        [sch.Stage("enc", 1.0, 0.0) if frozen_head
         else sch.Stage("s0", 1.0, 2.0, bwd_w=1.0),
         sch.Stage("s1", 1.0, 2.0, bwd_w=1.0)])


def sim_of(schedule="zb-h1", frozen_head=False):
    g = two_stage(frozen_head)
    return g, sch.get_scheduler(schedule).simulate(g, M)


def rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# findings spine
# ---------------------------------------------------------------------------

def test_finding_requires_registered_rule():
    with pytest.raises(KeyError):
        finding("not-a-rule", "here", "boom")


def test_filter_rejects_unknown_rule_ids():
    fs = [finding("fbw-order", "x", "y")]
    assert filter_findings(fs, ["fbw-order"]) == fs
    assert filter_findings(fs, ["device-overlap"]) == []
    with pytest.raises(KeyError):
        filter_findings(fs, ["no-such-rule"])


def test_gate_severity_policy():
    err = finding("fbw-order", "x", "y")
    warn = finding("dtype-drift", "x", "y")   # WARNING by default
    info = Finding("fbw-order", Severity.INFO, "x", "y")
    assert gate([err]) and gate([err], strict=True)
    assert not gate([warn]) and gate([warn], strict=True)
    assert not gate([info]) and not gate([info], strict=True)


def test_item_id_format():
    assert item_id((0.0, 1.0, 3, "B", 2, 5)) == "B(s2,m5)@d3"


# ---------------------------------------------------------------------------
# schedlint: valid timelines are clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", sch.SCHEDULES)
@pytest.mark.parametrize("frozen_head", [False, True])
def test_all_schedulers_lint_clean(schedule, frozen_head):
    g = two_stage(frozen_head)
    if schedule in ("interleaved", "zb-v"):
        g = sch.refine_chain(g, 2)
        sim = sch.get_scheduler(schedule, virtual_chunks=2).simulate(g, M)
    else:
        sim = sch.get_scheduler(schedule).simulate(g, M)
    assert schedlint.lint_timeline(g, sim) == []


# ---------------------------------------------------------------------------
# schedlint: each seeded violation trips its rule
# ---------------------------------------------------------------------------

def _replace_item(items, match, **changes):
    """Replace the first item matching (kind, stage, mb)."""
    out = []
    done = False
    for it in items:
        s0, e0, dev, kind, s, m = it
        if not done and (kind, s, m) == match:
            d = {"start": s0, "end": e0, "dev": dev, "kind": kind,
                 "s": s, "m": m, **changes}
            it = (d["start"], d["end"], d["dev"], d["kind"], d["s"],
                  d["m"])
            done = True
        out.append(it)
    assert done, f"no item {match}"
    return out


def test_seeded_b_before_f_trips_fbw_order():
    g, sim = sim_of()
    f = next(it for it in sim["items"] if it[3:] == ("F", 1, 0))
    sim["items"] = _replace_item(sim["items"], ("B", 1, 0),
                                 start=f[0] - 2.0, end=f[0] - 1.0)
    assert "fbw-order" in rules_of(schedlint.lint_timeline(g, sim))


def test_seeded_overlap_trips_device_overlap():
    g, sim = sim_of()
    a = next(it for it in sim["items"] if it[3:] == ("F", 0, 0))
    # stretch the second item on the same device into the first
    sim["items"] = _replace_item(sim["items"], ("F", 0, 1),
                                 start=a[0] + 0.25 * (a[1] - a[0]))
    assert "device-overlap" in rules_of(schedlint.lint_timeline(g, sim))


def test_seeded_w_on_frozen_stage_trips_frozen_no_w():
    g, sim = sim_of(frozen_head=True)
    t = max(it[1] for it in sim["items"])
    sim["items"] = list(sim["items"]) + [(t, t + 1.0, 0, "W", 0, 0)]
    assert "frozen-no-w" in rules_of(schedlint.lint_timeline(g, sim))


def test_seeded_dropped_item_trips_missing_item():
    g, sim = sim_of()
    sim["items"] = [it for it in sim["items"]
                    if it[3:] != ("B", 0, 2)]
    found = schedlint.lint_timeline(g, sim)
    assert any(f.rule == "missing-item" and "B(s0,m2)" in f.location
               for f in found)


def test_seeded_claim_doctoring_trips_peak_claim():
    g, sim = sim_of()
    sim["peak_activations_per_device"] = \
        [p + 1 for p in sim["peak_activations_per_device"]]
    assert "peak-claim" in rules_of(schedlint.lint_timeline(g, sim))


def test_gpipe_style_timeline_trips_activation_cap():
    """All forwards before any backward overflows 1F1B's
    depth_from_end envelope ([2, 1] on a 2-stage chain) — the schedule
    memory-policy violation the rule exists for."""
    g = two_stage()
    items = []
    for m in range(M):                       # all F first
        items.append((float(m), m + 1.0, 0, "F", 0, m))
        items.append((m + 1.0, m + 2.0, 1, "F", 1, m))
    t = M + 2.0
    for m in range(M):                       # then all B
        items.append((t, t + 1.0, 1, "B", 1, m))
        items.append((t + 1.0, t + 2.0, 0, "B", 0, m))
        t += 2.0
    sim = {"items": items, "device_of": [0, 1]}
    found = schedlint.lint_timeline(g, sim)
    assert "activation-cap" in rules_of(found)
    assert rules_of(found) <= {"activation-cap"}


def test_seeded_cross_wait_trips_send_recv_cycle():
    """The classic 2-device cross-wait: dev0 blocks on a cotangent
    dev1 only produces after a forward dev0 has scheduled later. The
    async-send/blocking-recv lowering deadlocks; the lint finds the
    4-item cycle instead of hanging a job."""
    g = two_stage()
    items = [
        (0.0, 1.0, 0, "F", 0, 0),
        (1.0, 2.0, 0, "B", 0, 0),            # needs B(s1,m0) — not yet
        (2.0, 3.0, 0, "F", 0, 1),
        (1.0, 2.0, 1, "F", 1, 0),
        (3.0, 4.0, 1, "F", 1, 1),            # needs F(s0,m1)
        (4.0, 5.0, 1, "B", 1, 1),
        (5.0, 6.0, 1, "B", 1, 0),
        (6.0, 7.0, 0, "B", 0, 1),
    ]
    sim = {"items": items, "device_of": [0, 1]}
    found = schedlint.lint_timeline(g, sim)
    assert "send-recv-cycle" in rules_of(found)
    msg = next(f for f in found if f.rule == "send-recv-cycle").message
    assert "B(s0,m0)@d0" in msg and "B(s1,m0)@d1" in msg


# ---------------------------------------------------------------------------
# schedlint: plan-level
# ---------------------------------------------------------------------------

def test_golden_plan_lints_clean():
    from repro.parallel.plan import MLLMParallelPlan
    plan = MLLMParallelPlan.load(entrypoints.GOLDEN_PLAN)
    assert schedlint.lint_plan(plan) == []


def test_doctored_plan_trips_plan_consistency():
    from repro.parallel.plan import MLLMParallelPlan
    plan = MLLMParallelPlan.load(entrypoints.GOLDEN_PLAN)
    bad = dataclasses.replace(
        plan, schedule=dataclasses.replace(plan.schedule,
                                           bubble_fraction=1.5))
    assert "plan-consistency" in rules_of(schedlint.lint_plan(bad))
    bad2 = dataclasses.replace(
        plan, context=dataclasses.replace(
            plan.context,
            assignment=tuple(plan.context.assignment[:-1])
            + (plan.context.num_ranks + 3,)))
    assert "plan-consistency" in rules_of(schedlint.lint_plan(bad2))


# ---------------------------------------------------------------------------
# jaxprlint
# ---------------------------------------------------------------------------

def test_quadratic_f32_trips_on_materialized_scores():
    T = 64
    a = jnp.zeros((T, 8), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda x: jnp.sum(x @ x.T))(a)
    hits = jaxprlint.quadratic_f32(jaxpr, T)
    assert hits and any(shape == (T, T) for _p, shape, _d in hits)
    assert jaxprlint.check_no_quadratic_intermediate(jaxpr, T, "t")


def test_collect_avals_recurses_into_scan():
    def f(x):
        return jax.lax.scan(lambda c, _: (c + 1.0, c), x, None,
                            length=3)[0]
    jaxpr = jax.make_jaxpr(f)(jnp.zeros((4,)))
    prims = {p for p, _s, _d in jaxprlint.collect_avals(jaxpr)}
    assert "add" in prims                    # from inside the scan body


def test_peak_live_bytes_linear_chain():
    # x f32[1024] -> y = x*2 -> z = y*3: two adjacent values live at a
    # time, 2 * 4096 bytes
    jaxpr = jax.make_jaxpr(lambda x: (x * 2.0) * 3.0)(
        jnp.zeros((1024,), jnp.float32))
    assert jaxprlint.peak_live_bytes(jaxpr) == 2 * 4096
    assert jaxprlint.check_peak_live_bytes(jaxpr, "t",
                                           budget_bytes=100)
    assert jaxprlint.check_peak_live_bytes(jaxpr, "t",
                                           budget_bytes=1 << 20) == []
    info = jaxprlint.check_peak_live_bytes(jaxpr, "t")
    assert [f.severity for f in info] == [Severity.INFO]


def test_dtype_drift_threshold():
    big = jnp.zeros((256, 256), jnp.bfloat16)
    jaxpr = jax.make_jaxpr(lambda x: x.astype(jnp.float32))(big)
    assert jaxprlint.check_dtype_drift(jaxpr, "t")
    small = jnp.zeros((16,), jnp.bfloat16)
    jaxpr_s = jax.make_jaxpr(lambda x: x.astype(jnp.float32))(small)
    assert jaxprlint.check_dtype_drift(jaxpr_s, "t") == []
    # threshold is tunable
    assert jaxprlint.check_dtype_drift(jaxpr_s, "t", min_elements=8)


# ---------------------------------------------------------------------------
# kernellint: seeded-bad source snippets
# ---------------------------------------------------------------------------

BAD_ARITY = """
import jax.experimental.pallas as pl
out = pl.pallas_call(
    kern,
    grid=(2, 2),
    in_specs=[pl.BlockSpec((16, 16), lambda i: (i, 0))],
)
"""

BAD_RANK = """
import jax.experimental.pallas as pl
out = pl.pallas_call(
    kern,
    grid=(2, 2),
    in_specs=[pl.BlockSpec((16, 16), lambda i, j: (i, j, 0))],
)
"""

BAD_PREFETCH_ARITY = """
from jax.experimental.pallas import tpu as pltpu
spec = pltpu.PrefetchScalarGridSpec(
    num_scalar_prefetch=3,
    grid=(2, 2),
    in_specs=[pl.BlockSpec((16, 16), lambda i, j: (i, j))],
)
"""

GOOD_CAPTURE = """
import jax.experimental.pallas as pl
n_rep = 4
out = pl.pallas_call(
    kern,
    grid=(2, 2),
    in_specs=[pl.BlockSpec((16, 16),
                           lambda i, j, n_rep=n_rep: (i, j))],
)
"""

GOOD_NAMED = """
import jax.experimental.pallas as pl
def imap(i, j):
    return (i, 0)
out = pl.pallas_call(
    kern,
    grid=(2, 2),
    in_specs=[pl.BlockSpec((16, 16), imap)],
)
"""

NON_LITERAL_GRID = """
import jax.experimental.pallas as pl
out = pl.pallas_call(
    kern,
    grid=grid,
    in_specs=[pl.BlockSpec((16, 16), lambda i: (i,))],
)
"""


def test_bad_index_arity_trips():
    found = kernellint.lint_source(BAD_ARITY)
    assert rules_of(found) == {"blockspec-index-arity"}
    assert "expected 2" in found[0].message


def test_bad_rank_trips():
    found = kernellint.lint_source(BAD_RANK)
    assert rules_of(found) == {"blockspec-rank-mismatch"}


def test_prefetch_arity_counts_scalar_operands():
    found = kernellint.lint_source(BAD_PREFETCH_ARITY)
    assert rules_of(found) == {"blockspec-index-arity"}
    assert "expected 5" in found[0].message


def test_capture_default_args_and_named_maps_are_clean():
    assert kernellint.lint_source(GOOD_CAPTURE) == []
    assert kernellint.lint_source(GOOD_NAMED) == []


def test_non_literal_grid_is_skipped_not_guessed():
    assert kernellint.lint_source(NON_LITERAL_GRID) == []


def test_real_kernels_lint_clean():
    assert kernellint.lint_kernels() == []


def test_coverage_findings_catch_missing_tile():
    dense = np.ones((8, 8), bool)
    bm = types.SimpleNamespace(
        nq=2, nk=2,
        # q-major grid silently lacks the (1, 1) tile
        q_steps=((0, 0, 1, 0, 1), (0, 1, 0, 1, 1), (1, 0, 1, 1, 1)),
        k_steps=((0, 0, 1, 0, 1), (1, 0, 0, 1, 1), (0, 1, 1, 0, 1),
                 (1, 1, 0, 1, 1)))
    found = kernellint._coverage_findings(dense, bm, 4, 4, "seeded")
    assert any(f.rule == "block-map-coverage"
               and "q_block=1, k_block=1" in f.message for f in found)


# ---------------------------------------------------------------------------
# executor trace <-> memory-model diff (satellite: shared item ids)
# ---------------------------------------------------------------------------

def _toy(S):
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (S, 8, 8)) * 0.1}
    mbs = jax.random.normal(jax.random.fold_in(key, 1), (M, 1, 2, 8))
    return (lambda lp, x: x + jnp.tanh(x @ lp["w"])), params, mbs


def test_executor_trace_matches_simulated_walk():
    g, sim = sim_of("1f1b")
    fn, params, mbs = _toy(len(g.stages))
    res = execute_schedule(fn, params, mbs, g, sim)
    assert res["activation_trace"] == simulated_activation_trace(g, sim)
    assert res["activation_nbytes"] == 2 * 8 * 4


def test_trace_diff_names_first_diverging_item():
    """A duplicated F makes the model count 2 live activations where
    the executor's real store holds 1 (same key overwritten) — the
    diff pins the exact item, with bytes."""
    g, sim = sim_of("1f1b")
    items = list(sim["items"])
    i = next(j for j, it in enumerate(items) if it[3:] == ("F", 0, 0))
    items.insert(i + 1, items[i])
    sim["items"] = items
    fn, params, mbs = _toy(len(g.stages))
    res = execute_schedule(fn, params, mbs, g, sim)
    div = diff_activation_traces(simulated_activation_trace(g, sim),
                                 res["activation_trace"],
                                 res["activation_nbytes"])
    assert div is not None
    iid, sim_live, exe_live, sim_bytes, exe_bytes = div
    assert iid == "F(s0,m0)@d0"
    assert (sim_live, exe_live) == (2, 1)
    assert (sim_bytes, exe_bytes) == (2 * 64, 64)


def test_mismatch_carries_divergence_field():
    g = two_stage()
    sim = sch.get_scheduler("zb-h1").simulate(g, M)
    sim["peak_activations_per_device"] = \
        [p + 1 for p in sim["peak_activations_per_device"]]
    with pytest.raises(MemoryModelMismatch) as ei:
        validate_schedule_memory(g, M, "zb-h1", sim=sim)
    # claim-only doctoring: the timelines agree item-for-item
    assert ei.value.first_divergence is None
    assert "timelines agree" in str(ei.value)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_list_exits_zero(capsys):
    assert cli.main(["--list"]) == 0
    out = capsys.readouterr().out
    assert "kernels" in out and "fbw-order" in out


def test_cli_kernels_entrypoint_clean(capsys):
    assert cli.main(["--entrypoint", "kernels", "--strict"]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_reports_entrypoint_crash(monkeypatch, capsys):
    def boom():
        raise RuntimeError("kaboom")
    monkeypatch.setitem(entrypoints.ENTRYPOINTS, "kernels", boom)
    assert cli.main(["--entrypoint", "kernels"]) == 1
    assert "entrypoint-crash" in capsys.readouterr().out


def test_cli_rejects_unknown_rule():
    with pytest.raises(SystemExit):
        cli.main(["--entrypoint", "kernels", "--rule", "no-such-rule"])


# ---------------------------------------------------------------------------
# property sweep: auto_parallelize winners always lint clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("enc_layers,llm_layers,devices,mbs,frozen", [
    (2, 4, 2, 4, True),
    (4, 8, 4, 8, False),
    (1, 6, 3, 6, True),
    (3, 6, 4, 4, False),
])
def test_auto_parallelize_winners_lint_clean(enc_layers, llm_layers,
                                             devices, mbs, frozen):
    """Deterministic slice of the property test (the hypothesis-driven
    version lives in test_analysis_properties.py): whatever schedule
    auto_parallelize picks, its shipped timeline passes every schedlint
    rule."""
    from repro.core import pipeline as pp
    encs = [pp.ModuleProfile("enc", np.full(enc_layers, 1.0),
                             frozen=frozen)]
    llm = pp.ModuleProfile("llm", np.full(llm_layers, 2.0),
                           frozen=False)
    best = pp.auto_parallelize(encs, llm, devices, mbs)
    assert schedlint.lint_timeline(best["graph"], best) == []


# ---------------------------------------------------------------------------
# launcher gate: resolve_plan refuses a plan schedlint rejects
# ---------------------------------------------------------------------------

def test_resolve_plan_lint_gate(tmp_path):
    """The training launcher runs schedlint on the resolved plan before
    step 0: a doctored plan dies with the findings in the message, and
    --no-lint (args.lint=False) bypasses the gate."""
    import argparse
    import dataclasses

    from repro.launch.train import resolve_plan
    from repro.models.mllm import build_paper_mllm
    from repro.parallel import MLLMParallelPlan

    plan = MLLMParallelPlan.load(entrypoints.GOLDEN_PLAN)
    bad = dataclasses.replace(
        plan, schedule=dataclasses.replace(plan.schedule,
                                           bubble_fraction=1.5))
    path = tmp_path / "bad_plan.json"
    bad.save(str(path))
    mllm = build_paper_mllm("vlm", reduced=True, text_len=plan.text_len)
    args = argparse.Namespace(plan=str(path), plan_out=None,
                              seq=plan.text_len, lint=True)
    with pytest.raises(SystemExit, match="plan-consistency"):
        resolve_plan(mllm, args)
    args.lint = False
    got, _executor = resolve_plan(mllm, args)
    assert got.schedule.bubble_fraction == 1.5


# ---------------------------------------------------------------------------
# lint_spmd_program: the emitted wave/ppermute program (not the model)
# ---------------------------------------------------------------------------

def spmd_program(schedule="zb-h1"):
    import copy

    from repro.parallel.spmd import compile_spmd_program
    chunked = schedule in ("interleaved", "zb-v")
    g = sch.refine_chain(two_stage(), 2) if chunked else two_stage()
    kwargs = {"virtual_chunks": 2} if chunked else {}
    sim = sch.get_scheduler(schedule, **kwargs).simulate(g, M)
    return copy.deepcopy(compile_spmd_program(g, sim))


@pytest.mark.parametrize("schedule", sch.SCHEDULES)
def test_compiled_spmd_programs_lint_clean(schedule):
    """What compile_spmd_program emits for every scheduler passes its
    own static contract: legal ppermute rounds, fresh send buffers,
    every cross-device input delivered before use."""
    assert schedlint.lint_spmd_program(spmd_program(schedule)) == []


def _first_round(prog, kind="fwd"):
    for w, wave in enumerate(prog.waves):
        for rnd in wave.rounds:
            if rnd.kind == kind:
                return w, rnd
    raise AssertionError(f"no {kind} round emitted")


def test_seeded_late_round_trips_send_recv_cycle():
    """Delaying a delivery past its consumer's wave is the blocking
    recv that never unblocks — and the moved round now ships a stale
    buffer too."""
    prog = spmd_program()
    w, rnd = _first_round(prog)
    prog.waves[w].rounds.remove(rnd)
    prog.waves[w + 1].rounds.append(rnd)
    found = schedlint.lint_spmd_program(prog)
    assert "send-recv-cycle" in rules_of(found)
    msg = next(f for f in found
               if f.rule == "send-recv-cycle").message
    assert "never satisfied" in msg and "device" in msg


def test_seeded_early_round_trips_stale_send():
    """Hoisting a round to an earlier wave makes it ship whatever the
    source device computed THEN — a stale send buffer."""
    prog = spmd_program()
    w, rnd = _first_round(prog, kind="bwd")
    assert w > 0
    prog.waves[w].rounds.remove(rnd)
    prog.waves[w - 1].rounds.append(rnd)
    found = schedlint.lint_spmd_program(prog)
    assert "ppermute-program" in rules_of(found)
    assert any("stale send" in f.message for f in found)


def test_seeded_duplicate_destination_trips_ppermute_program():
    import dataclasses as dc
    prog = spmd_program()
    w, rnd = _first_round(prog)
    t = rnd.transfers[0]
    rnd.transfers.append(dc.replace(t, src_dev=t.src_dev + 1))
    found = schedlint.lint_spmd_program(prog)
    assert "ppermute-program" in rules_of(found)
    assert any("not a partial permutation" in f.message for f in found)


def test_seeded_self_send_trips_ppermute_program():
    prog = spmd_program()
    _w, rnd = _first_round(prog)
    rnd.transfers[0].dst_dev = rnd.transfers[0].src_dev
    found = schedlint.lint_spmd_program(prog)
    assert "ppermute-program" in rules_of(found)
    assert any("self-send" in f.message for f in found)


def test_executor_contract_carries_spmd_program_lint():
    """An SPMD-mode executor contract ships its compiled program, and
    lint_executor_contract statically validates the ACTUAL emitted
    rounds under the contract's location."""
    g, sim = sim_of("zb-h1")
    prog = spmd_program()
    executor = {"sim_graph": g, "schedule": sim, "spmd_program": prog}
    assert schedlint.lint_executor_contract(executor) == []
    w, rnd = _first_round(prog)
    prog.waves[w].rounds.remove(rnd)
    prog.waves[w + 1].rounds.append(rnd)
    found = schedlint.lint_executor_contract(executor)
    assert "send-recv-cycle" in rules_of(found)
    assert all(f.location.startswith("executor:spmd")
               for f in found)
