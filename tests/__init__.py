# Make tests/ a package so `from .helpers import ...` resolves under
# plain `python -m pytest` (no rootdir-dependent sys.path games).
