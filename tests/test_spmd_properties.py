"""Hypothesis property tests for the SPMD schedule executor: for ANY
random chain length, freeze pattern, microbatch count, and schedule in
``core.schedule.SCHEDULES``, the distributed shard_map execution must
match the single-device autodiff reference (loss and grads) and its
replayed per-device activation peaks must match the
``SchedulePlan``-style simulator claim exactly.

The whole property runs inside one multi-device (sub)process
(tests/helpers.subprocess_test): hypothesis drives the examples, the
forced host mesh supplies the devices. Skips cleanly where hypothesis
is not installed — the seeded twin in test_spmd.py keeps the property
exercised there."""
import numpy as np
import pytest

import jax

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import schedule as sch                    # noqa: E402
from repro.parallel.spmd import (reference_dag_loss,      # noqa: E402
                                 run_schedule_spmd, toy_stage_model)

from .helpers import subprocess_test                      # noqa: E402

CHUNKED = ("interleaved", "zb-v")


def build_chain(schedule, coarse, frozen_prefix):
    stages = [sch.Stage(f"e{s}", 1.0, 0.0) if s < frozen_prefix
              else sch.Stage(f"s{s}", 1.0, 2.0, bwd_w=1.0)
              for s in range(coarse)]
    if schedule in CHUNKED:
        return sch.refine_chain(sch.chain_graph(stages[:coarse // 2]),
                                2)
    return sch.chain_graph(stages)


@subprocess_test(4, timeout=2400)
@settings(max_examples=8, deadline=None)
@given(data=st.data())
def test_spmd_chain_property(data):
    schedule = data.draw(st.sampled_from(sch.SCHEDULES))
    coarse = data.draw(st.sampled_from([2, 4]))
    frozen_prefix = data.draw(st.integers(0, coarse // 2))
    n_mb = data.draw(st.integers(2, 6))
    seed = data.draw(st.integers(0, 2 ** 16))
    g = build_chain(schedule, coarse, frozen_prefix)
    kwargs = {"virtual_chunks": 2} if schedule in CHUNKED else {}
    sim = sch.get_scheduler(schedule, **kwargs).simulate(g, n_mb)
    fn, params = toy_stage_model(len(g.stages), 8, seed=seed)
    mbs = jax.random.normal(jax.random.PRNGKey(seed), (n_mb, 1, 4, 8))
    got = run_schedule_spmd(fn, params, mbs, g, sim)
    oloss, ograds = reference_dag_loss(fn, params, mbs, g)
    np.testing.assert_allclose(float(got["loss"]), float(oloss),
                               rtol=1e-6)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        got["param_grads"], ograds)
    # measured peaks = the simulator's SchedulePlan claim, exactly
    assert got["peak_activations_per_device"] == \
        list(sim["peak_activations_per_device"])
    # frozen prefix stages never accumulate weight grads
    for s in range(len(g.stages)):
        if g.stages[s].bwd_w <= 0:
            assert not np.asarray(got["param_grads"]["w"][s]).any()
