"""SPMD schedule executor (repro.parallel.spmd): the distributed
shard_map program must compute exactly what the sequential replay
(core.modality_parallel.execute_schedule) and plain autodiff compute —
loss, outputs, stage grads — and its measured per-device activation
peaks/trace must match the simulator's claims, for chains, fan-in
modality-parallel DAGs, and the golden 8-rank plan, composed with
context parallelism on one multi-axis mesh.

Multi-device tests re-exec themselves in a subprocess with a forced
host device count (tests/helpers.subprocess_test); under the
multi-device CI job (global XLA_FLAGS) they run in-process."""
import pathlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import schedule as sch
from repro.core.modality_parallel import execute_schedule
from repro.core.schedule.graph import PipelineGraph
from repro.core.schedule.memory import (MemoryModelMismatch,
                                        validate_schedule_memory)
from repro.parallel.spmd import (compile_spmd_program, default_mesh,
                                 reference_dag_loss, run_schedule_spmd,
                                 toy_stage_model)

from .helpers import host_mesh, subprocess_test

DATA = pathlib.Path(__file__).parent / "data"
GOLDEN_PLAN = DATA / "paper_mllm_8rank_plan.json"
CHUNKED = ("interleaved", "zb-v")
M = 8


def chain_case(schedule: str, coarse: int = 4, frozen_prefix: int = 0):
    """A pipeline chain sized so every schedule runs on multiple
    devices: ``coarse`` stages for the unchunked schedules (one per
    device), the 2x-refined chain folded onto ``coarse // 2`` devices
    for the chunked ones. Frozen-prefix stages model the paper's
    frozen encoders (bwd = 0, nothing trainable upstream). Trainable
    stages always carry bwd_w > 0 — the schedule decides whether W is
    split out (zb-*) or glued into B (1f1b/interleaved), and either
    way the weight grads must be real, not trivially zero."""
    stages = [sch.Stage(f"e{s}", 1.0, 0.0) if s < frozen_prefix
              else sch.Stage(f"s{s}", 1.0, 2.0, bwd_w=1.0)
              for s in range(coarse)]
    g = sch.chain_graph(stages)
    if schedule in CHUNKED:
        g = sch.refine_chain(sch.chain_graph(stages[:coarse // 2]), 2)
    kwargs = {"virtual_chunks": 2} if schedule in CHUNKED else {}
    sim = sch.get_scheduler(schedule, **kwargs).simulate(g, M)
    return g, sim


def assert_equivalent(got, ref, *, rtol=1e-5, atol=1e-6):
    """The full executor-parity contract: loss, outputs, grads
    (allclose) and the activation bookkeeping (EXACT)."""
    np.testing.assert_allclose(float(got["loss"]), float(ref["loss"]),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(got["outputs"]),
                               np.asarray(ref["outputs"]),
                               rtol=rtol, atol=atol)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=rtol, atol=atol),
        got["param_grads"], ref["param_grads"])
    assert got["activation_trace"] == ref["activation_trace"]
    assert got["peak_activations_per_device"] == \
        ref["peak_activations_per_device"]
    assert got["peak_w_residuals_per_device"] == \
        ref["peak_w_residuals_per_device"]


# ---------------------------------------------------------------------------
# chain equivalence, all four schedules
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("schedule", sch.SCHEDULES)
@subprocess_test(4)
def test_spmd_matches_replay_chain(schedule):
    """Every schedule's timeline, distributed under shard_map, equals
    the sequential replay bit-for-bit in bookkeeping and to float
    tolerance in math."""
    g, sim = chain_case(schedule)
    fn, params = toy_stage_model(len(g.stages), 16)
    mbs = jax.random.normal(jax.random.PRNGKey(1), (M, 1, 4, 16))
    ref = execute_schedule(fn, params, mbs, g, sim)
    got = run_schedule_spmd(fn, params, mbs, g, sim)
    assert_equivalent(got, ref)
    # the comparison is not vacuous: every trainable stage trained
    assert all(np.asarray(got["param_grads"]["w"][s]).any()
               for s in range(len(g.stages)))
    counts = got["program"].counts()
    assert counts["items"] == len(sim["items"])
    assert counts["devices"] == sim["num_devices"]


@pytest.mark.parametrize("schedule", ["1f1b", "zb-v"])
@subprocess_test(4)
def test_spmd_frozen_prefix_zero_grads(schedule):
    """Frozen head stages (the paper's encoders) get exactly-zero
    grads through the distributed backward, and the trainable tail
    still matches the replay."""
    g, sim = chain_case(schedule, frozen_prefix=1)
    fn, params = toy_stage_model(len(g.stages), 16)
    mbs = jax.random.normal(jax.random.PRNGKey(2), (M, 1, 4, 16))
    got = run_schedule_spmd(fn, params, mbs, g, sim)
    ref = execute_schedule(fn, params, mbs, g, sim)
    assert_equivalent(got, ref)
    frozen = [s for s in range(len(g.stages))
              if g.stages[s].bwd_w <= 0 and g.stages[s].bwd_b <= 0]
    assert frozen
    for s in frozen:
        assert not np.asarray(got["param_grads"]["w"][s]).any()


# ---------------------------------------------------------------------------
# fan-in DAG (modality parallelism)
# ---------------------------------------------------------------------------

def fanin_dag():
    """Two frozen encoders fan into a 2-stage trainable LLM — the
    modality-parallel shape where two devices' outputs land on one."""
    stages = [sch.Stage("enc0", 1.0, 1.0, bwd_w=0.0),
              sch.Stage("enc1", 1.2, 1.2, bwd_w=0.0),
              sch.Stage("llm", 1.0, 2.0, bwd_w=1.0),
              sch.Stage("llm", 1.0, 2.0, bwd_w=1.0)]
    return PipelineGraph(stages, [(0, 2), (1, 2), (2, 3)])


@pytest.mark.parametrize("schedule", ["1f1b", "zb-h1"])
@subprocess_test(4)
def test_spmd_fanin_dag_matches_replay_and_autodiff(schedule):
    """Non-chain DAG: the cotangent fan-in merge must reproduce both
    the generalized replay and the single-device autodiff oracle."""
    g = fanin_dag()
    sim = sch.get_scheduler(schedule).simulate(g, 6)
    fn, params = toy_stage_model(4, 8)
    mbs = jax.random.normal(jax.random.PRNGKey(2), (6, 1, 4, 8))
    ref = execute_schedule(fn, params, mbs, g, sim)
    got = run_schedule_spmd(fn, params, mbs, g, sim)
    assert_equivalent(got, ref)
    oracle_loss, oracle_grads = reference_dag_loss(fn, params, mbs, g)
    np.testing.assert_allclose(float(got["loss"]), float(oracle_loss),
                               rtol=1e-6)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        got["param_grads"], oracle_grads)
    # frozen encoders: zero grads, exactly
    assert not np.asarray(got["param_grads"]["w"][:2]).any()


# ---------------------------------------------------------------------------
# the golden 8-rank plan, and PP x CP composition on one mesh
# ---------------------------------------------------------------------------

@subprocess_test(8)
def test_spmd_golden_plan_matches_reference():
    """Plan form: the checked-in 8-rank paper plan drives the SPMD
    executor end to end (apply -> compile -> split_devices mesh ->
    shard_map), matching replay + autodiff and the plan's own
    peak-activation claim."""
    from repro.models.mllm import build_paper_mllm
    from repro.parallel import MLLMParallelPlan
    plan = MLLMParallelPlan.load(str(GOLDEN_PLAN))
    mllm = build_paper_mllm("vlm", reduced=True, text_len=plan.text_len)
    ex = plan.apply(mllm, mode="spmd")
    graph, sim = ex["sim_graph"], ex["schedule"]
    assert ex["spmd_program"] is not None
    n_mb, d = plan.schedule.num_microbatches, 16
    mbs = jax.random.normal(jax.random.PRNGKey(3), (n_mb, 1, 4, d))
    got = run_schedule_spmd(plan, mllm, mbs, stage_fn="toy")
    fn, params = toy_stage_model(len(graph.stages), d)
    ref = execute_schedule(fn, params, mbs, graph, sim)
    assert_equivalent(got, ref)
    oloss, ograds = reference_dag_loss(fn, params, mbs, graph)
    np.testing.assert_allclose(float(got["loss"]), float(oloss),
                               rtol=1e-6)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        got["param_grads"], ograds)
    assert got["peak_activations_per_device"] == \
        list(sim["peak_activations_per_device"]) == \
        list(plan.schedule.peak_activations_per_device)


@subprocess_test(8)
def test_spmd_composed_pp_cp_one_mesh():
    """One plan JSON drives PP x CP on a single ("pp", "cp") mesh: the
    SPMD pipeline program runs over the pp axis (replicating over cp)
    and the plan's ContextPlan drives a CP train step over the cp axis
    — both matching their single-device references."""
    from repro.configs.base import get_config
    from repro.core import bam
    from repro.models import api
    from repro.models.mllm import build_paper_mllm
    from repro.optim import optimizer as opt
    from repro.parallel import MLLMParallelPlan
    from repro.training import steps

    plan = MLLMParallelPlan.load(str(GOLDEN_PLAN))
    mllm = build_paper_mllm("vlm", reduced=True, text_len=plan.text_len)
    ex = plan.apply(mllm, mode="spmd")
    graph, sim = ex["sim_graph"], ex["schedule"]
    with host_mesh((2, 4), ("pp", "cp")) as mesh:
        # pipeline half: program over "pp", replicated over "cp"
        n_mb, d = plan.schedule.num_microbatches, 8
        fn, params = toy_stage_model(len(graph.stages), d)
        mbs = jax.random.normal(jax.random.PRNGKey(4), (n_mb, 1, 4, d))
        got = run_schedule_spmd(fn, params, mbs, graph, sim, mesh=mesh)
        ref = execute_schedule(fn, params, mbs, graph, sim)
        assert_equivalent(got, ref)

        # context half: the SAME plan's ContextPlan on the cp axis
        T, B = plan.text_len, 1
        layout = plan.context.apply(T)
        cfg = get_config("qwen3-1.7b", reduced=True)
        lm_params = api.init(jax.random.PRNGKey(0), cfg)
        ocfg = opt.AdamWConfig(lr=1e-2, warmup_steps=0,
                               schedule="constant")
        state = opt.init(ocfg, lm_params)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, T)), jnp.int32),
            "positions": jnp.broadcast_to(
                jnp.arange(T, dtype=jnp.int32)[None], (B, T)),
            "bits": bam.causal_bits(B, T),
            "valid": jnp.ones((B, T), bool),
        }
        # the plan balanced 8 CP ranks; this mesh folds them onto 4
        # devices — exact, but the step must say the balance is lost
        with pytest.warns(UserWarning, match="balanced for 8 ranks"):
            step_cp = steps.make_cp_train_step(cfg, layout, mesh, ocfg,
                                               axis_name="cp")
        _, _, m_cp = jax.jit(step_cp)(lm_params, state, batch)
        _, _, m_ref = jax.jit(steps.make_train_step(cfg, ocfg))(
            lm_params, state, batch)
        assert abs(float(m_cp["loss"]) - float(m_ref["loss"])) < 1e-4
        assert abs(float(m_cp["grad_norm"])
                   - float(m_ref["grad_norm"])) < 1e-3


# ---------------------------------------------------------------------------
# distributed memory validation: MemoryModelMismatch.first_divergence
# ---------------------------------------------------------------------------

@subprocess_test(2)
def test_spmd_memory_validation_passes_and_reports():
    """validate_schedule_memory(executor="spmd") cross-checks the
    distributed measurement against the simulator claim, exactly like
    the replay path."""
    g, sim = chain_case("zb-v", coarse=4)
    rep = validate_schedule_memory(g, M, "zb-v", virtual_chunks=2,
                                   sim=sim, executor="spmd")
    assert rep["executor"] == "spmd"
    assert rep["simulated_peaks"] == rep["executor_peaks"]


@subprocess_test(2)
def test_spmd_first_divergence_names_device_and_item():
    """Seeded divergence on the SPMD path: execute a timeline scheduled
    with the WRONG per-chunk caps (uncapped, GPipe-style) while
    claiming the proper zb-v timeline — the per-item diff must name the
    offending timeline item on its device."""
    coarse = sch.chain_graph(
        [sch.Stage("m", 1.0, 2.0, bwd_w=1.0) for _ in range(2)])
    fine = sch.refine_chain(coarse, 2)
    proper = sch.get_scheduler("zb-v", virtual_chunks=2).simulate(fine,
                                                                  M)
    wrong = sch.run_schedule(fine, M,
                             device_of=sch.v_shape_devices(4),
                             split_bw=True, stage_caps=[M] * 4)
    wrong["schedule"] = "zb-v"
    wrong["virtual_chunks"] = 2
    assert wrong["peak_activations_per_device"] != \
        proper["peak_activations_per_device"]
    with pytest.raises(MemoryModelMismatch) as ei:
        validate_schedule_memory(fine, M, "zb-v", sim=wrong,
                                 claim_sim=proper, executor="spmd")
    div = ei.value.first_divergence
    assert div is not None
    iid, sim_live, exe_live, _sb, _eb = div
    assert "@d" in iid                      # names the device
    assert "(" in iid and "m" in iid        # names stage + microbatch
    assert sim_live != exe_live or " vs " in iid


@subprocess_test(2)
def test_spmd_claim_doctoring_raises_without_item_diff():
    """A doctored summary claim over an honest timeline: the distributed
    measurement still catches it, and the diff correctly reports that
    the timelines agree item-for-item (divergence is None)."""
    g, sim = chain_case("zb-h1", coarse=2)
    claim = dict(sim)
    claim["peak_activations_per_device"] = \
        [p + 1 for p in sim["peak_activations_per_device"]]
    with pytest.raises(MemoryModelMismatch) as ei:
        validate_schedule_memory(g, M, "zb-h1", sim=sim,
                                 claim_sim=claim, executor="spmd")
    assert ei.value.first_divergence is None
    assert "summary claim" in str(ei.value)


# ---------------------------------------------------------------------------
# static guards (single device, no mesh needed)
# ---------------------------------------------------------------------------

def test_default_mesh_raises_with_xla_flags_hint():
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        default_mesh(1024)


def test_runner_rejects_wrong_mesh_axis_size():
    from repro.parallel.spmd import build_spmd_runner
    g, sim = chain_case("1f1b", coarse=2)
    mesh = default_mesh(1)
    with pytest.raises(ValueError, match="compiled for 2"):
        build_spmd_runner(lambda lp, x: x, g, sim, mesh=mesh)


def test_compile_rejects_unreachable_cotangent():
    """A trainable stage whose every successor computes no input grads
    can never receive a cotangent — the compile must refuse, not emit a
    program that silently trains on zeros."""
    g = sch.chain_graph([sch.Stage("a", 1.0, 2.0, bwd_w=1.0),
                         sch.Stage("b", 1.0, 0.0),
                         sch.Stage("c", 1.0, 2.0, bwd_w=1.0)])
    sim = sch.get_scheduler("1f1b").simulate(g, 2)
    with pytest.raises(ValueError, match="no successor produces"):
        compile_spmd_program(g, sim)


def test_plan_apply_unknown_mode_raises():
    from repro.models.mllm import build_paper_mllm
    from repro.parallel import MLLMParallelPlan
    plan = MLLMParallelPlan.load(str(GOLDEN_PLAN))
    mllm = build_paper_mllm("vlm", reduced=True, text_len=plan.text_len)
    with pytest.raises(ValueError, match="mode"):
        plan.apply(mllm, mode="telepathy")


# ---------------------------------------------------------------------------
# randomized chain property (seeded; the hypothesis twin lives in
# test_spmd_properties.py and runs where hypothesis is installed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
@subprocess_test(4)
def test_spmd_random_chain_matches_reference(seed):
    """Random chain length x freeze prefix x schedule: distributed
    loss/grads match the autodiff oracle, and the measured per-device
    peaks match the simulator's claim exactly."""
    rng = np.random.default_rng(seed)
    schedule = sch.SCHEDULES[int(rng.integers(len(sch.SCHEDULES)))]
    coarse = int(rng.integers(1, 3)) * 2          # 2 or 4
    frozen_prefix = int(rng.integers(0, coarse // 2 + 1))
    n_mb = int(rng.integers(2, 7))
    g, sim0 = chain_case(schedule, coarse=coarse,
                         frozen_prefix=frozen_prefix)
    kwargs = {"virtual_chunks": 2} if schedule in CHUNKED else {}
    sim = sch.get_scheduler(schedule, **kwargs).simulate(g, n_mb)
    fn, params = toy_stage_model(len(g.stages), 8, seed=seed)
    mbs = jax.random.normal(jax.random.PRNGKey(seed + 10),
                            (n_mb, 1, 4, 8))
    got = run_schedule_spmd(fn, params, mbs, g, sim)
    oloss, ograds = reference_dag_loss(fn, params, mbs, g)
    np.testing.assert_allclose(float(got["loss"]), float(oloss),
                               rtol=1e-6)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        got["param_grads"], ograds)
    assert got["peak_activations_per_device"] == \
        list(sim["peak_activations_per_device"])
