"""End-to-end behaviour tests for the full system (deliverable c,
integration level): the Cornstarch MLLM training loop converges with
frozen masking, the serving path is self-consistent, and the dry-run
machinery (specs -> shardings -> HLO analysis) holds together."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, get_config
from repro.data.synthetic import MultimodalDataset
from repro.models import api
from repro.models.mllm import build_paper_mllm
from repro.optim import optimizer as opt
from repro.training import steps


def test_mllm_projector_training_converges():
    """The paper's core training scenario: frozen encoders + frozen LLM,
    train the projectors on a fixed batch -> loss decreases."""
    mllm = build_paper_mllm("vlm", reduced=True)
    params = mllm.init(jax.random.PRNGKey(0))
    ocfg = opt.AdamWConfig(lr=3e-3, warmup_steps=3, total_steps=100,
                           weight_decay=0.0)
    fmask = mllm.frozen_mask(params)
    state = opt.init(ocfg, params, fmask)
    step, _ = steps.make_mllm_train_step(mllm, ocfg)
    step = jax.jit(step)
    ds = iter(MultimodalDataset(
        vocab_size=mllm.llm_cfg.vocab_size, text_len=32, batch_size=2,
        encoder_dims={"vision": mllm.encoders["vision"].cfg.d_model},
        encoder_tokens={"vision": 16}, modality_ids={"vision": 1}))
    batch = next(ds)   # fixed batch: memorization
    losses = []
    for i in range(60):
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (losses[0], losses[-1])
    assert np.isfinite(losses).all()


def test_serving_prefill_decode_consistency():
    """Greedy decode continuation equals argmax of the parallel
    forward at each position (system-level serving correctness)."""
    cfg = get_config("qwen3-1.7b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    n = 10
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, n)), jnp.int32)
    pos = jnp.arange(n, dtype=jnp.int32)[None]
    logits, _ = api.forward(params, cfg, {"tokens": toks, "positions": pos})
    want = np.asarray(jnp.argmax(logits, axis=-1))[0]

    serve = jax.jit(steps.make_serve_step(cfg))
    cache = api.init_cache(cfg, 1, n)
    got = []
    for i in range(n):
        batch = {"tokens": toks[:, i:i + 1],
                 "positions": jnp.full((1, 1), i, jnp.int32)}
        tok, cache = serve(params, cache, batch)
        got.append(int(tok[0]))
    np.testing.assert_array_equal(np.asarray(got), want)


def test_dryrun_machinery_host_scale():
    """The exact dry-run pipeline (specs -> shardings -> jit -> lower ->
    compile -> static profile) at host scale (1 device, reduced cfg)."""
    from repro.launch import hlo_analysis as H
    from repro.launch import sharding as shd
    from repro.launch import specs as S

    cfg = get_config("qwen3-1.7b", reduced=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = shd.Rules(seq_parallel=False)
    shd.set_rules(rules)
    shd.set_mesh(mesh)
    try:
        p_spec = S.param_specs(cfg)
        b = {
            "tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
            "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32),
            "positions": jax.ShapeDtypeStruct((2, 16), jnp.int32),
        }
        o_spec = S.opt_state_specs(cfg, p_spec)
        fn = steps.make_train_step(cfg)
        with mesh:
            lowered = jax.jit(fn).lower(p_spec, o_spec, b)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        assert int(mem.temp_size_in_bytes) > 0
        prof = H.analyze(compiled.as_text())
        # trip-count-aware flops must cover >= L x the per-layer matmuls
        L, d, T = cfg.num_layers, cfg.d_model, 16
        min_flops = L * 2 * 2 * T * d * cfg.q_dim  # fwd+bwd q-proj alone
        assert prof["flops"] > min_flops
    finally:
        shd.set_rules(None)
        shd.set_mesh(None)


def test_multidataset_modes_produce_valid_bam():
    from repro.core import bam
    for mode, docs in (("ep", 1), ("ee", 1), ("mp", 4)):
        ds = MultimodalDataset(
            vocab_size=128, text_len=64, batch_size=2,
            encoder_dims={"vision": 16, "audio": 16},
            encoder_tokens={"vision": 8, "audio": 8},
            modality_ids={"vision": 1, "audio": 2},
            mask_mode=mode, docs_per_row=docs)
        bits, pos = ds.merged_bits()
        W = bam.token_workload(bits, pos)
        nonpad = bits != 0
        assert (W[nonpad] >= 1).all()   # every real token attends itself
        if docs > 1:
            assert len(np.unique(bam.instance_id(
                bits[nonpad].astype(np.uint32)))) == docs


def test_dryrun_preserves_user_xla_flags():
    """Regression: importing repro.launch.dryrun used to CLOBBER any
    user-set XLA_FLAGS with its 512-device override. It must append
    the device-count flag only when the user has not already chosen
    one, and never drop unrelated flags."""
    import os
    import subprocess
    import sys

    from .helpers import REPO

    code = ("import os, repro.launch.dryrun, jax\n"
            "print(os.environ['XLA_FLAGS'])\n"
            "print(jax.device_count())")

    def run(xla_flags):
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        if xla_flags is not None:
            env["XLA_FLAGS"] = xla_flags
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True,
                              timeout=600, cwd=REPO)
        assert proc.returncode == 0, proc.stderr
        flags, devices = proc.stdout.strip().rsplit("\n", 1)
        return flags, int(devices)

    # an explicit device-count choice wins — kept verbatim, honored
    flags, devices = run("--xla_force_host_platform_device_count=4")
    assert flags == "--xla_force_host_platform_device_count=4"
    assert devices == 4
    # unrelated user flags survive alongside the appended default
    flags, devices = run("--xla_cpu_enable_fast_math=false")
    assert "--xla_cpu_enable_fast_math=false" in flags
    assert "--xla_force_host_platform_device_count=512" in flags
    assert devices == 512
    # no user flags: the dry-run's 512-device default applies
    flags, devices = run(None)
    assert flags == "--xla_force_host_platform_device_count=512"
    assert devices == 512
