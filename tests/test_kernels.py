"""Pallas BAM flash-attention kernel vs pure-jnp oracle: shape / dtype /
mask-mode sweeps in interpret mode (kernel body executed on CPU), plus
the fused-backward and grid-compaction contracts."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bam
from repro.data.synthetic import random_multimodal_bits
from repro.kernels.ops import bam_attention, bam_attention_stats
from repro.kernels.ref import bam_attention_ref


def make_inputs(seed, B, T, H, Hkv, hd, dtype, segs=None):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, T, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, hd), dtype)
    segs = segs or [("text", 0, T // 4), ("mod", 1, T // 4),
                    ("text", 0, T // 4), ("mod", 2, T // 8),
                    ("text", 0, T - 7 * (T // 8))]
    bits_np, pos_np = bam.build_sample_bits(segs, T)
    bits = jnp.broadcast_to(jnp.asarray(bits_np)[None], (B, T))
    pos = jnp.broadcast_to(jnp.asarray(pos_np)[None], (B, T))
    return q, k, v, bits, pos


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("shape", [(1, 32, 2, 2, 16), (2, 48, 4, 2, 32),
                                   (1, 64, 8, 2, 64)])
def test_kernel_matches_oracle_shapes(seed, shape):
    B, T, H, Hkv, hd = shape
    q, k, v, bits, pos = make_inputs(seed, B, T, H, Hkv, hd, jnp.float32)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    q, k, v, bits, pos = make_inputs(0, 1, 32, 4, 4, 32, dtype)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=16, block_k=16)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("bq,bk", [(8, 8), (8, 32), (32, 8), (16, 48)])
def test_kernel_block_shapes(bq, bk):
    q, k, v, bits, pos = make_inputs(1, 1, 96, 2, 1, 16, jnp.float32)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_unpadded_lengths():
    """T not a multiple of the block size (ops.py pads with bits=0)."""
    q, k, v, bits, pos = make_inputs(2, 2, 41, 2, 2, 16, jnp.float32)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_softcap_window():
    q, k, v, bits, pos = make_inputs(3, 1, 32, 2, 2, 16, jnp.float32)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos, softcap=30.0,
                            window=7)
    out = bam_attention(q, k, v, bits, bits, pos, pos, softcap=30.0,
                        window=7, impl="bam_interpret", block_q=16,
                        block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_packed_documents():
    segs = [("text", 0, 8), ("mod", 1, 8), ("text", 0, 8),
            ("newdoc", 0, 0), ("text", 0, 8), ("mod", 2, 8),
            ("text", 0, 8)]
    q, k, v, bits, pos = make_inputs(4, 1, 48, 2, 2, 16, jnp.float32,
                                     segs=segs)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_gqa_no_repeat():
    """GQA handled by BlockSpec index_map (no materialized repeat)."""
    q, k, v, bits, pos = make_inputs(5, 1, 32, 8, 2, 16, jnp.float32)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_gradients_match():
    q, k, v, bits, pos = make_inputs(6, 1, 32, 2, 2, 16, jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(bam_attention(q, k, v, bits, bits, pos, pos,
                                     impl="bam_interpret", block_q=16,
                                     block_k=16) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(bam_attention_ref(q, k, v, bits, bits, pos,
                                         pos) ** 2)

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_block_skip_equivalence():
    """Block sparsity must be a pure optimization (no numeric change)."""
    from repro.kernels.bam_attention import bam_flash_attention
    q, k, v, bits, pos = make_inputs(7, 1, 64, 2, 2, 16, jnp.float32)
    a = bam_flash_attention(q, k, v, bits, bits, pos, pos, block_q=16,
                            block_k=16, block_skip=True, interpret=True)
    b = bam_flash_attention(q, k, v, bits, bits, pos, pos, block_q=16,
                            block_k=16, block_skip=False, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_xla_impl_matches_ref():
    q, k, v, bits, pos = make_inputs(8, 2, 40, 4, 2, 16, jnp.float32)
    out = bam_attention(q, k, v, bits, bits, pos, pos, impl="xla")
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=0)


# ---------------------------------------------------------------------------
# Fused backward (custom_vjp saves (out, lse); backward is two Pallas
# kernels — never recomputes through the XLA reference path)
# ---------------------------------------------------------------------------

def _mode_inputs(mode, seed, B=1, T=64, H=4, Hkv=2, hd=16):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, T, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, hd))
    bits_np, pos_np = random_multimodal_bits(T, mode, seed=seed)
    bits = jnp.broadcast_to(jnp.asarray(bits_np)[None], (B, T))
    pos = jnp.broadcast_to(jnp.asarray(pos_np)[None], (B, T))
    return q, k, v, bits, pos, bits_np, pos_np


def _grads(q, k, v, bits, pos, **kw):
    def loss(q, k, v):
        return jnp.sum(bam_attention(q, k, v, bits, bits, pos, pos,
                                     **kw) ** 2)
    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("mode", ["ep", "ee", "mp"])
@pytest.mark.parametrize("gqa", [(2, 2), (4, 2), (8, 2)])
def test_fused_backward_matches_xla(mode, gqa):
    H, Hkv = gqa
    q, k, v, bits, pos, *_ = _mode_inputs(mode, seed=0, H=H, Hkv=Hkv)
    gk = _grads(q, k, v, bits, pos, impl="bam_interpret",
                block_q=16, block_k=16)
    gx = _grads(q, k, v, bits, pos, impl="xla")
    for a, b in zip(gk, gx):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("softcap,window", [(30.0, 0), (0.0, 7), (20.0, 9)])
def test_fused_backward_softcap_window(softcap, window):
    q, k, v, bits, pos, *_ = _mode_inputs("ee", seed=1)
    kw = dict(softcap=softcap, window=window)
    gk = _grads(q, k, v, bits, pos, impl="bam_interpret",
                block_q=16, block_k=16, **kw)
    gx = _grads(q, k, v, bits, pos, impl="xla", **kw)
    for a, b in zip(gk, gx):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_fused_backward_padding_zero_grads():
    """bits=0 tokens must receive exactly-zero dQ/dK/dV."""
    B, T, H, hd = 1, 48, 2, 16
    key = jax.random.PRNGKey(3)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, T, H, hd))
               for i in range(3))
    bits_np, pos_np = bam.build_sample_bits(
        [("text", 0, 16), ("mod", 1, 8), ("text", 0, 8)], T)  # 16 padded
    bits = jnp.asarray(bits_np)[None]
    pos = jnp.asarray(pos_np)[None]
    dq, dk, dv = _grads(q, k, v, bits, pos, impl="bam_interpret",
                        block_q=16, block_k=16)
    assert not np.asarray(dq)[:, 32:].any()
    assert not np.asarray(dk)[:, 32:].any()
    assert not np.asarray(dv)[:, 32:].any()
    # and the non-pad grads match the oracle
    gx = _grads(q, k, v, bits, pos, impl="xla")
    for a, b in zip((dq, dk, dv), gx):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_fused_backward_no_quadratic_intermediate():
    """The traced backward must not allocate any O(Tq·Tk) f32 array —
    only [block_q, block_k] tiles inside the kernels. (The jaxpr walk
    lives in repro.analysis.jaxprlint, promoted from this file.)"""
    from repro.analysis.jaxprlint import quadratic_f32
    T = 64
    q, k, v, bits, pos, *_ = _mode_inputs("ee", seed=0, T=T)

    def loss(q, k, v):
        return jnp.sum(bam_attention(q, k, v, bits, bits, pos, pos,
                                     impl="bam_interpret", block_q=16,
                                     block_k=16) ** 2)

    jaxpr = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(q, k, v)
    assert not quadratic_f32(jaxpr, T), quadratic_f32(jaxpr, T)
    # sanity: the XLA fallback DOES trace a [T,T] intermediate, so the
    # assertion above is actually discriminating
    def loss_xla(q, k, v):
        return jnp.sum(bam_attention(q, k, v, bits, bits, pos, pos,
                                     impl="xla") ** 2)
    jaxpr_x = jax.make_jaxpr(jax.grad(loss_xla, argnums=(0, 1, 2)))(q, k, v)
    assert quadratic_f32(jaxpr_x, T)


# ---------------------------------------------------------------------------
# Grid compaction (host-side block map -> scalar-prefetch sparse grid)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["ee", "mp"])
def test_block_map_forward_equivalence(mode):
    q, k, v, bits, pos, bits_np, pos_np = _mode_inputs(mode, seed=2)
    bm = bam.build_block_map(bits_np, bits_np, pos_np, pos_np, 16, 16)
    assert 0.0 < bm.skip_fraction < 1.0      # compaction actually bites
    dense = bam_attention(q, k, v, bits, bits, pos, pos,
                          impl="bam_interpret", block_q=16, block_k=16)
    compact = bam_attention(q, k, v, bits, bits, pos, pos,
                            impl="bam_interpret", block_q=16, block_k=16,
                            block_map=bm)
    np.testing.assert_allclose(np.asarray(compact), np.asarray(dense),
                               atol=1e-6)


def test_block_map_backward_equivalence():
    q, k, v, bits, pos, bits_np, pos_np = _mode_inputs("mp", seed=4)
    bm = bam.build_block_map(bits_np, bits_np, pos_np, pos_np, 16, 16)
    gc = _grads(q, k, v, bits, pos, impl="bam_interpret",
                block_q=16, block_k=16, block_map=bm)
    gx = _grads(q, k, v, bits, pos, impl="xla")
    for a, b in zip(gc, gx):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_block_map_window_mismatch_rejected():
    """A map built for one sliding window prunes tiles that another
    window needs — using it with a different window must fail loudly,
    not silently return wrong attention."""
    q, k, v, bits, pos, bits_np, pos_np = _mode_inputs("ee", seed=6)
    bm = bam.build_block_map(bits_np, bits_np, pos_np, pos_np, 16, 16,
                             window=8)
    with pytest.raises(AssertionError, match="different sliding window"):
        bam_attention(q, k, v, bits, bits, pos, pos,
                      impl="bam_interpret", block_q=16, block_k=16,
                      block_map=bm)
    # matching window is fine
    out = bam_attention(q, k, v, bits, bits, pos, pos, window=8,
                        impl="bam_interpret", block_q=16, block_k=16,
                        block_map=bm)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos, window=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_block_map_padded_rows():
    """Sequences with fully-padded tail blocks: the dummy steps still
    write (zero) outputs for the empty q blocks."""
    B, T, H, hd = 1, 64, 2, 16
    key = jax.random.PRNGKey(5)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (B, T, H, hd))
               for i in range(3))
    bits_np, pos_np = bam.build_sample_bits([("text", 0, 24)], T)
    bm = bam.build_block_map(bits_np, bits_np, pos_np, pos_np, 16, 16)
    bits = jnp.asarray(bits_np)[None]
    pos = jnp.asarray(pos_np)[None]
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=16, block_k=16,
                        block_map=bm)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    assert not np.asarray(out)[:, 24:].any()


# ---------------------------------------------------------------------------
# Stats mode (context-parallel partials) + position-padding contract
# ---------------------------------------------------------------------------

def test_stats_mode_matches_forward():
    q, k, v, bits, pos = make_inputs(9, 2, 48, 4, 2, 16, jnp.float32)
    acc, m, l = bam_attention_stats(q, k, v, bits, bits, pos, pos,
                                    impl="bam_interpret", block_q=16,
                                    block_k=16)
    assert acc.shape == (2, 4, 48, 16) and m.shape == (2, 4, 48)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.where((l > 0)[..., None], out, 0.0)
    out = jnp.einsum("bhqd->bqhd", out)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_pad_positions_use_minus_one():
    """ops._pad_axis pads positions with -1 (not 0 — aliasing pad tokens
    onto real position 0 makes workload stats / debug dumps lie), and
    the kernel output is unchanged by the sentinel because bits=0
    already masks the pad tokens."""
    from repro.kernels.ops import _pad_axis
    pos = jnp.arange(5, dtype=jnp.int32)[None]
    padded = _pad_axis(pos, 8, 1, value=-1)
    np.testing.assert_array_equal(np.asarray(padded)[0, 5:], [-1, -1, -1])
    # window > 0 is where pos aliasing would have changed the math
    q, k, v, bits, pos = make_inputs(10, 1, 41, 2, 2, 16, jnp.float32)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos, window=5)
    out = bam_attention(q, k, v, bits, bits, pos, pos, window=5,
                        impl="bam_interpret", block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
