"""Pallas BAM flash-attention kernel vs pure-jnp oracle: shape / dtype /
mask-mode sweeps in interpret mode (kernel body executed on CPU)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import bam
from repro.kernels.ops import bam_attention
from repro.kernels.ref import bam_attention_ref


def make_inputs(seed, B, T, H, Hkv, hd, dtype, segs=None):
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, T, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, hd), dtype)
    segs = segs or [("text", 0, T // 4), ("mod", 1, T // 4),
                    ("text", 0, T // 4), ("mod", 2, T // 8),
                    ("text", 0, T - 7 * (T // 8))]
    bits_np, pos_np = bam.build_sample_bits(segs, T)
    bits = jnp.broadcast_to(jnp.asarray(bits_np)[None], (B, T))
    pos = jnp.broadcast_to(jnp.asarray(pos_np)[None], (B, T))
    return q, k, v, bits, pos


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("seed", range(3))
@pytest.mark.parametrize("shape", [(1, 32, 2, 2, 16), (2, 48, 4, 2, 32),
                                   (1, 64, 8, 2, 64)])
def test_kernel_matches_oracle_shapes(seed, shape):
    B, T, H, Hkv, hd = shape
    q, k, v, bits, pos = make_inputs(seed, B, T, H, Hkv, hd, jnp.float32)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    q, k, v, bits, pos = make_inputs(0, 1, 32, 4, 4, 32, dtype)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=16, block_k=16)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("bq,bk", [(8, 8), (8, 32), (32, 8), (16, 48)])
def test_kernel_block_shapes(bq, bk):
    q, k, v, bits, pos = make_inputs(1, 1, 96, 2, 1, 16, jnp.float32)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=bq, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_unpadded_lengths():
    """T not a multiple of the block size (ops.py pads with bits=0)."""
    q, k, v, bits, pos = make_inputs(2, 2, 41, 2, 2, 16, jnp.float32)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_softcap_window():
    q, k, v, bits, pos = make_inputs(3, 1, 32, 2, 2, 16, jnp.float32)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos, softcap=30.0,
                            window=7)
    out = bam_attention(q, k, v, bits, bits, pos, pos, softcap=30.0,
                        window=7, impl="bam_interpret", block_q=16,
                        block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_packed_documents():
    segs = [("text", 0, 8), ("mod", 1, 8), ("text", 0, 8),
            ("newdoc", 0, 0), ("text", 0, 8), ("mod", 2, 8),
            ("text", 0, 8)]
    q, k, v, bits, pos = make_inputs(4, 1, 48, 2, 2, 16, jnp.float32,
                                     segs=segs)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_gqa_no_repeat():
    """GQA handled by BlockSpec index_map (no materialized repeat)."""
    q, k, v, bits, pos = make_inputs(5, 1, 32, 8, 2, 16, jnp.float32)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_gradients_match():
    q, k, v, bits, pos = make_inputs(6, 1, 32, 2, 2, 16, jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(bam_attention(q, k, v, bits, bits, pos, pos,
                                     impl="bam_interpret", block_q=16,
                                     block_k=16) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(bam_attention_ref(q, k, v, bits, bits, pos,
                                         pos) ** 2)

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_block_skip_equivalence():
    """Block sparsity must be a pure optimization (no numeric change)."""
    from repro.kernels.bam_attention import bam_flash_attention
    q, k, v, bits, pos = make_inputs(7, 1, 64, 2, 2, 16, jnp.float32)
    a = bam_flash_attention(q, k, v, bits, bits, pos, pos, block_q=16,
                            block_k=16, block_skip=True, interpret=True)
    b = bam_flash_attention(q, k, v, bits, bits, pos, pos, block_q=16,
                            block_k=16, block_skip=False, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_xla_impl_matches_ref():
    q, k, v, bits, pos = make_inputs(8, 2, 40, 4, 2, 16, jnp.float32)
    out = bam_attention(q, k, v, bits, bits, pos, pos, impl="xla")
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=0)
