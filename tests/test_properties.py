"""Hypothesis property-based tests on the system's invariants
(deliverable c): BAM mask semantics, distribution planners, the
partitioner DP, the attention kernel vs its oracle, chunked scans."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bam, distribution as dist
from repro.core import pipeline as pp

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

@st.composite
def segment_lists(draw, max_total=64):
    """Random multimodal segment layouts (text/mod/newdoc)."""
    segs, total = [], 0
    n = draw(st.integers(2, 8))
    for i in range(n):
        kind = draw(st.sampled_from(["text", "mod", "newdoc"]))
        if kind == "newdoc":
            if total == 0:
                kind = "text"
            else:
                segs.append(("newdoc", 0, 0))
                continue
        length = draw(st.integers(1, max(1, (max_total - total) // 2)))
        if total + length > max_total:
            break
        if kind == "mod":
            segs.append(("mod", draw(st.integers(1, 4)), length))
        else:
            segs.append(("text", 0, length))
        total += length
    if total == 0:
        segs = [("text", 0, 4)]
        total = 4
    return segs, max_total


@st.composite
def workloads(draw):
    n = draw(st.integers(4, 64))
    return np.array(draw(st.lists(
        st.floats(0.1, 100.0, allow_nan=False), min_size=n, max_size=n)))


# ---------------------------------------------------------------------------
# BAM invariants
# ---------------------------------------------------------------------------

@given(segment_lists())
@settings(**SETTINGS)
def test_bam_mask_invariants(case):
    segs, total = case
    bits, pos = bam.build_sample_bits(segs, total)
    m = np.asarray(bam.allowed_mask(
        jnp.asarray(bits)[None], jnp.asarray(bits)[None],
        jnp.asarray(pos)[None], jnp.asarray(pos)[None]))[0]
    nonpad = bits != 0
    # every real token attends itself
    assert m[np.diag_indices_from(m)][nonpad].all()
    # padding never attends / is attended
    assert not m[~nonpad, :].any() and not m[:, ~nonpad].any()
    # text rows are causal: no attention to strictly-later positions
    mod = bam.own_modality(bits.astype(np.uint32))
    text_rows = nonpad & (mod == bam.TEXT)
    later = pos[None, :] > pos[:, None]
    assert not (m & later)[text_rows, :].any()
    # workload == row sums
    np.testing.assert_allclose(bam.token_workload(bits, pos), m.sum(1))
    # cross-document isolation
    inst = bam.instance_id(bits.astype(np.uint32))
    cross = inst[:, None] != inst[None, :]
    assert not (m & cross).any()


@given(segment_lists())
@settings(**SETTINGS)
def test_bam_window_only_restricts(case):
    segs, total = case
    bits, pos = bam.build_sample_bits(segs, total)
    args = (jnp.asarray(bits)[None], jnp.asarray(bits)[None],
            jnp.asarray(pos)[None], jnp.asarray(pos)[None])
    full = np.asarray(bam.allowed_mask(*args))
    win = np.asarray(bam.allowed_mask(*args, 4))
    assert not (win & ~full).any()   # windowing is monotone


# ---------------------------------------------------------------------------
# Distribution planners
# ---------------------------------------------------------------------------

@given(workloads(), st.integers(2, 8))
@settings(**SETTINGS)
def test_planner_partition_properties(W, G):
    for method in ("zigzag", "ring", "lpt", "random"):
        plan = dist.PLANNERS[method](W, G)
        blocks = np.concatenate(plan.per_rank_blocks)
        assert sorted(blocks.tolist()) == list(range(len(W)))
        np.testing.assert_allclose(plan.loads.sum(), W.sum())
    lpt = dist.lpt(W, G)
    assert lpt.makespan <= dist.graham_bound(W, G) + 1e-9
    # LPT is at least as balanced as the naive contiguous split
    assert lpt.makespan <= dist.ring(W, G).makespan + 1e-9


@given(workloads())
@settings(max_examples=10, deadline=None)
def test_lpt_within_433_of_optimal(W):
    W = W[:10]
    opt = dist.ilp(W, 3)
    greedy = dist.lpt(W, 3)
    assert greedy.makespan <= opt.makespan * (4 / 3) + 1e-9


@st.composite
def cp_plan_cases(draw):
    """(block workloads, ranks, block size) with the total token count
    divisible by the rank count — the CP layout invariant
    ``plan_permutation`` equalizes per-rank token counts under."""
    G = draw(st.integers(2, 4))
    bs = draw(st.sampled_from([1, 2, 4]))
    nb = G * draw(st.integers(1, 4))
    W = np.array(draw(st.lists(st.floats(0.1, 50.0, allow_nan=False),
                               min_size=nb, max_size=nb)))
    return W, G, bs


@given(cp_plan_cases())
@settings(max_examples=15, deadline=None)
def test_cp_plan_permutation_roundtrips(case):
    """For EVERY balancer (the exact ILP included): the CP layout
    permutation is a true permutation of the token axis, and
    apply_plan followed by its inverse is the identity on arbitrary
    token layouts — the property the whole permute/shard/unpermute CP
    pipeline rests on."""
    from repro.core import context_parallel as cp
    W, G, bs = case
    T = len(W) * bs
    key = jax.random.PRNGKey(int(W.sum() * 1e3) % (2 ** 31))
    tree = {
        "tokens": jnp.arange(T, dtype=jnp.int32)[None],
        "embeds": jax.random.normal(key, (1, T, 3)),
    }
    for method in ("zigzag", "ring", "lpt", "ilp"):
        kw = {"node_limit": 20_000} if method == "ilp" else {}
        plan = dist.PLANNERS[method](W, G, bs, **kw)
        perm = cp.plan_permutation(plan, T)
        assert sorted(perm.tolist()) == list(range(T)), method
        inv = cp.invert_perm(perm)
        assert sorted(inv.tolist()) == list(range(T)), method
        layout = cp.apply_plan(tree, perm)
        back = cp.apply_plan(layout, inv)
        for k in tree:
            np.testing.assert_array_equal(np.asarray(back[k]),
                                          np.asarray(tree[k]),
                                          err_msg=method)
        # the permuted layout is rank-contiguous with equal token
        # counts, and rank r's slice starts with its own assigned
        # tokens (count-rebalancing only trims a rank's tail and
        # refills from over-full ranks' surpluses, never reorders the
        # kept prefix)
        per_rank = np.asarray(perm).reshape(G, T // G)
        slices = plan.rank_token_slices()
        target = T // G
        for r, sl in enumerate(per_rank):
            keep = slices[r][:target]
            np.testing.assert_array_equal(sl[:len(keep)], keep,
                                          err_msg=method)


# ---------------------------------------------------------------------------
# Partitioner DP
# ---------------------------------------------------------------------------

@given(st.lists(st.floats(0.1, 50.0), min_size=3, max_size=10),
       st.integers(2, 4))
@settings(**SETTINGS)
def test_partition_layers_valid_and_bounded(costs, k):
    costs = np.array(costs)
    bounds = pp.partition_layers(costs, k)
    assert bounds[0][0] == 0 and bounds[-1][1] == len(costs)
    for (a, b), (c, d) in zip(bounds, bounds[1:]):
        assert b == c and a < b
    worst = max(costs[a:b].sum() for a, b in bounds)
    # optimal max-part is never below the mean or the max single layer
    assert worst >= max(costs.sum() / k - 1e-9, costs.max() - 1e-9)


# ---------------------------------------------------------------------------
# Kernel vs oracle (generated shapes; interpret mode)
# ---------------------------------------------------------------------------

@given(st.integers(0, 10_000), st.integers(1, 2),
       st.sampled_from([(2, 1), (4, 2), (4, 4)]),
       st.sampled_from([16, 32]))
@settings(max_examples=8, deadline=None)
def test_kernel_matches_oracle_generated(seed, B, heads, hd):
    from repro.kernels.ops import bam_attention
    from repro.kernels.ref import bam_attention_ref
    H, Hkv = heads
    T = 32
    key = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, T, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, Hkv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, Hkv, hd))
    rng = np.random.default_rng(seed)
    segs = [("text", 0, 8), ("mod", int(rng.integers(1, 4)), 8),
            ("text", 0, 16)]
    bits_np, pos_np = bam.build_sample_bits(segs, T)
    bits = jnp.broadcast_to(jnp.asarray(bits_np)[None], (B, T))
    pos = jnp.broadcast_to(jnp.asarray(pos_np)[None], (B, T))
    out = bam_attention(q, k, v, bits, bits, pos, pos,
                        impl="bam_interpret", block_q=16, block_k=16)
    ref = bam_attention_ref(q, k, v, bits, bits, pos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# Chunked-scan equivalences (generated lengths)
# ---------------------------------------------------------------------------

@given(st.integers(0, 1000), st.sampled_from([2, 4, 8]))
@settings(max_examples=8, deadline=None)
def test_mlstm_chunk_equivalence_generated(seed, chunk):
    from repro.models.xlstm import mlstm_chunked, mlstm_parallel
    T = 16
    key = jax.random.PRNGKey(seed)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (1, T, 2, 4))
               for i in range(3))
    log_i = jax.random.normal(jax.random.fold_in(key, 3), (1, T, 2))
    log_f = jax.nn.log_sigmoid(
        jax.random.normal(jax.random.fold_in(key, 4), (1, T, 2)) + 1)
    got, _ = mlstm_chunked(q, k, v, log_i, log_f, chunk)
    ref = mlstm_parallel(q, k, v, log_i, log_f)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
