"""Simulator-vs-executor memory validation: the discrete-event
simulator's per-device peak-activation claims must match what the real
schedule-driven executor (core.modality_parallel.execute_schedule)
measures when it replays the same item timeline with real forwards and
real B/W VJPs — and the executor's gradients must match plain
autodiff."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import schedule as sch
from repro.core.modality_parallel import execute_schedule
from repro.core.schedule.memory import (MemoryModelMismatch,
                                        activation_caps,
                                        validate_schedule_memory)

MICROBATCHES = 8
CHUNKED = ("interleaved", "zb-v")


def two_rank_graph(schedule: str, frozen_head: bool = False):
    """A 2-pipeline-rank fixture: 2 coarse stages, refined to 4 chunk
    stages for the chunked schedules so every schedule runs on exactly
    2 devices."""
    mk = [sch.Stage("enc", 1.0, 0.0) if frozen_head
          else sch.Stage("s0", 1.0, 2.0, bwd_w=1.0),
          sch.Stage("s1", 1.0, 2.0, bwd_w=1.0)]
    g = sch.chain_graph(mk)
    return sch.refine_chain(g, 2) if schedule in CHUNKED else g


def toy_model(S: int, d: int = 16, M: int = MICROBATCHES):
    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (S, d, d)) * 0.1}

    def stage_fn(lp, x):
        return x + jnp.tanh(x @ lp["w"])

    mbs = jax.random.normal(jax.random.fold_in(key, 1), (M, 1, 4, d))
    return stage_fn, params, mbs


@pytest.mark.parametrize("schedule", sch.SCHEDULES)
@pytest.mark.parametrize("frozen_head", [False, True])
def test_executor_peak_matches_simulator_two_ranks(schedule, frozen_head):
    """The ISSUE's small-model contract: on a 2-stage pipeline the
    executor-measured peak equals the simulator's claim exactly, per
    device, for every schedule — and stays inside the depth_from_end
    cap envelope. validate_schedule_memory raises on any divergence."""
    g = two_rank_graph(schedule, frozen_head)
    kwargs = {"virtual_chunks": 2} if schedule in CHUNKED else {}
    rep = validate_schedule_memory(g, MICROBATCHES, schedule, **kwargs)
    assert rep["num_devices"] == 2
    assert rep["simulated_peaks"] == rep["executor_peaks"]
    assert all(p <= c for p, c in zip(rep["executor_peaks"],
                                      rep["caps"]))
    if schedule == "1f1b" and not frozen_head:
        # the classic profile saturates its cap: depth_from_end = [2, 1]
        assert rep["executor_peaks"] == [2, 1] == rep["caps"]


def test_validation_fails_loudly_on_divergent_claim():
    g = two_rank_graph("zb-h1")
    sim = sch.get_scheduler("zb-h1").simulate(g, MICROBATCHES)
    sim["peak_activations_per_device"] = \
        [p + 1 for p in sim["peak_activations_per_device"]]
    with pytest.raises(MemoryModelMismatch):
        validate_schedule_memory(g, MICROBATCHES, "zb-h1", sim=sim)


@pytest.mark.parametrize("schedule", sch.SCHEDULES)
def test_executor_grads_match_autodiff(schedule):
    """Replaying any schedule's timeline computes the exact gradients
    of the sequential model — B/W splitting, W deferral, and chunk
    folding are pure reorderings."""
    g = two_rank_graph(schedule)
    S = len(g.stages)
    stage_fn, params, mbs = toy_model(S)
    kwargs = {"virtual_chunks": 2} if schedule in CHUNKED else {}
    sim = sch.get_scheduler(schedule, **kwargs).simulate(g, MICROBATCHES)
    res = execute_schedule(stage_fn, params, mbs, g, sim)

    def ref_loss(p):
        def one(x):
            for s in range(S):
                x = stage_fn(jax.tree.map(lambda a: a[s], p), x)
            return jnp.mean(x ** 2)
        return jnp.sum(jax.vmap(one)(mbs))

    gref = jax.grad(ref_loss)(params)
    assert float(jnp.abs(res["param_grads"]["w"] - gref["w"]).max()) \
        < 1e-5
    assert float(res["loss"]) == pytest.approx(float(ref_loss(params)),
                                               rel=1e-5)


def test_executor_skips_frozen_grads_and_cotangents():
    """A frozen head stage (bwd = 0) gets no W pass, no weight grads,
    and receives no cotangent — its B item only frees memory."""
    g = two_rank_graph("zb-h1", frozen_head=True)
    stage_fn, params, mbs = toy_model(len(g.stages))
    sim = sch.get_scheduler("zb-h1").simulate(g, MICROBATCHES)
    assert not any(kind == "W" and g.stages[s].bwd_w == 0
                   for _, _, _, kind, s, _ in sim["items"])
    res = execute_schedule(stage_fn, params, mbs, g, sim)
    assert float(jnp.abs(res["param_grads"]["w"][0]).max()) == 0.0
    assert float(jnp.abs(res["param_grads"]["w"][1]).max()) > 0.0


def test_activation_caps_math():
    g = sch.chain_graph([sch.Stage("m", 1.0, 2.0) for _ in range(4)])
    assert activation_caps(g) == [4, 3, 2, 1]
    assert activation_caps(g, num_microbatches=2) == [2, 2, 2, 1]
    # folded: device hosts several stages, caps add up
    assert activation_caps(g, device_of=[0, 1, 1, 0]) == [5, 5]


def test_zbv_memory_uniform_across_devices():
    """ZB-V's selling point vs 1F1B's p..1 ramp: peak activations are
    (near-)uniform across devices, at the deep end's envelope."""
    coarse = sch.chain_graph(
        [sch.Stage("m", 1.0, 2.0, bwd_w=1.0) for _ in range(4)])
    fine = sch.refine_chain(coarse, 2)
    rep = validate_schedule_memory(fine, 16, "zb-v", virtual_chunks=2)
    peaks = rep["executor_peaks"]
    assert max(peaks) - min(peaks) <= 1
    assert max(peaks) <= 2 * 4    # 2p chunk-activations = 1F1B deep end
