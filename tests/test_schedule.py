"""core.schedule: B/W-split work items, the three schedulers, and
their composition with frozen-aware costs (ZB-H1 / interleaved vs 1F1B
on frozen-MLLM fixtures; the glued-W regression anchor)."""
import numpy as np
import pytest

from repro.core import pipeline as pp
from repro.core import schedule as sch


# ---------------------------------------------------------------------------
# B/W cost decomposition
# ---------------------------------------------------------------------------

def test_bw_factor_decomposition():
    """frozen => W = 0; trainable => W = 1 fwd-equivalent; recompute
    time lands on B (it must precede the grad matmuls)."""
    frozen_head = pp.ModuleProfile("enc", np.ones(4), frozen=True)
    frozen_mid = pp.ModuleProfile("llm", np.ones(4), frozen=True,
                                  trainable_upstream=True)
    trainable = pp.ModuleProfile("proj", np.ones(4), frozen=False)
    for m in (frozen_head, frozen_mid, trainable):
        assert m.bwd_input_factor + m.bwd_weight_factor == m.bwd_factor
    assert frozen_head.bwd_weight_factor == 0.0
    assert frozen_mid.bwd_weight_factor == 0.0
    assert frozen_mid.bwd_input_factor == 1.0
    assert trainable.bwd_weight_factor == 1.0
    assert trainable.bwd_input_factor == 1.0
    trainable.recompute = True
    assert trainable.bwd_input_factor == 2.0      # recompute + B
    assert trainable.bwd_weight_factor == 1.0


def test_partition_carries_w_costs():
    m = pp.ModuleProfile("llm", np.ones(8) * 2.0, frozen=False)
    stages = pp.partition_module(m, 4)
    for s in stages:
        assert s.bwd_w == pytest.approx(s.fwd)     # W = 1 fwd-equivalent
        assert s.bwd_b == pytest.approx(s.bwd - s.bwd_w)
    frozen = pp.partition_module(
        pp.ModuleProfile("enc", np.ones(8), frozen=True,
                         trainable_upstream=True), 4)
    assert all(s.bwd_w == 0.0 and s.bwd > 0.0 for s in frozen)


# ---------------------------------------------------------------------------
# Regression anchor: glued B/W == legacy 1F1B
# ---------------------------------------------------------------------------

def test_bw_split_glued_reproduces_1f1b_closed_form():
    """All-trainable chain with explicit B/W split: when W runs
    immediately after B (the 1F1B scheduler's glued placement), the
    iteration time is the legacy closed form (M + S - 1)(f + b)."""
    for S, M, f, b in [(4, 8, 1.0, 2.0), (2, 4, 3.0, 1.0), (6, 12, 1.0, 1.0)]:
        g = sch.chain_graph(
            [sch.Stage("m", f, b, bwd_w=b / 2) for _ in range(S)])
        sim = sch.get_scheduler("1f1b").simulate(g, M)
        assert sim["iteration_time"] == pytest.approx((M + S - 1) * (f + b))
        assert sim["schedule"] == "1f1b"


def test_split_conserves_work():
    """Deferring W moves work around but never changes per-device busy
    totals — only the makespan."""
    g = sch.chain_graph(
        [sch.Stage("m", 1.0, 2.0, bwd_w=1.0) for _ in range(4)])
    glued = sch.get_scheduler("1f1b").simulate(g, 8)
    split = sch.get_scheduler("zb-h1").simulate(g, 8)
    np.testing.assert_allclose(sorted(glued["per_device_busy"]),
                               sorted(split["per_device_busy"]))
    assert split["iteration_time"] <= glued["iteration_time"] + 1e-9


# ---------------------------------------------------------------------------
# ZB-H1 / interleaved vs 1F1B on frozen-MLLM fixtures
# ---------------------------------------------------------------------------

def frozen_mllm_modules(llm_trainable: bool):
    """Frozen encoder + trainable projector (+ frozen or trainable
    LLM): the paper's fine-tuning settings."""
    enc = pp.ModuleProfile("vision", np.ones(48) * 2.0, frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(32) * 1.5,
                           frozen=not llm_trainable)
    pp.analyze_chain([enc, llm], projector_trainable=[True, False])
    return enc, llm


def frozen_mllm_graph(llm_trainable: bool, stages: int = 8):
    return pp.build_chain_fused(list(frozen_mllm_modules(llm_trainable)),
                                stages, frozen_aware=True)


@pytest.mark.parametrize("llm_trainable", [False, True])
@pytest.mark.parametrize("microbatches", [8, 16, 24])
def test_zbh1_and_interleaved_not_worse_than_1f1b(llm_trainable,
                                                  microbatches):
    """At a fixed 8-device budget: ZB-H1 runs the same 8-stage graph
    with deferred W; interleaved searches its chunk count (a 2x-finer
    partition folded onto the same devices, or the v=1 degenerate).
    Neither may bubble more than plain 1F1B."""
    modules = list(frozen_mllm_modules(llm_trainable))
    sims = {s: pp.simulate_fused_chain(modules, 8, microbatches,
                                       schedule=s)[1]
            for s in sch.SCHEDULES}
    assert all(s["num_devices"] == 8 for s in sims.values())
    for name in ("zb-h1", "interleaved"):
        assert sims[name]["bubble_fraction"] <= \
            sims["1f1b"]["bubble_fraction"] + 1e-9, \
            (name, llm_trainable, microbatches)


def test_interleaved_megatron_order_beats_1f1b_on_homogeneous_chain():
    """On a homogeneous chain (the schedule's home turf) the Megatron
    item order realizes the ~v-fold fill/drain reduction outright —
    no fallback involved."""
    g8 = sch.chain_graph([sch.Stage("m", 2.0, 4.0) for _ in range(8)])
    g16 = sch.chain_graph([sch.Stage("m", 1.0, 2.0) for _ in range(16)])
    base = sch.get_scheduler("1f1b").simulate(g8, 24)
    il = sch.get_scheduler("interleaved", virtual_chunks=2).simulate(
        g16, 24)
    assert il["num_devices"] == base["num_devices"] == 8
    # busy/device = 144; fill: (D-1)(f+b) = 42 vs (D-1)(f+b)/v = 21
    assert base["iteration_time"] == pytest.approx(186.0)
    assert il["iteration_time"] == pytest.approx(165.0)


def test_zbh1_strictly_beats_1f1b_with_trainable_llm():
    """With a trainable LLM there is W work to defer: ZB-H1 must win
    outright, not just tie."""
    g = frozen_mllm_graph(llm_trainable=True)
    base = sch.get_scheduler("1f1b").simulate(g, 8)
    zb = sch.get_scheduler("zb-h1").simulate(g, 8)
    assert zb["iteration_time"] < base["iteration_time"]


def test_zbh1_equals_1f1b_when_fully_frozen():
    """Fully frozen backbone => no W passes anywhere => the split
    changes nothing."""
    g = frozen_mllm_graph(llm_trainable=False)
    base = sch.get_scheduler("1f1b").simulate(g, 8)
    zb = sch.get_scheduler("zb-h1").simulate(g, 8)
    assert zb["iteration_time"] == pytest.approx(base["iteration_time"])


def test_zbh1_on_modality_parallel_dag():
    """The W pass defers on DAG graphs (Fig. 6) too, not just chains."""
    e1 = pp.ModuleProfile("vision", np.ones(4) * 3.0, frozen=True)
    e2 = pp.ModuleProfile("audio", np.ones(6), frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(8) * 2.0, frozen=False,
                           trainable_upstream=True)
    g = pp.build_modality_parallel([e1, e2], llm, [2, 2], 4)
    base = sch.get_scheduler("1f1b").simulate(g, 8)
    zb = sch.get_scheduler("zb-h1").simulate(g, 8)
    assert zb["iteration_time"] <= base["iteration_time"] + 1e-9


# ---------------------------------------------------------------------------
# Interleaved device mapping
# ---------------------------------------------------------------------------

def test_interleave_devices_round_robin():
    g = sch.chain_graph([sch.Stage("m", 1.0, 2.0) for _ in range(8)])
    assert sch.interleave_devices(g, 2) == [0, 1, 2, 3, 0, 1, 2, 3]
    assert sch.interleave_devices(g, 4) == [0, 1, 0, 1, 0, 1, 0, 1]
    assert sch.interleave_devices(g, 1) == list(range(8))


def test_interleaved_uses_fewer_devices_and_conserves_work():
    g = sch.chain_graph([sch.Stage("m", 1.0, 2.0) for _ in range(8)])
    base = sch.get_scheduler("1f1b").simulate(g, 16)
    il = sch.get_scheduler("interleaved", virtual_chunks=2).simulate(g, 16)
    assert il["num_devices"] == 4 and base["num_devices"] == 8
    assert sum(il["per_device_busy"]) == pytest.approx(
        sum(base["per_device_busy"]))
    assert il["bubble_fraction"] <= base["bubble_fraction"] + 1e-9


# ---------------------------------------------------------------------------
# Scheduler interface / Algorithm 1 integration
# ---------------------------------------------------------------------------

def test_get_scheduler_registry():
    for name in sch.SCHEDULES:
        s = sch.get_scheduler(name)
        assert s.name == name
    with pytest.raises(ValueError):
        sch.get_scheduler("gpipe")


def test_simulate_tags_schedule_name():
    g = sch.chain_graph([sch.Stage("m", 1.0, 2.0) for _ in range(4)])
    for name in sch.SCHEDULES:
        assert sch.simulate(g, 8, schedule=name)["schedule"] == name


def test_auto_parallelize_returns_schedule_name():
    e = pp.ModuleProfile("vision", np.ones(8) * 3.0, frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(16) * 2.0, frozen=False,
                           trainable_upstream=True)
    best = pp.auto_parallelize([e], llm, total_devices=8,
                               num_microbatches=8)
    assert best["schedule"] in sch.SCHEDULES
    assert best["encoder_names"] == ["vision"]
    # schedules are compared at the same device budget: the simulated
    # device count must equal the allocated stage count
    assert best["devices"] == best["llm_stages"] + \
        sum(best["encoder_stages"])
    # searching more schedules can only improve on 1F1B-only
    base = pp.auto_parallelize([e], llm, total_devices=8,
                               num_microbatches=8, schedules=("1f1b",))
    assert best["tput_per_device"] >= base["tput_per_device"] - 1e-12
    assert base["schedule"] == "1f1b"


def test_simulate_plan_keeps_device_budget():
    """Interleaved folds its virtual chunks onto the planned devices
    (and degrades v when a module lacks layers) — num_devices always
    equals the allocated stage count."""
    e = pp.ModuleProfile("vision", np.ones(4) * 3.0, frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(8) * 2.0, frozen=False,
                           trainable_upstream=True)
    for schedule in sch.SCHEDULES:
        g, sim = pp.simulate_plan([e], llm, [2], 4, 8, schedule=schedule)
        assert sim["num_devices"] == 6, schedule
        # the winning interleaved graph is v=2 (12 stages) or the
        # degenerate v=1 (6 stages) — never anything else
        assert len(g.stages) in (6, 12)
    # not enough layers for chunking anywhere => only v=1 feasible
    tiny = pp.ModuleProfile("llm", np.ones(4), frozen=False)
    g, sim = pp.simulate_plan([], tiny, [], 4, 8, schedule="interleaved")
    assert sim["num_devices"] == 4 and len(g.stages) == 4


def test_parallel_spec_threads_schedule():
    """MultimodalParallelSpec carries the schedule choice end to end."""
    from repro.core.modality import (ModalityModule, MultimodalModule,
                                     MultimodalParallelSpec, ParallelSpec)
    from repro.configs.paper_mllm import llm_config, vision_encoder_config
    mllm = MultimodalModule(
        encoders={"vision": ModalityModule(
            "vision", vision_encoder_config("S", reduced=True),
            modality_id=1, num_tokens=16)},
        llm_cfg=llm_config("S", reduced=True))
    mllm.freeze("vision", module=True, projector=False)
    mllm.freeze("llm", module=False)      # trainable LLM => W exists
    spec = MultimodalParallelSpec(
        encoder_specs={"vision": ParallelSpec(pp_size=1)},
        llm_spec=ParallelSpec(pp_size=2), num_microbatches=8,
        schedule="zb-h1")
    plan = spec.apply(mllm, text_len=64)
    assert plan["schedule_name"] == "zb-h1"
    assert plan["schedule"]["bubble_fraction"] >= 0.0


def test_parallel_spec_graph_stays_one_stage_per_device():
    """Executor contract: plan["graph"] always has one stage per
    simulated device — interleaved's v-times finer simulation graph
    must fold back to the planned partition, and schedule_from_plan
    resolves the name from the apply-plan flavor too."""
    from repro.core.modality import (ModalityModule, MultimodalModule,
                                     MultimodalParallelSpec, ParallelSpec)
    from repro.core.modality_parallel import schedule_from_plan
    from repro.configs.paper_mllm import llm_config, vision_encoder_config
    mllm = MultimodalModule(
        encoders={"vision": ModalityModule(
            "vision", vision_encoder_config("S"), modality_id=1,
            num_tokens=64)},
        llm_cfg=llm_config("S"))
    mllm.freeze("vision", module=True, projector=False)
    mllm.freeze("llm", module=False)
    for schedule in sch.SCHEDULES:
        spec = MultimodalParallelSpec(
            encoder_specs={"vision": ParallelSpec(pp_size=2)},
            llm_spec=ParallelSpec(pp_size=6), num_microbatches=16,
            schedule=schedule)
        plan = spec.apply(mllm, text_len=256)
        assert len(plan["graph"].stages) == \
            plan["schedule"]["num_devices"], schedule
        assert schedule_from_plan(plan) == schedule


def test_split_devices_accepts_auto_parallelize_plan():
    from repro.core import modality_parallel as mp

    class FakeMLLM:
        encoders = {"audio": None, "vision": None}

    # encoder_names carries the caller's profile order, so counts land
    # on the right encoder even when that order is not name-sorted
    plan = {"encoder_stages": [2, 1], "encoder_names": ["vision", "audio"],
            "schedule": "zb-h1", "llm_stages": 3}
    split = mp.split_devices(FakeMLLM(), list(range(6)), plan=plan)
    assert len(split["vision"]) == 2 and len(split["audio"]) == 1
    assert len(split["llm"]) == 3
    assert all(isinstance(v, list) for v in split.values())
    assert mp.schedule_from_plan(plan) == "zb-h1"
    assert mp.schedule_from_plan(None) == "1f1b"
    assert mp.schedule_from_plan({"vision": 1}) == "1f1b"
