"""core.schedule: B/W-split work items, the three schedulers, and
their composition with frozen-aware costs (ZB-H1 / interleaved vs 1F1B
on frozen-MLLM fixtures; the glued-W regression anchor)."""
import numpy as np
import pytest

from repro.core import pipeline as pp
from repro.core import schedule as sch


# ---------------------------------------------------------------------------
# B/W cost decomposition
# ---------------------------------------------------------------------------

def test_bw_factor_decomposition():
    """frozen => W = 0; trainable => W = 1 fwd-equivalent; recompute
    time lands on B (it must precede the grad matmuls)."""
    frozen_head = pp.ModuleProfile("enc", np.ones(4), frozen=True)
    frozen_mid = pp.ModuleProfile("llm", np.ones(4), frozen=True,
                                  trainable_upstream=True)
    trainable = pp.ModuleProfile("proj", np.ones(4), frozen=False)
    for m in (frozen_head, frozen_mid, trainable):
        assert m.bwd_input_factor + m.bwd_weight_factor == m.bwd_factor
    assert frozen_head.bwd_weight_factor == 0.0
    assert frozen_mid.bwd_weight_factor == 0.0
    assert frozen_mid.bwd_input_factor == 1.0
    assert trainable.bwd_weight_factor == 1.0
    assert trainable.bwd_input_factor == 1.0
    trainable.recompute = True
    assert trainable.bwd_input_factor == 2.0      # recompute + B
    assert trainable.bwd_weight_factor == 1.0


def test_partition_carries_w_costs():
    m = pp.ModuleProfile("llm", np.ones(8) * 2.0, frozen=False)
    stages = pp.partition_module(m, 4)
    for s in stages:
        assert s.bwd_w == pytest.approx(s.fwd)     # W = 1 fwd-equivalent
        assert s.bwd_b == pytest.approx(s.bwd - s.bwd_w)
    frozen = pp.partition_module(
        pp.ModuleProfile("enc", np.ones(8), frozen=True,
                         trainable_upstream=True), 4)
    assert all(s.bwd_w == 0.0 and s.bwd > 0.0 for s in frozen)


# ---------------------------------------------------------------------------
# Regression anchor: glued B/W == legacy 1F1B
# ---------------------------------------------------------------------------

def test_bw_split_glued_reproduces_1f1b_closed_form():
    """All-trainable chain with explicit B/W split: when W runs
    immediately after B (the 1F1B scheduler's glued placement), the
    iteration time is the legacy closed form (M + S - 1)(f + b)."""
    for S, M, f, b in [(4, 8, 1.0, 2.0), (2, 4, 3.0, 1.0), (6, 12, 1.0, 1.0)]:
        g = sch.chain_graph(
            [sch.Stage("m", f, b, bwd_w=b / 2) for _ in range(S)])
        sim = sch.get_scheduler("1f1b").simulate(g, M)
        assert sim["iteration_time"] == pytest.approx((M + S - 1) * (f + b))
        assert sim["schedule"] == "1f1b"


def test_split_conserves_work():
    """Deferring W moves work around but never changes per-device busy
    totals — only the makespan."""
    g = sch.chain_graph(
        [sch.Stage("m", 1.0, 2.0, bwd_w=1.0) for _ in range(4)])
    glued = sch.get_scheduler("1f1b").simulate(g, 8)
    split = sch.get_scheduler("zb-h1").simulate(g, 8)
    np.testing.assert_allclose(sorted(glued["per_device_busy"]),
                               sorted(split["per_device_busy"]))
    assert split["iteration_time"] <= glued["iteration_time"] + 1e-9


# ---------------------------------------------------------------------------
# ZB-H1 / interleaved vs 1F1B on frozen-MLLM fixtures
# ---------------------------------------------------------------------------

def frozen_mllm_modules(llm_trainable: bool):
    """Frozen encoder + trainable projector (+ frozen or trainable
    LLM): the paper's fine-tuning settings."""
    enc = pp.ModuleProfile("vision", np.ones(48) * 2.0, frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(32) * 1.5,
                           frozen=not llm_trainable)
    pp.analyze_chain([enc, llm], projector_trainable=[True, False])
    return enc, llm


def frozen_mllm_graph(llm_trainable: bool, stages: int = 8):
    return pp.build_chain_fused(list(frozen_mllm_modules(llm_trainable)),
                                stages, frozen_aware=True)


@pytest.mark.parametrize("llm_trainable", [False, True])
@pytest.mark.parametrize("microbatches", [8, 16, 24])
def test_zbh1_and_interleaved_not_worse_than_1f1b(llm_trainable,
                                                  microbatches):
    """At a fixed 8-device budget: ZB-H1 runs the same 8-stage graph
    with deferred W; interleaved searches its chunk count (a 2x-finer
    partition folded onto the same devices, or the v=1 degenerate).
    Neither may bubble more than plain 1F1B."""
    modules = list(frozen_mllm_modules(llm_trainable))
    sims = {s: pp.simulate_fused_chain(modules, 8, microbatches,
                                       schedule=s)[1]
            for s in sch.SCHEDULES}
    assert all(s["num_devices"] == 8 for s in sims.values())
    for name in ("zb-h1", "interleaved"):
        assert sims[name]["bubble_fraction"] <= \
            sims["1f1b"]["bubble_fraction"] + 1e-9, \
            (name, llm_trainable, microbatches)


def test_interleaved_megatron_order_beats_1f1b_on_homogeneous_chain():
    """On a homogeneous chain (the schedule's home turf) the Megatron
    item order realizes the ~v-fold fill/drain reduction outright —
    no fallback involved."""
    g8 = sch.chain_graph([sch.Stage("m", 2.0, 4.0) for _ in range(8)])
    g16 = sch.chain_graph([sch.Stage("m", 1.0, 2.0) for _ in range(16)])
    base = sch.get_scheduler("1f1b").simulate(g8, 24)
    il = sch.get_scheduler("interleaved", virtual_chunks=2).simulate(
        g16, 24)
    assert il["num_devices"] == base["num_devices"] == 8
    # busy/device = 144; fill: (D-1)(f+b) = 42 vs (D-1)(f+b)/v = 21
    assert base["iteration_time"] == pytest.approx(186.0)
    assert il["iteration_time"] == pytest.approx(165.0)


def test_zbh1_strictly_beats_1f1b_with_trainable_llm():
    """With a trainable LLM there is W work to defer: ZB-H1 must win
    outright, not just tie."""
    g = frozen_mllm_graph(llm_trainable=True)
    base = sch.get_scheduler("1f1b").simulate(g, 8)
    zb = sch.get_scheduler("zb-h1").simulate(g, 8)
    assert zb["iteration_time"] < base["iteration_time"]


def test_zbh1_equals_1f1b_when_fully_frozen():
    """Fully frozen backbone => no W passes anywhere => the split
    changes nothing."""
    g = frozen_mllm_graph(llm_trainable=False)
    base = sch.get_scheduler("1f1b").simulate(g, 8)
    zb = sch.get_scheduler("zb-h1").simulate(g, 8)
    assert zb["iteration_time"] == pytest.approx(base["iteration_time"])


def test_zbh1_on_modality_parallel_dag():
    """The W pass defers on DAG graphs (Fig. 6) too, not just chains."""
    e1 = pp.ModuleProfile("vision", np.ones(4) * 3.0, frozen=True)
    e2 = pp.ModuleProfile("audio", np.ones(6), frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(8) * 2.0, frozen=False,
                           trainable_upstream=True)
    g = pp.build_modality_parallel([e1, e2], llm, [2, 2], 4)
    base = sch.get_scheduler("1f1b").simulate(g, 8)
    zb = sch.get_scheduler("zb-h1").simulate(g, 8)
    assert zb["iteration_time"] <= base["iteration_time"] + 1e-9


# ---------------------------------------------------------------------------
# ZB-V invariants
# ---------------------------------------------------------------------------

def test_v_shape_devices_map():
    """Device i hosts chunks i and 2p-1-i: down the column and back."""
    assert sch.v_shape_devices(8) == [0, 1, 2, 3, 3, 2, 1, 0]
    assert sch.v_shape_devices(2) == [0, 0]
    with pytest.raises(AssertionError):
        sch.v_shape_devices(7)


def test_refine_chain_conserves_costs():
    g = sch.chain_graph([sch.Stage("m", 2.0, 4.0, (0, 8), bwd_w=2.0)
                         for _ in range(3)])
    fine = sch.refine_chain(g, 2)
    assert len(fine.stages) == 6
    assert sum(s.fwd for s in fine.stages) == pytest.approx(6.0)
    assert sum(s.bwd for s in fine.stages) == pytest.approx(12.0)
    assert sum(s.bwd_w for s in fine.stages) == pytest.approx(6.0)
    assert fine.stages[0].layer_range == (0, 4)
    assert fine.stages[1].layer_range == (4, 8)
    assert sch.refine_chain(g, 1) is g


@pytest.mark.parametrize("llm_trainable", [False, True])
@pytest.mark.parametrize("microbatches", [8, 16, 24])
def test_zbv_bubble_ordering_on_chains(llm_trainable, microbatches):
    """At a fixed 8-device budget: bubble(zb-v) <= bubble(zb-h1) <=
    bubble(1f1b). zb-v searches {2, 1} and v=1 IS the ZB-H1 placement,
    so the first inequality is structural; the second is ZB-H1's
    glued-fallback guarantee."""
    modules = list(frozen_mllm_modules(llm_trainable))
    sims = {s: pp.simulate_fused_chain(modules, 8, microbatches,
                                       schedule=s)[1]
            for s in ("1f1b", "zb-h1", "zb-v")}
    assert all(s["num_devices"] == 8 for s in sims.values())
    assert sims["zb-v"]["bubble_fraction"] <= \
        sims["zb-h1"]["bubble_fraction"] + 1e-9
    assert sims["zb-h1"]["bubble_fraction"] <= \
        sims["1f1b"]["bubble_fraction"] + 1e-9


def test_zbv_beats_zbh1_on_homogeneous_chain():
    """On a homogeneous all-trainable chain the V fold has fill/drain
    to win outright over one-chunk-per-device ZB-H1."""
    coarse = sch.chain_graph(
        [sch.Stage("m", 2.0, 4.0, bwd_w=2.0) for _ in range(4)])
    fine = sch.refine_chain(coarse, 2)
    zh = sch.get_scheduler("zb-h1").simulate(coarse, 8)
    zv = sch.get_scheduler("zb-v").simulate(fine, 8)
    assert zv["num_devices"] == zh["num_devices"] == 4
    assert zv["virtual_chunks"] == 2
    assert zv["iteration_time"] <= zh["iteration_time"] + 1e-9
    base = sch.get_scheduler("1f1b").simulate(coarse, 8)
    assert zv["iteration_time"] < base["iteration_time"]


def test_zbv_peak_activations_within_1f1b_envelope():
    """ZB-V's defining memory claim: with 2 chunk-stages per device
    (each half a 1F1B stage), every device's peak live activations stay
    within 2p chunk-activations = the deepest 1F1B device's p coarse
    activations — and, unlike 1F1B's p..1 ramp, uniformly."""
    for p, M in [(2, 8), (4, 8), (4, 24)]:
        coarse = sch.chain_graph(
            [sch.Stage("m", 2.0, 4.0, bwd_w=2.0) for _ in range(p)])
        fine = sch.refine_chain(coarse, 2)
        zv = sch.get_scheduler("zb-v").simulate(fine, M)
        base = sch.get_scheduler("1f1b").simulate(coarse, M)
        envelope = 2 * max(base["peak_activations_per_device"])
        assert all(pk <= envelope
                   for pk in zv["peak_activations_per_device"]), \
            (p, M, zv["peak_activations_per_device"], envelope)


def test_zbv_frozen_stages_emit_no_w_items():
    """Frozen chunks have no W pass at all — zero-bubble deferral
    headroom concentrates on the trainable chunks."""
    stages = [sch.Stage(f"enc{i}", 1.0, 0.0) for i in range(4)] + \
        [sch.Stage(f"llm{i}", 1.0, 3.0, bwd_w=1.0) for i in range(4)]
    g = sch.chain_graph(stages)
    sim = sch.get_scheduler("zb-v").simulate(g, 8)
    frozen = {s for s, st in enumerate(g.stages) if st.bwd_w == 0}
    w_items = [(s, m) for _, _, _, kind, s, m in sim["items"]
               if kind == "W"]
    assert w_items, "trainable chunks must have W passes"
    assert not [it for it in w_items if it[0] in frozen]
    # fully frozen chain: no W anywhere
    g0 = sch.chain_graph([sch.Stage("enc", 1.0, 0.0) for _ in range(4)])
    sim0 = sch.get_scheduler("zb-v").simulate(g0, 8)
    assert not any(kind == "W" for _, _, _, kind, _, _ in sim0["items"])


def test_zbv_degenerate_v1_is_zbh1():
    g = frozen_mllm_graph(llm_trainable=True)
    zh = sch.get_scheduler("zb-h1").simulate(g, 8)
    zv1 = sch.get_scheduler("zb-v", virtual_chunks=1).simulate(g, 8)
    assert zv1["iteration_time"] == pytest.approx(zh["iteration_time"])
    assert zv1["virtual_chunks"] == 1 and zv1["schedule"] == "zb-v"


# ---------------------------------------------------------------------------
# Interleaved device mapping
# ---------------------------------------------------------------------------

def test_interleave_devices_round_robin():
    g = sch.chain_graph([sch.Stage("m", 1.0, 2.0) for _ in range(8)])
    assert sch.interleave_devices(g, 2) == [0, 1, 2, 3, 0, 1, 2, 3]
    assert sch.interleave_devices(g, 4) == [0, 1, 0, 1, 0, 1, 0, 1]
    assert sch.interleave_devices(g, 1) == list(range(8))


def test_interleaved_uses_fewer_devices_and_conserves_work():
    g = sch.chain_graph([sch.Stage("m", 1.0, 2.0) for _ in range(8)])
    base = sch.get_scheduler("1f1b").simulate(g, 16)
    il = sch.get_scheduler("interleaved", virtual_chunks=2).simulate(g, 16)
    assert il["num_devices"] == 4 and base["num_devices"] == 8
    assert sum(il["per_device_busy"]) == pytest.approx(
        sum(base["per_device_busy"]))
    assert il["bubble_fraction"] <= base["bubble_fraction"] + 1e-9


# ---------------------------------------------------------------------------
# Scheduler interface / Algorithm 1 integration
# ---------------------------------------------------------------------------

def test_get_scheduler_registry():
    for name in sch.SCHEDULES:
        s = sch.get_scheduler(name)
        assert s.name == name
    with pytest.raises(ValueError):
        sch.get_scheduler("gpipe")


def test_simulate_tags_schedule_name():
    g = sch.chain_graph([sch.Stage("m", 1.0, 2.0) for _ in range(4)])
    for name in sch.SCHEDULES:
        assert sch.simulate(g, 8, schedule=name)["schedule"] == name


def test_auto_parallelize_returns_schedule_name():
    e = pp.ModuleProfile("vision", np.ones(8) * 3.0, frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(16) * 2.0, frozen=False,
                           trainable_upstream=True)
    best = pp.auto_parallelize([e], llm, total_devices=8,
                               num_microbatches=8)
    assert best["schedule"] in sch.SCHEDULES
    assert best["encoder_names"] == ["vision"]
    # schedules are compared at the same device budget: the simulated
    # device count must equal the allocated stage count
    assert best["devices"] == best["llm_stages"] + \
        sum(best["encoder_stages"])
    # searching more schedules can only improve on 1F1B-only
    base = pp.auto_parallelize([e], llm, total_devices=8,
                               num_microbatches=8, schedules=("1f1b",))
    assert best["tput_per_device"] >= base["tput_per_device"] - 1e-12
    assert base["schedule"] == "1f1b"


def test_auto_parallelize_joint_chunk_search():
    """Algorithm 1 searches (schedule, virtual_chunks) jointly: the
    winner carries its chunk count, every sim is tagged with one, and
    widening the v set can only improve throughput."""
    e = pp.ModuleProfile("vision", np.ones(8) * 3.0, frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(16) * 2.0, frozen=False,
                           trainable_upstream=True)
    best = pp.auto_parallelize([e], llm, total_devices=8,
                               num_microbatches=8)
    assert best["schedule"] in sch.SCHEDULES
    assert best["virtual_chunks"] >= 1
    narrow = pp.auto_parallelize([e], llm, total_devices=8,
                                 num_microbatches=8,
                                 virtual_chunks=(1,))
    assert best["tput_per_device"] >= narrow["tput_per_device"] - 1e-12


def test_infeasible_explicit_chunk_tuple_degrades_to_v1():
    """An explicit virtual_chunks candidate set that fits nowhere
    (v=4 on an 8-layer module split 4 ways) degrades to the v=1
    placement instead of dying — the documented fold-back behavior."""
    llm = pp.ModuleProfile("llm", np.ones(8) * 2.0, frozen=False)
    g, sim = pp.simulate_fused_chain([llm], 4, 8, schedule="interleaved",
                                     virtual_chunks=(4,))
    assert sim["num_devices"] == 4 and sim["virtual_chunks"] == 1


def test_simulate_plan_zbv_keeps_device_budget():
    """zb-v folds its two chunks per device back onto the planned
    ranks, so the simulated device count equals the allocation."""
    e = pp.ModuleProfile("vision", np.ones(4) * 3.0, frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(8) * 2.0, frozen=False,
                           trainable_upstream=True)
    g, sim = pp.simulate_plan([e], llm, [2], 4, 8, schedule="zb-v")
    assert sim["num_devices"] == 6
    assert len(g.stages) in (6, 12)
    assert sim["schedule"] == "zb-v"
    # not enough layers to chunk => the v=1 (ZB-H1 placement) degenerate
    tiny = pp.ModuleProfile("llm", np.ones(4), frozen=False)
    g, sim = pp.simulate_plan([], tiny, [], 4, 8, schedule="zb-v")
    assert sim["num_devices"] == 4 and len(g.stages) == 4
    assert sim["virtual_chunks"] == 1


def test_simulate_plan_keeps_device_budget():
    """Interleaved folds its virtual chunks onto the planned devices
    (and degrades v when a module lacks layers) — num_devices always
    equals the allocated stage count."""
    e = pp.ModuleProfile("vision", np.ones(4) * 3.0, frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(8) * 2.0, frozen=False,
                           trainable_upstream=True)
    for schedule in sch.SCHEDULES:
        g, sim = pp.simulate_plan([e], llm, [2], 4, 8, schedule=schedule)
        assert sim["num_devices"] == 6, schedule
        # the winning interleaved graph is v=2 (12 stages) or the
        # degenerate v=1 (6 stages) — never anything else
        assert len(g.stages) in (6, 12)
    # not enough layers for chunking anywhere => only v=1 feasible
    tiny = pp.ModuleProfile("llm", np.ones(4), frozen=False)
    g, sim = pp.simulate_plan([], tiny, [], 4, 8, schedule="interleaved")
    assert sim["num_devices"] == 4 and len(g.stages) == 4


def test_parallel_spec_threads_schedule():
    """MultimodalParallelSpec carries the schedule choice end to end."""
    from repro.core.modality import (ModalityModule, MultimodalModule,
                                     MultimodalParallelSpec, ParallelSpec)
    from repro.configs.paper_mllm import llm_config, vision_encoder_config
    mllm = MultimodalModule(
        encoders={"vision": ModalityModule(
            "vision", vision_encoder_config("S", reduced=True),
            modality_id=1, num_tokens=16)},
        llm_cfg=llm_config("S", reduced=True))
    mllm.freeze("vision", module=True, projector=False)
    mllm.freeze("llm", module=False)      # trainable LLM => W exists
    spec = MultimodalParallelSpec(
        encoder_specs={"vision": ParallelSpec(pp_size=1)},
        llm_spec=ParallelSpec(pp_size=2), num_microbatches=8,
        schedule="zb-h1")
    plan = spec.apply(mllm, text_len=64)
    assert plan["schedule_name"] == "zb-h1"
    assert plan["schedule"]["bubble_fraction"] >= 0.0


def test_parallel_spec_graph_stays_one_stage_per_device():
    """Executor contract: plan["graph"] always has one stage per
    simulated device — interleaved's v-times finer simulation graph
    must fold back to the planned partition, and schedule_from_plan
    resolves the name from the apply-plan flavor too."""
    from repro.core.modality import (ModalityModule, MultimodalModule,
                                     MultimodalParallelSpec, ParallelSpec)
    from repro.core.modality_parallel import schedule_from_plan
    from repro.configs.paper_mllm import llm_config, vision_encoder_config
    mllm = MultimodalModule(
        encoders={"vision": ModalityModule(
            "vision", vision_encoder_config("S"), modality_id=1,
            num_tokens=64)},
        llm_cfg=llm_config("S"))
    mllm.freeze("vision", module=True, projector=False)
    mllm.freeze("llm", module=False)
    for schedule in sch.SCHEDULES:
        spec = MultimodalParallelSpec(
            encoder_specs={"vision": ParallelSpec(pp_size=2)},
            llm_spec=ParallelSpec(pp_size=6), num_microbatches=16,
            schedule=schedule)
        plan = spec.apply(mllm, text_len=256)
        assert len(plan["graph"].stages) == \
            plan["schedule"]["num_devices"], schedule
        with pytest.warns(DeprecationWarning):
            assert schedule_from_plan(plan) == schedule


def test_split_devices_accepts_auto_parallelize_plan():
    from repro.core import modality_parallel as mp

    class FakeMLLM:
        encoders = {"audio": None, "vision": None}

    # encoder_names carries the caller's profile order, so counts land
    # on the right encoder even when that order is not name-sorted;
    # stage counts stay COARSE (one per device) even for chunked
    # schedules — virtual chunks fold onto the same devices
    plan = {"encoder_stages": [2, 1], "encoder_names": ["vision", "audio"],
            "schedule": "zb-v", "virtual_chunks": 2, "llm_stages": 3}
    split = mp.split_devices(FakeMLLM(), list(range(6)), plan=plan)
    assert len(split["vision"]) == 2 and len(split["audio"]) == 1
    assert len(split["llm"]) == 3
    assert all(isinstance(v, list) for v in split.values())
    with pytest.warns(DeprecationWarning):
        assert mp.schedule_from_plan(plan) == "zb-v"
    with pytest.warns(DeprecationWarning):
        assert mp.virtual_chunks_from_plan(plan) == 2


def test_plan_shims_deprecate_and_reject_malformed():
    """The legacy string-digging shims survive only as deprecated
    adapters: every call warns, None still means "no plan" (classic
    1F1B), and a dict that carries no recognizable schedule raises
    instead of silently defaulting to 1f1b."""
    from repro.core import modality_parallel as mp
    with pytest.warns(DeprecationWarning):
        assert mp.schedule_from_plan(None) == "1f1b"
    with pytest.warns(DeprecationWarning):
        assert mp.virtual_chunks_from_plan(None) == 1
    # apply-flavor dicts resolve through schedule_name
    with pytest.warns(DeprecationWarning):
        assert mp.schedule_from_plan(
            {"schedule": {"iteration_time": 1.0},
             "schedule_name": "interleaved"}) == "interleaved"
    # a recognized plan flavor without the chunk tag defaults to 1
    with pytest.warns(DeprecationWarning):
        assert mp.virtual_chunks_from_plan({"schedule": "1f1b"}) == 1
    for bad in ({"vision": 1}, {"schedule": "gpipe"}, 17, "zb-v"):
        with pytest.warns(DeprecationWarning), \
                pytest.raises(ValueError):
            mp.schedule_from_plan(bad)
    for bad in ({"vision": 1}, {"virtual_chunks": 0}, 17):
        with pytest.warns(DeprecationWarning), \
                pytest.raises(ValueError):
            mp.virtual_chunks_from_plan(bad)
