"""Token-distribution planner tests (paper §4.3.2 / Appendix A)."""
import numpy as np
import pytest

from repro.core import bam, distribution as dist


def rand_W(seed, n=64, lo=1.0, hi=20.0):
    return np.random.default_rng(seed).uniform(lo, hi, size=n)


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("method", ["zigzag", "ring", "lpt", "random"])
def test_plans_partition_all_blocks(seed, method):
    W = rand_W(seed)
    plan = dist.PLANNERS[method](W, 8)
    # completeness + disjointness
    seen = np.concatenate(plan.per_rank_blocks)
    assert sorted(seen) == list(range(len(W)))
    # loads consistent
    for g, blocks in enumerate(plan.per_rank_blocks):
        np.testing.assert_allclose(plan.loads[g], W[blocks].sum())


@pytest.mark.parametrize("seed", range(10))
def test_lpt_respects_graham_bound(seed):
    W = rand_W(seed, n=100)
    plan = dist.lpt(W, 8)
    assert plan.makespan <= dist.graham_bound(W, 8) + 1e-9


@pytest.mark.parametrize("seed", range(5))
def test_ilp_is_optimal_and_lpt_close(seed):
    W = rand_W(seed, n=12)
    opt = dist.ilp(W, 3)
    greedy = dist.lpt(W, 3)
    assert opt.makespan <= greedy.makespan + 1e-9
    # LPT's 4/3 - 1/(3m) approximation guarantee
    assert greedy.makespan <= opt.makespan * (4 / 3) + 1e-9


def test_zigzag_balances_causal():
    """Paper Fig. 4a: zigzag is perfectly balanced for causal masks."""
    T, G, bs = 128, 4, 1
    bits, pos = bam.build_sample_bits([("text", 0, T)], T)
    W = bam.block_workload(bits, pos, bs)
    z = dist.zigzag(W, G, bs)
    assert z.imbalance < 1.02


@pytest.mark.parametrize("mode", ["ee", "mp"])
def test_lpt_beats_zigzag_on_multimodal(mode):
    """Paper Fig. 4b / Table 4: zigzag degrades on EE/MP masks; LPT
    stays balanced."""
    from repro.data.synthetic import random_multimodal_bits
    rng_imb = {"zigzag": [], "lpt": [], "random": []}
    for seed in range(5):
        bits, pos = random_multimodal_bits(1024, mode, seed=seed)
        W = bam.block_workload(bits, pos, 16)
        for m in rng_imb:
            plan = dist.PLANNERS[m](W, 8) if m != "random" else \
                dist.random_plan(W, 8, seed=seed)
            rng_imb[m].append(plan.imbalance)
    assert np.mean(rng_imb["lpt"]) <= np.mean(rng_imb["zigzag"]) + 1e-9
    assert np.mean(rng_imb["lpt"]) < 1.1


@pytest.mark.parametrize("seed", range(3))
def test_random_close_to_lpt_for_large_T(seed):
    """Paper §5.3: for T >> G^2 random distribution variance approaches
    the greedy one (Chernoff)."""
    from repro.data.synthetic import random_multimodal_bits
    bits, pos = random_multimodal_bits(8192, "ee", seed=seed)
    W = bam.block_workload(bits, pos, 8)
    r = dist.random_plan(W, 4, seed=seed)
    l = dist.lpt(W, 4)
    assert r.imbalance < l.imbalance * 1.25 + 0.05


def test_plan_tokens_end_to_end():
    bits, pos = bam.build_sample_bits(
        [("text", 0, 32), ("mod", 1, 16), ("text", 0, 16)], 64)
    plan = dist.plan_tokens(bits, pos, 4, block_size=8, method="lpt")
    assert plan.num_ranks == 4
    assert sum(len(b) for b in plan.per_rank_blocks) == 8
