"""repro.parallel: the typed parallelize() entrypoint and the
MLLMParallelPlan it returns — search parity with Algorithm 1, JSON
round-trips, the golden 8-rank paper_mllm plan, the executor
fold-back contract, and the deprecated-shim interop."""
import json
import os

import numpy as np
import pytest

from repro.configs.paper_mllm import llm_config, vision_encoder_config
from repro.core import distribution as dist
from repro.core import pipeline as pp
from repro.core.modality import (ModalityModule, MultimodalModule,
                                 MultimodalParallelSpec, ParallelSpec)
from repro.parallel import (ClusterSpec, ContextPlan, MLLMParallelPlan,
                            SchedulePlan, StagePlan, WorkloadShape,
                            mllm_workload_bits, parallelize,
                            plan_context, search_plan)

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "paper_mllm_8rank_plan.json")

CLUSTER_8 = ClusterSpec(num_devices=8, cp_size=8)
SHAPE_1K = WorkloadShape(text_len=1024, num_microbatches=8)


@pytest.fixture(scope="module")
def paper_vlm():
    from repro.models.mllm import build_paper_mllm
    return build_paper_mllm("vlm")


@pytest.fixture(scope="module")
def paper_plan(paper_vlm):
    return parallelize(paper_vlm, CLUSTER_8, SHAPE_1K)


# ---------------------------------------------------------------------------
# parallelize(): joint search parity
# ---------------------------------------------------------------------------

def test_schedule_plan_matches_auto_parallelize_winner(paper_vlm,
                                                       paper_plan):
    """The typed entrypoint must pick EXACTLY what Algorithm 1 picks:
    same schedule, chunk count, stage allocation, and simulated
    figures (bit-for-bit — both run the same deterministic search)."""
    encs, llm = paper_vlm.profiles(1024)
    best = pp.auto_parallelize(encs, llm, total_devices=8,
                               num_microbatches=8)
    s = paper_plan.schedule
    assert s.name == best["schedule"]
    assert s.virtual_chunks == best["virtual_chunks"]
    assert s.iteration_time == best["iteration_time"]
    assert s.bubble_fraction == best["bubble_fraction"]
    assert s.tput_per_device == best["tput_per_device"]
    assert paper_plan.stage.llm_stages == best["llm_stages"]
    assert list(paper_plan.stage.encoder_stages) == \
        best["encoder_stages"]
    assert list(paper_plan.stage.encoder_names) == \
        best["encoder_names"]
    assert paper_plan.pp_devices == best["devices"]


def test_context_plan_reproduces_plan_tokens(paper_vlm, paper_plan):
    """The ContextPlan must reproduce plan_tokens' decision for the
    same workload: same balancer, same block->rank assignment, same
    makespan."""
    bits, pos = mllm_workload_bits(paper_vlm, 1024)
    ref = dist.plan_tokens(bits, pos, 8, block_size=128, method="lpt")
    c = paper_plan.context
    assert c.num_ranks == 8 and c.method == "lpt"
    assert list(c.assignment) == list(ref.assignment)
    assert c.makespan == ref.makespan
    np.testing.assert_allclose(np.array(c.loads), ref.loads)
    # the typed wrapper reconstructs a working core plan
    core = c.core_plan()
    assert core.makespan == ref.makespan
    assert sorted(np.concatenate(core.per_rank_blocks).tolist()) == \
        list(range(len(c.assignment)))


def test_plan_json_roundtrip_and_golden_stability(paper_plan):
    """Plans are plain data: to_json/from_json is lossless, and the
    recorded golden plan pins the search — an accidental regression in
    the partitioner, simulator, or balancer shows up as a diff against
    tests/data/paper_mllm_8rank_plan.json."""
    assert MLLMParallelPlan.from_json(paper_plan.to_json()) == \
        paper_plan
    golden = MLLMParallelPlan.load(GOLDEN)
    # the schedule choice must be stable (the headline guard) ...
    assert golden.schedule.name == paper_plan.schedule.name == "zb-v"
    assert golden.schedule.virtual_chunks == \
        paper_plan.schedule.virtual_chunks == 2
    # ... and so must everything else the search decided
    assert golden == paper_plan


def test_apply_instantiates_pinned_schedule(paper_vlm, paper_plan):
    """plan.apply re-simulates the PINNED (schedule, v) pair — the
    executor contract reproduces the recorded figures instead of
    re-searching."""
    ex = paper_plan.apply(paper_vlm)
    assert ex["schedule_name"] == paper_plan.schedule.name
    assert ex["virtual_chunks"] == paper_plan.schedule.virtual_chunks
    assert ex["schedule"]["bubble_fraction"] == \
        pytest.approx(paper_plan.schedule.bubble_fraction)
    assert len(ex["graph"].stages) == ex["devices"] == \
        paper_plan.pp_devices
    assert ex["plan"] is paper_plan
    assert ex["context"] == paper_plan.context
    # applying against a different encoder set fails loudly
    from repro.models.mllm import build_paper_mllm
    with pytest.raises(AssertionError):
        paper_plan.apply(build_paper_mllm("valm"))


# ---------------------------------------------------------------------------
# Executor fold-back: pinned before the port, equal after it
# ---------------------------------------------------------------------------

def _big_vlm():
    mllm = MultimodalModule(
        encoders={"vision": ModalityModule(
            "vision", vision_encoder_config("S"), modality_id=1,
            num_tokens=64)},
        llm_cfg=llm_config("S"))
    mllm.freeze("vision", module=True, projector=False)
    mllm.freeze("llm", module=False)
    return mllm


def test_spec_apply_folds_interleaved_sim_graph_back():
    """The fold-back path pinned by behavior: force an interleaved
    v=2 winner (24 sim stages on 8 devices); plan["graph"] must be the
    one-stage-per-device coarse partition — stage for stage equal to
    build_modality_parallel at the planned counts — while the sim dict
    keeps the finer graph's accounting."""
    mllm = _big_vlm()
    spec = MultimodalParallelSpec(
        encoder_specs={"vision": ParallelSpec(pp_size=2)},
        llm_spec=ParallelSpec(pp_size=6), num_microbatches=16,
        schedule="interleaved", virtual_chunks=(2,))
    plan = spec.apply(mllm, text_len=256)
    sim = plan["schedule"]
    assert sim["virtual_chunks"] == 2 and sim["num_devices"] == 8
    g = plan["graph"]
    assert len(g.stages) == 8
    encs, llm = mllm.profiles(256)
    ref = pp.build_modality_parallel(encs, llm, [2], 6,
                                     frozen_aware=True)
    assert sorted(g.edges) == sorted(ref.edges)
    for got, want in zip(g.stages, ref.stages):
        assert got.module == want.module
        assert got.layer_range == want.layer_range
        assert got.fwd == pytest.approx(want.fwd)
        assert got.bwd == pytest.approx(want.bwd)
        assert got.bwd_w == pytest.approx(want.bwd_w)


def test_typed_apply_equals_spec_apply_foldback():
    """MLLMParallelPlan.apply is the port of MultimodalParallelSpec.
    apply: for the same pinned allocation + (schedule, v) both emit
    identical executor contracts."""
    mllm = _big_vlm()
    spec = MultimodalParallelSpec(
        encoder_specs={"vision": ParallelSpec(pp_size=2)},
        llm_spec=ParallelSpec(pp_size=6), num_microbatches=16,
        schedule="interleaved", virtual_chunks=(2,))
    legacy = spec.apply(mllm, text_len=256)
    typed = MLLMParallelPlan(
        stage=StagePlan(("vision",), (2,), 6),
        schedule=SchedulePlan(
            name="interleaved", virtual_chunks=2, num_microbatches=16,
            iteration_time=legacy["schedule"]["iteration_time"],
            bubble_fraction=legacy["schedule"]["bubble_fraction"],
            num_devices=8,
            peak_activations_per_device=tuple(
                legacy["schedule"]["peak_activations_per_device"]),
            tput_per_device=0.0),
        context=None, text_len=256)
    ported = typed.apply(mllm)
    assert ported["schedule_name"] == legacy["schedule_name"]
    assert ported["virtual_chunks"] == legacy["virtual_chunks"]
    assert ported["schedule"]["iteration_time"] == \
        pytest.approx(legacy["schedule"]["iteration_time"])
    got, want = ported["graph"], legacy["graph"]
    assert sorted(got.edges) == sorted(want.edges)
    for a, b in zip(got.stages, want.stages):
        assert (a.module, a.layer_range) == (b.module, b.layer_range)
        assert a.fwd == pytest.approx(b.fwd)
        assert a.bwd == pytest.approx(b.bwd)
        assert a.bwd_w == pytest.approx(b.bwd_w)


# ---------------------------------------------------------------------------
# search_plan objectives / plan_context balancers
# ---------------------------------------------------------------------------

def small_profiles():
    enc = pp.ModuleProfile("vision", np.ones(8) * 3.0, frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(16) * 2.0, frozen=False,
                           trainable_upstream=True)
    return enc, llm


def test_search_plan_objectives():
    enc, llm = small_profiles()
    cluster, shape = ClusterSpec(8), WorkloadShape(num_microbatches=8)
    tput = search_plan([enc], llm, cluster, shape)
    fast = search_plan([enc], llm, cluster, shape,
                       objective="iteration_time")
    # min-iteration-time spends devices freely; tput/device never
    # prefers a slower iteration at the same footprint
    assert fast.schedule.iteration_time <= \
        tput.schedule.iteration_time + 1e-9
    assert fast.pp_devices >= tput.pp_devices
    with pytest.raises(ValueError):
        search_plan([enc], llm, cluster, shape, objective="speed")
    with pytest.raises(ValueError):
        pp.auto_parallelize([enc], llm, 8, 8, objective="speed")


def test_plan_context_balancers_and_auto():
    from repro.core import bam
    bits, pos = bam.build_sample_bits(
        [("text", 0, 64), ("mod", 1, 32), ("text", 0, 32)], 128)
    plans = {m: plan_context(bits, pos, 4, block_size=8, method=m)
             for m in ("lpt", "zigzag", "ring")}
    auto = plan_context(bits, pos, 4, block_size=8, method="auto")
    assert auto.makespan == min(p.makespan for p in plans.values())
    assert auto.method in ("lpt", "zigzag", "ring")
    for p in plans.values():
        assert p.num_ranks == 4
        assert len(p.assignment) == 16
        assert p.imbalance >= 1.0 - 1e-12
    with pytest.raises(ValueError):
        plan_context(bits, pos, 4, method="greedy")


# ---------------------------------------------------------------------------
# Serialization hygiene + typed input validation
# ---------------------------------------------------------------------------

def test_from_json_rejects_malformed():
    enc, llm = small_profiles()
    plan = search_plan([enc], llm, ClusterSpec(4),
                       WorkloadShape(num_microbatches=8))
    d = json.loads(plan.to_json())
    d["format_version"] = 99
    with pytest.raises(ValueError):
        MLLMParallelPlan.from_json(json.dumps(d))
    d = json.loads(plan.to_json())
    del d["schedule"]["name"]
    with pytest.raises(ValueError):
        MLLMParallelPlan.from_json(json.dumps(d))
    with pytest.raises(ValueError):
        MLLMParallelPlan.from_json("{}")


def test_component_validation():
    with pytest.raises(AssertionError):
        SchedulePlan(name="gpipe", virtual_chunks=1, num_microbatches=8,
                     iteration_time=1.0, bubble_fraction=0.0,
                     num_devices=1, peak_activations_per_device=(1,),
                     tput_per_device=1.0)
    with pytest.raises(AssertionError):
        ContextPlan(method="greedy", num_ranks=2, block_size=8,
                    assignment=(0, 1), loads=(1.0, 1.0))
    with pytest.raises(AssertionError):
        StagePlan(("vision",), (1, 2), 1)
    with pytest.raises(AssertionError):
        ClusterSpec(0)
    with pytest.raises(AssertionError):
        WorkloadShape(text_len=0)


def test_describe_mentions_every_decision():
    enc, llm = small_profiles()
    plan = search_plan([enc], llm, ClusterSpec(4),
                       WorkloadShape(num_microbatches=8))
    text = plan.describe()
    assert plan.schedule.name in text
    assert "vision" in text and "llm" in text
    assert "cp     : none" in text         # no workload given
    assert plan.context is None


def test_split_devices_accepts_typed_plan(paper_vlm, paper_plan):
    from repro.core.modality_parallel import split_devices
    split = split_devices(paper_vlm,
                          list(range(paper_plan.pp_devices)),
                          plan=paper_plan)
    assert len(split["vision"]) == \
        paper_plan.stage_counts_by_name()["vision"]
    assert len(split["llm"]) == paper_plan.stage.llm_stages
