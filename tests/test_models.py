"""Per-architecture smoke tests (deliverable f) + model-math
consistency tests (decode vs forward, chunked vs quadratic scans)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, list_archs
from repro.launch import specs as S
from repro.models import api, whisper
from repro.optim import optimizer as opt
from repro.training import steps

ARCHS = list_archs()
B, T = 2, 32


def tiny_batch(cfg, seed=0, seq=T, batch=B):
    return S.concrete_batch(cfg, seq, batch, seed=seed)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch, reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    seq = 64 if cfg.family == "vlm" else T   # room for the patch block
    batch = tiny_batch(cfg, seq=seq)
    logits, aux = api.forward(params, cfg, batch)
    assert logits.shape == (B, batch["tokens"].shape[1], cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    state = opt.init(ocfg, params)
    step = jax.jit(steps.make_train_step(cfg, ocfg))
    seq = 64 if cfg.family == "vlm" else T
    batch = tiny_batch(cfg, seq=seq)
    p2, s2, metrics = step(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    delta = max(float(jnp.abs(a - b).max()) for a, b in
                zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    cache = api.init_cache(cfg, B, T)
    if cfg.family == "audio":
        frames = jax.random.normal(
            jax.random.PRNGKey(1), (B, cfg.encdec.encoder_seq, cfg.d_model))
        cache = whisper.prefill_cross(params, cfg, cache, frames)
    batch = {"tokens": jnp.ones((B, 1), jnp.int32),
             "positions": jnp.full((B, 1), 3, jnp.int32)}
    logits, cache2 = api.decode_step(params, cfg, cache, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma2-9b",
                                  "starcoder2-7b", "qwen2.5-14b",
                                  "qwen2-moe-a2.7b", "zamba2-2.7b",
                                  "xlstm-125m"])
def test_decode_matches_forward(arch):
    """Prefill-free consistency: feeding tokens one-by-one through
    decode_step must match the parallel forward's logits."""
    cfg = get_config(arch, reduced=True)
    if cfg.family == "moe":
        # dense backend for exactness
        assert cfg.moe.backend == "dense"
    params = api.init(jax.random.PRNGKey(0), cfg)
    n = 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, n)), jnp.int32)
    pos = jnp.arange(n, dtype=jnp.int32)[None]
    full_logits, _ = api.forward(
        params, cfg, {"tokens": toks, "positions": pos})

    cache = api.init_cache(cfg, 1, n)
    got = []
    for i in range(n):
        batch = {"tokens": toks[:, i:i + 1],
                 "positions": jnp.full((1, 1), i, jnp.int32)}
        logits, cache = api.decode_step(params, cfg, cache, batch)
        got.append(logits[:, 0])
    got = jnp.stack(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               atol=2e-3, rtol=2e-3)


def test_mamba_chunked_vs_step():
    """SSD chunked scan == recurrent single-step scan."""
    from repro.configs.base import SSMConfig
    from repro.models.mamba2 import ssd_chunked, ssd_step
    rng = jax.random.PRNGKey(0)
    Bs, T_, nh, hd, ds = 2, 16, 3, 8, 4
    xh = jax.random.normal(jax.random.fold_in(rng, 0), (Bs, T_, nh, hd))
    Bm = jax.random.normal(jax.random.fold_in(rng, 1), (Bs, T_, ds))
    Cm = jax.random.normal(jax.random.fold_in(rng, 2), (Bs, T_, ds))
    dt = jax.nn.softplus(
        jax.random.normal(jax.random.fold_in(rng, 3), (Bs, T_, nh)))
    log_a = -jnp.exp(
        jax.random.normal(jax.random.fold_in(rng, 4), (Bs, T_, nh)) * 0.1
    ) * dt
    y_c, h_c = ssd_chunked(xh, Bm, Cm, dt, log_a, chunk=4)
    h = jnp.zeros((Bs, nh, hd, ds))
    ys = []
    for t in range(T_):
        y, h = ssd_step(xh[:, t:t+1], Bm[:, t:t+1], Cm[:, t:t+1],
                        dt[:, t:t+1], log_a[:, t:t+1], h)
        ys.append(y[:, 0])
    y_s = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("seed", range(3))
def test_mlstm_chunked_vs_parallel(seed):
    """Chunkwise mLSTM == stabilized quadratic oracle."""
    from repro.models.xlstm import mlstm_chunked, mlstm_parallel
    rng = jax.random.PRNGKey(seed)
    Bs, T_, nh, hd = 2, 24, 2, 8
    q = jax.random.normal(jax.random.fold_in(rng, 0), (Bs, T_, nh, hd))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (Bs, T_, nh, hd))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (Bs, T_, nh, hd))
    log_i = jax.random.normal(jax.random.fold_in(rng, 3), (Bs, T_, nh))
    log_f = jax.nn.log_sigmoid(
        jax.random.normal(jax.random.fold_in(rng, 4), (Bs, T_, nh)) + 2)
    ref = mlstm_parallel(q, k, v, log_i, log_f)
    got, _ = mlstm_chunked(q, k, v, log_i, log_f, chunk=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_moe_dense_vs_capacity_backend():
    """With ample capacity nothing is dropped -> backends agree."""
    from repro.configs.base import MoEConfig
    from repro.models import moe
    cfg = get_config("qwen2-moe-a2.7b", reduced=True)
    cfg_cap = cfg.replace(moe=MoEConfig(
        num_experts=4, top_k=2, num_shared_experts=1, d_expert=128,
        backend="capacity", capacity_factor=4.0))
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = tiny_batch(cfg)
    l1, _ = moe.forward(params, cfg, batch)
    l2, _ = moe.forward(params, cfg_cap, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=1e-4, rtol=1e-4)


def test_gemma2_local_global_masks_differ():
    cfg = get_config("gemma2-9b", reduced=True)
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = tiny_batch(cfg)
    logits, _ = api.forward(params, cfg, batch)
    # all-global variant must differ (window is active on local layers)
    cfg2 = cfg.replace(sliding_window=0, local_global_pattern=0)
    logits2, _ = api.forward(params, cfg2, batch)
    assert float(jnp.abs(logits - logits2).max()) > 1e-6


def test_attn_impl_kernel_matches_xla():
    """cfg.attn_impl="bam_interpret" routes the transformer's attention
    through the fused Pallas path (forward AND backward) — logits and
    parameter grads must match the XLA path."""
    from repro.configs.base import ModelConfig
    from repro.core import bam
    from repro.models import transformer as tf
    cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype="float32", remat=False,
                      seq_shard_activations=False)
    T_ = 40
    bits_np, pos_np = bam.build_sample_bits(
        [("text", 0, 10), ("mod", 1, 10), ("text", 0, 20)], T_)
    batch = {"tokens": jnp.zeros((2, T_), jnp.int32),
             "positions": jnp.broadcast_to(jnp.asarray(pos_np)[None],
                                           (2, T_)),
             "bits": jnp.broadcast_to(jnp.asarray(bits_np)[None], (2, T_))}
    params = tf.init(jax.random.PRNGKey(0), cfg)
    lx, _ = tf.forward(params, cfg, batch)
    lk, _ = tf.forward(params, cfg.replace(attn_impl="bam_interpret"), batch)
    np.testing.assert_allclose(np.asarray(lk), np.asarray(lx),
                               atol=2e-5, rtol=2e-5)

    def loss(p, c):
        lg, _ = tf.forward(p, c, batch)
        return jnp.sum(lg ** 2)

    g1 = jax.grad(loss)(params, cfg)
    g2 = jax.grad(loss)(params, cfg.replace(attn_impl="bam_interpret"))
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, rtol=2e-3)


def test_vlm_mrope_text_equals_rope():
    """M-RoPE with equal (t,h,w) ids == standard RoPE (text tokens)."""
    from repro.models.layers import apply_mrope, apply_rope
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32)[None], (2, 8))
    pos3 = jnp.broadcast_to(pos[None], (3, 2, 8))
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, pos3, (4, 2, 2), 1e4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_param_count_analytics():
    """Analytic param model tracks actual init within 20% (used by the
    frozen-aware partitioner cost oracle)."""
    for arch in ("qwen3-1.7b", "xlstm-125m", "zamba2-2.7b"):
        cfg = get_config(arch, reduced=True)
        params = api.init(jax.random.PRNGKey(0), cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        approx = cfg.param_count()
        assert 0.5 < approx / actual < 1.6, (arch, approx, actual)
