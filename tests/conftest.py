import os
import sys

# src on the path (tests also work without PYTHONPATH=src)
sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))
# NOTE: never set --xla_force_host_platform_device_count here — smoke
# tests must see the single real device; multi-device tests spawn
# subprocesses (tests/helpers.py).
