"""BAM (Bitfield Attention Mask) unit + property tests.

Property tests are seed-parametrized (no hypothesis wheel in the
container — same invariants, explicit seed sweep)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import bam


def brute_force_mask(bits, pos, window=0):
    """O(T^2) python reimplementation of the documented semantics."""
    T = len(bits)
    m = np.zeros((T, T), bool)
    for i in range(T):
        for j in range(T):
            bi, bj = int(bits[i]), int(bits[j])
            if bi == 0 or bj == 0:
                continue
            if (bi >> bam.INST_SHIFT) & 0xFF != (bj >> bam.INST_SHIFT) & 0xFF:
                continue
            mj = (bj >> bam.MOD_SHIFT) & 0x7F
            mi = (bi >> bam.MOD_SHIFT) & 0x7F
            if not ((bi & 0xFFFF) >> mj) & 1:
                continue
            if mi == bam.TEXT:
                ok = pos[j] <= pos[i]
                if window:
                    ok = ok and (pos[i] - pos[j]) < window
            else:
                ok = mj == mi
            m[i, j] = ok
    return m


def random_segments(rng, total):
    segs, used = [], 0
    doc = 0
    while used < total - 4:
        kind = rng.choice(["text", "mod", "newdoc"], p=[0.5, 0.4, 0.1])
        if kind == "newdoc" and used > 0:
            segs.append(("newdoc", 0, 0))
            continue
        n = int(rng.integers(1, min(8, total - used) + 1))
        if kind == "mod":
            segs.append(("mod", int(rng.integers(1, 5)), n))
        else:
            segs.append(("text", 0, n))
        used += n
    return segs


def test_encode_fields():
    b = bam.encode(0b101, 3, 7)
    assert bam.attends_set(np.uint32(b)) == 0b101
    assert bam.own_modality(np.uint32(b)) == 3
    assert bam.instance_id(np.uint32(b)) == 7


def test_text_and_modality_tokens():
    t = bam.text_token([1, 2])
    assert bam.attends_set(np.uint32(t)) == 0b111
    assert bam.own_modality(np.uint32(t)) == bam.TEXT
    m = bam.modality_token(2, instance=3)
    assert bam.attends_set(np.uint32(m)) == 0b100
    assert bam.own_modality(np.uint32(m)) == 2
    assert bam.instance_id(np.uint32(m)) == 3


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("window", [0, 5])
def test_allowed_mask_matches_bruteforce(seed, window):
    rng = np.random.default_rng(seed)
    T = 48
    bits, pos = bam.build_sample_bits(random_segments(rng, T), T)
    got = np.asarray(bam.allowed_mask(
        jnp.asarray(bits)[None], jnp.asarray(bits)[None],
        jnp.asarray(pos)[None], jnp.asarray(pos)[None], window))[0]
    want = brute_force_mask(bits, pos, window)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("seed", range(8))
def test_token_workload_is_mask_rowsum(seed):
    rng = np.random.default_rng(seed + 100)
    T = 64
    bits, pos = bam.build_sample_bits(random_segments(rng, T), T)
    W = bam.token_workload(bits, pos)
    rows = brute_force_mask(bits, pos).sum(axis=1)
    np.testing.assert_allclose(W, rows)


def test_causal_bits_degenerates_to_causal():
    bits = np.asarray(bam.causal_bits(1, 16))[0]
    pos = np.arange(16)
    m = brute_force_mask(bits, pos)
    np.testing.assert_array_equal(m, np.tril(np.ones((16, 16), bool)))


def test_padding_never_attends():
    bits = np.zeros(8, np.uint32)
    bits[:4] = bam.text_token()
    pos = np.arange(8)
    m = np.asarray(bam.allowed_mask(
        jnp.asarray(bits)[None], jnp.asarray(bits)[None],
        jnp.asarray(pos)[None], jnp.asarray(pos)[None]))[0]
    assert not m[4:, :].any() and not m[:, 4:].any()


def test_cross_document_isolation():
    segs = [("text", 0, 4), ("newdoc", 0, 0), ("text", 0, 4)]
    bits, pos = bam.build_sample_bits(segs, 8)
    m = brute_force_mask(bits, pos)
    assert not m[4:, :4].any() and not m[:4, 4:].any()


def test_block_workload_sums_tokens():
    segs = [("text", 0, 16)]
    bits, pos = bam.build_sample_bits(segs, 16)
    W = bam.token_workload(bits, pos)
    Wb = bam.block_workload(bits, pos, 4)
    assert len(Wb) == 4
    np.testing.assert_allclose(Wb, W.reshape(4, 4).sum(1))
