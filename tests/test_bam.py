"""BAM (Bitfield Attention Mask) unit + property tests.

Property tests are seed-parametrized (no hypothesis wheel in the
container — same invariants, explicit seed sweep)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import bam


def brute_force_mask(bits, pos, window=0):
    """O(T^2) python reimplementation of the documented semantics."""
    T = len(bits)
    m = np.zeros((T, T), bool)
    for i in range(T):
        for j in range(T):
            bi, bj = int(bits[i]), int(bits[j])
            if bi == 0 or bj == 0:
                continue
            if (bi >> bam.INST_SHIFT) & 0xFF != (bj >> bam.INST_SHIFT) & 0xFF:
                continue
            mj = (bj >> bam.MOD_SHIFT) & 0x7F
            mi = (bi >> bam.MOD_SHIFT) & 0x7F
            if not ((bi & 0xFFFF) >> mj) & 1:
                continue
            if mi == bam.TEXT:
                ok = pos[j] <= pos[i]
                if window:
                    ok = ok and (pos[i] - pos[j]) < window
            else:
                ok = mj == mi
            m[i, j] = ok
    return m


def random_segments(rng, total):
    segs, used = [], 0
    doc = 0
    while used < total - 4:
        kind = rng.choice(["text", "mod", "newdoc"], p=[0.5, 0.4, 0.1])
        if kind == "newdoc" and used > 0:
            segs.append(("newdoc", 0, 0))
            continue
        n = int(rng.integers(1, min(8, total - used) + 1))
        if kind == "mod":
            segs.append(("mod", int(rng.integers(1, 5)), n))
        else:
            segs.append(("text", 0, n))
        used += n
    return segs


def test_encode_fields():
    b = bam.encode(0b101, 3, 7)
    assert bam.attends_set(np.uint32(b)) == 0b101
    assert bam.own_modality(np.uint32(b)) == 3
    assert bam.instance_id(np.uint32(b)) == 7


def test_text_and_modality_tokens():
    t = bam.text_token([1, 2])
    assert bam.attends_set(np.uint32(t)) == 0b111
    assert bam.own_modality(np.uint32(t)) == bam.TEXT
    m = bam.modality_token(2, instance=3)
    assert bam.attends_set(np.uint32(m)) == 0b100
    assert bam.own_modality(np.uint32(m)) == 2
    assert bam.instance_id(np.uint32(m)) == 3


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("window", [0, 5])
def test_allowed_mask_matches_bruteforce(seed, window):
    rng = np.random.default_rng(seed)
    T = 48
    bits, pos = bam.build_sample_bits(random_segments(rng, T), T)
    got = np.asarray(bam.allowed_mask(
        jnp.asarray(bits)[None], jnp.asarray(bits)[None],
        jnp.asarray(pos)[None], jnp.asarray(pos)[None], window))[0]
    want = brute_force_mask(bits, pos, window)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("seed", range(8))
def test_token_workload_is_mask_rowsum(seed):
    rng = np.random.default_rng(seed + 100)
    T = 64
    bits, pos = bam.build_sample_bits(random_segments(rng, T), T)
    W = bam.token_workload(bits, pos)
    rows = brute_force_mask(bits, pos).sum(axis=1)
    np.testing.assert_allclose(W, rows)


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("window", [3, 5, 16])
def test_token_workload_windowed_is_mask_rowsum(seed, window):
    """Exact windowed causal count per modality — the old
    min(total, window) clamp over-subtracted for text rows that also
    attend modality keys (their row-sum legitimately exceeds window)."""
    rng = np.random.default_rng(seed + 200)
    T = 64
    bits, pos = bam.build_sample_bits(random_segments(rng, T), T)
    W = bam.token_workload(bits, pos, window=window)
    rows = brute_force_mask(bits, pos, window=window).sum(axis=1)
    np.testing.assert_allclose(W, rows)


def test_causal_bits_degenerates_to_causal():
    bits = np.asarray(bam.causal_bits(1, 16))[0]
    pos = np.arange(16)
    m = brute_force_mask(bits, pos)
    np.testing.assert_array_equal(m, np.tril(np.ones((16, 16), bool)))


def test_padding_never_attends():
    bits = np.zeros(8, np.uint32)
    bits[:4] = bam.text_token()
    pos = np.arange(8)
    m = np.asarray(bam.allowed_mask(
        jnp.asarray(bits)[None], jnp.asarray(bits)[None],
        jnp.asarray(pos)[None], jnp.asarray(pos)[None]))[0]
    assert not m[4:, :].any() and not m[:, 4:].any()


def test_cross_document_isolation():
    segs = [("text", 0, 4), ("newdoc", 0, 0), ("text", 0, 4)]
    bits, pos = bam.build_sample_bits(segs, 8)
    m = brute_force_mask(bits, pos)
    assert not m[4:, :4].any() and not m[:4, 4:].any()


def test_block_workload_sums_tokens():
    segs = [("text", 0, 16)]
    bits, pos = bam.build_sample_bits(segs, 16)
    W = bam.token_workload(bits, pos)
    Wb = bam.block_workload(bits, pos, 4)
    assert len(Wb) == 4
    np.testing.assert_allclose(Wb, W.reshape(4, 4).sum(1))


# ---------------------------------------------------------------------------
# Host-side grid compaction (BlockMask — drives the sparse Pallas grid)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_block_map_covers_active_tiles(seed):
    rng = np.random.default_rng(seed + 300)
    T, bq, bk = 48, 8, 16
    bits, pos = bam.build_sample_bits(random_segments(rng, T), T)
    bm = bam.build_block_map(bits, bits, pos, pos, bq, bk)
    mask = brute_force_mask(bits, pos)
    active = mask.reshape(T // bq, bq, T // bk, bk).any(axis=(1, 3))
    got = {(iq, ik) for iq, ik, _, _, a in bm.q_steps if a}
    want = {(int(i), int(j)) for i, j in zip(*np.nonzero(active))}
    assert got == want
    assert {(iq, ik) for iq, ik, _, _, a in bm.k_steps if a} == want
    # every q block flushes exactly once; ditto every k block
    assert sum(s[3] for s in bm.q_steps) == T // bq
    assert sum(s[3] for s in bm.k_steps) == T // bk
    assert bm.n_dense_steps == (T // bq) * (T // bk)


def test_block_map_empty_blocks_get_dummy_steps():
    bits = np.zeros(32, np.uint32)
    bits[:8] = bam.text_token()
    pos = np.arange(32)
    bm = bam.build_block_map(bits, bits, pos, pos, 8, 8)
    # 3 empty q blocks -> inactive flush steps so outputs still write
    inactive = [s for s in bm.q_steps if s[4] == 0]
    assert len(inactive) == 3
    assert all(f == 1 and l == 1 for _, _, f, l, _ in inactive)


def test_block_map_batch_is_union():
    """[B,T] bits: a tile active in ANY row must stay in the grid."""
    b0, p0 = bam.build_sample_bits([("text", 0, 16)], 32)
    b1, p1 = bam.build_sample_bits([("text", 0, 32)], 32)
    bits = np.stack([b0, b1])
    pos = np.stack([p0, p1])
    bm = bam.build_block_map(bits, bits, pos, pos, 8, 8)
    bm1 = bam.build_block_map(b1, b1, p1, p1, 8, 8)
    active = {(s[0], s[1]) for s in bm.q_steps if s[4]}
    active1 = {(s[0], s[1]) for s in bm1.q_steps if s[4]}
    assert active == active1        # row 1 dominates row 0 here
    assert bm.skip_fraction < 1.0
