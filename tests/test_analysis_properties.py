"""Hypothesis property: every winner ``auto_parallelize`` can emit —
any profile shape, any device/microbatch budget — ships a timeline
that passes every schedlint rule. Skipped when the hypothesis wheel is
absent (the deterministic slice in test_analysis.py still runs)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import assume, given, settings, strategies as st  # noqa: E402

from repro.analysis import schedlint  # noqa: E402
from repro.core import pipeline as pp  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(enc_layers=st.integers(1, 4),
       llm_layers=st.integers(2, 8),
       devices=st.integers(2, 5),
       mbs=st.integers(2, 8),
       frozen=st.booleans(),
       enc_cost=st.floats(0.25, 4.0),
       objective=st.sampled_from(sorted(pp.AUTO_OBJECTIVES)))
def test_auto_parallelize_winners_lint_clean(enc_layers, llm_layers,
                                             devices, mbs, frozen,
                                             enc_cost, objective):
    encs = [pp.ModuleProfile("enc", np.full(enc_layers, enc_cost),
                             frozen=frozen)]
    llm = pp.ModuleProfile("llm", np.full(llm_layers, 2.0),
                           frozen=False)
    try:
        best = pp.auto_parallelize(encs, llm, devices, mbs,
                                   objective=objective)
    except ValueError:
        assume(False)
        return
    assert schedlint.lint_timeline(best["graph"], best) == []
