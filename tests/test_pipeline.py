"""Frozen-status-aware pipeline partitioner + 1F1B simulator tests
(paper §4.2, Algorithm 1, Table 3 mechanics)."""
import itertools

import numpy as np
import pytest

from repro.core import pipeline as pp


def test_bwd_factor_rule():
    """The paper's T_bwd rule (§4.2)."""
    frozen_head = pp.ModuleProfile("enc", np.ones(4), frozen=True)
    frozen_mid = pp.ModuleProfile("llm", np.ones(4), frozen=True,
                                  trainable_upstream=True)
    trainable = pp.ModuleProfile("proj", np.ones(4), frozen=False)
    assert frozen_head.bwd_factor == 0.0
    assert frozen_mid.bwd_factor == 1.0
    assert trainable.bwd_factor == 2.0
    # activation checkpointing: +1 fwd only when grads exist
    frozen_head.recompute = True
    frozen_mid.recompute = True
    trainable.recompute = True
    assert frozen_head.bwd_factor == 0.0
    assert frozen_mid.bwd_factor == 2.0
    assert trainable.bwd_factor == 3.0


def test_analyze_chain():
    enc = pp.ModuleProfile("enc", np.ones(2), frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(2), frozen=True)
    pp.analyze_chain([enc, llm], projector_trainable=[True, False])
    assert not enc.trainable_upstream and llm.trainable_upstream
    # no trainable projector anywhere -> nothing upstream
    enc2 = pp.ModuleProfile("enc", np.ones(2), frozen=True)
    llm2 = pp.ModuleProfile("llm", np.ones(2), frozen=True)
    pp.analyze_chain([enc2, llm2], projector_trainable=[False, False])
    assert not llm2.trainable_upstream


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("k", [2, 3, 4])
def test_partition_layers_optimal(seed, k):
    """DP partition == brute-force optimum on small instances."""
    rng = np.random.default_rng(seed)
    costs = rng.uniform(1, 10, size=8)
    bounds = pp.partition_layers(costs, k)
    got = max(costs[a:b].sum() for a, b in bounds)
    best = np.inf
    n = len(costs)
    for cuts in itertools.combinations(range(1, n), k - 1):
        edges = [0, *cuts, n]
        m = max(costs[a:b].sum() for a, b in zip(edges, edges[1:]))
        best = min(best, m)
    assert abs(got - best) < 1e-9
    # contiguity + coverage
    assert bounds[0][0] == 0 and bounds[-1][1] == n
    for (a, b), (c, d) in zip(bounds, bounds[1:]):
        assert b == c


def test_simulator_matches_1f1b_closed_form():
    """Equal stages f, b: 1F1B iteration = (M + S - 1)(f + b)."""
    for S, M, f, b in [(4, 8, 1.0, 2.0), (2, 4, 3.0, 1.0), (6, 12, 1.0, 1.0)]:
        g = pp.chain_graph([pp.Stage("m", f, b) for _ in range(S)])
        sim = pp.simulate_1f1b(g, M)
        assert abs(sim["iteration_time"] - (M + S - 1) * (f + b)) < 1e-9


def test_simulator_single_stage_no_bubble():
    g = pp.chain_graph([pp.Stage("m", 1.0, 2.0)])
    sim = pp.simulate_1f1b(g, 8)
    assert sim["bubble_fraction"] < 1e-9


def test_frozen_aware_beats_unaware():
    """Table 3/Fig 7: frozen-aware partitioning (balancing true fwd+bwd)
    beats fwd-balanced partitioning when modules are frozen."""
    enc = pp.ModuleProfile("vision", np.ones(48) * 2.0, frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(32) * 1.5, frozen=True)
    pp.analyze_chain([enc, llm], projector_trainable=[True, False])
    aware = pp.simulate_1f1b(
        pp.build_chain_fused([enc, llm], 8, frozen_aware=True), 24)
    unaware = pp.simulate_1f1b(
        pp.build_chain_fused([enc, llm], 8, frozen_aware=False), 24)
    assert aware["iteration_time"] < unaware["iteration_time"]
    speedup = unaware["iteration_time"] / aware["iteration_time"]
    assert speedup > 1.1  # paper reports up to 1.53x


def test_modality_parallel_graph_shape():
    """Fig 6: two encoder chains feeding the LLM chain."""
    e1 = pp.ModuleProfile("vision", np.ones(4), frozen=True)
    e2 = pp.ModuleProfile("audio", np.ones(6), frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(8), frozen=True,
                           trainable_upstream=True)
    g = pp.build_modality_parallel([e1, e2], llm, [2, 2], 4)
    assert len(g.stages) == 8
    preds = g.preds
    llm_first = 4  # after 2+2 encoder stages
    assert sorted(preds[llm_first]) == [1, 3]  # both encoder chain tails
    sim = pp.simulate_1f1b(g, 8)
    assert sim["iteration_time"] > 0


def test_replicated_pays_encoder_cost_everywhere():
    e = pp.ModuleProfile("vision", np.ones(4) * 2.0, frozen=False)
    llm = pp.ModuleProfile("llm", np.ones(8), frozen=False)
    rep = pp.build_replicated([e], llm, 4, frozen_aware=True)
    colo = pp.build_colocated([e], llm, 2, 4, frozen_aware=True)
    # every replicated stage carries the full encoder fwd cost
    assert all(s.fwd >= 8.0 for s in rep.stages)
    sim_r = pp.simulate_1f1b(rep, 8)
    sim_c = pp.simulate_1f1b(colo, 8)
    # paper Fig. 2a: replication is slower end-to-end
    assert sim_r["iteration_time"] > sim_c["iteration_time"]


def test_auto_parallelize_returns_feasible():
    e1 = pp.ModuleProfile("vision", np.ones(8) * 3.0, frozen=True)
    e2 = pp.ModuleProfile("audio", np.ones(8) * 1.0, frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(16) * 2.0, frozen=True,
                           trainable_upstream=True)
    best = pp.auto_parallelize([e1, e2], llm, total_devices=8,
                               num_microbatches=8)
    assert best["devices"] <= 8
    assert best["llm_stages"] >= 1
    assert len(best["encoder_stages"]) == 2


def test_auto_parallelize_gives_fewer_stages_to_frozen_encoders():
    """Paper §6.2.2 (VALM-MM): frozen-aware assigns more stages to the
    LLM (which still has backward) and fewer to frozen encoders."""
    enc = pp.ModuleProfile("vision", np.ones(32) * 1.0, frozen=True)
    llm = pp.ModuleProfile("llm", np.ones(32) * 1.0, frozen=True,
                           trainable_upstream=True)
    best = pp.auto_parallelize([enc], llm, total_devices=8,
                               num_microbatches=16)
    assert best["llm_stages"] >= best["encoder_stages"][0]
