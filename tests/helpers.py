"""Test helpers: run JAX snippets in a subprocess with a forced host
device count (the main pytest process must keep 1 device — the dry-run
is the only 512-device context, per the assignment)."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, \
        f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout
