"""Multi-device test machinery, consolidated.

The main pytest process must keep 1 device (the dry-run is the only
512-device context, per the assignment), so anything that needs a real
multi-device mesh runs under ``XLA_FLAGS=--xla_force_host_platform_
device_count=N`` in a subprocess. Three tools, one place:

* ``run_in_subprocess(code, n_devices)`` — run a python snippet in a
  fresh interpreter with N forced host devices (``run_with_devices``
  is the original name, kept as an alias).
* ``host_mesh(n, axis_names)`` — build a named mesh over host devices
  *inside* an already-multi-device process; skips when the process has
  too few devices.
* ``@subprocess_test(n_devices)`` — decorate a test so it re-execs
  ITSELF via ``pytest <nodeid>`` in a subprocess with N forced host
  devices when the current process has too few, and runs in-process
  (no fork) when devices are already available — which is what makes
  the whole suite first-class under the multi-device CI job, where
  XLA_FLAGS is set globally and nothing forks.
"""
import contextlib
import functools
import inspect
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: set in children spawned by subprocess_test — a belt-and-braces guard
#: against recursive re-exec if the forced device count ever fails to
#: materialize (e.g. an XLA that ignores the flag)
_SUBPROC_ENV = "REPRO_SUBPROCESS_TEST"


def run_in_subprocess(code: str, n_devices: int,
                      timeout: int = 600) -> str:
    """Run ``code`` in a fresh interpreter with ``n_devices`` forced
    host devices; assert success and return stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, \
        f"subprocess failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


#: original name — existing tests keep working unchanged
run_with_devices = run_in_subprocess


@contextlib.contextmanager
def host_mesh(n, axis_names=("pp",)):
    """Yield a ``jax.sharding.Mesh`` over host devices. ``n`` is an int
    (1-D mesh) or a shape tuple matching ``axis_names`` (e.g.
    ``host_mesh((2, 4), ("pp", "cp"))``). Skips the test when the
    process has fewer devices than the mesh needs — pair with
    ``@subprocess_test`` (or the multi-device CI job's global
    XLA_FLAGS) to guarantee they exist."""
    import jax
    import numpy as np
    import pytest
    from jax.sharding import Mesh
    shape = (n,) if isinstance(n, int) else tuple(n)
    assert len(shape) == len(axis_names), (shape, axis_names)
    total = 1
    for k in shape:
        total *= k
    devs = jax.devices()
    if len(devs) < total:
        pytest.skip(
            f"needs {total} host devices, have {len(devs)} "
            f"(XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{total})")
    with Mesh(np.array(devs[:total]).reshape(shape), axis_names) as m:
        yield m


def subprocess_test(n_devices: int, timeout: int = 1200):
    """Decorator: run the test in-process when ``jax.device_count() >=
    n_devices``, otherwise re-exec exactly this test node via pytest in
    a subprocess with the forced host device count. The test body can
    then use ``host_mesh`` / plain jax APIs as if the devices were
    always there."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(request, *args, **kwargs):
            import jax
            if (jax.device_count() >= n_devices
                    or os.environ.get(_SUBPROC_ENV) == "1"):
                return fn(*args, **kwargs)
            env = dict(os.environ)
            env["XLA_FLAGS"] = \
                f"--xla_force_host_platform_device_count={n_devices}"
            env[_SUBPROC_ENV] = "1"
            env["PYTHONPATH"] = os.path.join(REPO, "src")
            proc = subprocess.run(
                [sys.executable, "-m", "pytest", "-x", "-q",
                 "-p", "no:cacheprovider", request.node.nodeid],
                cwd=REPO, env=env, capture_output=True, text=True,
                timeout=timeout)
            assert proc.returncode == 0, (
                f"subprocess test {request.node.nodeid} failed "
                f"under {n_devices} devices:\nSTDOUT:\n{proc.stdout}\n"
                f"STDERR:\n{proc.stderr}")

        # pytest resolves fixtures from the SIGNATURE: expose `request`
        # plus the wrapped test's own params (dedup in case it already
        # asks for request). __signature__ wins over __wrapped__.
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        if not any(p.name == "request" for p in params):
            params = [inspect.Parameter(
                "request",
                inspect.Parameter.POSITIONAL_OR_KEYWORD)] + params
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper
    return deco
