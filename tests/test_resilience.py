"""Fault-tolerance runtime tests (repro.resilience): the in-jit health
gate, the host verdict classifier, atomic resumable checkpoints, the
rollback-and-retry loop, and the deterministic fault harness — up to
the two acceptance properties: crash-at-step-k + resume reproduces an
uninterrupted run's losses bit-exactly, and an injected NaN-grad step
is detected, rolled back, and training re-converges."""
import argparse
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.checkpoint.checkpoint import CheckpointError
from repro.optim import optimizer as opt
from repro.resilience import (ABORT, BUNDLE_KEYS, OK, ROLLBACK, SKIP,
                              CheckpointManager, CrashInjected,
                              CursorStream, EventLog, Fault,
                              FaultInjector, FaultPlan, HealthMonitor,
                              MonitorConfig, ResilientTrainer,
                              RetryPolicy, TrainingAborted, bundle_dict,
                              corrupt_shard, default_controls,
                              init_health, make_resilient_train_step)


# ---------------------------------------------------------------------------
# A tiny deterministic regression problem: fast, converges, bit-exact
# ---------------------------------------------------------------------------

_W_TRUE = np.random.default_rng(7).normal(size=(4, 1)).astype(np.float32)


def _loss_fn(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2), {}


def _batches():
    rng = np.random.default_rng(42)
    while True:
        x = rng.normal(size=(8, 4)).astype(np.float32)
        yield {"x": jnp.asarray(x), "y": jnp.asarray(x @ _W_TRUE)}


def _fresh(lr=3e-2):
    params = {"w": jnp.zeros((4, 1), jnp.float32)}
    ocfg = opt.AdamWConfig(lr=lr, warmup_steps=0, schedule="constant",
                           weight_decay=0.0)
    state = opt.init(ocfg, params)
    step_fn = jax.jit(make_resilient_train_step(_loss_fn, ocfg),
                      donate_argnums=(0, 1, 2))
    return params, state, step_fn


def _trainer(tmp=None, *, faults=(), monitor=None, ckpt_every=0,
             resume=False, policy=None, on_device_loss=None):
    params, state, step_fn = _fresh()
    return ResilientTrainer(
        step_fn, params, state, CursorStream(_batches),
        monitor=monitor,
        manager=CheckpointManager(str(tmp)) if tmp is not None else None,
        injector=FaultInjector(FaultPlan.make(list(faults))),
        ckpt_every=ckpt_every, resume=resume, policy=policy,
        on_device_loss=on_device_loss)


# ---------------------------------------------------------------------------
# Guarded step: the fused bundle + the in-jit gate
# ---------------------------------------------------------------------------

def test_guarded_step_ok_path_trains():
    params, state, step_fn = _fresh()
    health = init_health()
    it = iter(_batches())
    first = last = None
    for _ in range(25):
        params, state, health, bundle = step_fn(
            params, state, health, next(it), default_controls())
        b = bundle_dict(bundle)
        first = first if first is not None else b["loss"]
        last = b["loss"]
    assert set(b) == set(BUNDLE_KEYS)
    assert b["applied"] == 1.0 and b["nonfinite"] == 0.0
    assert last < first * 0.5
    assert int(health["count"]) == 25
    assert int(state["step"]) == 25


def test_nonfinite_step_gated_inside_jit():
    """An injected NaN-grad step must leave params, optimizer moments,
    AND the EMA state bit-identical — the gate lives in the jitted
    step, not in host policy."""
    params, state, step_fn = _fresh()
    health = init_health()
    it = iter(_batches())
    for _ in range(3):
        params, state, health, _ = step_fn(params, state, health,
                                           next(it), default_controls())
    # np.array(copy) — np.asarray can alias the donated device buffer
    before = jax.tree.map(lambda x: np.array(x), {"p": params,
                                                  "s": state,
                                                  "h": health})
    ctl = default_controls()
    ctl["inject_nan"] = jnp.float32(1.0)
    params, state, health, bundle = step_fn(params, state, health,
                                            next(it), ctl)
    b = bundle_dict(bundle)
    assert b["nonfinite"] == 1.0 and b["applied"] == 0.0
    assert not np.isfinite(b["grad_norm"])
    after = jax.tree.map(np.asarray, {"p": params, "s": state,
                                      "h": health})
    for x, y in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
        np.testing.assert_array_equal(x, y)


def test_grad_norm_ceiling_gates_update():
    params, state, step_fn = _fresh()
    health = init_health()
    ctl = default_controls()
    ctl["max_grad_norm"] = jnp.float32(1e-9)    # everything is over
    w_before = np.asarray(params["w"]).copy()   # args are donated
    p2, s2, _, bundle = step_fn(params, state, health,
                                next(iter(_batches())), ctl)
    assert bundle_dict(bundle)["applied"] == 0.0
    np.testing.assert_array_equal(np.asarray(p2["w"]), w_before)
    assert int(s2["step"]) == 0


# ---------------------------------------------------------------------------
# Host classifier + event log
# ---------------------------------------------------------------------------

def _bundle(loss=1.0, gnorm=1.0, spike=0.0, nonfinite=0.0):
    return {"loss": loss, "grad_norm": gnorm, "spike": spike,
            "nonfinite": nonfinite, "applied": 1.0 - nonfinite}


def test_classifier_escalation_ladder():
    mon = HealthMonitor(MonitorConfig(skip_limit=1, max_rollbacks=1,
                                      spike_sigma=4.0, spike_warmup=2))
    assert mon.classify(0, _bundle()) == OK
    assert mon.classify(1, _bundle(nonfinite=1.0)) == SKIP
    # second consecutive bad step exceeds skip_limit=1 -> rollback
    assert mon.classify(2, _bundle(nonfinite=1.0)) == ROLLBACK
    # an ok step resets the skip streak
    assert mon.classify(3, _bundle()) == OK
    assert mon.classify(4, _bundle(nonfinite=1.0)) == SKIP
    # spike after warmup -> rollback; rollback budget (1) exhausted ->
    # escalates to abort
    assert mon.classify(5, _bundle(spike=9.0)) == ABORT
    kinds = [e["verdict"] for e in mon.log.of_kind("verdict")]
    assert kinds == [SKIP, ROLLBACK, SKIP, ABORT]


def test_spike_needs_warmup():
    mon = HealthMonitor(MonitorConfig(spike_sigma=4.0, spike_warmup=3))
    for i in range(3):
        assert mon.classify(i, _bundle(spike=100.0)) == OK
    assert mon.classify(3, _bundle(spike=100.0)) == ROLLBACK


def test_event_log_jsonl_roundtrip(tmp_path):
    path = str(tmp_path / "events.jsonl")
    log = EventLog(path)
    log.emit("verdict", 3, verdict=SKIP, reason="nonfinite")
    log.emit("checkpoint", 4, dir="x")
    with open(path, encoding="utf-8") as f:
        lines = [json.loads(ln) for ln in f]
    assert lines == log.events
    assert lines[0]["kind"] == "verdict" and lines[0]["step"] == 3


# ---------------------------------------------------------------------------
# Checkpoint hardening (satellite: ValueError-based validation)
# ---------------------------------------------------------------------------

def test_load_errors_name_offending_path_and_shape(tmp_path):
    d = str(tmp_path / "ck")
    ckpt.save(d, {"w": np.zeros((4, 2), np.float32)}, step=1)
    with pytest.raises(CheckpointError) as e:
        ckpt.load(d, like={"w": jnp.zeros((5, 2), jnp.float32)})
    assert "'w'" in str(e.value) and "(4, 2)" in str(e.value) \
        and "(5, 2)" in str(e.value)
    with pytest.raises(CheckpointError) as e:
        ckpt.load(d, like={"w": jnp.zeros((4, 2)), "b": jnp.zeros(2)})
    assert "'b'" in str(e.value) and "missing" in str(e.value)


def test_manifest_missing_and_truncated_errors(tmp_path):
    with pytest.raises(CheckpointError, match="manifest.msgpack is "
                                              "missing"):
        ckpt.load(str(tmp_path / "nope"))
    d = str(tmp_path / "ck")
    ckpt.save(d, {"w": np.zeros(3, np.float32)}, step=1)
    mpath = os.path.join(d, "manifest.msgpack")
    with open(mpath, "rb") as f:
        blob = f.read()
    with open(mpath, "wb") as f:
        f.write(blob[:len(blob) // 2])          # torn write
    with pytest.raises(CheckpointError, match="corrupt or truncated"):
        ckpt.load(d)


def test_corrupted_shard_detected_by_checksum(tmp_path):
    """Bit rot in a shard must fail the load with the shard named —
    never be silently trained on."""
    d = str(tmp_path / "ck")
    tree = {"w": np.arange(12, dtype=np.float32),
            "b": np.ones(3, np.float32)}
    ckpt.save(d, tree, step=5)
    corrupt_shard(d, 1)                          # 'w' (paths sort b, w)
    with pytest.raises(CheckpointError) as e:
        ckpt.load(d, like=jax.tree.map(jnp.asarray, tree))
    assert "crc32" in str(e.value) and "arr_1.npy" in str(e.value)
    # verify=False is the explicit escape hatch (e.g. forensics)
    restored, step = ckpt.load(d, like=jax.tree.map(jnp.asarray, tree),
                               verify=False)
    assert step == 5


# ---------------------------------------------------------------------------
# CheckpointManager: atomicity, latest(), retention
# ---------------------------------------------------------------------------

def test_manager_latest_retention_and_meta(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    assert mgr.latest() is None
    tree = {"w": jnp.arange(4, dtype=jnp.float32)}
    for s in (2, 4, 6):
        mgr.save(s, tree, meta={"cursor": s * 10})
    assert mgr.steps() == [4, 6]                 # keep=2 retention
    assert mgr.latest().endswith("step_00000006")
    got, step, meta = mgr.restore(tree)
    assert step == 6 and meta["cursor"] == 60
    np.testing.assert_array_equal(np.asarray(got["w"]),
                                  np.asarray(tree["w"]))
    # stale/missing LATEST pointer: discovery falls back to a scan
    os.remove(os.path.join(str(tmp_path), "LATEST"))
    assert CheckpointManager(str(tmp_path)).latest() \
        .endswith("step_00000006")


def test_kill_mid_save_leaves_previous_checkpoint_loadable(tmp_path):
    """The crash-safety contract: a save killed mid-shard must leave
    the prior checkpoint fully intact and discoverable, and the torn
    temp dir must be collected on the next manager construction."""
    params, state, step_fn = _fresh()
    tr = ResilientTrainer(
        step_fn, params, state, CursorStream(_batches),
        manager=CheckpointManager(str(tmp_path)),
        injector=FaultInjector(FaultPlan.make(
            [Fault("crash_in_save", 7, arg=2)])),
        ckpt_every=4)
    with pytest.raises(CrashInjected, match="mid-save at step 7"):
        tr.run(20)
    assert any(n.startswith(".tmp-") for n in os.listdir(str(tmp_path)))
    mgr = CheckpointManager(str(tmp_path))       # a fresh process
    assert not any(n.startswith(".tmp-")
                   for n in os.listdir(str(tmp_path)))
    assert mgr.steps() == [4]
    tree, step, meta = mgr.restore(
        {"params": params, "opt": state, "health": init_health()})
    assert step == 4 and meta["cursor"] == 4


def test_fault_plan_json_roundtrip(tmp_path):
    plan = FaultPlan.make([Fault("nan_grads", 3),
                           Fault("crash", 9),
                           Fault("corrupt_shard", 5, arg=2)])
    path = str(tmp_path / "faults.json")
    plan.save(path)
    assert FaultPlan.load(path) == plan
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("meteor", 1)


def test_cursor_stream_seek_replays_exactly():
    s1, s2 = CursorStream(_batches), CursorStream(_batches)
    for _ in range(5):
        b5 = s1.next()
    s2.seek(4)
    np.testing.assert_array_equal(np.asarray(s2.next()["x"]),
                                  np.asarray(b5["x"]))
    assert s1.cursor == s2.cursor == 5


# ---------------------------------------------------------------------------
# The acceptance properties
# ---------------------------------------------------------------------------

def test_resume_equivalence_after_injected_crash(tmp_path):
    """Crash at step 13 (ckpt every 4), resume from latest() — the
    union of pre-crash and post-resume logged losses must equal an
    uninterrupted run's, bit-exactly."""
    ref = _trainer().run(20)["losses"]

    tr = _trainer(tmp_path, faults=[Fault("crash", 13)], ckpt_every=4)
    with pytest.raises(CrashInjected):
        tr.run(20)
    pre = dict(tr.losses)

    tr2 = _trainer(tmp_path, resume=True)
    assert tr2.step == 12                        # latest checkpoint
    post = tr2.run(20)["losses"]

    merged = {**{k: v for k, v in pre.items() if k < tr2.step}, **post}
    assert merged.keys() == ref.keys()
    for k in sorted(ref):
        assert merged[k] == ref[k], (k, merged[k], ref[k])


def test_nan_grad_rollback_and_reconvergence(tmp_path):
    """An injected NaN-grad step is detected, rolled back to the last
    good checkpoint, retried, and the run re-converges."""
    mon = HealthMonitor(MonitorConfig(skip_limit=0))   # bad step ->
    #                                                    rollback now
    tr = _trainer(tmp_path, faults=[Fault("nan_grads", 12)],
                  monitor=mon, ckpt_every=5)
    res = tr.run(30)
    assert res["rollbacks"] == 1
    assert [f["kind"] for f in res["fired_faults"]] == ["nan_grads"]
    restores = mon.log.of_kind("restore")
    assert len(restores) == 1 and restores[0]["step"] == 10
    # every step completed, no NaN ever reached params, loss converged
    assert sorted(res["losses"]) == list(range(30))
    vals = [res["losses"][k] for k in sorted(res["losses"])]
    assert np.isfinite(vals).all()
    assert vals[-1] < vals[0] * 0.1
    # escalating grad clip engaged for the retry
    retries = mon.log.of_kind("retry")
    assert retries and retries[0]["clip_scale"] == 0.5


def test_skip_policy_drops_poisoned_step_and_continues(tmp_path):
    """With skips tolerated, a NaN step is simply dropped: the batch is
    consumed, nothing is applied, and training proceeds without any
    rollback."""
    mon = HealthMonitor(MonitorConfig(skip_limit=3))
    tr = _trainer(tmp_path, faults=[Fault("nan_grads", 6)], monitor=mon)
    res = tr.run(15)
    assert res["rollbacks"] == 0 and res["skipped"] == 1
    assert 6 not in res["losses"]                # dropped, not logged
    assert len(res["losses"]) == 14
    vals = [res["losses"][k] for k in sorted(res["losses"])]
    assert np.isfinite(vals).all() and vals[-1] < vals[0]


def test_abort_after_retry_budget(tmp_path):
    faults = [Fault("nan_grads", s) for s in range(4, 10)]
    mon = HealthMonitor(MonitorConfig(skip_limit=0, max_rollbacks=100))
    tr = _trainer(tmp_path, faults=faults, monitor=mon, ckpt_every=2,
                  policy=RetryPolicy(max_attempts=2))
    with pytest.raises(TrainingAborted, match="retry attempts"):
        tr.run(30)


def test_rollback_without_checkpoint_aborts():
    mon = HealthMonitor(MonitorConfig(skip_limit=0))
    tr = _trainer(None, faults=[Fault("nan_grads", 3)], monitor=mon)
    with pytest.raises(TrainingAborted, match="no checkpoint"):
        tr.run(10)


def test_device_loss_replans_and_resumes(tmp_path):
    """A simulated device loss triggers the replan hook, restores the
    last checkpoint, and the run still completes every step."""
    seen = []
    tr = _trainer(tmp_path, faults=[Fault("device_loss", 9, arg=2)],
                  ckpt_every=4, on_device_loss=seen.append)
    res = tr.run(16)
    assert seen == [2]
    assert res["last_step"] == 16
    assert sorted(res["losses"]) == list(range(16))
    ev = tr.monitor.log
    assert ev.of_kind("device-loss")[0] == {"kind": "device-loss",
                                           "step": 9, "lost": 2}
    assert any(e["why"] == "device-loss" for e in ev.of_kind("restore"))


def test_shrink_plan_degrades_gracefully():
    """The launch driver's device-loss hook: parallelize() re-runs over
    the shrunken ClusterSpec and yields a valid, smaller plan."""
    from repro.launch.train import shrink_plan
    from repro.models.mllm import build_paper_mllm
    from repro.parallel import ClusterSpec, WorkloadShape, parallelize
    mllm = build_paper_mllm("vlm", reduced=True, text_len=32)
    plan = parallelize(mllm, ClusterSpec(num_devices=4),
                       WorkloadShape(text_len=32, num_microbatches=4,
                                     block_size=8))
    args = argparse.Namespace(seq=32, microbatches=4, batch=2)
    # losing more devices than can be spared clamps to the MLLM floor
    # (1 LLM stage + 1 stage per encoder) instead of an infeasible
    # 1-device search
    degraded = shrink_plan(mllm, plan, 2, args)
    assert degraded.pp_devices >= 1 + len(mllm.encoders)
    assert degraded.pp_devices <= plan.pp_devices
    assert degraded.schedule.bubble_fraction >= 0.0
    degraded.apply(mllm, text_len=32)            # still instantiates


# ---------------------------------------------------------------------------
# Driver-level (launch/train): --resume, fault plans, checkpoint fix
# ---------------------------------------------------------------------------

def _lm_argv(tmp, steps, extra=()):
    return ["--arch", "xlstm-125m", "--reduced", "--steps", str(steps),
            "--seq", "16", "--batch", "2", "--vocab", "64",
            "--log-every", "1000", "--ckpt-dir", str(tmp),
            "--ckpt-every", "3", *extra]


def test_driver_resume_equivalence(tmp_path):
    """The --resume acceptance test at the CLI surface: a crash-
    interrupted run resumed with --resume logs the same losses as an
    uninterrupted run."""
    from repro.launch import train
    ref = train.main(_lm_argv(tmp_path / "ref", 8))
    ref_losses = ref["resilience"]["losses"]

    fplan = str(tmp_path / "faults.json")
    FaultPlan.make([Fault("crash", 5)]).save(fplan)
    with pytest.raises(CrashInjected):
        train.main(_lm_argv(tmp_path / "run", 8,
                            ["--fault-plan", fplan]))
    res = train.main(_lm_argv(tmp_path / "run", 8, ["--resume"]))
    post = res["resilience"]["losses"]
    assert post, "resume produced no steps"
    for k, v in post.items():
        assert v == ref_losses[k], (k, v, ref_losses[k])
    # the pre-crash checkpoint at step 3 covered steps the resume
    # didn't re-run; together they span the whole schedule
    assert max(post) == 7


def test_driver_mllm_checkpoint_bundles_everything(tmp_path):
    """Regression for the train_mllm checkpoint bug: the saved
    checkpoint must bundle params + optimizer state + health EMA +
    step/cursor meta (it used to save bare params with frozen_paths
    computed and dropped), and frozen shards must actually be reused
    across checkpoints."""
    from repro.launch import train
    d = tmp_path / "mllm"
    train.main(["--mllm", "vlm", "--reduced", "--steps", "4",
                "--seq", "32", "--batch", "2", "--log-every", "1000",
                "--plan-devices", "2", "--microbatches", "2",
                "--ckpt-dir", str(d), "--ckpt-every", "2"])
    mgr = CheckpointManager(str(d))
    last = mgr.latest()
    assert last.endswith("step_00000004")
    arrays, step = ckpt.load(last)
    assert step == 4
    prefixes = {p.split("/", 1)[0] for p in arrays}
    assert {"params", "opt", "health"} <= prefixes
    meta = ckpt.read_manifest(last)["meta"]
    assert meta["step"] == 4 and meta["cursor"] == 4
    assert "plan" in meta                        # the plan rides along
    # frozen-module shards are hardlinked forward, not rewritten
    man = ckpt.read_manifest(last)
    frozen = [e for e in man["entries"]
              if e["path"].startswith("params/encoders/") or
              e["path"].startswith("params/llm/")]
    assert frozen
    linked = [e for e in frozen if os.stat(
        os.path.join(last, e["file"])).st_nlink > 1]
    assert linked, "no frozen shard was reused across checkpoints"
