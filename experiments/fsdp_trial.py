import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "src")
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch import dryrun, sharding as shd, specs as S
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh, PEAK_FLOPS_BF16, HBM_BW, ICI_BW
from repro.configs.base import SHAPES, get_config
from repro.optim import optimizer as opt
from repro.training import steps

arch = sys.argv[1]
shape = SHAPES["train_4k"]
cfg = dryrun.config_for(arch, shape)
mesh = make_production_mesh()
rules = shd.Rules(seq_parallel=False, fsdp=True)
shd.set_rules(rules); shd.set_mesh(mesh)
with mesh:
    p_spec = S.param_specs(cfg)
    p_sh = dryrun._named(mesh, shd.fsdp_param_pspecs(p_spec, mesh, rules))
    b_spec = S.train_input_specs(cfg, shape)
    b_sh = dryrun._named(mesh, shd.fsdp_batch_pspecs(rules, b_spec, mesh))
    o_spec = S.opt_state_specs(cfg, p_spec)
    o_sh = {"step": NamedSharding(mesh, P()),
            "m": dryrun._named(mesh, shd.fsdp_param_pspecs(p_spec, mesh, rules)),
            "v": dryrun._named(mesh, shd.fsdp_param_pspecs(p_spec, mesh, rules))}
    fn = steps.make_train_step(cfg)
    jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                  out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
    args = (dryrun._with_sharding(p_spec, p_sh),
            dryrun._with_sharding(o_spec, o_sh),
            dryrun._with_sharding(b_spec, b_sh))
    compiled = jfn.lower(*args).compile()
mem = compiled.memory_analysis()
per_dev = int(mem.argument_size_in_bytes + mem.temp_size_in_bytes)
prof = H.analyze(compiled.as_text())
print(f"FSDP {arch} train_4k: compute={prof['flops']/PEAK_FLOPS_BF16:.4f}s "
      f"mem={prof['hbm_bytes']/HBM_BW:.4f}s "
      f"coll={prof['collective_bytes']['total']/ICI_BW:.4f}s "
      f"bytes/dev={per_dev/1e9:.2f}GB")
for tot, kind, w, b, name in H.top_collectives(compiled.as_text(), 6):
    print(f"  {tot/1e9:8.1f} GB {kind:15s} x{w:<4d} {name[:110]}")
