"""Regenerate the EXPERIMENTS.md §Roofline table from dry-run JSONs."""
import glob
import json
import sys


def main(out_dir="experiments/dryrun"):
    rows = []
    skips = []
    for p in sorted(glob.glob(f"{out_dir}/*.json")):
        d = json.load(open(p))
        if "skipped" in d:
            skips.append((d["arch"], d["shape"], d["mesh"], d["skipped"]))
            continue
        r = d["roofline"]
        rows.append((d["arch"], d["shape"], d["mesh"], r, d))
    rows.sort(key=lambda x: (x[0], x[1], x[2]))
    print("| arch | shape | mesh | compute s | memory s | collective s "
          "| dominant | useful | GB/dev | fits 16GB |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for arch, shape, mesh, r, d in rows:
        print(f"| {arch} | {shape} | {mesh} | {r['compute_s']:.3f} | "
              f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
              f"{r['dominant'].replace('_s', '')} | "
              f"{r['useful_flops_ratio']:.2f} | "
              f"{d['per_device_bytes'] / 1e9:.1f} | {d['fits_16GB']} |")
    print()
    print("Skipped (documented, DESIGN.md §long_500k policy):")
    for arch, shape, mesh, why in skips:
        print(f"- {arch} × {shape} ({mesh}): {why}")


if __name__ == "__main__":
    main(*sys.argv[1:])
