import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "src")
from repro.launch import dryrun, hlo_analysis as H
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.configs.base import SHAPES

arch, shape_name = sys.argv[1], sys.argv[2]
shape = SHAPES[shape_name]
cfg = dryrun.config_for(arch, shape)
mesh = make_production_mesh()
rules = dryrun.rules_for(shape, False)
shd.set_rules(rules); shd.set_mesh(mesh)
with mesh:
    # reuse internals to get the compiled text
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    res = dryrun._lower_inner.__wrapped__ if hasattr(dryrun._lower_inner, "__wrapped__") else None
    # simpler: call lower_pair but we need hlo; replicate minimal logic
from repro.launch import specs as S
from repro.optim import optimizer as opt
from repro.training import steps
import jax
from jax.sharding import NamedSharding, PartitionSpec as P
shd.set_rules(rules); shd.set_mesh(mesh)
with mesh:
    p_spec = S.param_specs(cfg)
    p_sh = dryrun._named(mesh, shd.param_pspecs(p_spec, mesh))
    if shape.kind == "train":
        b_spec = S.train_input_specs(cfg, shape)
        b_sh = dryrun._named(mesh, shd.batch_pspecs(rules, b_spec, mesh))
        o_spec = S.opt_state_specs(cfg, p_spec)
        o_sh = dryrun._named(mesh, shd.opt_state_pspecs(rules, p_spec, mesh))
        o_sh = {"step": NamedSharding(mesh, P()), "m": o_sh, "v": o_sh}
        fn = steps.make_train_step(cfg)
        jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                      out_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))
        args = (dryrun._with_sharding(p_spec, p_sh),
                dryrun._with_sharding(o_spec, o_sh),
                dryrun._with_sharding(b_spec, b_sh))
    else:
        c_spec = S.cache_specs(cfg, shape)
        c_sh = dryrun._named(mesh, shd.cache_pspecs(rules, c_spec, mesh))
        b_spec = S.decode_input_specs(cfg, shape)
        b_sh = dryrun._named(mesh, shd.batch_pspecs(rules, b_spec, mesh))
        fn = steps.make_serve_step(cfg)
        jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, b_sh),
                      out_shardings=(None, c_sh), donate_argnums=(1,))
        args = (dryrun._with_sharding(p_spec, p_sh),
                dryrun._with_sharding(c_spec, c_sh),
                dryrun._with_sharding(b_spec, b_sh))
    hlo = jfn.lower(*args).compile().as_text()
for tot, kind, w, b, name in H.top_collectives(hlo, 15):
    print(f"{tot/1e9:9.1f} GB  {kind:18s} x{w:<5d} {b/1e6:9.1f} MB  {name}")
