"""Training / serving step builders.

``make_train_step(cfg)`` -> jit-able ``step(params, opt_state, batch)``
for any registered architecture; cross-entropy is computed **chunked
over the sequence** (``cfg.loss_chunk``) so the full [B,T,V] logits
tensor never materializes — essential for 150k-256k vocabularies at 4k
sequence (the memory-roofline lever recorded in EXPERIMENTS.md §Perf).

``make_serve_step(cfg)`` -> one-token decode against a KV/state cache
(the ``decode_32k`` / ``long_500k`` dry-run entry point).

``make_mllm_train_step(mllm)`` -> the Cornstarch path: frozen-aware
MLLM training (encoders + projectors + LLM with frozen masking).

``make_cp_train_step(cfg, layout, mesh)`` -> context-parallel training
(Cornstarch §4.3): the batch is permuted to a ``ContextPlan`` token
layout (``layout = plan.context.apply(seq_len)``), attention runs
through the differentiable CP bodies under ``mesh``, and loss + grads
come out identical to the unpermuted step (cross-entropy is
permutation-invariant, CP attention is exact).

``make_spmd_train_step(stage_fn, graph, sim)`` -> pipeline-parallel
training under the shard_map schedule executor
(``repro.parallel.spmd``): each step runs the plan's F/B/W timeline
distributed over the mesh's pipeline axis and feeds the stage-stacked
grads to the optimizer. The mesh may carry a ``cp`` axis alongside, so
one plan JSON drives PP x CP on a single device mesh.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import api
from repro.models import transformer as T
from repro.optim import optimizer as opt


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, valid=None):
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None],
                             axis=-1)[..., 0]
    nll = lse - ll
    if valid is None:
        return jnp.mean(nll)
    w = valid.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def chunked_cross_entropy(h, params, cfg: ModelConfig, labels, valid=None,
                          chunk: Optional[int] = None):
    """h: [B,T,d] final hidden; computes CE scanning seq chunks so only
    [B,chunk,V] logits exist at a time (recomputed in backward)."""
    B, T_, d = h.shape
    c = chunk or cfg.loss_chunk
    if not c or T_ % c != 0:
        logits = T.unembed(params, cfg, h)
        return cross_entropy(logits, labels, valid)
    nc = T_ // c
    hs = jnp.moveaxis(h.reshape(B, nc, c, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, nc, c), 1, 0)
    vs = None if valid is None else \
        jnp.moveaxis(valid.reshape(B, nc, c), 1, 0)

    def body(carry, xs):
        if vs is None:
            hc, lc = xs
            vc = jnp.ones(lc.shape, jnp.float32)
        else:
            hc, lc, vc = xs
            vc = vc.astype(jnp.float32)

        def f(hc):
            logits = T.unembed(params, cfg, hc).astype(jnp.float32)
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
            return jnp.sum((lse - ll) * vc), jnp.sum(vc)
        s, n = jax.checkpoint(f)(hc)
        tot, cnt = carry
        return (tot + s, cnt + n), None

    xs = (hs, ls) if vs is None else (hs, ls, vs)
    (tot, cnt), _ = lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)), xs)
    return tot / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# LM train step (all assigned architectures)
# ---------------------------------------------------------------------------

def make_loss_fn(cfg: ModelConfig):
    mod = api.module_for(cfg)

    def loss_fn(params, batch):
        valid = batch.get("valid")
        if cfg.loss_chunk and hasattr(mod, "hidden"):
            h, aux = mod.hidden(params, cfg, batch)
            loss = chunked_cross_entropy(h, params, cfg, batch["labels"],
                                         valid)
        else:
            logits, aux = mod.forward(params, cfg, batch)
            loss = cross_entropy(logits, batch["labels"], valid)
        return loss + aux.get("aux_loss", 0.0), \
            {"ce": loss, **{k: v for k, v in aux.items()}}

    return loss_fn


def make_train_step(cfg: ModelConfig, ocfg: Optional[opt.AdamWConfig] = None,
                    frozen_mask=None):
    ocfg = ocfg or opt.AdamWConfig()
    loss_fn = make_loss_fn(cfg)

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = opt.update(ocfg, grads, opt_state, params,
                                           frozen_mask)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return step


# ---------------------------------------------------------------------------
# Context-parallel train step (Cornstarch §4.3: train THROUGH the CP
# bodies — attention gradients cross ranks via the combining-aware
# custom_vjps in core.context_parallel)
# ---------------------------------------------------------------------------

#: batch keys whose token axis follows the CP permutation -> token axis
#: (pos3 is [3, B, T] — M-RoPE position ids travel with their tokens)
_CP_TOKEN_KEYS = {"tokens": 1, "labels": 1, "positions": 1, "bits": 1,
                  "valid": 1, "inputs_embeds": 1, "embed_mask": 1,
                  "pos3": 2}


def make_cp_train_step(cfg: ModelConfig, layout, mesh,
                       ocfg: Optional[opt.AdamWConfig] = None, *,
                       axis_name: str = "cp", method: str = "allgather",
                       frozen_mask=None):
    """Context-parallel LM train step.

    ``layout`` is ``ContextPlan.apply(seq_len)``'s dict (``perm``,
    ``inv_perm``, ``num_ranks``): the step permutes every token-axis
    batch array into plan layout, then runs the ordinary loss with
    ``cfg`` rewired so attention dispatches through
    ``core.context_parallel.cp_attention`` over ``mesh``'s
    ``axis_name`` axis (per-step math = ``cfg.attn_impl``; ``method``
    picks allgather vs ring). Because the permutation rides every
    per-token tensor and CP attention is exact, loss and grads match
    ``make_train_step`` on the unpermuted batch.
    """
    ocfg = ocfg or opt.AdamWConfig()
    perm = jnp.asarray(layout["perm"])
    n_dev = mesh.shape[axis_name]
    if len(layout["perm"]) % n_dev != 0:
        raise ValueError(
            f"seq_len {len(layout['perm'])} is not divisible by the "
            f"{n_dev}-device {axis_name!r} mesh axis; pad the sequence "
            f"to a rank multiple before planning")
    if layout["num_ranks"] != n_dev:
        # math stays exact on any mesh size (shard_map just re-slices
        # the permuted axis), but the plan's workload balance only
        # holds when rank slices align with devices — say so
        import warnings
        warnings.warn(
            f"ContextPlan was balanced for {layout['num_ranks']} ranks "
            f"but the {axis_name!r} mesh axis has {n_dev} devices; "
            f"results are exact but the planned load balance is lost",
            stacklevel=2)
    cp_cfg = cfg.replace(cp_mesh=mesh, cp_axis=axis_name,
                         cp_method=method, attn_q_chunk=0)
    loss_inner = make_loss_fn(cp_cfg)

    def loss_fn(params, batch):
        if batch.get("bits") is None:
            # without bits run_attention cannot dispatch to
            # cp_attention — every device would replicate the full
            # dense attention and nothing would be context-parallel
            raise ValueError(
                "make_cp_train_step needs batch['bits'] (BAM "
                "bitfields); use bam.causal_bits for pure-text batches")
        pb = dict(batch)
        for key, axis in _CP_TOKEN_KEYS.items():
            if pb.get(key) is not None:
                pb[key] = jnp.take(pb[key], perm, axis=axis)
        return loss_inner(params, pb)

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = opt.update(ocfg, grads, opt_state, params,
                                           frozen_mask)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return step


# ---------------------------------------------------------------------------
# SPMD pipeline train step (schedule executor under shard_map)
# ---------------------------------------------------------------------------

def make_spmd_train_step(stage_fn, graph, sim,
                         ocfg: Optional[opt.AdamWConfig] = None, *,
                         mesh=None, axis_name: str = "pp",
                         microbatch_loss=None, frozen_mask=None,
                         trainable=None, grad_scale: float = 1.0,
                         dispatch: str = "rolled", program=None):
    """Pipeline-parallel train step driven by a simulated schedule
    timeline, executed distributed (``repro.parallel.spmd``).

    ``stage_fn`` / ``stage_params`` follow the ``execute_schedule``
    contract — a single homogeneous callable with stage-stacked params,
    or a real-model stage list (``models.stages.StageBundle``:
    per-stage 3-arg fns, list params, ``trainable`` flags);
    ``graph``/``sim`` come from the plan (``executor["sim_graph"]`` /
    ``executor["schedule"]`` of ``plan.apply(mllm, mode="spmd")``, pass
    ``program=executor["spmd_program"]`` to reuse its compile). The
    schedule program is compiled once; every ``step(stage_params,
    opt_state, microbatches)`` replays it under ``shard_map`` (the
    jitted core is cached across steps) and applies AdamW — list
    params flow through AdamW as a pytree, with ``frozen_mask``
    keeping optimizer state out of frozen slots. ``grad_scale``
    rescales the summed per-microbatch loss/grads to the full-batch
    mean (``1/num_microbatches`` for ``StageBundle.microbatch_loss``).
    Frozen stages contribute exactly-zero grads by construction."""
    from repro.parallel.spmd import build_spmd_runner
    ocfg = ocfg or opt.AdamWConfig()
    runner = build_spmd_runner(stage_fn, graph, sim, mesh=mesh,
                               axis_name=axis_name,
                               microbatch_loss=microbatch_loss,
                               trainable=trainable, dispatch=dispatch,
                               program=program)

    def step(stage_params, opt_state, microbatches):
        res = runner(stage_params, microbatches)
        grads, loss = res["param_grads"], res["loss"]
        if grad_scale != 1.0:
            grads = jax.tree.map(lambda g: g * grad_scale, grads)
            loss = loss * grad_scale
        params, opt_state, om = opt.update(
            ocfg, grads, opt_state, stage_params, frozen_mask)
        return params, opt_state, {"loss": loss, **om}

    return step


# ---------------------------------------------------------------------------
# Serve step (decode shapes)
# ---------------------------------------------------------------------------

def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, batch):
        logits, cache = api.decode_step(params, cfg, cache, batch)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache
    return serve_step


def make_prefill(cfg: ModelConfig):
    """prefill = full forward returning logits of the last position
    (prefill_32k dry-run entry point)."""
    mod = api.module_for(cfg)

    def prefill(params, batch):
        if cfg.loss_chunk and hasattr(mod, "hidden"):
            h, _ = mod.hidden(params, cfg, batch)
            return T.unembed(params, cfg, h[:, -1:, :])
        logits, _ = mod.forward(params, cfg, batch)
        return logits[:, -1:, :]
    return prefill


# ---------------------------------------------------------------------------
# Cornstarch MLLM train step (frozen-aware)
# ---------------------------------------------------------------------------

def make_mllm_train_step(mllm, ocfg: Optional[opt.AdamWConfig] = None):
    ocfg = ocfg or opt.AdamWConfig()

    def loss_fn(params, batch):
        (logits, aux), merged = mllm.forward(params, batch)
        # loss over text positions only (modality tokens carry no labels)
        is_text = (merged["bits"] != 0) & (~merged["embed_mask"])
        B, Tm = merged["tokens"].shape
        labels = jnp.zeros((B, Tm), jnp.int32)
        # labels provided for the original text token stream; scatter
        # them to text slots
        txt_idx = jnp.cumsum(is_text.astype(jnp.int32), axis=1) - 1
        lab_src = batch["labels"]
        gathered = jnp.take_along_axis(
            lab_src, jnp.clip(txt_idx, 0, lab_src.shape[1] - 1), axis=1)
        labels = jnp.where(is_text, gathered, 0)
        loss = cross_entropy(logits, labels, valid=is_text)
        return loss + aux.get("aux_loss", 0.0), {"ce": loss}

    def step(params, opt_state, batch):
        # frozen mask is a *static* structure of python bools derived
        # from the module flags (not traced values)
        frozen_mask = mllm.frozen_mask(params)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        params, opt_state, om = opt.update(ocfg, grads, opt_state, params,
                                           frozen_mask)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return step, loss_fn
