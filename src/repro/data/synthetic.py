"""Synthetic data pipeline (the paper evaluates on synthetic multimodal
batches: ~1k text tokens + one 1280x720 image + one 30 s audio clip per
sample, modality tokens injected mid-text -> 1.5k–4k tokens total).

Provides:
  * ``TextLMDataset`` — deterministic random-token LM batches for the
    assigned unimodal architectures.
  * ``MultimodalDataset`` — text + stubbed frame/patch embeddings with
    BAM bitfields in the three paper mask modes (Fig. 11):
    EP (encoder outputs prepended), EE (embedded mid-text),
    MP (multimodal packing: several documents packed per row).
All host-side numpy, seeded, zero external deps — a real input pipeline
shape (iterator -> device batches) without fake downloads.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core import bam


@dataclasses.dataclass
class TextLMDataset:
    """Seeded synthetic LM stream. ``noise = 1.0`` gives i.i.d. uniform
    tokens (throughput benchmarking); ``noise < 1`` draws from a fixed
    first-order Markov chain (next = perm[cur] w.p. 1-noise), giving a
    *learnable* distribution with entropy ≈ noise·ln(V) — the e2e
    training driver uses this so the loss curve means something."""
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    noise: float = 0.1

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        perm = np.random.default_rng(1234).permutation(self.vocab_size)
        while True:
            if self.noise >= 1.0:
                tok = rng.integers(0, self.vocab_size,
                                   (self.batch_size, self.seq_len + 1),
                                   dtype=np.int64)
            else:
                tok = np.empty((self.batch_size, self.seq_len + 1),
                               np.int64)
                tok[:, 0] = rng.integers(0, self.vocab_size,
                                         self.batch_size)
                for t in range(1, self.seq_len + 1):
                    nxt = perm[tok[:, t - 1]]
                    rand = rng.integers(0, self.vocab_size,
                                        self.batch_size)
                    flip = rng.random(self.batch_size) < self.noise
                    tok[:, t] = np.where(flip, rand, nxt)
            pos = np.broadcast_to(np.arange(self.seq_len, dtype=np.int32),
                                  (self.batch_size, self.seq_len))
            yield {
                "tokens": jnp.asarray(tok[:, :-1], jnp.int32),
                "labels": jnp.asarray(tok[:, 1:], jnp.int32),
                "positions": jnp.asarray(pos),
            }


def sample_segments(mode: str, text_len: int, mod_tokens: Dict[int, int],
                    rng: np.random.Generator,
                    docs: int = 1) -> List[Tuple]:
    """Build a segment list for bam.build_sample_bits.

    mode: "ep" (modality prepended), "ee" (embedded mid-text),
    "mp" (several packed documents, each ee-style)."""
    segs: List[Tuple] = []
    for d in range(docs):
        if d > 0:
            segs.append(("newdoc", 0, 0))
        if mode == "ep":
            for m, n in mod_tokens.items():
                segs.append(("mod", m, n))
            segs.append(("text", 0, text_len))
        else:  # ee (and each packed doc in mp)
            cuts = sorted(rng.integers(1, max(text_len - 1, 2),
                                       len(mod_tokens)))
            prev = 0
            for (m, n), c in zip(mod_tokens.items(), cuts):
                segs.append(("text", 0, int(c - prev)))
                segs.append(("mod", m, n))
                prev = c
            segs.append(("text", 0, int(text_len - prev)))
    return segs


@dataclasses.dataclass
class MultimodalDataset:
    """Yields Cornstarch MLLM batches: text tokens + per-modality stub
    embeddings + BAM bits for the merged sequence."""
    vocab_size: int
    text_len: int
    batch_size: int
    encoder_dims: Dict[str, int]          # name -> d_model
    encoder_tokens: Dict[str, int]        # name -> emitted tokens
    modality_ids: Dict[str, int]          # name -> BAM bit
    mask_mode: str = "ee"                 # ep | ee | mp
    docs_per_row: int = 1                 # >1 only for mp
    seed: int = 0

    def __iter__(self) -> Iterator[dict]:
        rng = np.random.default_rng(self.seed)
        while True:
            out = {
                "text_tokens": jnp.asarray(
                    rng.integers(0, self.vocab_size,
                                 (self.batch_size, self.text_len)),
                    jnp.int32),
                "labels": jnp.asarray(
                    rng.integers(0, self.vocab_size,
                                 (self.batch_size, self.text_len)),
                    jnp.int32),
            }
            for name, d in self.encoder_dims.items():
                n = self.encoder_tokens[name]
                out[f"{name}_embeds"] = jnp.asarray(
                    rng.normal(0, 1, (self.batch_size, n, d)), jnp.float32)
            yield out

    def merged_bits(self, rng: Optional[np.random.Generator] = None
                    ) -> Tuple[np.ndarray, np.ndarray]:
        """One row's merged-sequence BAM bits/pos (for CP planning and
        the Table-4 benchmark)."""
        rng = rng or np.random.default_rng(self.seed)
        mt = {self.modality_ids[n]: self.encoder_tokens[n]
              for n in self.encoder_dims}
        per_doc_text = self.text_len // self.docs_per_row
        segs = sample_segments(self.mask_mode, per_doc_text, mt, rng,
                               docs=self.docs_per_row)
        total = self.text_len + self.docs_per_row * sum(mt.values())
        return bam.build_sample_bits(segs, total)


def random_multimodal_bits(seq_len: int, mode: str, G_hint: int = 8,
                           seed: int = 0,
                           n_modalities: int = 2
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Random mask instances for the Table-4 benchmark: a seq_len
    sequence with randomly sized modality streams (EP/EE) or randomly
    packed documents (MP), like the paper's per-run random masks."""
    rng = np.random.default_rng(seed)
    if mode == "mp":
        docs = int(rng.integers(3, 9))
        text = int(seq_len * 0.6)
        mod_total = seq_len - text
        per_doc_mod = {m + 1: max(mod_total // docs // n_modalities, 1)
                       for m in range(n_modalities)}
        segs = sample_segments("mp", text // docs, per_doc_mod, rng,
                               docs=docs)
    else:
        frac = rng.uniform(0.2, 0.5)
        mod_total = int(seq_len * frac)
        mt = {m + 1: mod_total // n_modalities for m in range(n_modalities)}
        text = seq_len - sum(mt.values())
        segs = sample_segments(mode, text, mt, rng)
    return bam.build_sample_bits(segs, seq_len)
