"""Paged KV cache for decode serving (host-side page table + device pool).

The serving cache is a pool of fixed-size pages rather than one dense
[B, Tmax] strip per request:

* ``PageTable`` (host, numpy) — owns the free list and the per-request
  logical-token -> (physical page, slot) mapping, plus host mirrors of
  the per-slot BAM bitfields and positions. The mirrors are what make
  the cache *multimodal-aware*: page compaction for the decode kernel
  is computed from the same ``repro.core.bam`` machinery that drives
  the training kernels' grid compaction.
* ``init_paged_cache`` (device) — the page pool itself:
  ``k``/``v`` [L, P, page_size, Hkv, hd] plus device copies of the
  bits/pos slot metadata (the decode kernel evaluates the mask
  in-registers from these, exactly like the training kernels).

Page 0 is a reserved **null page**: its bits stay 0 (= never
attends / attended), so any padded page-table entry or inactive batch
row can safely point at it — reads are masked out, writes are garbage
into a slot nothing will ever read.

Because BAM mask semantics use *explicit* positions (never iota), the
physical order of tokens inside the pool is irrelevant to correctness.
That is what lets a ``ContextPlan``-permuted prefill (CP ranks hold
permuted token blocks) write its K/V straight into the decode pool with
no re-gather: allocate the prompt's pages in plan layout
(``plan_page_owners``) and each CP rank's tokens land in a contiguous
run of rank-owned pages.

``build_decode_grid`` turns the table + per-request query bitfields
into the flattened step list the single-query flash-decode kernel
consumes (``repro.kernels.paged_decode``): per request, a k-major sweep
over only the pages the bitfield mask can reach — fully-masked pages
are compacted out of the grid and cost no grid step or DMA. The
per-request page pruning reuses ``bam.build_block_map`` with
``block_q=1`` (the decode query is one token) and ``block_k=page_size``
so the coverage obligations already proven for the training grids
(kernellint ``block-map-coverage``) carry over.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core import bam

#: reserved all-zero-bits page every padded/inactive reference points at
NULL_PAGE = 0


# ---------------------------------------------------------------------------
# Host-side page table
# ---------------------------------------------------------------------------

class PageTable:
    """Free-list page allocator + logical->physical token mapping.

    One instance serves all layers (the pool's layer axis is stacked on
    device; the mapping is layer-invariant). All state is host numpy —
    the engine mutates it between jitted steps.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError(
                f"num_pages={num_pages}: need at least the null page "
                f"plus one allocatable page")
        if page_size < 1:
            raise ValueError(f"page_size={page_size} must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        self.bits = np.zeros((num_pages, page_size), np.uint32)
        self.pos = np.full((num_pages, page_size), -1, np.int32)
        #: informational CP ownership (rank id, -1 = unowned) — set by
        #: plan-layout prefill so docs/benchmarks can show rank-local
        #: writes; correctness never depends on it
        self.page_owner = np.full(num_pages, -1, np.int32)
        self._free: List[int] = list(range(num_pages - 1, NULL_PAGE, -1))
        self._pages: Dict[int, List[int]] = {}
        self._len: Dict[int, int] = {}

    # -- allocation --------------------------------------------------------

    @property
    def num_free(self) -> int:
        return len(self._free)

    def requests(self) -> List[int]:
        return sorted(self._pages)

    def pages_of(self, rid: int) -> List[int]:
        return list(self._pages[rid])

    def length(self, rid: int) -> int:
        return self._len[rid]

    def capacity(self, rid: int) -> int:
        return len(self._pages.get(rid, ())) * self.page_size

    def pages_needed(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def alloc(self, rid: int, n_tokens: int) -> List[int]:
        """Grow ``rid``'s page list until it can hold ``n_tokens``
        tokens. Returns the newly allocated physical pages. Raises
        ``RuntimeError`` when the pool cannot satisfy the request (the
        engine's admission control checks ``num_free`` first)."""
        pages = self._pages.setdefault(rid, [])
        self._len.setdefault(rid, 0)
        need = self.pages_needed(n_tokens) - len(pages)
        if need > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: request {rid} needs {need} more "
                f"pages for {n_tokens} tokens but only {len(self._free)} "
                f"of {self.num_pages - 1} allocatable pages are free")
        new = [self._free.pop() for _ in range(max(need, 0))]
        pages.extend(new)
        return new

    def free(self, rid: int) -> None:
        """Release all of ``rid``'s pages back to the pool, scrubbing
        the host bits/pos mirrors so a reused page never leaks stale
        mask metadata (the device arrays are scrubbed by the engine)."""
        for p in self._pages.pop(rid, ()):
            self.bits[p] = 0
            self.pos[p] = -1
            self.page_owner[p] = -1
            self._free.append(p)
        self._len.pop(rid, None)

    # -- logical <-> physical ---------------------------------------------

    def coords(self, rid: int, idx) -> Tuple[np.ndarray, np.ndarray]:
        """Logical token indices -> (physical page, slot) arrays."""
        idx = np.asarray(idx, np.int64)
        pages = np.asarray(self._pages[rid], np.int32)
        if idx.size and int(idx.max()) >= len(pages) * self.page_size:
            raise IndexError(
                f"request {rid}: token index {int(idx.max())} exceeds "
                f"allocated capacity {len(pages) * self.page_size}")
        return pages[idx // self.page_size], \
            (idx % self.page_size).astype(np.int32)

    def write(self, rid: int, idx, bits, pos) -> None:
        """Record tokens in the host mirrors (device scatter happens
        inside the jitted step with the same coordinates)."""
        page, slot = self.coords(rid, idx)
        self.bits[page, slot] = np.asarray(bits, np.uint32)
        self.pos[page, slot] = np.asarray(pos, np.int32)
        idx = np.asarray(idx, np.int64)
        if idx.size:
            self._len[rid] = max(self._len[rid], int(idx.max()) + 1)

    def kv_view(self, rid: int) -> Tuple[np.ndarray, np.ndarray]:
        """The request's logical KV metadata, page-padded: (bits, pos)
        flat arrays of length n_pages * page_size (trailing slots of
        the last page carry bits=0 / pos=-1 and mask out)."""
        pages = self._pages[rid]
        return self.bits[pages].reshape(-1), self.pos[pages].reshape(-1)

    def page_table_row(self, rid: int, max_pages: int) -> np.ndarray:
        """Dense [max_pages] physical-page row for the XLA gather path,
        padded with the null page."""
        pages = self._pages[rid]
        if len(pages) > max_pages:
            raise ValueError(
                f"request {rid} holds {len(pages)} pages > "
                f"max_pages={max_pages}")
        row = np.full(max_pages, NULL_PAGE, np.int32)
        row[:len(pages)] = pages
        return row


# ---------------------------------------------------------------------------
# Device page pool
# ---------------------------------------------------------------------------

def init_paged_cache(cfg, num_pages: int, page_size: int, dtype=None):
    """Device page pool for ``cfg``: ``{"k","v"}`` [L, P, page_size,
    Hkv, hd] (Hkv honors ``cfg.decode_kv_replicate``, like the dense
    decode cache) plus ``{"bits","pos"}`` [P, page_size] slot metadata
    the kernel masks from."""
    from repro.models.transformer import _cache_cfg
    ccfg = _cache_cfg(cfg)
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    shape = (cfg.num_layers, num_pages, page_size, ccfg.num_kv_heads,
             ccfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "bits": jnp.zeros((num_pages, page_size), jnp.uint32),
            "pos": jnp.full((num_pages, page_size), -1, jnp.int32)}


# ---------------------------------------------------------------------------
# Decode grid: per-request active-page compaction
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodeGrid:
    """Flattened step list for the single-query flash-decode kernel.

    One step = (batch row ``req``, physical page, first, last, active);
    each request's steps are consecutive (k-major sweep over its active
    pages) so the kernel's online-softmax scratch can init on ``first``
    and flush on ``last`` — the same framing contract as
    ``bam.BlockMask``. ``active == 0`` steps exist only to (a) flush a
    request none of whose pages are reachable and (b) pad the step
    count to a static bucket (``pad_to``) so the jit cache is stable
    while lengths grow.
    """
    page_size: int
    window: int
    req: np.ndarray      # [n_steps] int32 batch row
    page: np.ndarray     # [n_steps] int32 physical page
    first: np.ndarray    # [n_steps] int32 0/1
    last: np.ndarray     # [n_steps] int32 0/1
    active: np.ndarray   # [n_steps] int32 0/1
    n_dense_steps: int   # total pages held by the batched requests

    @property
    def n_steps(self) -> int:
        return len(self.req)

    @property
    def n_active_steps(self) -> int:
        return int(self.active.sum())

    @property
    def skip_fraction(self) -> float:
        """Fraction of resident pages the compacted grid never visits
        (masked pages cost no grid step and no K/V DMA)."""
        return 1.0 - self.n_active_steps / max(self.n_dense_steps, 1)

    def arrays(self):
        """(req, page, first, last, active) int32 — the kernel's
        scalar-prefetch operands."""
        return (self.req, self.page, self.first, self.last, self.active)


def build_decode_grid(table: PageTable, rids: Sequence[Optional[int]],
                      q_bits, q_pos, *, window: int = 0,
                      pad_to: Optional[int] = None) -> DecodeGrid:
    """Active-page step list for one decode batch.

    ``rids[i]`` is the request occupying batch row ``i`` (``None`` =
    empty row: contributes one inactive flush step against the null
    page). ``q_bits``/``q_pos``: [B] host arrays for the current query
    token of each row — the engine must have ``write``-n the current
    token into the table first, so the query can attend itself.

    Page pruning is ``bam.build_block_map`` with ``block_q=1`` /
    ``block_k=page_size`` over the request's page-padded KV metadata —
    the mask reduction, q-major flattening, and first/last framing are
    the exact machinery the training kernels' compacted grids use.
    ``window`` must be 0 unless every decode layer shares the same
    sliding window (per-layer windows mask in-kernel instead; grid
    pruning with a nonzero window would drop pages a full-attention
    layer still needs).
    """
    q_bits = np.asarray(q_bits, np.uint32)
    q_pos = np.asarray(q_pos, np.int32)
    if len(rids) != len(q_bits) or len(rids) != len(q_pos):
        raise ValueError(
            f"rids/q_bits/q_pos disagree on batch size: "
            f"{len(rids)}/{len(q_bits)}/{len(q_pos)}")
    req, page, first, last, active = [], [], [], [], []
    n_dense = 0
    for i, rid in enumerate(rids):
        if rid is None:
            req.append(i)
            page.append(NULL_PAGE)
            first.append(1)
            last.append(1)
            active.append(0)
            continue
        pages = table.pages_of(rid)
        n_dense += len(pages)
        kv_bits, kv_pos = table.kv_view(rid)
        bm = bam.build_block_map(
            q_bits[i:i + 1], kv_bits, q_pos[i:i + 1], kv_pos,
            block_q=1, block_k=table.page_size, window=window)
        for (_iq, ik, f, l, a) in bm.q_steps:
            req.append(i)
            page.append(pages[ik] if a else NULL_PAGE)
            first.append(f)
            last.append(l)
            active.append(a)
    if pad_to is not None:
        if pad_to < len(req):
            raise ValueError(
                f"pad_to={pad_to} < {len(req)} real decode steps")
        while len(req) < pad_to:
            req.append(0)
            page.append(NULL_PAGE)
            first.append(0)
            last.append(0)
            active.append(0)
    return DecodeGrid(
        page_size=table.page_size, window=window,
        req=np.asarray(req, np.int32), page=np.asarray(page, np.int32),
        first=np.asarray(first, np.int32), last=np.asarray(last, np.int32),
        active=np.asarray(active, np.int32), n_dense_steps=n_dense)


def decode_grid_bucket(n_steps: int, granule: int = 16) -> int:
    """Round a step count up to a retrace bucket: the step arrays are
    traced operands but their LENGTH is a static shape, so bucketing
    keeps the jit cache warm while caches grow."""
    return max(granule, -(-n_steps // granule) * granule)


# ---------------------------------------------------------------------------
# ContextPlan page layout (CP prefill -> sharded decode cache handoff)
# ---------------------------------------------------------------------------

def plan_page_owners(layout: Dict, page_size: int) -> np.ndarray:
    """Per-page CP rank ownership for a prompt laid out in ContextPlan
    order.

    ``layout`` is ``ContextPlan.apply(seq_len)``'s dict: ``perm`` maps
    plan-layout slots -> source token indices and per-rank slot counts
    differ by at most one. Writing the prompt's K/V in *plan-layout
    order* (slot j of the cache holds source token ``perm[j]``) makes
    each rank's tokens a contiguous slot run, so rank r's prefill
    output lands in pages ``owners == r`` — no cross-rank re-gather
    between prefill and decode. Returns [n_pages] int32 rank ids; a
    page straddling two ranks' slot ranges is owned by the rank holding
    its first slot (only possible when counts don't divide
    ``page_size``)."""
    n = len(layout["perm"])
    ranks = int(layout["num_ranks"])
    base, extra = divmod(n, ranks)
    counts = [base + (1 if r < extra else 0) for r in range(ranks)]
    slot_rank = np.repeat(np.arange(ranks, dtype=np.int32), counts)
    n_pages = -(-n // page_size)
    return slot_rank[np.arange(n_pages) * page_size]
