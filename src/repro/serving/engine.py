"""Continuous batching engine over the paged BAM decode cache.

The engine owns four pieces of state and interleaves two jitted steps:

* a host ``PageTable`` (free list + logical->physical mapping + bits/pos
  mirrors) and the device page pool from ``init_paged_cache``;
* a waiting queue of ``Request``s and a fixed bank of ``max_batch``
  decode rows (``None`` = empty slot).

``step()`` is one scheduler tick: admit waiting requests into free rows
(admission control reserves the *full* prompt+generation page budget up
front, so an admitted request can never hit pool exhaustion mid-
flight), prefill each admission (one jitted prompt forward that
scatters K/V straight into its pages and emits its first token), then
run one batched decode step for every occupied row. Requests finish on
EOS or ``max_new_tokens``; their pages are freed and their bits/pos
metadata scrubbed (host and device) so reused pages never leak stale
mask state, and the row is immediately available to the next admission
— classic continuous batching, no generation-length barrier.

Decode attention runs either through the XLA dense-gather reference
(``attn="xla"``) or the paged flash-decode kernel (``attn="kernel"`` /
``"interpret"``); the kernel path gets its step list from
``build_decode_grid`` — per-request active-page compaction, bucketed
(``decode_grid_bucket``) so the jit cache stays warm while caches grow.

Greedy decoding is intentional: continuous batching must be
*composition-invariant* (a request's tokens do not depend on which
other requests share the batch), and the determinism test in
``tests/test_serving.py`` asserts exactly that.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import bam
from repro.serving import model as M
from repro.serving.paged_cache import (NULL_PAGE, PageTable,
                                       build_decode_grid,
                                       decode_grid_bucket,
                                       init_paged_cache, plan_page_owners)

from functools import lru_cache


class InfeasibleRequest(ValueError):
    """Raised at ``submit`` time for a request whose page budget can
    NEVER fit the pool, even with the engine otherwise empty — without
    this check the request would sit at the head of the FIFO forever
    (admission control only waits for pages to free up; an infeasible
    budget never frees enough). Structured fields so callers can
    degrade gracefully (shrink ``max_new_tokens``, chunk the prompt,
    route to a bigger pool)."""

    def __init__(self, *, prompt_len: int, max_new_tokens: int,
                 needed_pages: int, capacity: int, page_size: int):
        self.prompt_len = prompt_len
        self.max_new_tokens = max_new_tokens
        self.needed_pages = needed_pages
        self.capacity = capacity
        self.page_size = page_size
        super().__init__(
            f"request needs {needed_pages} pages (prompt {prompt_len} "
            f"tokens + {max_new_tokens} new, page_size {page_size}) "
            f"but the pool only has {capacity} allocatable pages — it "
            f"can never be admitted; shrink the request or grow "
            f"num_pages")


@lru_cache(maxsize=None)
def _jitted_steps(cfg, attn: str):
    """Engines with the same (frozen) cfg and attention path share one
    pair of jitted step functions, so spinning up a second engine — the
    determinism tests, the benchmark's per-batch-size runs — reuses the
    compile cache instead of retracing from scratch."""
    return (jax.jit(partial(M.paged_prefill, cfg=cfg)),
            jax.jit(partial(M.paged_decode_step, cfg=cfg, attn=attn)))


@dataclasses.dataclass
class Request:
    """One generation request. ``tokens``/``bits``/``positions`` cover
    the (unpadded) prompt; ``gen_bits`` is the bitfield stamped on every
    generated token (text by default — generation emits text even when
    the prompt is multimodal)."""
    rid: int
    tokens: np.ndarray                  # [T] int32 prompt
    max_new_tokens: int
    bits: Optional[np.ndarray] = None       # [T] uint32 (None = causal text)
    positions: Optional[np.ndarray] = None  # [T] int32 (None = arange)
    gen_bits: int = 0                   # 0 -> bam.text_token() at admission
    eos_id: Optional[int] = None
    plan: object = None                 # optional ContextPlan for prefill
    # -- runtime state (engine-owned) --------------------------------------
    generated: List[int] = dataclasses.field(default_factory=list)
    next_idx: int = 0                   # next logical cache index
    next_pos: int = 0                   # next semantic position
    done: bool = False


class ServingEngine:
    def __init__(self, params, cfg, *, num_pages: int = 64,
                 page_size: int = 16, max_batch: int = 4,
                 attn: str = "xla", cache_dtype=None):
        M.check_serving_cfg(cfg)
        if attn not in M.ATTN_PATHS:
            raise ValueError(f"attn={attn!r}; pick from {M.ATTN_PATHS}")
        self.params = params
        self.cfg = cfg
        self.attn = attn
        self.max_batch = max_batch
        self.table = PageTable(num_pages, page_size)
        self.cache = init_paged_cache(cfg, num_pages, page_size,
                                      dtype=cache_dtype)
        self.rows: List[Optional[int]] = [None] * max_batch
        self.requests: Dict[int, Request] = {}
        self.queue: deque = deque()
        self._next_rid = 0
        self.grid_window = M.grid_window(cfg)
        self._prefill_fn, self._decode_fn = _jitted_steps(cfg, attn)

    # -- submission --------------------------------------------------------

    def submit(self, tokens, *, bits=None, positions=None,
               max_new_tokens: int = 16, eos_id: Optional[int] = None,
               gen_bits: Optional[int] = None, plan=None) -> int:
        """Queue a request; returns its rid. ``bits`` (uint32 [T]) carry
        the prompt's multimodal BAM bitfields (None = causal text);
        ``plan`` lays the prompt's pages out in ContextPlan order."""
        rid = self._next_rid
        self._next_rid += 1
        r = Request(
            rid=rid, tokens=np.asarray(tokens, np.int32).reshape(-1),
            max_new_tokens=int(max_new_tokens),
            bits=None if bits is None else
            np.asarray(bits, np.uint32).reshape(-1),
            positions=None if positions is None else
            np.asarray(positions, np.int32).reshape(-1),
            gen_bits=int(gen_bits) if gen_bits is not None
            else bam.text_token(),
            eos_id=eos_id, plan=plan)
        if r.bits is not None and len(r.bits) != len(r.tokens):
            raise ValueError(
                f"request {rid}: bits length {len(r.bits)} != prompt "
                f"length {len(r.tokens)}")
        budget = self._page_budget(r)
        capacity = self.table.num_pages - 1      # page 0 is the null page
        if budget > capacity:
            self._next_rid = rid                 # rid not consumed
            raise InfeasibleRequest(
                prompt_len=len(r.tokens),
                max_new_tokens=r.max_new_tokens,
                needed_pages=budget, capacity=capacity,
                page_size=self.table.page_size)
        self.requests[rid] = r
        self.queue.append(rid)
        return rid

    # -- scheduling --------------------------------------------------------

    def _padded_len(self, n: int) -> int:
        ps = self.table.page_size
        return -(-n // ps) * ps

    def _page_budget(self, r: Request) -> int:
        # prompt (page-padded) + every generated token that re-enters
        # the cache as a decode query (the last one never does)
        return self.table.pages_needed(
            self._padded_len(len(r.tokens)) + max(r.max_new_tokens - 1, 0))

    def _admit(self) -> List[int]:
        admitted = []
        while self.queue and None in self.rows:
            r = self.requests[self.queue[0]]
            if self._page_budget(r) > self.table.num_free:
                break   # FIFO: don't starve the head of the queue
            self.queue.popleft()
            row = self.rows.index(None)
            self.rows[row] = r.rid
            admitted.append(r.rid)
        return admitted

    def _prefill(self, r: Request) -> None:
        """Jitted prompt forward -> K/V scattered into r's pages; emits
        the request's first generated token from the last-prompt-token
        logits."""
        T = len(r.tokens)
        Tp = self._padded_len(T)
        budget_tokens = Tp + max(r.max_new_tokens - 1, 0)
        self.table.alloc(r.rid, budget_tokens)

        tokens = np.zeros(Tp, np.int32)
        tokens[:T] = r.tokens
        bits = np.zeros(Tp, np.uint32)
        bits[:T] = r.bits if r.bits is not None \
            else np.full(T, bam.text_token(), np.uint32)
        pos = np.full(Tp, -1, np.int32)
        pos[:T] = r.positions if r.positions is not None \
            else np.arange(T, dtype=np.int32)

        last_row = T - 1
        if r.plan is not None:
            layout = r.plan.apply(Tp)
            perm = np.asarray(layout["perm"])
            tokens, bits, pos = tokens[perm], bits[perm], pos[perm]
            last_row = int(np.asarray(layout["inv_perm"])[T - 1])
            owners = plan_page_owners(layout, self.table.page_size)
            pages = self.table.pages_of(r.rid)[:len(owners)]
            self.table.page_owner[pages] = owners

        idx = np.arange(Tp)
        self.table.write(r.rid, idx, bits, pos)
        page, slot = self.table.coords(r.rid, idx)
        batch = {"tokens": jnp.asarray(tokens)[None],
                 "positions": jnp.asarray(pos)[None],
                 "bits": jnp.asarray(bits)[None]}
        logits, self.cache = self._prefill_fn(
            self.params, cache=self.cache, batch=batch,
            page=jnp.asarray(page), slot=jnp.asarray(slot))
        r.next_idx = Tp
        r.next_pos = T
        self._emit(r, int(jnp.argmax(logits[0, last_row])))

    def _emit(self, r: Request, token: int) -> None:
        r.generated.append(token)
        if (r.eos_id is not None and token == r.eos_id) or \
                len(r.generated) >= r.max_new_tokens:
            r.done = True

    def _retire(self, rid: int) -> None:
        pages = np.asarray(self.table.pages_of(rid), np.int32)
        self.table.free(rid)
        # device-side scrub: the kernel masks from cache["bits"]/"pos",
        # so a reused page must not carry the old request's metadata
        self.cache["bits"] = self.cache["bits"].at[pages].set(0)
        self.cache["pos"] = self.cache["pos"].at[pages].set(-1)
        self.rows[self.rows.index(rid)] = None

    # -- decode ------------------------------------------------------------

    def _decode_batch(self):
        """Batch arrays for one decode tick over the current rows. Each
        occupied row inserts its pending token (the last generated one)
        at its next logical index; empty rows point at the null page
        with bits=0 (mask out everywhere, write nothing visible)."""
        B = self.max_batch
        tokens = np.zeros(B, np.int32)
        pos = np.zeros(B, np.int32)
        qbits = np.zeros(B, np.uint32)
        page = np.full(B, NULL_PAGE, np.int32)
        slot = np.zeros(B, np.int32)
        for i, rid in enumerate(self.rows):
            if rid is None:
                continue
            r = self.requests[rid]
            tokens[i] = r.generated[-1]
            pos[i] = r.next_pos
            qbits[i] = r.gen_bits
            self.table.write(r.rid, [r.next_idx], [r.gen_bits],
                             [r.next_pos])
            p, s = self.table.coords(r.rid, [r.next_idx])
            page[i], slot[i] = p[0], s[0]
        batch = {"tokens": jnp.asarray(tokens)[:, None],
                 "positions": jnp.asarray(pos)[:, None],
                 "bits": jnp.asarray(qbits)[:, None],
                 "page": jnp.asarray(page), "slot": jnp.asarray(slot)}
        if self.attn == "xla":
            mp = max([1] + [len(self.table.pages_of(rid))
                            for rid in self.rows if rid is not None])
            mp = decode_grid_bucket(mp, granule=4)
            pt = np.stack([
                self.table.page_table_row(rid, mp) if rid is not None
                else np.full(mp, NULL_PAGE, np.int32)
                for rid in self.rows])
            batch["page_tables"] = jnp.asarray(pt)
        else:
            # bucket by the dense page count so the step-array length
            # (a static shape) stays put while requests grow
            bound = sum(len(self.table.pages_of(rid)) if rid is not None
                        else 1 for rid in self.rows)
            grid = build_decode_grid(
                self.table, self.rows, qbits, pos,
                window=self.grid_window,
                pad_to=decode_grid_bucket(max(bound, 1)))
            self.last_grid = grid
            batch["steps"] = tuple(jnp.asarray(a) for a in grid.arrays())
        return batch

    def step(self) -> Dict[int, int]:
        """One scheduler tick. Returns {rid: token} emitted this tick
        (admitted requests stream their first token from prefill)."""
        out: Dict[int, int] = {}
        for rid in self._admit():
            r = self.requests[rid]
            self._prefill(r)
            out[rid] = r.generated[-1]
            if r.done:
                self._retire(rid)
        if not any(rid is not None for rid in self.rows):
            return out
        batch = self._decode_batch()
        logits, self.cache = self._decode_fn(
            self.params, cache=self.cache, batch=batch)
        next_tok = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i, rid in enumerate(self.rows):
            if rid is None:
                continue
            r = self.requests[rid]
            r.next_idx += 1
            r.next_pos += 1
            self._emit(r, int(next_tok[i]))
            out[rid] = r.generated[-1]
            if r.done:
                self._retire(rid)
        return out

    @property
    def pending(self) -> bool:
        return bool(self.queue) or \
            any(rid is not None for rid in self.rows)

    def run(self, max_ticks: int = 10_000) -> Dict[int, List[int]]:
        """Drive ``step()`` until every submitted request completes;
        returns {rid: generated tokens}."""
        ticks = 0
        while self.pending:
            ticks += 1
            if ticks > max_ticks:
                raise RuntimeError(
                    f"engine did not drain within {max_ticks} ticks "
                    f"(queue={len(self.queue)}, rows={self.rows})")
            self.step()
        return {rid: list(r.generated)
                for rid, r in self.requests.items()}
