"""Serving subsystem: paged BAM KV cache + continuous batching.

Three layers (see ``docs/serving.md``):

* ``paged_cache`` — host ``PageTable`` + device page pool; per-page BAM
  bitfield metadata; ``build_decode_grid`` compacts masked pages out of
  the decode kernel's grid with the training kernels' block-map
  machinery; ``plan_page_owners`` records the ContextPlan prefill
  layout.
* ``model`` — jit-able ``paged_prefill`` (prompt forward that scatters
  K/V straight into pages) and ``paged_decode_step`` (ragged one-token
  decode over resident pages, XLA or Pallas kernel attention).
* ``engine`` — ``ServingEngine``: request queue, admission control with
  upfront page budgets, prefill/decode interleaving, greedy streaming.
"""
from repro.serving.engine import (InfeasibleRequest, Request,
                                  ServingEngine)
from repro.serving.model import (check_serving_cfg, grid_window,
                                 make_paged_decode_step, paged_decode_step,
                                 paged_prefill, prefill_forward,
                                 static_layer_window)
from repro.serving.paged_cache import (NULL_PAGE, DecodeGrid, PageTable,
                                       build_decode_grid,
                                       decode_grid_bucket,
                                       init_paged_cache, plan_page_owners)

__all__ = [
    "NULL_PAGE", "DecodeGrid", "InfeasibleRequest", "PageTable",
    "Request", "ServingEngine",
    "build_decode_grid", "check_serving_cfg", "decode_grid_bucket",
    "grid_window", "init_paged_cache", "make_paged_decode_step",
    "paged_decode_step", "paged_prefill", "plan_page_owners",
    "prefill_forward", "static_layer_window",
]
