"""Paged decode / prefill step builders for the dense transformer family.

Two jit-able pure functions over the page pool from
``serving.paged_cache``:

* ``paged_prefill`` — full forward over one prompt (any ``batch``
  layout, including a ContextPlan-permuted one) that captures every
  layer's projected+roped K/V via ``models.transformer._block`` and
  scatters prompt K/V + slot bitfields/positions straight into the
  page pool. Because ``cfg`` flows through ``layers.run_attention``
  unchanged, a cfg with ``cp_mesh`` set runs the prefill attention
  through the context-parallel bodies — CP prefill writing the sharded
  decode cache with no re-gather in between.
* ``make_paged_decode_step`` — one-token decode for a batch of
  requests with *ragged* per-row cache positions: each row scatters its
  new K/V into its own (page, slot) coordinate, then attends over its
  resident pages either through the dense-gather XLA reference
  (``attn="xla"``) or the single-query flash-decode kernel
  (``attn="kernel"`` on TPU, ``attn="interpret"`` on CPU).

The decode layer loop is a *python* loop (not ``lax.scan``): the Pallas
kernel needs a static per-layer sliding window, and unrolling is what
lets gemma2's local/global alternation run on the kernel path at decode
— the training side has to fall back to XLA for exactly this reason.
Decode state is tiny (one token), so the unrolled trace stays cheap.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import bam
from repro.models import layers as L
from repro.models import transformer as T

ATTN_PATHS = ("xla", "kernel", "interpret")


def check_serving_cfg(cfg: ModelConfig) -> None:
    """The paged path covers the dense-transformer decode family; fail
    loudly (and early) for the families it does not."""
    from repro.models import api
    if api.module_for(cfg) is not T:
        raise ValueError(
            f"paged serving supports the dense transformer family; "
            f"{cfg.name!r} decodes through "
            f"{api.module_for(cfg).__name__}")
    if cfg.mm is not None and cfg.mm.mrope_sections:
        raise ValueError(
            f"{cfg.name!r} uses M-RoPE (pos3) — not yet wired through "
            f"the paged decode path")


def static_layer_window(cfg: ModelConfig, layer_idx: int) -> int:
    """Python-int twin of ``transformer._layer_window`` (the kernel
    needs the window at trace time; the unrolled decode loop makes the
    layer index static)."""
    if cfg.local_global_pattern:
        is_global = (layer_idx % cfg.local_global_pattern) == (
            cfg.local_global_pattern - 1)
        return 0 if is_global else cfg.sliding_window
    return cfg.sliding_window


def grid_window(cfg: ModelConfig) -> int:
    """Sliding window the *decode grid* may prune pages with: only
    when every layer shares it. With gemma2-style alternation the grid
    must keep full-attention reach (window=0) and per-layer windows
    mask in-kernel instead."""
    return 0 if cfg.local_global_pattern else cfg.sliding_window


def _replicate_kv(cfg: ModelConfig, k, v):
    """Match the cache's (possibly ``decode_kv_replicate``-widened) KV
    head count. k/v are 4-D with heads at axis 2 — [B, T, Hkv, hd] at
    decode, [L, T, Hkv, hd] for the stacked prefill K/V."""
    rep = cfg.decode_kv_replicate
    if rep > k.shape[2]:
        k = bam.repeat_kv(k, rep // k.shape[2])
        v = bam.repeat_kv(v, rep // v.shape[2])
    return k, v


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill_forward(params, cfg: ModelConfig, batch):
    """Forward over the prompt that keeps each layer's K/V.

    Returns (logits [B,T,V], k [L,B,T,Hkv,hd], v [L,B,T,Hkv,hd]).
    The layer loop is unrolled so the per-layer K/V can be stacked —
    same math as ``transformer.hidden`` (it runs ``T._block``)."""
    x = T.embed_tokens(params, cfg, batch)
    ks, vs = [], []
    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda a, i=i: a[i], params["layers"])
        x, _aux, (k, v) = T._block(cfg, lp, x, batch, jnp.int32(i), None)
        ks.append(k)
        vs.append(v)
    h = L.apply_norm(cfg, params["final_ln"], x)
    return T.unembed(params, cfg, h), jnp.stack(ks), jnp.stack(vs)


def paged_prefill(params, cfg: ModelConfig, cache, batch, page, slot):
    """Run the prompt forward and write its K/V + slot metadata into
    the page pool.

    batch: tokens/positions/bits [1, T] (one request — continuous
    batching admits and prefills requests one at a time); ``page``/
    ``slot`` [T] int32 physical coordinates from
    ``PageTable.coords`` — in whatever order the batch rows are laid
    out, so a ContextPlan-permuted batch writes each rank's token run
    into its own pages. Rows with bits=0 (page-alignment padding) are
    written but masked everywhere.

    Returns (logits [1,T,V], new cache). jit with static cfg; retraces
    per distinct padded prompt length.
    """
    if batch.get("bits") is None:
        raise ValueError(
            "paged_prefill needs batch['bits'] — the page pool's mask "
            "metadata is the bitfield; use bam.causal_bits for text")
    logits, k, v = prefill_forward(params, cfg, batch)
    k, v = _replicate_kv(cfg, k[:, 0], v[:, 0])     # [L, T, Hkv, hd]
    new = dict(cache)
    new["k"] = cache["k"].at[:, page, slot].set(k.astype(cache["k"].dtype))
    new["v"] = cache["v"].at[:, page, slot].set(v.astype(cache["v"].dtype))
    new["bits"] = cache["bits"].at[page, slot].set(batch["bits"][0])
    new["pos"] = cache["pos"].at[page, slot].set(batch["positions"][0])
    return logits, new


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def paged_decode_step(params, cfg: ModelConfig, cache, batch, *,
                      attn: str = "xla"):
    """One decode token for every batch row against the page pool.

    batch keys:
      tokens/positions/bits [B, 1] — positions are *semantic* (RoPE +
        masking); rows are independent requests at arbitrary ragged
        offsets;
      page/slot [B] int32 — each row's physical insert coordinate
        (empty rows point at the null page);
      page_tables [B, max_pages] int32 (attn="xla") — dense gather
        rows, null-page padded;
      steps — 5-tuple of [n_steps] int32 arrays (attn="kernel"/
        "interpret") from ``build_decode_grid(...).arrays()``.

    Returns (logits [B, 1, V], new cache). The new token's K/V and its
    bits/pos metadata are inserted *before* attention, so each query
    attends itself — matching ``transformer.decode_step``.
    """
    if attn not in ATTN_PATHS:
        raise ValueError(f"attn={attn!r}; pick from {ATTN_PATHS}")
    from repro.kernels.paged_decode import (paged_decode_attention,
                                            paged_decode_ref)
    B = batch["tokens"].shape[0]
    page = batch["page"]
    slot = batch["slot"]
    pos = batch["positions"]                            # [B, 1]
    q_bits = batch.get("bits")
    if q_bits is None:
        q_bits = jnp.full((B, 1), bam.text_token(), jnp.uint32)

    x = T.embed_tokens(params, cfg, batch)              # [B, 1, d]
    bits_pages = cache["bits"].at[page, slot].set(q_bits[:, 0])
    pos_pages = cache["pos"].at[page, slot].set(pos[:, 0])
    ks, vs = cache["k"], cache["v"]

    for i in range(cfg.num_layers):
        lp = jax.tree.map(lambda a, i=i: a[i], params["layers"])
        window = static_layer_window(cfg, i)
        h = L.apply_norm(cfg, lp["ln1"], x)
        q, k, v = L.attn_project_qkv(lp["attn"], cfg, h, h)
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
        k, v = _replicate_kv(cfg, k, v)
        ks = ks.at[i, page, slot].set(k[:, 0].astype(ks.dtype))
        vs = vs.at[i, page, slot].set(v[:, 0].astype(vs.dtype))
        if attn == "xla":
            out = paged_decode_ref(
                q[:, 0], ks[i], vs[i], q_bits, pos, bits_pages, pos_pages,
                batch["page_tables"], softcap=cfg.attn_softcap,
                window=window)
        else:
            out = paged_decode_attention(
                q[:, 0], ks[i], vs[i], q_bits, pos, bits_pages, pos_pages,
                batch["steps"], softcap=cfg.attn_softcap, window=window,
                interpret=(attn == "interpret"))
        attn_out = out[:, None].reshape(B, 1, cfg.q_dim) @ lp["attn"]["wo"]
        if cfg.post_block_norm:
            attn_out = L.apply_norm(cfg, lp["post_ln1"], attn_out)
        x = x + attn_out
        h = L.apply_norm(cfg, lp["ln2"], x)
        mlp_out, _ = T._default_ffn(lp, h, cfg)
        if cfg.post_block_norm:
            mlp_out = L.apply_norm(cfg, lp["post_ln2"], mlp_out)
        x = x + mlp_out

    h = L.apply_norm(cfg, params["final_ln"], x)
    logits = T.unembed(params, cfg, h)
    return logits, {"k": ks, "v": vs, "bits": bits_pages, "pos": pos_pages}


def make_paged_decode_step(cfg: ModelConfig, attn: str = "xla"):
    """jit-ready closure over (params, cache, batch)."""
    check_serving_cfg(cfg)

    def step(params, cache, batch):
        return paged_decode_step(params, cfg, cache, batch, attn=attn)

    return step
