"""Qwen3-1.7B [hf:Qwen/Qwen3-1.7B, family per Qwen/Qwen3-8B card] —
dense, GQA(kv=8), qk_norm, tied embeddings."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-1.7b", family="dense", num_layers=28, d_model=2048,
        num_heads=16, num_kv_heads=8, d_ff=6144, vocab_size=151936,
        head_dim=128, rope_theta=1e6, use_qk_norm=True, tie_embeddings=True,
        decode_kv_replicate=16,
        source="hf:Qwen/Qwen3-8B",
    )


def reduced() -> ModelConfig:
    return full().replace(
        name="qwen3-1.7b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        dtype="float32", remat=False, seq_shard_activations=False,
        loss_chunk=0,
        decode_kv_replicate=4,   # valid for the 4-head reduced variant
    )


register("qwen3-1.7b", full, reduced)
