"""StarCoder2-7B [arXiv:2402.19173] — dense, GQA(kv=4), RoPE, layernorm,
gelu MLP, learned biases on QKV."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b", family="dense", num_layers=32, d_model=4608,
        num_heads=36, num_kv_heads=4, d_ff=18432, vocab_size=49152,
        head_dim=128, rope_theta=1e5, qkv_bias=True, act="gelu",
        norm="layernorm", source="arXiv:2402.19173",
    )


def reduced() -> ModelConfig:
    return full().replace(
        name="starcoder2-7b-reduced", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        dtype="float32", remat=False, seq_shard_activations=False,
        loss_chunk=0,
    )


register("starcoder2-7b", full, reduced)
