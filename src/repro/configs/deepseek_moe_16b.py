"""DeepSeekMoE-16B [arXiv:2401.06066] — fine-grained 64 routed experts
top-6 + 2 shared experts, first layer dense."""
from repro.configs.base import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe", num_layers=28, d_model=2048,
        num_heads=16, num_kv_heads=16, d_ff=10944, vocab_size=102400,
        head_dim=128, rope_theta=1e4,
        moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                      d_expert=1408, first_dense_layers=1),
        source="arXiv:2401.06066",
    )


def reduced() -> ModelConfig:
    return full().replace(
        name="deepseek-moe-16b-reduced", num_layers=3, d_model=128,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                      d_expert=128, first_dense_layers=1, backend="dense"),
        dtype="float32", remat=False, seq_shard_activations=False,
        loss_chunk=0,
    )


register("deepseek-moe-16b", full, reduced)
