"""Config system: model configs, input-shape configs, and the registry.

Every assigned architecture gets one file in this package defining a
``ModelConfig`` with the exact published dimensions (source cited in the
file docstring) plus a ``reduced()`` smoke-test variant. Input shapes are
the four assigned (seq_len, global_batch) workloads.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN config (shared + routed experts)."""

    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    d_expert: int = 0           # per-expert FFN hidden size
    first_dense_layers: int = 0  # leading dense layers (deepseek-moe style)
    router_aux_coef: float = 0.01
    capacity_factor: float = 1.25
    backend: str = "capacity"    # capacity (scatter, expert-parallel) | dense
    expert_pad_to: int = 0       # pad E up for expert-parallel divisibility

    @property
    def num_experts_padded(self) -> int:
        if not self.expert_pad_to:
            return self.num_experts
        m = self.expert_pad_to
        return ((self.num_experts + m - 1) // m) * m


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) config."""

    d_state: int = 64
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block-mix config."""

    slstm_at: Tuple[int, ...] = ()   # layer indices that are sLSTM; rest mLSTM
    proj_factor_m: float = 2.0       # mLSTM up-projection factor
    proj_factor_s: float = 4.0 / 3.0  # sLSTM FFN factor
    conv_kernel: int = 4
    chunk: int = 64                  # chunkwise-parallel mLSTM chunk length


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder (whisper-style) extras."""

    num_encoder_layers: int = 0
    encoder_seq: int = 1500   # frames after the (stubbed) conv frontend
    max_source_positions: int = 1500


@dataclass(frozen=True)
class MultimodalConfig:
    """Multimodal (vlm/audio) composition extras — frontend is stubbed."""

    num_patches: int = 256        # image patch tokens fed to the backbone
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE: (t, h, w) dims
    modality_name: str = "vision"


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    source: str = ""            # citation

    # attention variants
    rope_theta: float = 1e4
    use_qk_norm: bool = False          # qwen3
    qkv_bias: bool = False             # qwen2.5 / qwen2-vl
    attn_softcap: float = 0.0          # gemma2 (0 = off)
    final_softcap: float = 0.0         # gemma2 final-logit softcap
    sliding_window: int = 0            # 0 = full attention
    local_global_pattern: int = 0      # gemma2: every Nth layer global, rest local
    tie_embeddings: bool = False
    act: str = "silu"                  # silu | gelu
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    post_block_norm: bool = False      # gemma2 post-norms
    embed_scale: bool = False          # gemma2 sqrt(d) embedding scale

    # family extras
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    encdec: Optional[EncDecConfig] = None
    mm: Optional[MultimodalConfig] = None
    attn_layer_period: int = 0   # hybrid (zamba2): attention block every N layers
    shared_attn: bool = False    # zamba2: the interleaved attention block shares weights

    # numerics / execution
    dtype: str = "bfloat16"       # compute/param dtype for dry-runs
    remat: bool = True
    seq_shard_activations: bool = True  # Megatron sequence parallelism
    loss_chunk: int = 1024        # chunked cross-entropy over seq (0 = off)
    attn_impl: str = "xla"        # xla | bam_kernel | bam_interpret
    # decode: replicate GQA KV heads in the cache up to this count so the
    # cache head dim divides the model axis (head-sharded attention, no
    # cross-shard softmax). 0 = off. Memory/collective trade, §Perf.
    decode_kv_replicate: int = 0
    # chunk queries in the XLA attention path (flash-style online
    # softmax over q blocks): peak memory O(chunk·T) instead of O(T^2).
    # 0 = off. Set for prefill_32k (§Perf-D).
    attn_q_chunk: int = 0
    # context parallelism (Cornstarch §4.3): when cp_mesh is set (a
    # jax.sharding.Mesh), run_attention dispatches BAM attention through
    # core.context_parallel.cp_attention, sharding the token axis over
    # mesh axis cp_axis with the attn_impl-selected per-step body. The
    # batch must already be permuted to the ContextPlan layout
    # (training.steps.make_cp_train_step does this). Runtime handles —
    # never serialized; thread them per-step via cfg.replace(...).
    cp_mesh: Any = None
    cp_axis: str = "cp"
    cp_method: str = "allgather"   # allgather | ring

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- analytic parameter / flop model (used by the frozen-aware partitioner
    #    and the roofline MODEL_FLOPS term) ---------------------------------
    def param_count(self) -> int:
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family == "moe" and self.moe is not None:
            m = self.moe
            ff_rout = 3 * d * m.d_expert * m.num_experts
            ff_shared = 3 * d * m.d_expert * m.num_shared_experts
            router = d * m.num_experts
            dense_ff = 3 * d * self.d_ff if m.first_dense_layers else 0
            n_moe = L - m.first_dense_layers
            layers = n_moe * (attn + ff_rout + ff_shared + router) + \
                m.first_dense_layers * (attn + dense_ff)
        elif self.family in ("ssm",):
            layers = L * self._xlstm_layer_params()
        elif self.family == "hybrid":
            ssm_p = self._mamba_layer_params()
            n_attn = (L // self.attn_layer_period) if self.attn_layer_period else 0
            attn_p = attn + 3 * d * self.d_ff
            if self.shared_attn:
                layers = L * ssm_p + attn_p  # one shared block
            else:
                layers = L * ssm_p + n_attn * attn_p
        else:
            ff = 3 * d * self.d_ff if self.act == "silu" else 2 * d * self.d_ff
            layers = L * (attn + ff)
            if self.encdec is not None:
                enc_attn = 4 * d * d
                enc_ff = 2 * d * self.d_ff
                cross = 4 * d * d
                layers += self.encdec.num_encoder_layers * (enc_attn + enc_ff)
                layers += L * cross  # decoder cross-attention
        embed = V * d * (1 if self.tie_embeddings else 2)
        return int(layers + embed)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed-in experts)."""
        if self.family != "moe" or self.moe is None:
            return self.param_count()
        m = self.moe
        d, L = self.d_model, self.num_layers
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        ff_act = 3 * d * m.d_expert * (m.top_k + m.num_shared_experts)
        router = d * m.num_experts
        dense_ff = 3 * d * self.d_ff if m.first_dense_layers else 0
        n_moe = L - m.first_dense_layers
        layers = n_moe * (attn + ff_act + router) + \
            m.first_dense_layers * (attn + dense_ff)
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return int(layers + embed)

    def _mamba_layer_params(self) -> int:
        s = self.ssm or SSMConfig()
        d = self.d_model
        di = s.d_inner(d)
        nh = s.n_heads(d)
        in_proj = d * (2 * di + 2 * s.d_state + nh)  # z,x,B,C,dt (grouped)
        conv = s.d_conv * (di + 2 * s.d_state)
        out = di * d
        return in_proj + conv + out + di + 2 * nh

    def _xlstm_layer_params(self) -> int:
        x = self.xlstm or XLSTMConfig()
        d = self.d_model
        dm = int(d * x.proj_factor_m)
        n_s = len(x.slstm_at)
        n_m = self.num_layers - n_s
        # mLSTM: up + gate-up, q/k/v, down (i/f gates are [dm, nh]: tiny)
        m = 2 * d * dm + 3 * dm * dm + dm * d
        # sLSTM: zifo input weights, block-diag recurrent, gated FFN
        dff = int(d * x.proj_factor_s)
        hd = d // max(self.num_heads, 1)
        sl = 4 * d * d + 4 * hd * d + 3 * d * dff
        return int((m * n_m + sl * n_s) / max(self.num_layers, 1))


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str   # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], ModelConfig]] = {}
_REDUCED: dict[str, Callable[[], ModelConfig]] = {}


def register(name: str, full: Callable[[], ModelConfig],
             reduced: Callable[[], ModelConfig]) -> None:
    _REGISTRY[name] = full
    _REDUCED[name] = reduced


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    _ensure_imported()
    table = _REDUCED if reduced else _REGISTRY
    if name not in table:
        raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")
    return table[name]()


def list_archs() -> list[str]:
    _ensure_imported()
    return sorted(_REGISTRY)


_IMPORTED = False


def _ensure_imported() -> None:
    global _IMPORTED
    if _IMPORTED:
        return
    # import all config modules for side-effect registration
    from repro.configs import (  # noqa: F401
        starcoder2_7b, whisper_base, qwen2_vl_7b, qwen3_1_7b, gemma2_9b,
        qwen2_moe_a2_7b, zamba2_2_7b, xlstm_125m, deepseek_moe_16b,
        qwen2_5_14b, paper_mllm,
    )
    _IMPORTED = True


# Which (arch, shape) pairs are skipped and why (DESIGN.md §long_500k policy).
LONG_CONTEXT_OK = {"zamba2-2.7b", "xlstm-125m", "gemma2-9b"}

SKIPS: dict[tuple[str, str], str] = {
    (a, "long_500k"): "pure full-attention arch; no sub-quadratic variant (DESIGN.md)"
    for a in (
        "starcoder2-7b", "qwen3-1.7b", "qwen2.5-14b", "qwen2-vl-7b",
        "whisper-base", "qwen2-moe-a2.7b", "deepseek-moe-16b",
    )
}


def pair_skip_reason(arch: str, shape: str) -> Optional[str]:
    return SKIPS.get((arch, shape))
