"""The paper's own evaluation model zoo (Table 1): Llama-3.1-style LLMs,
EVA-CLIP-style vision encoders, Whisper-style audio encoders in S/M/L,
used by the Cornstarch MLLM composition, the pipeline-partitioner
benchmarks (Tables 2/3) and the end-to-end examples (Fig. 9/10)."""
from repro.configs.base import EncDecConfig, ModelConfig, register

# Table 1: (layers, hidden) per size
_LLM = {"S": (16, 2048), "M": (32, 4096), "L": (64, 5120)}
_VISION = {"S": (40, 1408), "M": (32, 4096), "L": (48, 5120)}
_AUDIO = {"S": (32, 1920), "M": (40, 3840), "L": (48, 5120)}


def llm_config(size: str = "M", reduced: bool = False) -> ModelConfig:
    L, d = _LLM[size]
    cfg = ModelConfig(
        name=f"paper-llama-{size}", family="dense", num_layers=L, d_model=d,
        num_heads=max(d // 128, 1), num_kv_heads=max(d // 512, 1),
        d_ff=int(3.5 * d), vocab_size=128256, head_dim=128,
        rope_theta=5e5, source="arXiv:2407.21783 (Llama 3.1 herd)",
    )
    if reduced:
        cfg = cfg.replace(num_layers=2, d_model=256, num_heads=4,
                          num_kv_heads=2, head_dim=64, d_ff=512,
                          vocab_size=512, dtype="float32", remat=False,
                          seq_shard_activations=False, loss_chunk=0)
    return cfg


def vision_encoder_config(size: str = "M", reduced: bool = False):
    """EVA-CLIP-style ViT encoder *backbone dims* (patch embeds stubbed;
    we model the encoder as bidirectional transformer layers)."""
    L, d = _VISION[size]
    cfg = ModelConfig(
        name=f"paper-evaclip-{size}", family="dense", num_layers=L,
        d_model=d, num_heads=max(d // 88, 1), num_kv_heads=max(d // 88, 1),
        head_dim=88 if d % 88 == 0 else d // max(d // 88, 1),
        d_ff=4 * d, vocab_size=1, norm="layernorm", act="gelu",
        source="arXiv:2303.15389 (EVA-CLIP)",
    )
    if reduced:
        cfg = cfg.replace(num_layers=2, d_model=128, num_heads=2,
                          num_kv_heads=2, head_dim=64, d_ff=256,
                          dtype="float32", remat=False,
                          seq_shard_activations=False)
    return cfg


def audio_encoder_config(size: str = "M", reduced: bool = False):
    L, d = _AUDIO[size]
    cfg = ModelConfig(
        name=f"paper-whisper-{size}", family="dense", num_layers=L,
        d_model=d, num_heads=max(d // 96, 1), num_kv_heads=max(d // 96, 1),
        head_dim=96 if d % 96 == 0 else d // max(d // 96, 1),
        d_ff=4 * d, vocab_size=1, norm="layernorm", act="gelu",
        source="arXiv:2212.04356 (Whisper)",
    )
    if reduced:
        cfg = cfg.replace(num_layers=2, d_model=128, num_heads=2,
                          num_kv_heads=2, head_dim=64, d_ff=256,
                          dtype="float32", remat=False,
                          seq_shard_activations=False)
    return cfg
