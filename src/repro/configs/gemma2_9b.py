"""Gemma2-9B [arXiv:2408.00118] — local(4096)/global alternating
attention, attn+final logit softcaps, post-block norms, tied embeddings,
sqrt(d) embedding scale, head_dim 256."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b", family="dense", num_layers=42, d_model=3584,
        num_heads=16, num_kv_heads=8, d_ff=14336, vocab_size=256000,
        head_dim=256, rope_theta=1e4, attn_softcap=50.0, final_softcap=30.0,
        sliding_window=4096, local_global_pattern=2, post_block_norm=True,
        tie_embeddings=True, embed_scale=True, act="gelu",
        decode_kv_replicate=16,
        source="arXiv:2408.00118",
    )


def long_context_variant() -> ModelConfig:
    """long_500k: all layers local sliding-window (DESIGN.md deviation)."""
    return full().replace(name="gemma2-9b-swa", local_global_pattern=0,
                          sliding_window=4096)


def reduced() -> ModelConfig:
    return full().replace(
        name="gemma2-9b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        sliding_window=16, dtype="float32", remat=False,
        seq_shard_activations=False, loss_chunk=0,
        decode_kv_replicate=4,   # valid for the 4-head reduced variant
    )


register("gemma2-9b", full, reduced)
