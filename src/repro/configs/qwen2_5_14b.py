"""Qwen2.5-14B [hf:Qwen/Qwen2.5-14B; family per Qwen/Qwen2.5-0.5B card]
— dense, GQA(kv=8), QKV bias."""
from repro.configs.base import ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-14b", family="dense", num_layers=48, d_model=5120,
        num_heads=40, num_kv_heads=8, d_ff=13824, vocab_size=152064,
        head_dim=128, rope_theta=1e6, qkv_bias=True,
        source="hf:Qwen/Qwen2.5-0.5B",
    )


def reduced() -> ModelConfig:
    return full().replace(
        name="qwen2.5-14b-reduced", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        dtype="float32", remat=False, seq_shard_activations=False,
        loss_chunk=0,
    )


register("qwen2.5-14b", full, reduced)
