"""Zamba2-2.7B [arXiv:2411.15242] — Mamba2 backbone + shared attention
block applied every 6 layers (parameter sharing)."""
from repro.configs.base import ModelConfig, SSMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
        num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32000,
        head_dim=80, attn_layer_period=6, shared_attn=True,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      chunk=128),
        source="arXiv:2411.15242",
    )


def reduced() -> ModelConfig:
    return full().replace(
        name="zamba2-2.7b-reduced", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        attn_layer_period=2,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32, chunk=8),
        dtype="float32", remat=False, seq_shard_activations=False,
        loss_chunk=0,
    )


register("zamba2-2.7b", full, reduced)
