"""xLSTM-125M [arXiv:2405.04517] — mLSTM + sLSTM mix (sLSTM at blocks
3 and 9, xLSTM[.. :1] style); blocks carry their own projections
(d_ff = 0 in the assigned spec)."""
from repro.configs.base import ModelConfig, XLSTMConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm", num_layers=12, d_model=768,
        num_heads=4, num_kv_heads=4, d_ff=0, vocab_size=50304,
        head_dim=192, tie_embeddings=True,
        xlstm=XLSTMConfig(slstm_at=(3, 9), proj_factor_m=2.0,
                          conv_kernel=4, chunk=64),
        source="arXiv:2405.04517",
    )


def reduced() -> ModelConfig:
    return full().replace(
        name="xlstm-125m-reduced", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=4, head_dim=32, vocab_size=512,
        xlstm=XLSTMConfig(slstm_at=(1,), proj_factor_m=2.0, conv_kernel=4,
                          chunk=8),
        dtype="float32", remat=False, seq_shard_activations=False,
        loss_chunk=0,
    )


register("xlstm-125m", full, reduced)
