"""Qwen2-VL-7B language backbone [arXiv:2409.12191] — M-RoPE, dynamic
resolution (vision ViT stubbed: patch embeddings provided)."""
from repro.configs.base import ModelConfig, MultimodalConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm", num_layers=28, d_model=3584,
        num_heads=28, num_kv_heads=4, d_ff=18944, vocab_size=152064,
        head_dim=128, rope_theta=1e6, qkv_bias=True,
        mm=MultimodalConfig(num_patches=1024, mrope_sections=(16, 24, 24),
                            modality_name="vision"),
        source="arXiv:2409.12191",
    )


def reduced() -> ModelConfig:
    return full().replace(
        name="qwen2-vl-7b-reduced", num_layers=2, d_model=256,
        num_heads=4, num_kv_heads=2, head_dim=64, d_ff=512, vocab_size=512,
        mm=MultimodalConfig(num_patches=16, mrope_sections=(8, 12, 12),
                            modality_name="vision"),
        dtype="float32", remat=False, seq_shard_activations=False,
        loss_chunk=0,
    )


register("qwen2-vl-7b", full, reduced)
