"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed experts
top-4 + 4 shared experts, fine-grained d_expert=1408."""
from repro.configs.base import ModelConfig, MoEConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe", num_layers=24, d_model=2048,
        num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=151936,
        head_dim=128, rope_theta=1e6,
        # expert_pad_to=64: four dummy experts make E divisible by the
        # 16-wide model axis -> true expert parallelism (EXPERIMENTS.md
        # §Perf iteration 3); router only ever routes to the real 60.
        moe=MoEConfig(num_experts=60, top_k=4, num_shared_experts=4,
                      d_expert=1408, expert_pad_to=64),
        source="hf:Qwen/Qwen1.5-MoE-A2.7B",
    )


def reduced() -> ModelConfig:
    return full().replace(
        name="qwen2-moe-a2.7b-reduced", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=128, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, num_shared_experts=1,
                      d_expert=128, backend="dense"),
        dtype="float32", remat=False, seq_shard_activations=False,
        loss_chunk=0,
    )


register("qwen2-moe-a2.7b", full, reduced)
