"""Whisper-base [arXiv:2212.04356] — enc-dec audio backbone; the
mel+conv frontend is stubbed (frame embeddings provided)."""
from repro.configs.base import EncDecConfig, ModelConfig, register


def full() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio", num_layers=6, d_model=512,
        num_heads=8, num_kv_heads=8, d_ff=2048, vocab_size=51865,
        head_dim=64, qkv_bias=True, act="gelu", norm="layernorm",
        tie_embeddings=True,
        encdec=EncDecConfig(num_encoder_layers=6, encoder_seq=1500),
        source="arXiv:2212.04356",
    )


def reduced() -> ModelConfig:
    return full().replace(
        name="whisper-base-reduced", num_layers=2, d_model=128,
        num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512,
        encdec=EncDecConfig(num_encoder_layers=2, encoder_seq=64),
        dtype="float32", remat=False, seq_shard_activations=False,
        loss_chunk=0,
    )


register("whisper-base", full, reduced)
