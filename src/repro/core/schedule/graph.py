"""Pipeline stage graph with B/W-decomposed backward costs.

A :class:`Stage` carries three cost terms per microbatch:

    fwd     forward pass (F)
    bwd     TOTAL backward = B + W (kept as one field so legacy callers
            that build ``Stage(name, f, b)`` see unchanged semantics)
    bwd_w   weight-gradient (W) share of ``bwd``; the input-gradient
            share B = ``bwd - bwd_w`` is what blocks the upstream
            stage's backward.

Frozen modules have ``bwd_w == 0`` (no weights to update), which is
why zero-bubble-style scheduling composes so well with Cornstarch's
frozen-aware costs: there is simply no W work to defer on frozen
stages, and all the deferral headroom concentrates on trainable ones.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass
class Stage:
    module: str
    fwd: float
    bwd: float                          # total backward (B + W)
    layer_range: Tuple[int, int] = (0, 0)
    bwd_w: float = 0.0                  # weight-grad (W) share of bwd

    @property
    def bwd_b(self) -> float:
        """Input-grad (B) share of backward — the part on the critical
        path to the upstream stage (includes recompute time)."""
        return self.bwd - self.bwd_w

    @property
    def total(self) -> float:
        return self.fwd + self.bwd


@dataclasses.dataclass
class PipelineGraph:
    """stages: flat list in topological order; edges: forward-order
    dependencies (src_stage_idx -> dst_stage_idx). A chain is edges
    (i, i+1)."""
    stages: List[Stage]
    edges: List[Tuple[int, int]]

    @property
    def preds(self) -> Dict[int, List[int]]:
        p: Dict[int, List[int]] = {i: [] for i in range(len(self.stages))}
        for a, b in self.edges:
            p[b].append(a)
        return p

    @property
    def succs(self) -> Dict[int, List[int]]:
        s: Dict[int, List[int]] = {i: [] for i in range(len(self.stages))}
        for a, b in self.edges:
            s[a].append(b)
        return s

    def depth_from_end(self, i: int) -> int:
        succ = self.succs
        memo: Dict[int, int] = {}

        def rec(j):
            if j in memo:
                return memo[j]
            memo[j] = 1 + max((rec(s) for s in succ[j]), default=0)
            return memo[j]
        return rec(i)


def chain_graph(stages: List[Stage]) -> PipelineGraph:
    return PipelineGraph(stages, [(i, i + 1) for i in range(len(stages) - 1)])


def interleave_devices(graph: PipelineGraph, virtual_chunks: int
                       ) -> List[int]:
    """Megatron-style round-robin stage->device map for interleaved
    1F1B: with S stages and v virtual chunks, D = ceil(S/v) devices and
    stage s (topological order) runs on device ``s % D`` — device d
    hosts chunks {d, d+D, d+2D, ...}."""
    S = len(graph.stages)
    v = max(1, int(virtual_chunks))
    D = max(1, -(-S // v))
    return [s % D for s in range(S)]


def v_shape_devices(num_stages: int) -> List[int]:
    """ZB-V stage->device map (Qi et al. 2023): S = 2p chunk-stages on
    p devices, device i hosting chunks i and 2p-1-i. The forward chain
    walks down the device column and back up — a V — so the LAST chunk
    lives on device 0, whose backward can start the moment its own
    forward ramp finishes, and the W passes of both hosted chunks fill
    the two ramps."""
    S = int(num_stages)
    assert S >= 2 and S % 2 == 0, \
        "ZB-V placement needs an even chunk-stage count (2 per device)"
    p = S // 2
    return [s if s < p else S - 1 - s for s in range(S)]


def refine_chain(graph: PipelineGraph, virtual_chunks: int
                 ) -> PipelineGraph:
    """Split every stage of a CHAIN graph into ``virtual_chunks`` equal
    sub-stages (costs divided evenly, layer ranges split contiguously).
    This is the generalized virtual-chunk construction used when a
    finer partition cannot be re-derived from module profiles — e.g.
    raw ``Stage`` fixtures; ``auto_parallelize`` re-partitions from
    profiles instead, which respects real per-layer costs."""
    v = max(1, int(virtual_chunks))
    if v == 1:
        return graph
    assert sorted(graph.edges) == [(i, i + 1)
                                   for i in range(len(graph.stages) - 1)], \
        "refine_chain only applies to chain graphs"
    out: List[Stage] = []
    for st in graph.stages:
        a, b = st.layer_range
        n = b - a
        for c in range(v):
            la = a + (n * c) // v
            lb = a + (n * (c + 1)) // v
            out.append(Stage(st.module, st.fwd / v, st.bwd / v,
                             (la, lb), bwd_w=st.bwd_w / v))
    return chain_graph(out)
