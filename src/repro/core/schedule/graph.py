"""Pipeline stage graph with B/W-decomposed backward costs.

A :class:`Stage` carries three cost terms per microbatch:

    fwd     forward pass (F)
    bwd     TOTAL backward = B + W (kept as one field so legacy callers
            that build ``Stage(name, f, b)`` see unchanged semantics)
    bwd_w   weight-gradient (W) share of ``bwd``; the input-gradient
            share B = ``bwd - bwd_w`` is what blocks the upstream
            stage's backward.

Frozen modules have ``bwd_w == 0`` (no weights to update), which is
why zero-bubble-style scheduling composes so well with Cornstarch's
frozen-aware costs: there is simply no W work to defer on frozen
stages, and all the deferral headroom concentrates on trainable ones.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


@dataclasses.dataclass
class Stage:
    module: str
    fwd: float
    bwd: float                          # total backward (B + W)
    layer_range: Tuple[int, int] = (0, 0)
    bwd_w: float = 0.0                  # weight-grad (W) share of bwd

    @property
    def bwd_b(self) -> float:
        """Input-grad (B) share of backward — the part on the critical
        path to the upstream stage (includes recompute time)."""
        return self.bwd - self.bwd_w

    @property
    def total(self) -> float:
        return self.fwd + self.bwd


@dataclasses.dataclass
class PipelineGraph:
    """stages: flat list in topological order; edges: forward-order
    dependencies (src_stage_idx -> dst_stage_idx). A chain is edges
    (i, i+1)."""
    stages: List[Stage]
    edges: List[Tuple[int, int]]

    @property
    def preds(self) -> Dict[int, List[int]]:
        p: Dict[int, List[int]] = {i: [] for i in range(len(self.stages))}
        for a, b in self.edges:
            p[b].append(a)
        return p

    @property
    def succs(self) -> Dict[int, List[int]]:
        s: Dict[int, List[int]] = {i: [] for i in range(len(self.stages))}
        for a, b in self.edges:
            s[a].append(b)
        return s

    def depth_from_end(self, i: int) -> int:
        succ = self.succs
        memo: Dict[int, int] = {}

        def rec(j):
            if j in memo:
                return memo[j]
            memo[j] = 1 + max((rec(s) for s in succ[j]), default=0)
            return memo[j]
        return rec(i)


def chain_graph(stages: List[Stage]) -> PipelineGraph:
    return PipelineGraph(stages, [(i, i + 1) for i in range(len(stages) - 1)])


def interleave_devices(graph: PipelineGraph, virtual_chunks: int
                       ) -> List[int]:
    """Megatron-style round-robin stage->device map for interleaved
    1F1B: with S stages and v virtual chunks, D = ceil(S/v) devices and
    stage s (topological order) runs on device ``s % D`` — device d
    hosts chunks {d, d+D, d+2D, ...}."""
    S = len(graph.stages)
    v = max(1, int(virtual_chunks))
    D = max(1, -(-S // v))
    return [s % D for s in range(S)]
