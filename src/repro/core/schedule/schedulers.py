"""The four pipeline schedulers behind one interface.

``Scheduler.simulate(graph, num_microbatches)`` -> dict with
iteration_time / bubble_fraction / per_device_busy / num_devices /
schedule / virtual_chunks, plus the item timeline and per-device peak
activations the simulator instruments (see ``simulator``). Construct
via :func:`get_scheduler` or iterate :data:`SCHEDULES`.
"""
from __future__ import annotations

from typing import Dict

from .graph import PipelineGraph, interleave_devices, v_shape_devices
from .simulator import is_chain, run_interleaved, run_schedule


class Scheduler:
    """One pipeline schedule policy, evaluated by simulation."""
    name = "base"

    def simulate(self, graph: PipelineGraph, num_microbatches: int
                 ) -> Dict[str, object]:
        raise NotImplementedError

    def _tag(self, sim: Dict[str, object],
             virtual_chunks: int = 1) -> Dict[str, object]:
        sim["schedule"] = self.name
        sim["virtual_chunks"] = virtual_chunks
        return sim


class OneFOneB(Scheduler):
    """Classic 1F1B: one stage per device, monolithic backward (W glued
    immediately after B)."""
    name = "1f1b"

    def simulate(self, graph, num_microbatches):
        return self._tag(run_schedule(graph, num_microbatches))


class Interleaved1F1B(Scheduler):
    """Interleaved 1F1B (Megatron virtual stages): device d hosts
    chunks {d, d+D, ...} of the stage chain, shrinking the pipeline
    fill/drain bubble by ~the chunk count at the price of holding more
    in-flight activations per device.

    On a chain whose stage count divides by v and whose microbatch
    count divides by D, this simulates Megatron's exact per-device item
    order (warmup forwards in chunk-rotation groups, 1F1B steady state,
    cooldown) — the ordering that actually realizes the bubble win.
    Otherwise (DAG graphs, ragged counts) it degrades to greedy list
    scheduling over the folded device map."""
    name = "interleaved"

    def __init__(self, virtual_chunks: int = 2):
        assert virtual_chunks >= 1
        self.virtual_chunks = virtual_chunks

    def simulate(self, graph, num_microbatches):
        S = len(graph.stages)
        v = self.virtual_chunks
        if v > 1 and S % v == 0 and is_chain(graph) and \
                num_microbatches % (S // v) == 0:
            return self._tag(run_interleaved(graph, num_microbatches, v),
                             virtual_chunks=v)
        dev = interleave_devices(graph, v)
        return self._tag(run_schedule(graph, num_microbatches,
                                      device_of=dev), virtual_chunks=v)


class ZBH1(Scheduler):
    """ZB-H1-style zero-bubble schedule: backward splits into B
    (input-grad, critical path) and W (weight-grad, deferred); W passes
    fill bubbles under the same activation-memory cap as 1F1B. Frozen
    stages have no W at all, so on frozen-heavy MLLMs the B passes
    shorten (bwd_b <= bwd) while trainable stages soak their W into the
    drain phase.

    Like the offline schedule constructors in the zero-bubble papers,
    this picks the better of the two valid executions it knows: the
    split/deferred placement, and the glued one (W immediately after B,
    = 1F1B). Greedy list scheduling is not monotone in task durations,
    so on rare graphs splitting B can reorder the F/B path for the
    worse; the fallback guarantees ZB-H1 is never scheduled worse than
    1F1B."""
    name = "zb-h1"

    def simulate(self, graph, num_microbatches):
        if not any(st.bwd_w > 0 for st in graph.stages):
            # nothing to defer: split and glued are byte-identical
            return self._tag(run_schedule(graph, num_microbatches))
        split = run_schedule(graph, num_microbatches, split_bw=True)
        glued = run_schedule(graph, num_microbatches)
        best = split if split["iteration_time"] <= \
            glued["iteration_time"] else glued
        return self._tag(best)


class ZBV(Scheduler):
    """ZB-V zero-bubble schedule (Qi et al. 2023, the V placement): the
    stage chain is cut into 2p chunk-stages folded onto p devices in a
    V — device i hosts chunks i and 2p-1-i, so the forward walks down
    the device column and back up. The LAST chunk sits on device 0,
    which therefore starts its backward as soon as its own forward ramp
    finishes (no drain wait), and the deferred W passes fill BOTH ramps
    of the V. Backward is B/W-split as in ZB-H1; frozen chunks have no
    W at all, so on frozen-heavy MLLM chains the ramp-filling headroom
    concentrates exactly on the trainable (usually LLM) chunks —
    Cornstarch's frozen-aware costs compose with the V for free.

    Like ZBH1 this picks the better of the split and glued placements
    on the same V device map (greedy list scheduling is not monotone in
    task durations), so zb-v is never scheduled worse than its own
    glued execution. On non-chain (modality-parallel DAG) graphs or odd
    stage counts the exact V map is undefined; the scheduler degrades
    to the round-robin two-chunk fold. ``virtual_chunks=1`` is the
    degenerate one-chunk-per-device placement, i.e. ZB-H1.
    """
    name = "zb-v"

    def __init__(self, virtual_chunks: int = 2):
        assert virtual_chunks in (1, 2), \
            "zb-v places exactly two chunks per device (or the v=1 " \
            "degenerate)"
        self.virtual_chunks = virtual_chunks

    def simulate(self, graph, num_microbatches):
        S = len(graph.stages)
        dev, caps = None, None
        v = self.virtual_chunks
        if v == 2 and S >= 2:
            if S % 2 == 0 and is_chain(graph):
                dev = v_shape_devices(S)
                # 1F1B memory parity: the deepest 1F1B device holds one
                # coarse activation per pipeline rank = 2p chunk-stage
                # activations per device. The depth_from_end caps of
                # device i's two chunks sum to (2p-i) + (i+1) = 2p+1 —
                # one chunk over the envelope — so shave the down-chunk
                # (the one with slack) by one: 2p-i-1 down, i+1 up.
                # Every cap stays >= 1 (bottom device's down-chunk gets
                # p), preserving the no-deadlock guarantee
                p = S // 2
                caps = [2 * p - dev[s] - 1 if s < p else dev[s] + 1
                        for s in range(S)]
            else:
                dev = interleave_devices(graph, 2)
        split = run_schedule(graph, num_microbatches, device_of=dev,
                             split_bw=True, stage_caps=caps) \
            if any(st.bwd_w > 0 for st in graph.stages) else None
        glued = run_schedule(graph, num_microbatches, device_of=dev,
                             stage_caps=caps)
        best = glued if split is None or glued["iteration_time"] < \
            split["iteration_time"] else split
        return self._tag(best, virtual_chunks=v if dev is not None else 1)


SCHEDULES = ("1f1b", "interleaved", "zb-h1", "zb-v")


def get_scheduler(name: str, **kwargs) -> Scheduler:
    """Factory: '1f1b' | 'interleaved' | 'zb-h1' | 'zb-v' (kwargs
    forwarded, e.g. virtual_chunks for interleaved/zb-v)."""
    registry = {"1f1b": OneFOneB, "interleaved": Interleaved1F1B,
                "zb-h1": ZBH1, "zb-v": ZBV}
    try:
        cls = registry[name]
    except KeyError:
        raise ValueError(
            f"unknown schedule {name!r}; pick from {SCHEDULES}") from None
    return cls(**kwargs)


def simulate(graph: PipelineGraph, num_microbatches: int,
             schedule: str = "1f1b", **kwargs) -> Dict[str, object]:
    """One-shot convenience wrapper around get_scheduler(...).simulate."""
    return get_scheduler(schedule, **kwargs).simulate(graph,
                                                      num_microbatches)
