"""The three pipeline schedulers behind one interface.

``Scheduler.simulate(graph, num_microbatches)`` -> dict with
iteration_time / bubble_fraction / per_device_busy / num_devices /
schedule. Construct via :func:`get_scheduler` or iterate
:data:`SCHEDULES`.
"""
from __future__ import annotations

from typing import Dict

from .graph import PipelineGraph, interleave_devices
from .simulator import is_chain, run_interleaved, run_schedule


class Scheduler:
    """One pipeline schedule policy, evaluated by simulation."""
    name = "base"

    def simulate(self, graph: PipelineGraph, num_microbatches: int
                 ) -> Dict[str, object]:
        raise NotImplementedError

    def _tag(self, sim: Dict[str, object]) -> Dict[str, object]:
        sim["schedule"] = self.name
        return sim


class OneFOneB(Scheduler):
    """Classic 1F1B: one stage per device, monolithic backward (W glued
    immediately after B)."""
    name = "1f1b"

    def simulate(self, graph, num_microbatches):
        return self._tag(run_schedule(graph, num_microbatches))


class Interleaved1F1B(Scheduler):
    """Interleaved 1F1B (Megatron virtual stages): device d hosts
    chunks {d, d+D, ...} of the stage chain, shrinking the pipeline
    fill/drain bubble by ~the chunk count at the price of holding more
    in-flight activations per device.

    On a chain whose stage count divides by v and whose microbatch
    count divides by D, this simulates Megatron's exact per-device item
    order (warmup forwards in chunk-rotation groups, 1F1B steady state,
    cooldown) — the ordering that actually realizes the bubble win.
    Otherwise (DAG graphs, ragged counts) it degrades to greedy list
    scheduling over the folded device map."""
    name = "interleaved"

    def __init__(self, virtual_chunks: int = 2):
        assert virtual_chunks >= 1
        self.virtual_chunks = virtual_chunks

    def simulate(self, graph, num_microbatches):
        S = len(graph.stages)
        v = self.virtual_chunks
        if v > 1 and S % v == 0 and is_chain(graph) and \
                num_microbatches % (S // v) == 0:
            return self._tag(run_interleaved(graph, num_microbatches, v))
        dev = interleave_devices(graph, v)
        return self._tag(run_schedule(graph, num_microbatches,
                                      device_of=dev))


class ZBH1(Scheduler):
    """ZB-H1-style zero-bubble schedule: backward splits into B
    (input-grad, critical path) and W (weight-grad, deferred); W passes
    fill bubbles under the same activation-memory cap as 1F1B. Frozen
    stages have no W at all, so on frozen-heavy MLLMs the B passes
    shorten (bwd_b <= bwd) while trainable stages soak their W into the
    drain phase.

    Like the offline schedule constructors in the zero-bubble papers,
    this picks the better of the two valid executions it knows: the
    split/deferred placement, and the glued one (W immediately after B,
    = 1F1B). Greedy list scheduling is not monotone in task durations,
    so on rare graphs splitting B can reorder the F/B path for the
    worse; the fallback guarantees ZB-H1 is never scheduled worse than
    1F1B."""
    name = "zb-h1"

    def simulate(self, graph, num_microbatches):
        if not any(st.bwd_w > 0 for st in graph.stages):
            # nothing to defer: split and glued are byte-identical
            return self._tag(run_schedule(graph, num_microbatches))
        split = run_schedule(graph, num_microbatches, split_bw=True)
        glued = run_schedule(graph, num_microbatches)
        best = split if split["iteration_time"] <= \
            glued["iteration_time"] else glued
        return self._tag(best)


SCHEDULES = ("1f1b", "interleaved", "zb-h1")


def get_scheduler(name: str, **kwargs) -> Scheduler:
    """Factory: '1f1b' | 'interleaved' | 'zb-h1' (kwargs forwarded,
    e.g. virtual_chunks for interleaved)."""
    registry = {"1f1b": OneFOneB, "interleaved": Interleaved1F1B,
                "zb-h1": ZBH1}
    try:
        cls = registry[name]
    except KeyError:
        raise ValueError(
            f"unknown schedule {name!r}; pick from {SCHEDULES}") from None
    return cls(**kwargs)


def simulate(graph: PipelineGraph, num_microbatches: int,
             schedule: str = "1f1b", **kwargs) -> Dict[str, object]:
    """One-shot convenience wrapper around get_scheduler(...).simulate."""
    return get_scheduler(schedule, **kwargs).simulate(graph,
                                                      num_microbatches)
