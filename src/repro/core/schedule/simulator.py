"""Generic discrete-event pipeline-schedule simulator over F/B/W items.

Work items per (stage s, microbatch m):

    F(s, m)  forward — ready when F(p, m) done for every pred p
    B(s, m)  input-grad backward — ready when F(s, m) done and
             B(q, m) done for every succ q; blocks upstream B
    W(s, m)  weight-grad backward — ready when B(s, m) done; blocks
             ONLY the optimizer step (i.e. the end of the iteration),
             never another stage's compute

With ``split_bw=False`` the classic monolithic backward is modeled: B
runs with duration ``bwd`` (= B + W glued together) and no separate W
items exist — byte-for-byte the legacy 1F1B simulation.

With ``split_bw=True`` the event loop schedules only the F/B critical
path (B with duration ``bwd_b``), then a second phase packs the
deferred W passes (ZB-H1 style) into each device's recorded idle gaps
and tail. Because F/B placements are already fixed, a W can never delay
compute on the critical path — the insertion is exact, not heuristic.
Frozen stages have ``bwd_w == 0`` and contribute no W items at all.

``device_of`` maps stage index -> device index (default: identity, one
stage per device). Passing a many-to-one map simulates interleaved
(virtual-stage) schedules, where one device round-robins between its
chunks.

Activation-memory policy: a stage admits a new forward only while its
in-flight microbatches (forwards issued minus backwards issued) stay
below ``depth_from_end`` — exactly 1F1B's memory cap. ZB-H1 inherits
the same cap (its defining property: zero-bubble gains at 1F1B memory).

Every simulation also returns its full work-item timeline (``items``:
``(start, end, device, kind, stage, microbatch)`` tuples, sorted in a
dependency-respecting execution order), the stage->device map it ran
under (``device_of``), and the measured per-device peak of live
activations (``peak_activations_per_device``). An activation is live
from the execution of F(s, m) until the execution of B(s, m) — the
inter-stage residual the input-grad pass consumes. These three fields
feed the memory-validation harness (``core.schedule.memory``), which
replays the same timeline on the real executor and cross-checks the
peaks against ``depth_from_end``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .graph import PipelineGraph

Item = Tuple[float, float, int, str, int, int]
_KIND_RANK = {"B": 0, "F": 1, "W": 2}


def item_id(item: Item) -> str:
    """Stable human-readable id for one timeline item — the anchor
    shared by schedlint findings (``repro.analysis.schedlint``) and the
    memory-validation timeline diff (``core.schedule.memory``)."""
    _start, _end, dev, kind, stage, mb = item
    return f"{kind}(s{stage},m{mb})@d{dev}"


def sort_items(items: List[Item]) -> List[Item]:
    """Dependency-respecting total order: by start time; at equal start
    (only possible through zero-duration frozen B passes) B before F
    before W, B chains in reverse stage order (successor's B feeds the
    predecessor's), F chains in forward stage order."""
    def key(it):
        start, _end, _dev, kind, stage, mb = it
        return (start, _KIND_RANK[kind],
                -stage if kind == "B" else stage, mb)
    return sorted(items, key=key)


def peak_live_activations(items: List[Item], num_devices: int
                          ) -> List[int]:
    """Per-device peak number of live activations over an item
    timeline. F(s, m) materializes one activation on its device;
    B(s, m) consumes it (W passes read per-layer weight-grad residuals
    accounted to the W item itself, not this store — the simplification
    the module docstring spells out). Items on one device never overlap
    in time, so the per-device prefix-sum walk is exact."""
    occ = [0] * num_devices
    peak = [0] * num_devices
    for _start, _end, dev, kind, _stage, _mb in items:
        if kind == "F":
            occ[dev] += 1
            peak[dev] = max(peak[dev], occ[dev])
        elif kind == "B":
            occ[dev] -= 1
    return peak


def run_schedule(graph: PipelineGraph, num_microbatches: int, *,
                 device_of: Optional[List[int]] = None,
                 split_bw: bool = False,
                 stage_caps: Optional[List[int]] = None
                 ) -> Dict[str, object]:
    """Greedy earliest-start list scheduling (deterministic). Returns
    iteration time (optimizer-step start: all B AND W complete),
    per-device busy time, bubble fraction, device count.

    ``stage_caps`` overrides the per-stage ``depth_from_end`` in-flight
    bound (clamped to it from above, floored at 1 so the no-deadlock
    guarantee of per-stage caps >= 1 holds). Folded placements need
    tighter caps: per-stage depth caps are exact for one stage per
    device, but their per-device SUM exceeds the 1F1B envelope once a
    device hosts several chunks — ZB-V passes V-shaped caps here to
    keep its 1F1B memory-parity claim honest."""
    S = len(graph.stages)
    M = num_microbatches
    preds, succs = graph.preds, graph.succs
    cap = [graph.depth_from_end(i) for i in range(S)]
    if stage_caps is not None:
        assert len(stage_caps) == S
        cap = [max(1, min(cap[i], int(stage_caps[i]))) for i in range(S)]
    if device_of is None:
        device_of = list(range(S))
    assert len(device_of) == S
    D = max(device_of) + 1

    assert all(0.0 <= st.bwd_w <= st.bwd + 1e-12 for st in graph.stages), \
        "stage bwd_w (weight-grad share) must lie within [0, bwd]"
    b_dur = [st.bwd_b if split_bw else st.bwd for st in graph.stages]

    fwd_done = [[None] * M for _ in range(S)]    # completion times
    bwd_done = [[None] * M for _ in range(S)]
    dev_free = [0.0] * D
    fwd_issued = [0] * S
    bwd_issued = [0] * S
    busy = [0.0] * D
    intervals = [[] for _ in range(D)]           # per-device (start, end)
    items: List[Item] = []
    finish = 0.0                                 # max B completion

    def fwd_ready_at(s, m):
        ts = [fwd_done[p][m] for p in preds[s]]
        if any(t is None for t in ts):
            return None
        return max(ts, default=0.0)

    def bwd_ready_at(s, m):
        if fwd_done[s][m] is None:
            return None
        ts = [bwd_done[q][m] for q in succs[s]]
        if any(t is None for t in ts):
            return None
        return max(ts + [fwd_done[s][m]])

    # -- phase 1: F/B critical path (event loop) ---------------------------
    remaining = 2 * S * M
    guard = 0
    while remaining > 0:
        guard += 1
        if guard > 16 * S * M + 64:
            raise RuntimeError("simulator deadlock")
        # choose the globally earliest-startable item (greedy list sched;
        # backward preferred on ties — the 1F1B policy)
        candidates = []
        for s in range(S):
            d = device_of[s]
            m = bwd_issued[s]
            if m < M:
                r = bwd_ready_at(s, m)
                if r is not None:
                    candidates.append((max(r, dev_free[d]), 0, s, "B", m))
            m = fwd_issued[s]
            if m < M and fwd_issued[s] - bwd_issued[s] < cap[s]:
                r = fwd_ready_at(s, m)
                if r is not None:
                    candidates.append((max(r, dev_free[d]), 1, s, "F", m))
        if not candidates:
            raise RuntimeError("simulator stalled (bad graph?)")
        start, _, s, kind, m = min(candidates)
        d = device_of[s]
        dur = graph.stages[s].fwd if kind == "F" else b_dur[s]
        end = start + dur
        dev_free[d] = end
        busy[d] += dur
        intervals[d].append((start, end))
        items.append((start, end, d, kind, s, m))
        if kind == "F":
            fwd_done[s][m] = end
            fwd_issued[s] += 1
        else:
            bwd_done[s][m] = end
            bwd_issued[s] += 1
            finish = max(finish, end)
        remaining -= 1

    # -- phase 2: pack deferred W passes into idle gaps (ZB-H1) ------------
    if split_bw:
        for d in range(D):
            gaps = []
            prev = 0.0
            for a, b in intervals[d]:            # already time-ordered
                if a > prev + 1e-12:
                    gaps.append([prev, a])
                prev = b
            tail = prev
            ws = sorted((bwd_done[s][m], s, m)
                        for s in range(S)
                        if device_of[s] == d and graph.stages[s].bwd_w > 0
                        for m in range(M))
            for ready, s, m in ws:
                dur = graph.stages[s].bwd_w
                end = None
                for g in gaps:
                    st = max(g[0], ready)
                    if st + dur <= g[1] + 1e-12:
                        end = st + dur
                        g[0] = end               # consume the gap prefix
                        break
                if end is None:                  # append to the tail
                    tail = max(tail, ready) + dur
                    end = tail
                busy[d] += dur
                items.append((end - dur, end, d, "W", s, m))
                finish = max(finish, end)

    items = sort_items(items)
    total = finish
    bubble = 1.0 - (sum(busy) / (D * total)) if total > 0 else 0.0
    return {"iteration_time": float(total),
            "bubble_fraction": float(bubble),
            "per_device_busy": busy,
            "num_devices": D,
            "device_of": list(device_of),
            "items": items,
            "peak_activations_per_device":
                peak_live_activations(items, D)}


def is_chain(graph: PipelineGraph) -> bool:
    """True when the graph is a linear chain 0 -> 1 -> ... -> S-1.
    Edge ORDER is irrelevant — builders like build_modality_parallel
    append cross-module edges last, so a single-encoder MLLM graph is
    a chain whose edge list is merely unsorted."""
    return sorted(graph.edges) == [(i, i + 1)
                                   for i in range(len(graph.stages) - 1)]


def _interleaved_order(D: int, v: int, M: int):
    """Megatron-LM's interleaved-1F1B per-device item order (schedules.
    py, forward_backward_pipelining_with_interleaving), in simulator
    units: device d owns chunk c's stage ``c*D + d``; forwards walk
    chunks in groups of D microbatches; backwards walk chunks in
    reverse. Requires M % D == 0."""
    total = M * v
    orders = []
    for d in range(D):
        def fitem(k):
            return ("F", (k // D) % v, (k // (D * v)) * D + (k % D))

        def bitem(j):
            return ("B", v - 1 - ((j // D) % v),
                    (j // (D * v)) * D + (j % D))

        warmup = min((D - d - 1) * 2 + (v - 1) * D, total)
        seq = [fitem(k) for k in range(warmup)]
        j = 0
        for k in range(warmup, total):        # steady 1F1B: F then B
            seq.append(fitem(k))
            seq.append(bitem(j))
            j += 1
        seq.extend(bitem(jj) for jj in range(j, total))   # cooldown
        orders.append(seq)
    return orders


def run_interleaved(graph: PipelineGraph, num_microbatches: int,
                    virtual_chunks: int) -> Dict[str, object]:
    """Simulate Megatron's interleaved-1F1B order on a CHAIN graph of
    S = v*D stages folded onto D devices. Unlike the greedy list
    scheduler, each device executes its fixed item sequence (warmup
    forwards in chunk-rotation order, 1F1B steady state, cooldown),
    which is what realizes the ~v-fold fill/drain bubble reduction.
    Caller guarantees: chain graph, S % v == 0, M % D == 0."""
    S = len(graph.stages)
    M = num_microbatches
    v = virtual_chunks
    D = S // v
    preds, succs = graph.preds, graph.succs

    fwd_done = [[None] * M for _ in range(S)]
    bwd_done = [[None] * M for _ in range(S)]
    dev_free = [0.0] * D
    busy = [0.0] * D
    items: List[Item] = []
    finish = 0.0
    orders = _interleaved_order(D, v, M)
    ptr = [0] * D

    def ready_at(d):
        kind, c, m = orders[d][ptr[d]]
        s = c * D + d
        if kind == "F":
            ts = [fwd_done[p][m] for p in preds[s]]
            if any(t is None for t in ts):
                return None
            return max(ts, default=0.0)
        if fwd_done[s][m] is None:
            return None
        ts = [bwd_done[q][m] for q in succs[s]]
        if any(t is None for t in ts):
            return None
        return max(ts + [fwd_done[s][m]])

    remaining = 2 * S * M
    while remaining > 0:
        candidates = []
        for d in range(D):
            if ptr[d] < len(orders[d]):
                r = ready_at(d)
                if r is not None:
                    candidates.append((max(r, dev_free[d]), d))
        if not candidates:
            raise RuntimeError("interleaved schedule deadlock (bad order)")
        start, d = min(candidates)
        kind, c, m = orders[d][ptr[d]]
        s = c * D + d
        dur = graph.stages[s].fwd if kind == "F" else graph.stages[s].bwd
        end = start + dur
        dev_free[d] = end
        busy[d] += dur
        items.append((start, end, d, kind, s, m))
        if kind == "F":
            fwd_done[s][m] = end
        else:
            bwd_done[s][m] = end
            finish = max(finish, end)
        ptr[d] += 1
        remaining -= 1

    items = sort_items(items)
    total = finish
    bubble = 1.0 - (sum(busy) / (D * total)) if total > 0 else 0.0
    return {"iteration_time": float(total),
            "bubble_fraction": float(bubble),
            "per_device_busy": busy,
            "num_devices": D,
            "device_of": [s % D for s in range(S)],
            "items": items,
            "peak_activations_per_device":
                peak_live_activations(items, D)}
