"""Pipeline scheduling subsystem: stage graphs, a discrete-event
simulator over F/B/W work items, and three schedulers behind one
interface.

Map to the papers:

* ``OneFOneB`` ("1f1b") — the baseline schedule in Cornstarch's
  Table 3 / Fig. 7 experiments (one stage per device, backward
  monolithic). Identical behavior to the legacy
  ``core.pipeline.simulate_1f1b``.
* ``Interleaved1F1B`` ("interleaved") — Megatron-LM virtual stages:
  each device hosts v chunks of the layer chain, cutting the
  fill/drain bubble roughly v-fold (Narayanan et al. 2021, Fig. 8 of
  that paper; referenced in Cornstarch §2 as the strongest homogeneous
  baseline).
* ``ZBH1`` ("zb-h1") — zero-bubble H1 schedule (Qi et al. 2023,
  ZB-H1/Fig. 4): backward splits into input-grad (B) and weight-grad
  (W); W only blocks the optimizer step, so it is deferred into
  bubbles under 1F1B's activation-memory cap. Composed with
  Cornstarch's frozen-aware costs (§4.2): frozen modules have W = 0,
  so the split helps MLLMs with frozen encoders more than homogeneous
  LLMs — the B critical path shrinks by the frozen fraction and all
  deferral headroom lands on the trainable stages.

The B/W cost decomposition lives on :class:`Stage` (``bwd_w`` field,
``bwd_b`` property) and is derived from the frozen-aware ``bwd_factor``
rule by ``core.pipeline.ModuleProfile`` (frozen => W = 0; trainable =>
W = 1 fwd-equivalent; recompute time attaches to B, where it must run).
"""
from .graph import (PipelineGraph, Stage, chain_graph,  # noqa: F401
                    interleave_devices)
from .schedulers import (SCHEDULES, Interleaved1F1B,  # noqa: F401
                         OneFOneB, Scheduler, ZBH1, get_scheduler,
                         simulate)
from .simulator import run_schedule  # noqa: F401
