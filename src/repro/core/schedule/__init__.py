"""Pipeline scheduling subsystem: stage graphs, a discrete-event
simulator over F/B/W work items, four schedulers behind one interface,
and a memory-validation harness tying the simulator's activation model
to the real executor.

Map to the papers:

* ``OneFOneB`` ("1f1b") — the baseline schedule in Cornstarch's
  Table 3 / Fig. 7 experiments (one stage per device, backward
  monolithic). Identical behavior to the legacy
  ``core.pipeline.simulate_1f1b``.
* ``Interleaved1F1B`` ("interleaved") — Megatron-LM virtual stages:
  each device hosts v chunks of the layer chain, cutting the
  fill/drain bubble roughly v-fold (Narayanan et al. 2021, Fig. 8 of
  that paper; referenced in Cornstarch §2 as the strongest homogeneous
  baseline).
* ``ZBH1`` ("zb-h1") — zero-bubble H1 schedule (Qi et al. 2023,
  ZB-H1/Fig. 4): backward splits into input-grad (B) and weight-grad
  (W); W only blocks the optimizer step, so it is deferred into
  bubbles under 1F1B's activation-memory cap. Composed with
  Cornstarch's frozen-aware costs (§4.2): frozen modules have W = 0,
  so the split helps MLLMs with frozen encoders more than homogeneous
  LLMs — the B critical path shrinks by the frozen fraction and all
  deferral headroom lands on the trainable stages.
* ``ZBV`` ("zb-v") — zero-bubble V schedule (Qi et al. 2023, the V
  placement): the chain is cut into 2p chunk-stages on p devices,
  device i hosting chunks i and 2p-1-i, so the forward walks down the
  device column and back up. The last chunk lives on device 0, whose
  backward starts without a drain wait, and the deferred W passes fill
  BOTH ramps of the V under V-shaped per-chunk caps that keep the
  per-device live-activation total inside the 1F1B envelope (and,
  unlike 1F1B, uniform across devices). Frozen chunks have no W, so
  the ramp-filling headroom concentrates on the trainable chunks.

The B/W cost decomposition lives on :class:`Stage` (``bwd_w`` field,
``bwd_b`` property) and is derived from the frozen-aware ``bwd_factor``
rule by ``core.pipeline.ModuleProfile`` (frozen => W = 0; trainable =>
W = 1 fwd-equivalent; recompute time attaches to B, where it must run).

Every simulation returns its work-item timeline, stage->device map,
and per-device peak live activations; ``core.schedule.memory``
replays that timeline on the real executor
(``core.modality_parallel.execute_schedule``) and fails loudly if the
measured peaks diverge from the simulated ones or breach the
``depth_from_end`` caps.
"""
from .graph import (PipelineGraph, Stage, chain_graph,  # noqa: F401
                    interleave_devices, refine_chain, v_shape_devices)
from .schedulers import (SCHEDULES, Interleaved1F1B,  # noqa: F401
                         OneFOneB, Scheduler, ZBH1, ZBV, get_scheduler,
                         simulate)
from .simulator import (peak_live_activations, run_schedule,  # noqa: F401
                        sort_items)
from .memory import (MemoryModelMismatch,  # noqa: F401
                     activation_caps, validate_schedule_memory)
