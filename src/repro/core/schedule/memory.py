"""Simulator-vs-executor validation of the activation-memory model.

The discrete-event simulator (``simulator.run_schedule``) admits a
forward only while the stage's in-flight microbatches stay below
``depth_from_end`` — 1F1B's activation cap — and reports the per-device
peak of live activations its timeline actually reaches
(``peak_activations_per_device``). That number is a *model*; this
module checks it against *measurement*: the schedule-driven executor
(``core.modality_parallel.execute_schedule``) replays the same item
timeline with real JAX stage computations and real VJPs, holding every
inter-stage activation in an explicit store filled at F and drained at
B, and reports the store's measured peak per device.

``validate_schedule_memory`` runs both sides for one (graph, schedule)
pair and **fails loudly** (:class:`MemoryModelMismatch`) when:

* the executor-measured peak differs from the simulator's on any
  device. They must match EXACTLY: the simulator counts its claim off
  the item timeline, the executor counts the entries its real
  activation store holds while replaying it. What this catches is
  bookkeeping divergence — a store leak, a double free, an item
  attributed to the wrong device, an admission decision the timeline
  does not honor. What it cannot catch, by construction, is a blind
  spot shared by both sides' *model* (both deliberately exclude
  in-transit outputs and cotangents — see the unit definition below),
  so it complements rather than replaces the two independent checks:
* any measured peak exceeds the ``depth_from_end`` cap envelope
  (``activation_caps``), i.e. the schedule used more memory than the
  policy it claims to respect — an absolute bound, not a
  self-comparison;
* the timeline is not executable as emitted: a dependency violation
  or premature free dies with a KeyError inside the executor, and the
  executor's gradients are checked against plain autodiff in the
  tests, so the replay provably computes the real backward.

The memory *unit* is one inter-stage activation (the residual-stream
tensor the input-grad pass B consumes). Chunked placements (zb-v,
interleaved) hold proportionally smaller per-chunk activations — a
device at peak 2p under ZB-V's two-chunks-per-device fold holds the
same bytes as a 1F1B device at peak p — so cross-schedule comparisons
must weight peaks by 1/v; same-schedule sim-vs-executor comparisons
are exact counts. Deferred W passes additionally park their operands
in a separate W-residual store, reported (not capped) as the zero-
bubble papers' explicit memory-vs-bubble trade-off.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from .graph import PipelineGraph
from .schedulers import get_scheduler
from .simulator import item_id


class MemoryModelMismatch(AssertionError):
    """The simulator's activation-memory claim diverged from the
    executor's measurement (or breached its own cap). Carries the
    per-item timeline diff: ``first_divergence`` is ``(item_id,
    simulated_live, replayed_live, simulated_bytes, replayed_bytes)``
    for the first item where the model and the measurement disagree
    (None when the timelines agree and only the summary claim is
    wrong). Item ids are ``simulator.item_id`` strings — the same
    anchors ``repro.analysis.schedlint`` findings use."""

    def __init__(self, message: str,
                 first_divergence: Optional[Tuple] = None):
        super().__init__(message)
        self.first_divergence = first_divergence


def simulated_activation_trace(graph: PipelineGraph,
                               sim: Dict[str, object]) -> List[tuple]:
    """The simulator-side per-item activation walk, in replay order:
    ``(item_id, device, live_after)`` per item — +1 at F, -1 at B on
    the stage's device, exactly the model ``execute_schedule`` measures
    against (its ``activation_trace`` return uses the same ids)."""
    device_of = list(sim["device_of"])  # type: ignore[arg-type]
    occ: Dict[int, int] = {}
    trace: List[tuple] = []
    for item in sim["items"]:           # type: ignore[union-attr]
        _s0, _e0, dev, kind, s, _m = item
        d = device_of[s]
        if kind == "F":
            occ[d] = occ.get(d, 0) + 1
        elif kind == "B":
            occ[d] = occ.get(d, 0) - 1
        trace.append((item_id(item), dev, occ.get(dev, 0)))
    return trace


def diff_activation_traces(sim_trace: Sequence[tuple],
                           exe_trace: Sequence[tuple],
                           nbytes: int) -> Optional[Tuple]:
    """First item where the simulated walk and the replayed measurement
    disagree, as ``(item_id, sim_live, exe_live, sim_bytes,
    exe_bytes)``; None when they agree item-for-item."""
    for (sid, _sd, sc), (eid, _ed, ec) in zip(sim_trace, exe_trace):
        if sid != eid or sc != ec:
            return (sid if sid == eid else f"{sid} vs {eid}",
                    sc, ec, sc * nbytes, ec * nbytes)
    if len(sim_trace) != len(exe_trace):
        longer = sim_trace if len(sim_trace) > len(exe_trace) \
            else exe_trace
        extra = longer[min(len(sim_trace), len(exe_trace))]
        return (extra[0], len(sim_trace), len(exe_trace), -1, -1)
    return None


def activation_caps(graph: PipelineGraph,
                    device_of: Optional[Sequence[int]] = None,
                    num_microbatches: Optional[int] = None) -> List[int]:
    """Per-device in-flight activation cap: the sum over hosted stages
    of ``depth_from_end`` (each additionally bounded by the microbatch
    count — a stage can never hold more activations than there are
    microbatches). One stage per device when ``device_of`` is None."""
    S = len(graph.stages)
    if device_of is None:
        device_of = list(range(S))
    D = max(device_of) + 1
    caps = [0] * D
    for s in range(S):
        d = graph.depth_from_end(s)
        if num_microbatches is not None:
            d = min(d, num_microbatches)
        caps[device_of[s]] += d
    return caps


def validate_schedule_memory(graph: PipelineGraph, num_microbatches: int,
                             schedule: str = "1f1b", *,
                             virtual_chunks: Optional[int] = None,
                             d_model: int = 16, batch: int = 1,
                             seq: int = 4, seed: int = 0,
                             stage_fn=None, stage_params=None,
                             microbatches=None,
                             sim: Optional[Dict[str, object]] = None,
                             executor: str = "replay",
                             mesh=None,
                             claim_sim: Optional[Dict[str, object]] = None
                             ) -> Dict[str, object]:
    """Simulate ``schedule`` on ``graph``, replay the timeline on the
    real executor, and cross-check the activation-memory claims.

    When no model is supplied, a toy residual stage (``x + tanh(x W)``,
    one weight matrix per stage) is built — enough to exercise real
    forwards, real input-grad and weight-grad VJPs, and real activation
    buffers. A precomputed ``sim`` dict skips the scheduler call (used
    to prove the harness actually fails on a divergent claim).

    ``executor`` picks the measurement side: ``"replay"`` (sequential
    ``execute_schedule``) or ``"spmd"`` (the shard_map executor,
    ``repro.parallel.spmd`` — the distributed path; ``mesh`` rides
    through to it). ``claim_sim`` lets the *claimed* timeline differ
    from the one executed (the distributed reality check: a plan's
    claim vs the program a rank actually runs) — peaks and the
    per-item trace diff then compare the measurement against the
    claim. Raises :class:`MemoryModelMismatch` on any divergence;
    returns the comparison report otherwise."""
    import jax
    import jax.numpy as jnp
    from repro.core.modality_parallel import execute_schedule

    if sim is None:
        kwargs = {"virtual_chunks": virtual_chunks} \
            if virtual_chunks is not None else {}
        sim = get_scheduler(schedule, **kwargs).simulate(graph,
                                                         num_microbatches)

    if stage_fn is None:
        S = len(graph.stages)
        key = jax.random.PRNGKey(seed)
        stage_params = {"w": jax.random.normal(
            key, (S, d_model, d_model)) * 0.1}

        def stage_fn(lp, x):
            return x + jnp.tanh(x @ lp["w"])

        microbatches = jax.random.normal(
            jax.random.fold_in(key, 1),
            (num_microbatches, batch, seq, d_model))

    if executor == "spmd":
        from repro.parallel.spmd import run_schedule_spmd
        measured = run_schedule_spmd(stage_fn, stage_params,
                                     microbatches, graph, sim,
                                     mesh=mesh)
    elif executor == "replay":
        measured = execute_schedule(stage_fn, stage_params,
                                    microbatches, graph, sim)
    else:
        raise ValueError(f"unknown executor {executor!r}; pick "
                         f"'replay' or 'spmd'")
    claimed = sim if claim_sim is None else claim_sim
    sim_peaks = claimed["peak_activations_per_device"]
    exe_peaks = measured["peak_activations_per_device"]
    caps = activation_caps(graph, sim["device_of"], num_microbatches)
    report = {
        "schedule": sim["schedule"],
        "virtual_chunks": sim["virtual_chunks"],
        "num_devices": sim["num_devices"],
        "executor": executor,
        "simulated_peaks": list(sim_peaks),
        "executor_peaks": list(exe_peaks),
        "caps": caps,
        "peak_w_residuals": measured["peak_w_residuals_per_device"],
        "loss": float(measured["loss"]),
    }
    if list(sim_peaks) != list(exe_peaks):
        div = diff_activation_traces(
            simulated_activation_trace(graph, claimed),
            measured["activation_trace"],
            int(measured.get("activation_nbytes", 0)))
        if div is None:
            detail = ("the item timelines agree item-for-item — the "
                      "summary claim itself is inconsistent with the "
                      "timeline it shipped with")
        else:
            iid, sc, ec, sb, eb = div
            detail = (f"first diverging item {iid}: simulated "
                      f"{sc} live activations ({sb} bytes) vs "
                      f"replayed {ec} ({eb} bytes)")
        raise MemoryModelMismatch(
            f"simulator peak activations {sim_peaks} != executor "
            f"measurement {exe_peaks} for schedule "
            f"{sim['schedule']!r}; {detail} ({report})",
            first_divergence=div)
    over = [d for d in range(sim["num_devices"])
            if exe_peaks[d] > caps[d]]
    if over:
        raise MemoryModelMismatch(
            f"measured peaks exceed depth_from_end caps on devices "
            f"{over}: peaks={exe_peaks} caps={caps} for schedule "
            f"{sim['schedule']!r} ({report})")
    return report
