"""Multimodality-aware context parallelism (Cornstarch §4.3 + §5.3).

Each CP rank holds the token *blocks* assigned by a distribution plan
(core/distribution.py) — note positions/bitfields travel with the
tokens, since after LPT assignment a rank's tokens are NOT contiguous.

Implementations:

* ``allgather`` (paper §5.3, Llama-3 style, the default): every rank
  all-gathers K/V (+ kv bits/positions) and computes attention rows for
  its local queries only. Load balance therefore depends ONLY on the
  per-rank row workloads — exactly what the LPT plan equalizes.
* ``ring``: P2P ring (ppermute) with online-softmax combination —
  the baseline the paper compares against (and the fallback for which
  random distribution is provided).

Both run under ``shard_map`` over a named mesh axis. A collective-free
reference (``cp_reference``) computes identical math for single-device
tests; multi-device equivalence is tested in a subprocess with
``--xla_force_host_platform_device_count``.

Per-step attention math (``impl=``): the default ``"xla"`` body
materializes the [B,H,Tq,Tk] logits in HBM per step; ``"bam_kernel"`` /
``"bam_interpret"`` route through the Pallas stats kernel
(``repro.kernels.ops.bam_attention_stats``) which returns the same
unnormalized (acc, m, l) partials with the bitfield mask evaluated
in-registers — the per-step logits never leave VMEM. The XLA body is
kept as the CPU fallback and ``cp_reference`` stays the oracle.

Both bodies are DIFFERENTIABLE: each carries a combining-aware
``custom_vjp`` that saves the per-rank (out, lse) flash residuals
derived from the cross-chunk combined (m, l), so the backward runs the
same fused per-chunk flash backward the single-device kernel path uses
(``repro.kernels.ops.bam_attention_chunk_bwd``) — no O(Tq·Tk)
intermediate is ever traced on the kernel impls. Backward collectives:
allgather's backward reduce-scatters dK/dV back to their owner ranks
(``psum_scatter``); ring's backward runs the REVERSE ring, with the
accumulating dK/dV chunk traveling alongside its K/V chunk so both are
home after G steps. Training enters through
``repro.models.layers.run_attention`` (``ModelConfig.cp_mesh``) and
``repro.training.steps.make_cp_train_step``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import bam
from repro.core.distribution import Plan

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Plan application (host side): permute tokens so each rank's assigned
# blocks are contiguous in the sharded layout.
# ---------------------------------------------------------------------------

def plan_permutation(plan: Plan, seq_len: int) -> np.ndarray:
    """perm[i] = source token index of the i-th token in CP layout.

    The result is always a TRUE permutation of ``arange(seq_len)`` —
    every token appears exactly once. Plans balance block *workloads*,
    so per-rank token counts may differ; counts are rebalanced to
    differ by at most one (ranks ``0..seq_len % num_ranks - 1`` get the
    extra token), moving the trailing tokens of over-full ranks to
    under-full ranks deterministically. When ``seq_len % num_ranks !=
    0`` equal counts are impossible — shard_map consumers must pad the
    sequence to a rank multiple first. Raises ``ValueError`` if the
    plan's blocks do not cover ``seq_len`` tokens."""
    slices = [s[s < seq_len] for s in plan.rank_token_slices()]
    total = sum(len(s) for s in slices)
    if total != seq_len:
        raise ValueError(
            f"plan covers {total} tokens "
            f"({len(plan.assignment)} blocks x {plan.block_size}) "
            f"but seq_len={seq_len}")
    counts = [len(s) for s in slices]
    if len(set(counts)) != 1:
        # rebalance counts while keeping workload order: move trailing
        # tokens from over-full to under-full ranks (deterministic).
        # Excess and deficit match exactly because targets sum to
        # seq_len, so no token is ever dropped.
        base, rem = divmod(seq_len, plan.num_ranks)
        targets = [base + (1 if g < rem else 0)
                   for g in range(plan.num_ranks)]
        extra: list = []
        for g, s in enumerate(slices):
            if len(s) > targets[g]:
                extra.extend(s[targets[g]:])
                slices[g] = s[:targets[g]]
        for g, s in enumerate(slices):
            need = targets[g] - len(s)
            if need > 0:
                slices[g] = np.concatenate(
                    [s, np.asarray(extra[:need], dtype=np.int64)])
                extra = extra[need:]
        assert not extra, "rebalance left unassigned tokens"
    return np.concatenate(slices).astype(np.int64)


def apply_plan(tree, perm: np.ndarray, axis: int = 1):
    """Gather ``axis`` (the token axis) of every array by perm."""
    return jax.tree.map(lambda a: jnp.take(a, perm, axis=axis), tree)


def invert_perm(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    return inv


# ---------------------------------------------------------------------------
# Local attention with explicit (m, l) stats for online combination
# ---------------------------------------------------------------------------

def _masked_attn_stats(q, k, v, mask, scale, softcap: float = 0.0):
    """Returns (acc [B,H,Tq,hd] = sum exp(l-m)·V, m [B,H,Tq], l [B,H,Tq])
    — unnormalized flash-attention partials for cross-chunk combine.
    Dense XLA body: materializes [B,H,Tq,Tk] logits (CPU fallback; the
    kernel path in ``_attn_stats`` avoids exactly this). GQA K/V are
    head-expanded (the kernel folds the mapping into its index maps
    instead)."""
    k = bam.repeat_kv(k, q.shape[2] // k.shape[2])
    v = bam.repeat_kv(v, q.shape[2] // v.shape[2])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, axis=-1)                         # [B,H,Tq]
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v.dtype), v)
    return acc.astype(jnp.float32), m, l


def _attn_stats(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                softcap: float, window: int, impl: str,
                block_q: Optional[int] = None,
                block_k: Optional[int] = None):
    """Stats-path dispatch: ``impl="xla"`` builds the dense mask and
    logits; kernel impls evaluate the bitfield in-registers and never
    materialize an O(Tq·Tk) intermediate. Both derive the hd**-0.5
    scale themselves (the kernel hardcodes it) so the paths can't
    silently diverge."""
    if impl == "xla":
        mask = bam.allowed_mask(q_bits, kv_bits, q_pos, kv_pos,
                                window)[:, None]
        return _masked_attn_stats(q, k, v, mask, q.shape[-1] ** -0.5,
                                  softcap)
    from repro.kernels.ops import auto_block, bam_attention_stats
    return bam_attention_stats(
        q, k, v, q_bits, kv_bits, q_pos, kv_pos, softcap=softcap,
        window=window, impl=impl,
        block_q=block_q or auto_block(q.shape[1]),
        block_k=block_k or auto_block(k.shape[1]))


def _combine_stats(acc1, m1, l1, acc2, m2, l2):
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    return acc1 * a1[..., None] + acc2 * a2[..., None], m, l1 * a1 + l2 * a2


def _finish(acc, m, l, dtype):
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.where((l > 0)[..., None], out, 0.0)
    return jnp.einsum("bhqd->bqhd", out).astype(dtype)


def _lse_from_stats(m, l):
    """Combined (m, l) -> per-row log-sum-exp [B,H,Tq] — the flash
    residual every per-chunk backward renormalizes against. Rows with
    no allowed key (l == 0) get NEG_INF, matching the kernel's own
    padding convention."""
    return jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), NEG_INF)


# ---------------------------------------------------------------------------
# Per-chunk flash backward from the COMBINED residuals
# ---------------------------------------------------------------------------

def _dense_chunk_bwd(q, k, v, out, g, lse, q_bits, kv_bits, q_pos, kv_pos,
                     softcap: float, window: int):
    """XLA fallback chunk backward: same math as the fused kernels
    (dS = P·(dP − Δ) from the combined lse), dense [B,H,Tq,Tk]
    intermediates. Returns (dq_contrib, dk, dv) with dk/dv GQA-folded
    to the K/V head count."""
    n_rep = q.shape[2] // k.shape[2]
    scale = q.shape[-1] ** -0.5
    mask = bam.allowed_mask(q_bits, kv_bits, q_pos, kv_pos, window)[:, None]
    kf = bam.repeat_kv(k, n_rep).astype(jnp.float32)
    vf = bam.repeat_kv(v, n_rep).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    # fully-masked rows carry lse = NEG_INF; clamp so the (discarded)
    # masked lanes of exp() cannot overflow to inf
    lse_safe = jnp.where(lse <= NEG_INF / 2, 0.0, lse)
    p = jnp.where(mask, jnp.exp(s - lse_safe[..., None]), 0.0)
    delta = jnp.einsum("bqhd,bqhd->bhq", out.astype(jnp.float32), gf)
    dp = jnp.einsum("bqhd,bkhd->bhqk", gf, vf)
    ds = p * (dp - delta[..., None])
    if softcap:
        ds = ds * (1.0 - (s / softcap) ** 2)
    dq = jnp.einsum("bhqk,bkhd->bqhd", ds, kf) * scale
    dk_h = jnp.einsum("bhqk,bqhd->bkhd", ds, qf) * scale
    dv_h = jnp.einsum("bhqk,bqhd->bkhd", p, gf)
    if n_rep > 1:
        B, Tk, H, hd = dk_h.shape
        dk_h = dk_h.reshape(B, Tk, H // n_rep, n_rep, hd).sum(axis=3)
        dv_h = dv_h.reshape(B, Tk, H // n_rep, n_rep, hd).sum(axis=3)
    return dq.astype(q.dtype), dk_h.astype(k.dtype), dv_h.astype(v.dtype)


def _chunk_bwd(q, k, v, out, g, lse, q_bits, kv_bits, q_pos, kv_pos,
               softcap: float, window: int, impl: str,
               block_q: Optional[int] = None,
               block_k: Optional[int] = None):
    """One K/V chunk's flash backward against the combined (out, lse)
    residuals: (dq_contrib, dk, dv). dq contributions sum over chunks;
    dk/dv are complete for the chunk. Kernel impls run the fused Pallas
    dQ / dK-dV kernels per chunk — no O(Tq·Tk) recompute."""
    if impl == "xla":
        return _dense_chunk_bwd(q, k, v, out, g, lse, q_bits, kv_bits,
                                q_pos, kv_pos, softcap, window)
    from repro.kernels.ops import auto_block, bam_attention_chunk_bwd
    return bam_attention_chunk_bwd(
        q, k, v, out, g, lse, q_bits, kv_bits, q_pos, kv_pos,
        softcap=softcap, window=window, impl=impl,
        block_q=block_q or auto_block(q.shape[1]),
        block_k=block_k or auto_block(k.shape[1]))


# ---------------------------------------------------------------------------
# CP attention bodies (run inside shard_map) — differentiable via
# combining-aware custom_vjps: residuals are the per-rank (out, lse)
# derived from the cross-chunk combined (m, l).
# ---------------------------------------------------------------------------

def _gather_kv(axis_name, k, v, kv_bits, kv_pos):
    return (lax.all_gather(k, axis_name, axis=1, tiled=True),
            lax.all_gather(v, axis_name, axis=1, tiled=True),
            lax.all_gather(kv_bits, axis_name, axis=1, tiled=True),
            lax.all_gather(kv_pos, axis_name, axis=1, tiled=True))


_NONDIFF = (0, 1, 2, 3, 4, 5)   # axis_name, softcap, window, impl, bq, bk


@functools.partial(jax.custom_vjp, nondiff_argnums=_NONDIFF)
def _allgather_diff(axis_name, softcap, window, impl, block_q, block_k,
                    q, k, v, q_bits, kv_bits, q_pos, kv_pos):
    out, _ = _allgather_fwd(axis_name, softcap, window, impl, block_q,
                            block_k, q, k, v, q_bits, kv_bits, q_pos,
                            kv_pos)
    return out


def _allgather_fwd(axis_name, softcap, window, impl, block_q, block_k,
                   q, k, v, q_bits, kv_bits, q_pos, kv_pos):
    k_all, v_all, kb_all, kp_all = _gather_kv(axis_name, k, v, kv_bits,
                                              kv_pos)
    acc, m, l = _attn_stats(q, k_all, v_all, q_bits, kb_all, q_pos, kp_all,
                            softcap, window, impl, block_q, block_k)
    out = _finish(acc, m, l, q.dtype)
    # residuals are O(Tq_local·H·hd): local tensors + (out, lse); the
    # gathered K/V are re-gathered in backward instead of saved
    return out, (q, k, v, q_bits, kv_bits, q_pos, kv_pos, out,
                 _lse_from_stats(m, l))


def _allgather_bwd(axis_name, softcap, window, impl, block_q, block_k,
                   res, g):
    q, k, v, q_bits, kv_bits, q_pos, kv_pos, out, lse = res
    k_all, v_all, kb_all, kp_all = _gather_kv(axis_name, k, v, kv_bits,
                                              kv_pos)
    dq, dk_all, dv_all = _chunk_bwd(
        q, k_all, v_all, out, g, lse, q_bits, kb_all, q_pos, kp_all,
        softcap, window, impl, block_q, block_k)
    # every rank produced grads for ALL keys; reduce-scatter them back
    # to the owner rank's token slice
    dk = lax.psum_scatter(dk_all, axis_name, scatter_dimension=1,
                          tiled=True)
    dv = lax.psum_scatter(dv_all, axis_name, scatter_dimension=1,
                          tiled=True)
    return dq, dk, dv, None, None, None, None


_allgather_diff.defvjp(_allgather_fwd, _allgather_bwd)


def _allgather_body(q, k, v, q_bits, kv_bits, q_pos, kv_pos, *,
                    axis_name: str, softcap: float, window: int,
                    impl: str = "xla", block_q: Optional[int] = None,
                    block_k: Optional[int] = None):
    """Per-rank: local queries [B,Tq/G]; gather all K/V."""
    return _allgather_diff(axis_name, softcap, window, impl, block_q,
                           block_k, q, k, v, q_bits, kv_bits, q_pos,
                           kv_pos)


def _ring_shift(axis_name, G, arrays, reverse: bool = False):
    perm = [((j + 1) % G, j) if reverse else (j, (j + 1) % G)
            for j in range(G)]
    return tuple(lax.ppermute(a, axis_name, perm) for a in arrays)


@functools.partial(jax.custom_vjp, nondiff_argnums=_NONDIFF)
def _ring_diff(axis_name, softcap, window, impl, block_q, block_k,
               q, k, v, q_bits, kv_bits, q_pos, kv_pos):
    out, _ = _ring_fwd(axis_name, softcap, window, impl, block_q, block_k,
                       q, k, v, q_bits, kv_bits, q_pos, kv_pos)
    return out


def _ring_fwd(axis_name, softcap, window, impl, block_q, block_k,
              q, k, v, q_bits, kv_bits, q_pos, kv_pos):
    G = lax.psum(1, axis_name)
    B, Tq, H, hd = q.shape

    def step(i, carry):
        acc, m, l, kc, vc, kb, kp = carry
        a2, m2, l2 = _attn_stats(q, kc, vc, q_bits, kb, q_pos, kp,
                                 softcap, window, impl, block_q, block_k)
        acc, m, l = _combine_stats(acc, m, l, a2, m2, l2)
        kc, vc, kb, kp = _ring_shift(axis_name, G, (kc, vc, kb, kp))
        return acc, m, l, kc, vc, kb, kp

    acc0 = jnp.zeros((B, H, Tq, hd), jnp.float32)
    m0 = jnp.full((B, H, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Tq), jnp.float32)
    acc, m, l, *_ = lax.fori_loop(
        0, G, step, (acc0, m0, l0, k, v, kv_bits, kv_pos))
    out = _finish(acc, m, l, q.dtype)
    # after G shifts every chunk is home again: residuals stay local
    return out, (q, k, v, q_bits, kv_bits, q_pos, kv_pos, out,
                 _lse_from_stats(m, l))


def _ring_bwd(axis_name, softcap, window, impl, block_q, block_k, res, g):
    """Reverse ring: the K/V chunk travels the opposite direction with
    its accumulating dK/dV alongside; after G steps chunk and grads are
    back on the owner rank."""
    q, k, v, q_bits, kv_bits, q_pos, kv_pos, out, lse = res
    G = lax.psum(1, axis_name)

    def step(i, carry):
        dq, kc, vc, kb, kp, dkc, dvc = carry
        dq2, dk2, dv2 = _chunk_bwd(q, kc, vc, out, g, lse, q_bits, kb,
                                   q_pos, kp, softcap, window, impl,
                                   block_q, block_k)
        dq = dq + dq2.astype(jnp.float32)
        dkc = dkc + dk2.astype(jnp.float32)
        dvc = dvc + dv2.astype(jnp.float32)
        kc, vc, kb, kp, dkc, dvc = _ring_shift(
            axis_name, G, (kc, vc, kb, kp, dkc, dvc), reverse=True)
        return dq, kc, vc, kb, kp, dkc, dvc

    dq0 = jnp.zeros(q.shape, jnp.float32)
    dk0 = jnp.zeros(k.shape, jnp.float32)
    dv0 = jnp.zeros(v.shape, jnp.float32)
    dq, _, _, _, _, dk, dv = lax.fori_loop(
        0, G, step, (dq0, k, v, kv_bits, kv_pos, dk0, dv0))
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None, None, None)


_ring_diff.defvjp(_ring_fwd, _ring_bwd)


def _ring_body(q, k, v, q_bits, kv_bits, q_pos, kv_pos, *,
               axis_name: str, softcap: float, window: int,
               impl: str = "xla", block_q: Optional[int] = None,
               block_k: Optional[int] = None):
    """P2P ring: pass K/V chunks around, combine online-softmax stats."""
    return _ring_diff(axis_name, softcap, window, impl, block_q, block_k,
                      q, k, v, q_bits, kv_bits, q_pos, kv_pos)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

_CP_BODIES = {"allgather": _allgather_body, "ring": _ring_body}


def cp_attention(mesh, axis_name: str, q, k, v, q_bits, kv_bits, q_pos,
                 kv_pos, *, method: str = "allgather", softcap: float = 0.0,
                 window: int = 0, impl: str = "xla",
                 block_q: Optional[int] = None,
                 block_k: Optional[int] = None):
    """Inputs are GLOBAL arrays already permuted to plan layout
    ([B, T, H, hd] etc.); shard_map splits the token axis over
    ``axis_name``. Output is the global [B, T, H, hd] in plan layout.

    impl: per-step attention math — "xla" (dense logits, CPU fallback)
    or "bam_kernel" / "bam_interpret" (Pallas stats kernel, no
    O(Tq·Tk) intermediate per rank). Fully differentiable on every
    impl: the bodies carry combining-aware custom_vjps whose backward
    runs the fused per-chunk flash kernels from the combined (out, lse)
    residuals (reduce-scatter for allgather, reverse ring for ring) —
    grads match ``jax.grad`` of ``cp_reference``. block_q/block_k
    override the kernel tile sizes (default: auto from local lengths).
    """
    if method not in _CP_BODIES:
        raise ValueError(f"unknown CP method {method!r}; valid methods: "
                         f"{sorted(_CP_BODIES)}")
    fn = functools.partial(_CP_BODIES[method], axis_name=axis_name,
                           softcap=softcap, window=window, impl=impl,
                           block_q=block_q, block_k=block_k)
    tok = P(None, axis_name)
    tok3 = P(None, axis_name, None, None)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(tok3, tok3, tok3, tok, tok, tok, tok),
        out_specs=tok3, check_rep=False,
    )(q, k, v, q_bits, kv_bits, q_pos, kv_pos)


def cp_reference(q, k, v, q_bits, kv_bits, q_pos, kv_pos, *,
                 softcap: float = 0.0, window: int = 0):
    """Collective-free oracle: identical math on the full arrays (and,
    being plain jnp, the gradient oracle for the CP backward)."""
    mask = bam.allowed_mask(q_bits, kv_bits, q_pos, kv_pos, window)[:, None]
    scale = q.shape[-1] ** -0.5
    acc, m, l = _masked_attn_stats(q, k, v, mask, scale, softcap)
    return _finish(acc, m, l, q.dtype)


def simulate_rank_workloads(plan: Plan, bits: np.ndarray, pos: np.ndarray,
                            window: int = 0) -> np.ndarray:
    """Per-rank attention FLOPs proxy (row workload sums) used by the
    Table-4 style benchmark: the max over ranks bounds the attention
    step time under all-gather CP. Vectorized: blockwise reshape-sum
    then one scatter-add over the plan's block -> rank map (no
    O(ranks × blocks) Python loop)."""
    W = bam.token_workload(bits, pos, window)
    bs = plan.block_size
    nb = len(plan.assignment)
    padded = np.zeros(nb * bs, np.float64)
    n = min(len(W), nb * bs)
    padded[:n] = W[:n]
    block_sums = padded.reshape(nb, bs).sum(axis=1)
    loads = np.zeros(plan.num_ranks)
    np.add.at(loads, plan.assignment, block_sums)
    return loads
