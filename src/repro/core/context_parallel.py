"""Multimodality-aware context parallelism (Cornstarch §4.3 + §5.3).

Each CP rank holds the token *blocks* assigned by a distribution plan
(core/distribution.py) — note positions/bitfields travel with the
tokens, since after LPT assignment a rank's tokens are NOT contiguous.

Implementations:

* ``allgather`` (paper §5.3, Llama-3 style, the default): every rank
  all-gathers K/V (+ kv bits/positions) and computes attention rows for
  its local queries only. Load balance therefore depends ONLY on the
  per-rank row workloads — exactly what the LPT plan equalizes.
* ``ring``: P2P ring (ppermute) with online-softmax combination —
  the baseline the paper compares against (and the fallback for which
  random distribution is provided).

Both run under ``shard_map`` over a named mesh axis. A collective-free
reference (``cp_reference``) computes identical math for single-device
tests; multi-device equivalence is tested in a subprocess with
``--xla_force_host_platform_device_count``.

Per-step attention math (``impl=``): the default ``"xla"`` body
materializes the [B,H,Tq,Tk] logits in HBM per step; ``"bam_kernel"`` /
``"bam_interpret"`` route through the Pallas stats kernel
(``repro.kernels.ops.bam_attention_stats``) which returns the same
unnormalized (acc, m, l) partials with the bitfield mask evaluated
in-registers — the per-step logits never leave VMEM. The XLA body is
kept as the CPU fallback and ``cp_reference`` stays the oracle.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import bam
from repro.core.distribution import Plan


# ---------------------------------------------------------------------------
# Plan application (host side): permute tokens so each rank's assigned
# blocks are contiguous in the sharded layout.
# ---------------------------------------------------------------------------

def plan_permutation(plan: Plan, seq_len: int) -> np.ndarray:
    """perm[i] = source token index of the i-th token in CP layout.
    Ranks get equal token counts (plans balance block *workloads*, and
    block counts may differ by rank; we pad rank slices to the max count
    with the trailing blocks of the least loaded ranks — in practice
    LPT/zigzag produce equal counts for uniform block workloads)."""
    slices = plan.rank_token_slices()
    counts = [len(s) for s in slices]
    if len(set(counts)) != 1:
        # rebalance counts while keeping workload order: move whole
        # blocks from over-full to under-full ranks (rare path)
        target = seq_len // plan.num_ranks
        extra = []
        for g, s in enumerate(slices):
            if len(s) > target:
                extra.extend(s[target:])
                slices[g] = s[:target]
        for g, s in enumerate(slices):
            need = target - len(s)
            if need > 0:
                slices[g] = np.concatenate([s, extra[:need]])
                extra = extra[need:]
    return np.concatenate(slices).astype(np.int64)


def apply_plan(tree, perm: np.ndarray, axis: int = 1):
    """Gather ``axis`` (the token axis) of every array by perm."""
    return jax.tree.map(lambda a: jnp.take(a, perm, axis=axis), tree)


def invert_perm(perm: np.ndarray) -> np.ndarray:
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    return inv


# ---------------------------------------------------------------------------
# Local attention with explicit (m, l) stats for online combination
# ---------------------------------------------------------------------------

def _masked_attn_stats(q, k, v, mask, scale, softcap: float = 0.0):
    """Returns (acc [B,H,Tq,hd] = sum exp(l-m)·V, m [B,H,Tq], l [B,H,Tq])
    — unnormalized flash-attention partials for cross-chunk combine.
    Dense XLA body: materializes [B,H,Tq,Tk] logits (CPU fallback; the
    kernel path in ``_attn_stats`` avoids exactly this)."""
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    neg = -1e30
    logits = jnp.where(mask, logits, neg)
    m = jnp.max(logits, axis=-1)                         # [B,H,Tq]
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v.dtype), v)
    return acc.astype(jnp.float32), m, l


def _attn_stats(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                softcap: float, window: int, impl: str):
    """Stats-path dispatch: ``impl="xla"`` builds the dense mask and
    logits; kernel impls evaluate the bitfield in-registers and never
    materialize an O(Tq·Tk) intermediate. Both derive the hd**-0.5
    scale themselves (the kernel hardcodes it) so the paths can't
    silently diverge."""
    if impl == "xla":
        mask = bam.allowed_mask(q_bits, kv_bits, q_pos, kv_pos,
                                window)[:, None]
        return _masked_attn_stats(q, k, v, mask, q.shape[-1] ** -0.5,
                                  softcap)
    from repro.kernels.ops import auto_block, bam_attention_stats
    return bam_attention_stats(
        q, k, v, q_bits, kv_bits, q_pos, kv_pos, softcap=softcap,
        window=window, impl=impl, block_q=auto_block(q.shape[1]),
        block_k=auto_block(k.shape[1]))


def _combine_stats(acc1, m1, l1, acc2, m2, l2):
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    return acc1 * a1[..., None] + acc2 * a2[..., None], m, l1 * a1 + l2 * a2


def _finish(acc, m, l, dtype):
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.where((l > 0)[..., None], out, 0.0)
    return jnp.einsum("bhqd->bqhd", out).astype(dtype)


# ---------------------------------------------------------------------------
# CP attention bodies (run inside shard_map)
# ---------------------------------------------------------------------------

def _allgather_body(q, k, v, q_bits, kv_bits, q_pos, kv_pos, *,
                    axis_name: str, softcap: float, window: int,
                    impl: str = "xla"):
    """Per-rank: local queries [B,Tq/G]; gather all K/V."""
    k_all = lax.all_gather(k, axis_name, axis=1, tiled=True)
    v_all = lax.all_gather(v, axis_name, axis=1, tiled=True)
    kb_all = lax.all_gather(kv_bits, axis_name, axis=1, tiled=True)
    kp_all = lax.all_gather(kv_pos, axis_name, axis=1, tiled=True)
    acc, m, l = _attn_stats(q, k_all, v_all, q_bits, kb_all, q_pos, kp_all,
                            softcap, window, impl)
    return _finish(acc, m, l, q.dtype)


def _ring_body(q, k, v, q_bits, kv_bits, q_pos, kv_pos, *,
               axis_name: str, softcap: float, window: int,
               impl: str = "xla"):
    """P2P ring: pass K/V chunks around, combine online-softmax stats."""
    G = lax.psum(1, axis_name)
    B, Tq, H, hd = q.shape

    def step(i, carry):
        acc, m, l, kc, vc, kb, kp = carry
        a2, m2, l2 = _attn_stats(q, kc, vc, q_bits, kb, q_pos, kp,
                                 softcap, window, impl)
        acc, m, l = _combine_stats(acc, m, l, a2, m2, l2)
        perm = [(j, (j + 1) % G) for j in range(G)]
        kc = lax.ppermute(kc, axis_name, perm)
        vc = lax.ppermute(vc, axis_name, perm)
        kb = lax.ppermute(kb, axis_name, perm)
        kp = lax.ppermute(kp, axis_name, perm)
        return acc, m, l, kc, vc, kb, kp

    acc0 = jnp.zeros((B, H, Tq, hd), jnp.float32)
    m0 = jnp.full((B, H, Tq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Tq), jnp.float32)
    acc, m, l, *_ = lax.fori_loop(
        0, G, step, (acc0, m0, l0, k, v, kv_bits, kv_pos))
    return _finish(acc, m, l, q.dtype)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def cp_attention(mesh, axis_name: str, q, k, v, q_bits, kv_bits, q_pos,
                 kv_pos, *, method: str = "allgather", softcap: float = 0.0,
                 window: int = 0, impl: str = "xla"):
    """Inputs are GLOBAL arrays already permuted to plan layout
    ([B, T, H, hd] etc.); shard_map splits the token axis over
    ``axis_name``. Output is the global [B, T, H, hd] in plan layout.

    impl: per-step attention math — "xla" (dense logits, CPU fallback)
    or "bam_kernel" / "bam_interpret" (Pallas stats kernel, no
    O(Tq·Tk) intermediate per rank). The kernel impls are FORWARD-ONLY
    (benchmarks/serving): the stats kernel has no VJP, so jax.grad
    through them fails at trace time — train through the "xla" body or
    through ops.bam_attention's fused backward instead."""
    body = {"allgather": _allgather_body, "ring": _ring_body}[method]
    fn = functools.partial(body, axis_name=axis_name, softcap=softcap,
                           window=window, impl=impl)
    tok = P(None, axis_name)
    tok3 = P(None, axis_name, None, None)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(tok3, tok3, tok3, tok, tok, tok, tok),
        out_specs=tok3, check_rep=False,
    )(q, k, v, q_bits, kv_bits, q_pos, kv_pos)


def cp_reference(q, k, v, q_bits, kv_bits, q_pos, kv_pos, *,
                 softcap: float = 0.0, window: int = 0):
    """Collective-free oracle: identical math on the full arrays."""
    mask = bam.allowed_mask(q_bits, kv_bits, q_pos, kv_pos, window)[:, None]
    scale = q.shape[-1] ** -0.5
    acc, m, l = _masked_attn_stats(q, k, v, mask, scale, softcap)
    return _finish(acc, m, l, q.dtype)


def simulate_rank_workloads(plan: Plan, bits: np.ndarray, pos: np.ndarray,
                            window: int = 0) -> np.ndarray:
    """Per-rank attention FLOPs proxy (row workload sums) used by the
    Table-4 style benchmark: the max over ranks bounds the attention
    step time under all-gather CP."""
    W = bam.token_workload(bits, pos, window)
    loads = np.zeros(plan.num_ranks)
    bs = plan.block_size
    for g, blocks in enumerate(plan.per_rank_blocks):
        for b in blocks:
            loads[g] += W[b * bs:(b + 1) * bs].sum()
    return loads
