"""Bitfield Attention Mask (BAM) — Cornstarch §4.3.1, TPU/JAX adaptation.

A full multimodal attention mask is O(T^2); BAM represents it as a 1-D
vector of per-token integer bitfields, expanded blockwise only inside the
attention computation (the Pallas kernel evaluates it in-registers; the
XLA path lets the compiler fuse it into the softmax).

Bit layout (uint32 — container JAX runs x64-disabled; the paper uses
int64 with ~60 modality bits. Semantics are identical, widening to two
lanes of uint32 or uint64 is mechanical):

    [15:0]   attends-set  A_i : bit m set => token i may attend modality m
    [22:16]  own modality m_i : 0 = text, 1..15 = encoder streams
    [30:23]  instance id  d_i : packed-document id (multimodal packing)
    value 0                  : padding token (never attends / attended)

Mask semantics (single source of truth; mirrored by kernels/ref.py and
validated against each other in tests):

    allowed(i, j) =
        bits_q[i] != 0 and bits_k[j] != 0          (non-padding)
        and d_i == d_j                             (same packed document)
        and (A_i >> m_j) & 1                       (modality-attend bit)
        and ( m_i == 0  ->  pos_j <= pos_i         (text queries: causal)
              m_i != 0  ->  m_j == m_i )           (modality: bidirectional
                                                    within own stream)

Sliding-window (gemma2 local layers) further requires
``pos_i - pos_j < window`` for text queries.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

TEXT = 0
ATTEND_BITS = 16
MOD_SHIFT = 16
MOD_BITS = 7
INST_SHIFT = 23
INST_BITS = 8

_ATTEND_MASK = (1 << ATTEND_BITS) - 1
_MOD_MASK = (1 << MOD_BITS) - 1
_INST_MASK = (1 << INST_BITS) - 1


def encode(attends: int, modality: int, instance: int = 0) -> int:
    assert 0 <= attends <= _ATTEND_MASK
    assert 0 <= modality <= _MOD_MASK
    assert 0 <= instance <= _INST_MASK
    return attends | (modality << MOD_SHIFT) | (instance << INST_SHIFT)


def text_token(attend_modalities: Sequence[int] = (), instance: int = 0) -> int:
    """A text token attends text + the given encoder modality streams."""
    a = 1 << TEXT
    for m in attend_modalities:
        a |= 1 << m
    return encode(a, TEXT, instance)


def modality_token(modality: int, instance: int = 0) -> int:
    """Encoder-output tokens attend (bidirectionally) their own stream."""
    assert modality != TEXT
    return encode(1 << modality, modality, instance)


# -- field extraction (works on jnp or np arrays) ---------------------------

def attends_set(bits):
    return bits & _ATTEND_MASK


def own_modality(bits):
    return (bits >> MOD_SHIFT) & _MOD_MASK


def instance_id(bits):
    return (bits >> INST_SHIFT) & _INST_MASK


# ---------------------------------------------------------------------------
# Mask expansion (oracle; O(Tq*Tk) — only for tests/XLA-fused paths)
# ---------------------------------------------------------------------------

def allowed_mask(q_bits, kv_bits, q_pos, kv_pos, window: int = 0):
    """Expand BAM to a boolean mask.

    q_bits: [..., Tq] uint32; kv_bits: [..., Tk]; q_pos/kv_pos: int32
    positions (global sequence positions — CP ranks hold permuted blocks,
    so positions are explicit, not iota).
    Returns bool [..., Tq, Tk].
    """
    qb = q_bits[..., :, None].astype(jnp.uint32)
    kb = kv_bits[..., None, :].astype(jnp.uint32)
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]

    nonpad = (qb != 0) & (kb != 0)
    same_doc = instance_id(qb) == instance_id(kb)
    bit_ok = ((attends_set(qb) >> own_modality(kb)) & 1) != 0
    q_is_text = own_modality(qb) == TEXT
    causal = kp <= qp
    if window:
        causal &= (qp - kp) < window
    within = own_modality(kb) == own_modality(qb)
    rule = jnp.where(q_is_text, causal, within)
    return nonpad & same_doc & bit_ok & rule


def causal_bits(batch: int, seq: int, dtype=jnp.uint32):
    """Degenerate BAM for a pure-text causal LM (paper §4.3.1: causal is
    the 1-D special case)."""
    return jnp.full((batch, seq), text_token(), dtype)


# ---------------------------------------------------------------------------
# Per-token workload (row-sums of the mask) — O(T * M) via per-modality
# cumulative counts, no O(T^2) materialization. Used by the token
# distribution planners (§4.3.2).
# ---------------------------------------------------------------------------

def token_workload(bits: np.ndarray, pos: np.ndarray,
                   window: int = 0) -> np.ndarray:
    """bits/pos: [T] (numpy, host-side planning). Returns float64 [T]:
    W_i = number of keys token i attends = row-sum of allowed_mask."""
    bits = np.asarray(bits, np.uint32)
    pos = np.asarray(pos, np.int64)
    T = bits.shape[0]
    order = np.argsort(pos, kind="stable")
    inv = np.empty_like(order)
    inv[order] = np.arange(T)

    mod = (bits >> MOD_SHIFT) & _MOD_MASK
    inst = (bits >> INST_SHIFT) & _INST_MASK
    att = bits & _ATTEND_MASK
    nonpad = bits != 0

    W = np.zeros(T, np.float64)
    for d in np.unique(inst[nonpad]):
        sel = nonpad & (inst == d)
        idx = np.where(sel)[0]
        idx = idx[np.argsort(pos[idx], kind="stable")]
        m = mod[idx]
        a = att[idx]
        n = idx.shape[0]
        # cumulative count of keys of each modality up to (and incl) position
        mods_here = np.unique(m)
        cum = {mm: np.cumsum(m == mm) for mm in mods_here}
        total = {mm: int((m == mm).sum()) for mm in mods_here}
        w = np.zeros(n, np.float64)
        text_rows = m == TEXT
        for mm in mods_here:
            bit_ok = ((a >> int(mm)) & 1) != 0
            # text queries: causal count of modality-mm keys <= my position
            w += np.where(text_rows & bit_ok, cum[mm], 0.0)
            # modality queries: bidirectional within own stream only
            if mm != TEXT:
                w += np.where((m == mm) & bit_ok, float(total[mm]), 0.0)
        if window:
            # subtract out-of-window causal keys for text rows (approx:
            # window only used with pure-text local layers)
            w_uncapped = w
            w = np.where(text_rows, np.minimum(w_uncapped, window), w)
        W[idx] = w
    return W


def block_workload(bits: np.ndarray, pos: np.ndarray, block: int,
                   window: int = 0) -> np.ndarray:
    """Sum token workloads over contiguous blocks of ``block`` tokens
    (paper: assignment is done at block granularity for accelerator
    efficiency)."""
    W = token_workload(bits, pos, window)
    T = W.shape[0]
    nb = (T + block - 1) // block
    padded = np.zeros(nb * block, np.float64)
    padded[:T] = W
    return padded.reshape(nb, block).sum(axis=1)


# ---------------------------------------------------------------------------
# BAM construction for the synthetic multimodal batches (EP / EE / MP —
# paper Fig. 11 mask types)
# ---------------------------------------------------------------------------

def build_sample_bits(segments: Sequence[Tuple[str, int, int]],
                      seq_len: int) -> Tuple[np.ndarray, np.ndarray]:
    """segments: list of (kind, modality_id, length); kind in
    {"text", "mod"}; instance id increments on a "doc" boundary marker
    ("newdoc", 0, 0). Returns (bits [T] uint32, pos [T] int32), padded
    with zeros to seq_len."""
    bits, pos = [], []
    inst = 0
    p = 0
    seen_mods: set[int] = set()
    for kind, m, n in segments:
        if kind == "newdoc":
            inst += 1
            p = 0
            seen_mods = set()
            continue
        if kind == "mod":
            seen_mods.add(m)
            for _ in range(n):
                bits.append(modality_token(m, inst))
                pos.append(p)
                p += 1
        else:
            for _ in range(n):
                bits.append(text_token(sorted(seen_mods), inst))
                pos.append(p)
                p += 1
    assert len(bits) <= seq_len, (len(bits), seq_len)
    out_b = np.zeros(seq_len, np.uint32)
    out_p = np.zeros(seq_len, np.int32)
    out_b[: len(bits)] = bits
    out_p[: len(pos)] = pos
    return out_b, out_p
