"""Bitfield Attention Mask (BAM) — Cornstarch §4.3.1, TPU/JAX adaptation.

A full multimodal attention mask is O(T^2); BAM represents it as a 1-D
vector of per-token integer bitfields, expanded blockwise only inside the
attention computation (the Pallas kernel evaluates it in-registers; the
XLA path lets the compiler fuse it into the softmax).

Bit layout (uint32 — container JAX runs x64-disabled; the paper uses
int64 with ~60 modality bits. Semantics are identical, widening to two
lanes of uint32 or uint64 is mechanical):

    [15:0]   attends-set  A_i : bit m set => token i may attend modality m
    [22:16]  own modality m_i : 0 = text, 1..15 = encoder streams
    [30:23]  instance id  d_i : packed-document id (multimodal packing)
    value 0                  : padding token (never attends / attended)

Mask semantics (single source of truth; mirrored by kernels/ref.py and
validated against each other in tests):

    allowed(i, j) =
        bits_q[i] != 0 and bits_k[j] != 0          (non-padding)
        and d_i == d_j                             (same packed document)
        and (A_i >> m_j) & 1                       (modality-attend bit)
        and ( m_i == 0  ->  pos_j <= pos_i         (text queries: causal)
              m_i != 0  ->  m_j == m_i )           (modality: bidirectional
                                                    within own stream)

Sliding-window (gemma2 local layers) further requires
``pos_i - pos_j < window`` for text queries.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

TEXT = 0
ATTEND_BITS = 16
MOD_SHIFT = 16
MOD_BITS = 7
INST_SHIFT = 23
INST_BITS = 8

_ATTEND_MASK = (1 << ATTEND_BITS) - 1
_MOD_MASK = (1 << MOD_BITS) - 1
_INST_MASK = (1 << INST_BITS) - 1


def encode(attends: int, modality: int, instance: int = 0) -> int:
    assert 0 <= attends <= _ATTEND_MASK
    assert 0 <= modality <= _MOD_MASK
    assert 0 <= instance <= _INST_MASK
    return attends | (modality << MOD_SHIFT) | (instance << INST_SHIFT)


def text_token(attend_modalities: Sequence[int] = (), instance: int = 0) -> int:
    """A text token attends text + the given encoder modality streams."""
    a = 1 << TEXT
    for m in attend_modalities:
        a |= 1 << m
    return encode(a, TEXT, instance)


def modality_token(modality: int, instance: int = 0) -> int:
    """Encoder-output tokens attend (bidirectionally) their own stream."""
    assert modality != TEXT
    return encode(1 << modality, modality, instance)


# -- field extraction (works on jnp or np arrays) ---------------------------

def attends_set(bits):
    return bits & _ATTEND_MASK


def own_modality(bits):
    return (bits >> MOD_SHIFT) & _MOD_MASK


def instance_id(bits):
    return (bits >> INST_SHIFT) & _INST_MASK


# ---------------------------------------------------------------------------
# Mask expansion (oracle; O(Tq*Tk) — only for tests/XLA-fused paths)
# ---------------------------------------------------------------------------

def allowed_mask(q_bits, kv_bits, q_pos, kv_pos, window: int = 0):
    """Expand BAM to a boolean mask.

    q_bits: [..., Tq] uint32; kv_bits: [..., Tk]; q_pos/kv_pos: int32
    positions (global sequence positions — CP ranks hold permuted blocks,
    so positions are explicit, not iota).
    Returns bool [..., Tq, Tk].
    """
    qb = q_bits[..., :, None].astype(jnp.uint32)
    kb = kv_bits[..., None, :].astype(jnp.uint32)
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]

    nonpad = (qb != 0) & (kb != 0)
    same_doc = instance_id(qb) == instance_id(kb)
    bit_ok = ((attends_set(qb) >> own_modality(kb)) & 1) != 0
    q_is_text = own_modality(qb) == TEXT
    causal = kp <= qp
    if window:
        causal &= (qp - kp) < window
    within = own_modality(kb) == own_modality(qb)
    rule = jnp.where(q_is_text, causal, within)
    return nonpad & same_doc & bit_ok & rule


def causal_bits(batch: int, seq: int, dtype=jnp.uint32):
    """Degenerate BAM for a pure-text causal LM (paper §4.3.1: causal is
    the 1-D special case)."""
    return jnp.full((batch, seq), text_token(), dtype)


def repeat_kv(k, n_rep: int):
    """GQA head expansion [B, T, Hkv, hd] -> [B, T, Hkv*n_rep, hd] —
    the dense-path pairing of the kernel's index-map head fold (shared
    by models.layers and the CP XLA bodies)."""
    if n_rep == 1:
        return k
    b, t, h, d = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, t, h, n_rep, d))
    return k.reshape(b, t, h * n_rep, d)


# ---------------------------------------------------------------------------
# Per-token workload (row-sums of the mask) — O(T * M) via per-modality
# cumulative counts, no O(T^2) materialization. Used by the token
# distribution planners (§4.3.2).
# ---------------------------------------------------------------------------

def token_workload(bits: np.ndarray, pos: np.ndarray,
                   window: int = 0) -> np.ndarray:
    """bits/pos: [T] (numpy, host-side planning). Returns float64 [T]:
    W_i = number of keys token i attends = row-sum of allowed_mask."""
    bits = np.asarray(bits, np.uint32)
    pos = np.asarray(pos, np.int64)
    T = bits.shape[0]
    order = np.argsort(pos, kind="stable")
    inv = np.empty_like(order)
    inv[order] = np.arange(T)

    mod = (bits >> MOD_SHIFT) & _MOD_MASK
    inst = (bits >> INST_SHIFT) & _INST_MASK
    att = bits & _ATTEND_MASK
    nonpad = bits != 0

    W = np.zeros(T, np.float64)
    for d in np.unique(inst[nonpad]):
        sel = nonpad & (inst == d)
        idx = np.where(sel)[0]
        idx = idx[np.argsort(pos[idx], kind="stable")]
        m = mod[idx]
        a = att[idx]
        p = pos[idx]
        n = idx.shape[0]
        mods_here = np.unique(m)
        total = {mm: int((m == mm).sum()) for mm in mods_here}
        w = np.zeros(n, np.float64)
        text_rows = m == TEXT
        for mm in mods_here:
            bit_ok = ((a >> int(mm)) & 1) != 0
            # text queries: count of modality-mm keys with
            # pos_i - window < pos_j <= pos_i (exact per modality — a
            # single min(total, window) clamp would over-subtract for
            # text rows that also attend modality keys)
            pos_mm = p[m == mm]          # ascending (p is sorted)
            hi = np.searchsorted(pos_mm, p, side="right")
            if window:
                lo = np.searchsorted(pos_mm, p - window, side="right")
            else:
                lo = 0
            w += np.where(text_rows & bit_ok, hi - lo, 0.0)
            # modality queries: bidirectional within own stream only
            # (window constrains text queries only, matching allowed_mask)
            if mm != TEXT:
                w += np.where((m == mm) & bit_ok, float(total[mm]), 0.0)
        W[idx] = w
    return W


def block_workload(bits: np.ndarray, pos: np.ndarray, block: int,
                   window: int = 0) -> np.ndarray:
    """Sum token workloads over contiguous blocks of ``block`` tokens
    (paper: assignment is done at block granularity for accelerator
    efficiency)."""
    W = token_workload(bits, pos, window)
    T = W.shape[0]
    nb = (T + block - 1) // block
    padded = np.zeros(nb * block, np.float64)
    padded[:T] = W
    return padded.reshape(nb, block).sum(axis=1)


# ---------------------------------------------------------------------------
# Host-side kernel grid compaction: from the block-level reduction of the
# bitfield mask, a flattened list of active (q-block, k-block) tiles that
# drives the Pallas kernel through a scalar-prefetch index map. Fully
# masked tiles are dropped from the grid itself — they cost neither a
# grid step nor a K/V DMA (the in-kernel `pl.when` skip only saves the
# MXU work, not the copies).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockMask:
    """Compacted kernel grid for one (bits, pos) mask instance.

    Two flattened orderings of the active tiles, both as tuples of
    python ints so the object is hashable (it rides through
    ``jax.custom_vjp`` as a static argument):

    * q-major (forward + dQ backward): tiles sorted by q-block, each
      q-block's active k-blocks consecutive. ``first``/``last`` flag the
      accumulator init/flush steps; a q-block with NO active tile still
      gets one step with ``active == 0`` so its output rows are written
      (as zeros) exactly once.
    * k-major (dK/dV backward): same construction transposed.
    """
    block_q: int
    block_k: int
    nq: int
    nk: int
    window: int
    q_steps: Tuple[Tuple[int, int, int, int, int], ...]  # (iq, ik, first, last, active)
    k_steps: Tuple[Tuple[int, int, int, int, int], ...]

    @property
    def n_steps(self) -> int:
        return len(self.q_steps)

    @property
    def n_dense_steps(self) -> int:
        return self.nq * self.nk

    @property
    def skip_fraction(self) -> float:
        active = sum(s[4] for s in self.q_steps)
        return 1.0 - active / max(self.n_dense_steps, 1)

    def arrays(self, major: str = "q"):
        """(q_block, k_block, first, last, active) int32 arrays for the
        scalar-prefetch operands."""
        steps = self.q_steps if major == "q" else self.k_steps
        cols = np.asarray(steps, np.int32).reshape(len(steps), 5)
        return tuple(np.ascontiguousarray(cols[:, j]) for j in range(5))


def _flatten_active(active: np.ndarray) -> Tuple[Tuple[int, ...], ...]:
    """active: [n_major, n_minor] bool -> q-major flattened step tuples."""
    steps = []
    for i in range(active.shape[0]):
        js = np.flatnonzero(active[i])
        if js.size == 0:
            steps.append((i, 0, 1, 1, 0))
            continue
        for t, j in enumerate(js):
            steps.append((i, int(j), int(t == 0), int(t == js.size - 1), 1))
    return tuple(steps)


def build_block_map(q_bits, kv_bits, q_pos, kv_pos, block_q: int,
                    block_k: int, window: int = 0) -> BlockMask:
    """Block-level reduction of the bitfield mask (host side, numpy).

    Accepts [T] or [B, T] arrays; a tile is active if ANY batch row has
    any allowed (q, k) pair inside it, so one map is valid for the whole
    batch. Sequences are padded to block multiples with bits=0 (never
    attends — identical to the kernel wrapper's padding)."""
    q_bits = np.atleast_2d(np.asarray(q_bits, np.uint32))
    kv_bits = np.atleast_2d(np.asarray(kv_bits, np.uint32))
    q_pos = np.atleast_2d(np.asarray(q_pos, np.int64))
    kv_pos = np.atleast_2d(np.asarray(kv_pos, np.int64))
    Tq, Tk = q_bits.shape[1], kv_bits.shape[1]
    nq = -(-Tq // block_q)
    nk = -(-Tk // block_k)

    def _pad(x, to, value=0):
        pad = to - x.shape[1]
        if pad:
            x = np.pad(x, ((0, 0), (0, pad)), constant_values=value)
        return x

    qb = _pad(q_bits, nq * block_q)
    kb = _pad(kv_bits, nk * block_k)
    qp = _pad(q_pos, nq * block_q, -1)
    kp = _pad(kv_pos, nk * block_k, -1)
    # reduce strip-by-strip: peak host memory O(B·block_q·Tk), never the
    # full O(Tq·Tk) mask — at the long-context scale this feature
    # targets, materializing the dense mask would be the very blow-up
    # the compacted grid exists to avoid
    active = np.zeros((nq, nk), bool)
    for iq in range(nq):
        s = slice(iq * block_q, (iq + 1) * block_q)
        strip = np.asarray(allowed_mask(qb[:, s], kb, qp[:, s], kp, window))
        active[iq] = strip.reshape(-1, block_q, nk, block_k).any(
            axis=(0, 1, 3))
    return BlockMask(block_q=block_q, block_k=block_k, nq=nq, nk=nk,
                     window=window,
                     q_steps=_flatten_active(active),
                     k_steps=tuple((i, j, f, l, a) for (j, i, f, l, a)
                                   in _flatten_active(active.T)))


# ---------------------------------------------------------------------------
# BAM construction for the synthetic multimodal batches (EP / EE / MP —
# paper Fig. 11 mask types)
# ---------------------------------------------------------------------------

def build_sample_bits(segments: Sequence[Tuple[str, int, int]],
                      seq_len: int) -> Tuple[np.ndarray, np.ndarray]:
    """segments: list of (kind, modality_id, length); kind in
    {"text", "mod"}; instance id increments on a "doc" boundary marker
    ("newdoc", 0, 0). Returns (bits [T] uint32, pos [T] int32), padded
    with zeros to seq_len."""
    bits, pos = [], []
    inst = 0
    p = 0
    seen_mods: set[int] = set()
    for kind, m, n in segments:
        if kind == "newdoc":
            inst += 1
            p = 0
            seen_mods = set()
            continue
        if kind == "mod":
            seen_mods.add(m)
            for _ in range(n):
                bits.append(modality_token(m, inst))
                pos.append(p)
                p += 1
        else:
            for _ in range(n):
                bits.append(text_token(sorted(seen_mods), inst))
                pos.append(p)
                p += 1
    assert len(bits) <= seq_len, (len(bits), seq_len)
    out_b = np.zeros(seq_len, np.uint32)
    out_p = np.zeros(seq_len, np.int32)
    out_b[: len(bits)] = bits
    out_p[: len(pos)] = pos
    return out_b, out_p
