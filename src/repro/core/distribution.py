"""Workload-balanced token distribution for multimodal context
parallelism (Cornstarch §4.3.2 + §5.3, Appendix A).

Tokens are assigned to CP ranks at *block* granularity (contiguous
``block_size`` tokens share a destination — accelerator-friendly, paper:
"distributing 1 million tokens with 128 block size ... within 1 ms").
Per-block workload = row-sums of the BAM mask (repro.core.bam).

Planners (all return a ``Plan``):
  * ``zigzag``   — Llama-3/Megatron causal balancing (baseline; paper
                   Fig. 4a): rank i gets blocks i and 2G-1-i, repeating.
  * ``ring``     — naive contiguous split (ring-attention baseline).
  * ``lpt``      — greedy Longest-Processing-Time-First (Algorithm 2):
                   sort blocks by workload desc, assign to min-loaded
                   rank (heap). Makespan ≤ Σw/G + w_max (Graham 1969).
  * ``random``   — uniform random block assignment (§5.3; Chernoff-
                   bounded imbalance for T >> G²).
  * ``ilp``      — exact branch-and-bound makespan minimization (the
                   §4.3.2 ILP), tractable for small instances; used in
                   tests to certify LPT's bound.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Plan:
    """Block -> rank assignment.

    assignment: [num_blocks] int rank id per block.
    per_rank_blocks: list (len G) of block-index arrays, each sorted.
    loads: [G] total workload per rank.
    """
    assignment: np.ndarray
    block_size: int
    num_ranks: int
    loads: np.ndarray

    @property
    def per_rank_blocks(self) -> List[np.ndarray]:
        return [np.where(self.assignment == g)[0]
                for g in range(self.num_ranks)]

    @property
    def makespan(self) -> float:
        return float(self.loads.max())

    @property
    def imbalance(self) -> float:
        """max/mean load (1.0 = perfect)."""
        mean = self.loads.mean()
        return float(self.loads.max() / mean) if mean > 0 else 1.0

    def rank_token_slices(self, tokens_per_block: Optional[int] = None):
        bs = tokens_per_block or self.block_size
        return [np.concatenate([np.arange(b * bs, (b + 1) * bs)
                                for b in blocks]) if len(blocks) else
                np.zeros((0,), np.int64)
                for blocks in self.per_rank_blocks]


def _finalize(assignment, W, block_size, G) -> Plan:
    loads = np.zeros(G, np.float64)
    np.add.at(loads, assignment, W)
    return Plan(assignment=assignment.astype(np.int32),
                block_size=block_size, num_ranks=G, loads=loads)


# ---------------------------------------------------------------------------
# Planners
# ---------------------------------------------------------------------------

def zigzag(W: np.ndarray, G: int, block_size: int = 128) -> Plan:
    """Blocks paired (i, 2G-1-i) per group of 2G (paper Fig. 4a)."""
    nb = len(W)
    assignment = np.zeros(nb, np.int64)
    pattern = np.concatenate([np.arange(G), np.arange(G)[::-1]])
    for i in range(nb):
        assignment[i] = pattern[i % (2 * G)]
    return _finalize(assignment, W, block_size, G)


def ring(W: np.ndarray, G: int, block_size: int = 128) -> Plan:
    """Contiguous equal-count split (naive ring attention)."""
    nb = len(W)
    assignment = np.minimum(np.arange(nb) * G // max(nb, 1), G - 1)
    return _finalize(assignment, W, block_size, G)


def lpt(W: np.ndarray, G: int, block_size: int = 128) -> Plan:
    """Greedy LPT (Algorithm 2): O(nb (log nb + log G))."""
    order = np.argsort(-W, kind="stable")
    assignment = np.zeros(len(W), np.int64)
    heap = [(0.0, g) for g in range(G)]
    heapq.heapify(heap)
    for b in order:
        load, g = heapq.heappop(heap)
        assignment[b] = g
        heapq.heappush(heap, (load + float(W[b]), g))
    return _finalize(assignment, W, block_size, G)


def random_plan(W: np.ndarray, G: int, block_size: int = 128,
                seed: int = 0) -> Plan:
    rng = np.random.default_rng(seed)
    assignment = rng.integers(0, G, size=len(W))
    return _finalize(assignment, W, block_size, G)


def ilp(W: np.ndarray, G: int, block_size: int = 128,
        node_limit: int = 2_000_000) -> Plan:
    """Exact makespan minimization by branch-and-bound (the paper's ILP
    — intractable live, used offline/tests). Blocks in descending order;
    prune with (Σremaining)/G lower bound and incumbent."""
    W = np.asarray(W, np.float64)
    nb = len(W)
    order = np.argsort(-W, kind="stable")
    Ws = W[order]
    suffix = np.concatenate([np.cumsum(Ws[::-1])[::-1], [0.0]])

    best_plan = lpt(W, G, block_size)
    best = best_plan.makespan
    best_assign = best_plan.assignment[order].copy()

    loads = np.zeros(G, np.float64)
    assign = np.zeros(nb, np.int64)
    nodes = 0

    def rec(i):
        nonlocal best, best_assign, nodes
        nodes += 1
        if nodes > node_limit:
            return
        if i == nb:
            m = loads.max()
            if m < best - 1e-12:
                best = m
                best_assign = assign.copy()
            return
        lb = max(loads.max(), (loads.sum() + suffix[i]) / G)
        if lb >= best - 1e-12:
            return
        tried = set()
        for g in np.argsort(loads, kind="stable"):
            key = round(loads[g], 9)
            if key in tried:   # symmetric ranks
                continue
            tried.add(key)
            if loads[g] + Ws[i] >= best - 1e-12:
                continue
            loads[g] += Ws[i]
            assign[i] = g
            rec(i + 1)
            loads[g] -= Ws[i]

    rec(0)
    final = np.zeros(nb, np.int64)
    final[order] = best_assign
    return _finalize(final, W, block_size, G)


PLANNERS = {"zigzag": zigzag, "ring": ring, "lpt": lpt,
            "random": random_plan, "ilp": ilp}


def plan_tokens(bits: np.ndarray, pos: np.ndarray, G: int,
                block_size: int = 128, method: str = "lpt",
                window: int = 0, **kw) -> Plan:
    """End-to-end: BAM bitfields -> block workloads -> plan."""
    from repro.core.bam import block_workload
    W = block_workload(bits, pos, block_size, window)
    return PLANNERS[method](W, G, block_size, **kw)


def graham_bound(W: np.ndarray, G: int) -> float:
    """LPT worst-case makespan bound: Σw/G + w_max (paper §4.3.2)."""
    return float(W.sum() / G + W.max())
