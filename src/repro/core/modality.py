"""Modular MLLM construction (Cornstarch §3.2): ModalityModule,
MultimodalModule, ParallelSpec, execution DAG, callback interface.

JAX adaptation of the paper's programming model (Listing 1/2):

    vis   = ModalityModule("vision", vis_cfg, modality_id=1, proj="mlp")
    audio = ModalityModule("audio", audio_cfg, modality_id=2)
    mllm  = MultimodalModule(encoders={...}, llm=llm_cfg)
    mllm.freeze("vision", module=True, projector=False)
    params = mllm.init(key)
    logits, aux = mllm.forward(params, batch)          # single-program
    spec  = MultimodalParallelSpec(encoder_specs=..., llm_spec=...)
    plan  = spec.apply(mllm)                           # -> pipeline plan

The execution graph is explicit (networkx DiGraph) and is constructed
only from true data flow — no false dependencies between encoders
(paper C1). The frozen flags feed the frozen-aware partitioner
(core/pipeline.py) and the gradient masking in optim/.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import networkx as nx

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import bam, pipeline as pp
from repro.models import layers as Lyr
from repro.models import transformer as T

Callback = Callable[..., Any]


# ---------------------------------------------------------------------------
# ModalityModule
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ModalityModule:
    """One unimodal model + its projector into the LLM embedding space.

    The modality *frontend* (conv codec / ViT patcher) is stubbed per
    DESIGN.md — the module consumes precomputed frame/patch embeddings
    and runs the transformer backbone + projector.
    """
    name: str
    cfg: ModelConfig
    modality_id: int                      # BAM bit (1..15; 0 = text)
    projector: str = "linear"             # linear | mlp
    num_tokens: int = 0                   # tokens this encoder emits
    frozen_module: bool = True
    frozen_projector: bool = False
    preprocess_callback: Optional[Callback] = None
    postprocess_module_callback: Optional[Callback] = None
    postprocess_projector_callback: Optional[Callback] = None

    # -- params ------------------------------------------------------------
    def init(self, key, llm_d_model: int):
        from repro.models import mllm as M
        k1, k2 = jax.random.split(key)
        dtype = jnp.dtype(self.cfg.dtype)
        p = {"module": M.encoder_init(k1, self.cfg)}
        d = self.cfg.d_model
        if self.projector == "mlp":
            p["projector"] = {
                "w1": Lyr.dense_init(k2, d, llm_d_model, dtype),
                "w2": Lyr.dense_init(jax.random.fold_in(k2, 1),
                                     llm_d_model, llm_d_model, dtype),
            }
        else:
            p["projector"] = {
                "w1": Lyr.dense_init(k2, d, llm_d_model, dtype)}
        return p

    # -- forward -----------------------------------------------------------
    def forward(self, params, inputs):
        """inputs: dict with f"{name}_embeds" [B, T_m, d_m]. Applies the
        call order of Listing 2: cb_before -> module -> cb_after ->
        projector -> cb_after_proj. Frozen parts run under
        stop_gradient so backward truly skips them (paper §4.2)."""
        from repro.models import mllm as M
        if self.preprocess_callback:
            inputs = self.preprocess_callback(inputs)
        embeds = inputs[f"{self.name}_embeds"]
        mod_p = params["module"]
        if self.frozen_module:
            mod_p = jax.tree.map(jax.lax.stop_gradient, mod_p)
        out = M.encoder_forward(mod_p, self.cfg, embeds)
        if self.postprocess_module_callback:
            out = self.postprocess_module_callback(inputs, out)
        proj_p = params["projector"]
        if self.frozen_projector:
            proj_p = jax.tree.map(jax.lax.stop_gradient, proj_p)
        out = out @ proj_p["w1"]
        if "w2" in proj_p:
            out = jax.nn.gelu(out) @ proj_p["w2"]
        if self.postprocess_projector_callback:
            out = self.postprocess_projector_callback(inputs, out)
        return out

    # -- cost profile for the partitioner -----------------------------------
    def profile(self, seq_tokens: int, batch: int = 1,
                recompute: bool = False) -> pp.ModuleProfile:
        prof = pp.profile_from_config(
            self.cfg, seq_tokens or self.num_tokens, batch=batch,
            frozen=self.frozen_module, recompute=recompute, name=self.name)
        return prof


# ---------------------------------------------------------------------------
# MultimodalModule
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MultimodalModule:
    encoders: Dict[str, ModalityModule]
    llm_cfg: ModelConfig
    frozen_llm: bool = True
    # merge policy: list of segments ("text", n) | (encoder_name,)
    layout: Optional[List[Tuple]] = None
    preprocess_callback: Optional[Callback] = None   # cb_before_llm

    def __post_init__(self):
        ids = [e.modality_id for e in self.encoders.values()]
        assert len(set(ids)) == len(ids) and 0 not in ids, \
            "modality ids must be unique and nonzero"

    # -- execution DAG (paper §3.2) -----------------------------------------
    def execution_graph(self) -> nx.DiGraph:
        g = nx.DiGraph()
        for name in self.encoders:
            g.add_node(name, kind="encoder")
        g.add_node("llm", kind="llm")
        for name in self.encoders:
            g.add_edge(name, "llm")   # only true data flow — no false deps
        assert nx.is_directed_acyclic_graph(g)
        return g

    def independent_sets(self) -> List[List[str]]:
        """Antichains of the DAG = groups executable in parallel
        (modality parallelism, §4.1)."""
        g = self.execution_graph()
        order = list(nx.topological_generations(g))
        return [sorted(gen) for gen in order]

    # -- freezing ------------------------------------------------------------
    def freeze(self, name: str, *, module: Optional[bool] = None,
               projector: Optional[bool] = None):
        if name == "llm":
            assert module is not None
            self.frozen_llm = module
            return
        e = self.encoders[name]
        if module is not None:
            e.frozen_module = module
        if projector is not None:
            e.frozen_projector = projector

    # -- params ---------------------------------------------------------------
    def init(self, key):
        keys = jax.random.split(key, len(self.encoders) + 1)
        params = {"encoders": {}}
        for k, (name, enc) in zip(keys, sorted(self.encoders.items())):
            params["encoders"][name] = enc.init(k, self.llm_cfg.d_model)
        params["llm"] = T.init(keys[-1], self.llm_cfg)
        return params

    def frozen_mask(self, params):
        """Pytree of bools: True = frozen (no optimizer update)."""
        mask = {"encoders": {}}
        for name, enc in self.encoders.items():
            mask["encoders"][name] = {
                "module": jax.tree.map(lambda _: enc.frozen_module,
                                       params["encoders"][name]["module"]),
                "projector": jax.tree.map(
                    lambda _: enc.frozen_projector,
                    params["encoders"][name]["projector"]),
            }
        mask["llm"] = jax.tree.map(lambda _: self.frozen_llm, params["llm"])
        return mask

    # -- batch merge (cb_before_llm default policy) ---------------------------
    def default_layout(self, text_len: int) -> List[Tuple]:
        """EE-style: text prefix, then each encoder stream, then the
        remaining text (encoder outputs embedded, Fig. 11b)."""
        n_enc = len(self.encoders)
        pre = max(text_len // (n_enc + 1), 1)
        lay: List[Tuple] = [("text", pre)]
        rest = text_len - pre
        for name in sorted(self.encoders):
            lay.append((name,))
            seg = max(rest // n_enc, 0)
            lay.append(("text", seg))
        used = sum(s[1] for s in lay if s[0] == "text")
        if used < text_len:
            lay.append(("text", text_len - used))
        return lay

    def merged_length(self, text_len: int) -> int:
        return text_len + sum(e.num_tokens for e in self.encoders.values())

    def build_merge(self, text_tokens, enc_outputs: Dict[str, Any],
                    layout: Optional[List[Tuple]] = None):
        """Merge text tokens + projected encoder outputs into one
        sequence; returns a transformer batch (inputs_embeds path) with
        BAM bits and positions. Pure host logic for segment offsets
        (static layout), jnp for tensors."""
        import numpy as np
        B, Tt = text_tokens.shape
        layout = layout or self.layout or self.default_layout(Tt)
        total = self.merged_length(Tt)
        d = self.llm_cfg.d_model

        segs = []
        t_used = 0
        for seg in layout:
            if seg[0] == "text":
                segs.append(("text", 0, seg[1]))
                t_used += seg[1]
            else:
                enc = self.encoders[seg[0]]
                segs.append(("mod", enc.modality_id, enc.num_tokens))
        assert t_used == Tt, (t_used, Tt)
        bits_np, pos_np = bam.build_sample_bits(segs, total)
        bits = jnp.broadcast_to(jnp.asarray(bits_np)[None], (B, total))
        positions = jnp.broadcast_to(jnp.asarray(pos_np)[None], (B, total))

        # scatter maps
        tok_full = jnp.zeros((B, total), text_tokens.dtype)
        embeds = jnp.zeros((B, total, d),
                           jnp.dtype(self.llm_cfg.dtype))
        emask_np = np.zeros((total,), bool)
        off, t_off = 0, 0
        for seg in layout:
            if seg[0] == "text":
                n = seg[1]
                tok_full = jax.lax.dynamic_update_slice(
                    tok_full, jax.lax.dynamic_slice(
                        text_tokens, (0, t_off), (B, n)), (0, off))
                t_off += n
            else:
                enc = self.encoders[seg[0]]
                n = enc.num_tokens
                embeds = jax.lax.dynamic_update_slice(
                    embeds, enc_outputs[seg[0]].astype(embeds.dtype),
                    (0, off, 0))
                emask_np[off:off + n] = True
            off += n
        embed_mask = jnp.broadcast_to(jnp.asarray(emask_np)[None],
                                      (B, total))
        return {"tokens": tok_full, "positions": positions, "bits": bits,
                "inputs_embeds": embeds, "embed_mask": embed_mask}

    # -- single-program forward (reference; pipelined execution lives in
    #    core/modality_parallel.py) -----------------------------------------
    def forward(self, params, batch):
        enc_out = {}
        for name, enc in sorted(self.encoders.items()):
            enc_out[name] = enc.forward(params["encoders"][name], batch)
        merged = self.build_merge(batch["text_tokens"], enc_out)
        if self.preprocess_callback:
            merged = self.preprocess_callback(enc_out, merged)
        llm_p = params["llm"]
        if self.frozen_llm:
            llm_p = jax.tree.map(jax.lax.stop_gradient, llm_p)
        return T.forward(llm_p, self.llm_cfg, merged), merged

    # -- profiles for the partitioner ----------------------------------------
    def profiles(self, text_len: int, batch: int = 1,
                 recompute: bool = False):
        encs = []
        for name, enc in sorted(self.encoders.items()):
            encs.append(enc.profile(enc.num_tokens, batch, recompute))
        merged = self.merged_length(text_len)
        llm = pp.profile_from_config(self.llm_cfg, merged, batch=batch,
                                     frozen=self.frozen_llm,
                                     recompute=recompute, name="llm")
        # forward-order chain: encoders (parallel) then llm; a trainable
        # projector after encoder => llm must compute input grads
        any_trainable_proj = any(not e.frozen_projector
                                 for e in self.encoders.values())
        for e, enc in zip(encs, sorted(self.encoders.values(),
                                       key=lambda x: x.name)):
            e.trainable_upstream = False
        llm.trainable_upstream = any_trainable_proj or \
            any(not e.frozen_module for e in self.encoders.values())
        return encs, llm


# ---------------------------------------------------------------------------
# Parallelism specs (paper §3.2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParallelSpec:
    tp_size: int = 1
    cp_size: int = 1
    pp_size: int = 1

    @property
    def devices(self) -> int:
        return self.tp_size * self.cp_size * self.pp_size


@dataclasses.dataclass
class MultimodalParallelSpec:
    encoder_specs: Dict[str, ParallelSpec]
    llm_spec: ParallelSpec
    num_microbatches: int = 8
    microbatch_size: int = 1
    frozen_aware: bool = True
    schedule: str = "1f1b"   # "1f1b" | "interleaved" | "zb-h1" | "zb-v"
    # interleaved's virtual-chunk search: an int ceiling (try v..1) or
    # an explicit candidate tuple; zb-v always searches {2, 1}
    virtual_chunks: Any = 2

    def apply(self, mllm: MultimodalModule, text_len: int = 1024) -> dict:
        """Build the pipeline plan: per-module stage partitions (using
        the frozen-aware rule) + the modality-parallel graph + its
        simulated schedule (any core.schedule scheduler). The shard_map
        executor (core/modality_parallel.py) consumes plan["graph"],
        which always has one stage per simulated device — chunked
        schedules keep their v-times finer simulation for bubble
        accounting but fold the executor graph back to the planned
        partition.

        Superseded by ``repro.parallel``: ``parallelize()`` searches
        the allocation instead of taking it as given, and
        ``MLLMParallelPlan.apply`` replays a recorded plan — both
        share this method's fold-back construction
        (``repro.parallel.build_executor_plan``)."""
        from repro.parallel.plan import build_executor_plan
        assert set(self.encoder_specs) == set(mllm.encoders)
        encs, llm = mllm.profiles(text_len, batch=self.microbatch_size)
        enc_counts = [self.encoder_specs[e.name].pp_size for e in encs]
        out = build_executor_plan(
            encs, llm, enc_counts, self.llm_spec.pp_size,
            self.num_microbatches, schedule=self.schedule,
            virtual_chunks=self.virtual_chunks,
            frozen_aware=self.frozen_aware)
        # legacy accounting: tp x cp x pp of every spec, not just the
        # simulated pipeline ranks
        out["devices"] = sum(s.devices
                             for s in self.encoder_specs.values()) \
            + self.llm_spec.devices
        return out
