"""Frozen-status-aware pipeline parallelism (Cornstarch §4.2, Alg. 1)
+ a deterministic 1F1B schedule simulator.

The paper's key observation: the rule of thumb "backward ≈ 2× forward"
breaks for MLLMs with frozen constituents. The corrected per-module rule

    T_bwd = 0·T_fwd   frozen, no trainable module upstream (forward order)
            1·T_fwd   frozen, trainable module upstream (input grads only)
            2·T_fwd   trainable
    (+1·T_fwd recompute when activation checkpointing is on AND the
     module has gradients to compute)

drives stage partitioning: balance **fwd+bwd** per stage, not fwd.

On this CPU-only container the cost oracle is the analytic per-layer
FLOPs model (validated against the dry-run roofline terms); on real
hardware the same interfaces accept measured profiles — the paper itself
profiles. The partitioning algorithm is unchanged.

Also here: the 1F1B simulator used to reproduce Table 3 / Fig. 7
(per-stage fwd/bwd times -> iteration time, bubble fraction), DAG-aware
so modality-parallel schedules (Fig. 6) simulate too.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def layer_fwd_flops(cfg: ModelConfig, seq: int, batch: int = 1) -> float:
    """Analytic forward FLOPs of ONE transformer layer (2·m·n·k matmuls
    + attention scores)."""
    d, hd = cfg.d_model, cfg.head_dim
    t = seq * batch
    qkvo = 2 * t * d * (cfg.q_dim + 2 * cfg.kv_dim + cfg.q_dim)
    attn = 2 * 2 * batch * seq * seq * cfg.num_heads * hd  # scores + AV
    if cfg.family == "moe" and cfg.moe is not None:
        m = cfg.moe
        ff = 2 * 3 * t * d * m.d_expert * (m.top_k + m.num_shared_experts)
    else:
        n_mat = 3 if (cfg.act == "silu" or cfg.name.startswith("gemma2")) \
            else 2
        ff = 2 * n_mat * t * d * cfg.d_ff
    return float(qkvo + attn + ff)


@dataclasses.dataclass
class ModuleProfile:
    """One ModalityModule (or LLM) as seen by the partitioner."""
    name: str
    layer_fwd: np.ndarray          # per-layer forward cost (time units)
    frozen: bool
    # trainable module upstream in FORWARD order? (set by analyze_chain)
    trainable_upstream: bool = False
    recompute: bool = False        # activation checkpointing enabled

    @property
    def bwd_factor(self) -> float:
        if not self.frozen:
            f = 2.0
        elif self.trainable_upstream:
            f = 1.0
        else:
            return 0.0
        if self.recompute:
            f += 1.0
        return f

    @property
    def layer_bwd(self) -> np.ndarray:
        return self.layer_fwd * self.bwd_factor


def profile_from_config(cfg: ModelConfig, seq: int, *, frozen: bool,
                        batch: int = 1, recompute: bool = False,
                        name: Optional[str] = None) -> ModuleProfile:
    f = np.array([layer_fwd_flops(cfg, seq, batch)] * cfg.num_layers)
    return ModuleProfile(name or cfg.name, f, frozen, recompute=recompute)


def analyze_chain(modules: Sequence[ModuleProfile],
                  projector_trainable: Sequence[bool]) -> None:
    """Set trainable_upstream flags along a forward-order chain
    (projectors sit between modules; a trainable projector upstream
    forces input-grad backward in all later modules)."""
    upstream = False
    for i, m in enumerate(modules):
        m.trainable_upstream = upstream
        if not m.frozen:
            upstream = True
        if i < len(projector_trainable) and projector_trainable[i]:
            upstream = True


# ---------------------------------------------------------------------------
# Stage partitioning (contiguous layers -> stages, minimize max stage cost)
# ---------------------------------------------------------------------------

def partition_layers(costs: np.ndarray, k: int) -> List[Tuple[int, int]]:
    """DP optimal contiguous partition of ``costs`` into k parts
    minimizing the max part-sum. Returns [(start, end), ...)."""
    n = len(costs)
    k = min(k, n)
    prefix = np.concatenate([[0.0], np.cumsum(costs)])

    def part_sum(a, b):
        return prefix[b] - prefix[a]

    INF = float("inf")
    dp = np.full((k + 1, n + 1), INF)
    cut = np.zeros((k + 1, n + 1), np.int64)
    dp[0, 0] = 0.0
    for parts in range(1, k + 1):
        for end in range(parts, n + 1):
            best, arg = INF, parts - 1
            for mid in range(parts - 1, end):
                v = max(dp[parts - 1, mid], part_sum(mid, end))
                if v < best - 1e-12:
                    best, arg = v, mid
            dp[parts, end] = best
            cut[parts, end] = arg
    bounds = []
    end = n
    for parts in range(k, 0, -1):
        start = int(cut[parts, end])
        bounds.append((start, end))
        end = start
    return bounds[::-1]


@dataclasses.dataclass
class Stage:
    module: str
    fwd: float
    bwd: float
    layer_range: Tuple[int, int] = (0, 0)

    @property
    def total(self) -> float:
        return self.fwd + self.bwd


def partition_module(m: ModuleProfile, k: int, *,
                     frozen_aware: bool = True) -> List[Stage]:
    """Partition one module into k stages. frozen_aware balances
    fwd+bwd (Cornstarch); frozen_unaware balances fwd alone assuming
    bwd = 2·fwd (the baseline's broken assumption)."""
    costs = m.layer_fwd + m.layer_bwd if frozen_aware else m.layer_fwd
    bounds = partition_layers(costs, k)
    out = []
    for (a, b) in bounds:
        f = float(m.layer_fwd[a:b].sum())
        w = float(m.layer_bwd[a:b].sum())
        out.append(Stage(m.name, f, w, (a, b)))
    return out


# ---------------------------------------------------------------------------
# 1F1B schedule simulator (DAG-aware)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineGraph:
    """stages: flat list; edges: forward-order dependencies
    (src_stage_idx -> dst_stage_idx). A chain is edges (i, i+1)."""
    stages: List[Stage]
    edges: List[Tuple[int, int]]

    @property
    def preds(self) -> Dict[int, List[int]]:
        p: Dict[int, List[int]] = {i: [] for i in range(len(self.stages))}
        for a, b in self.edges:
            p[b].append(a)
        return p

    @property
    def succs(self) -> Dict[int, List[int]]:
        s: Dict[int, List[int]] = {i: [] for i in range(len(self.stages))}
        for a, b in self.edges:
            s[a].append(b)
        return s

    def depth_from_end(self, i: int) -> int:
        succ = self.succs
        memo: Dict[int, int] = {}

        def rec(j):
            if j in memo:
                return memo[j]
            memo[j] = 1 + max((rec(s) for s in succ[j]), default=0)
            return memo[j]
        return rec(i)


def chain_graph(stages: List[Stage]) -> PipelineGraph:
    return PipelineGraph(stages, [(i, i + 1) for i in range(len(stages) - 1)])


def simulate_1f1b(graph: PipelineGraph, num_microbatches: int
                  ) -> Dict[str, float]:
    """Deterministic discrete-event 1F1B simulation.

    Each stage = one device. Ready work: fwd(s,m) after all fwd(p,m) for
    p in preds(s); bwd(s,m) after fwd(s,m) and all bwd(q,m) for q in
    succs(s). 1F1B policy per device: prefer backward; admit a new
    forward only while in-flight < depth_from_end(s) (limits activation
    memory exactly as 1F1B does).
    Returns iteration time, per-device busy time, bubble fraction.
    """
    S = len(graph.stages)
    M = num_microbatches
    preds, succs = graph.preds, graph.succs
    inflight_cap = [graph.depth_from_end(i) for i in range(S)]

    fwd_done = [[None] * M for _ in range(S)]   # completion times
    bwd_done = [[None] * M for _ in range(S)]
    dev_free = [0.0] * S
    fwd_issued = [0] * S                        # next fwd mb index
    bwd_issued = [0] * S
    busy = [0.0] * S

    def fwd_ready_at(s, m):
        ts = [fwd_done[p][m] for p in preds[s]]
        if any(t is None for t in ts):
            return None
        return max(ts, default=0.0)

    def bwd_ready_at(s, m):
        if fwd_done[s][m] is None:
            return None
        ts = [bwd_done[q][m] for q in succs[s]]
        if any(t is None for t in ts):
            return None
        return max(ts + [fwd_done[s][m]])

    # event loop: repeatedly pick, per device, the next admissible item
    remaining = 2 * S * M
    guard = 0
    while remaining > 0:
        guard += 1
        if guard > 16 * S * M + 64:
            raise RuntimeError("simulator deadlock")
        progressed = False
        # choose the globally earliest-startable item (greedy list sched)
        candidates = []
        for s in range(S):
            # backward preferred
            m = bwd_issued[s]
            if m < M:
                r = bwd_ready_at(s, m)
                if r is not None:
                    candidates.append((max(r, dev_free[s]), 0, s, "bwd", m))
            m = fwd_issued[s]
            if m < M:
                inflight = fwd_issued[s] - bwd_issued[s]
                if inflight < inflight_cap[s]:
                    r = fwd_ready_at(s, m)
                    if r is not None:
                        candidates.append(
                            (max(r, dev_free[s]), 1, s, "fwd", m))
        if not candidates:
            raise RuntimeError("simulator stalled (bad graph?)")
        start, _, s, kind, m = min(candidates)
        dur = graph.stages[s].fwd if kind == "fwd" else graph.stages[s].bwd
        end = start + dur
        dev_free[s] = end
        busy[s] += dur
        if kind == "fwd":
            fwd_done[s][m] = end
            fwd_issued[s] += 1
        else:
            bwd_done[s][m] = end
            bwd_issued[s] += 1
        remaining -= 1
        progressed = True

    total = max(max(filter(None, row), default=0.0) for row in bwd_done)
    bubble = 1.0 - (sum(busy) / (S * total)) if total > 0 else 0.0
    return {"iteration_time": float(total),
            "bubble_fraction": float(bubble),
            "per_device_busy": busy}


# ---------------------------------------------------------------------------
# MLLM pipeline construction: colocated / replicated / modality-parallel
# ---------------------------------------------------------------------------

def build_colocated(encoders: Sequence[ModuleProfile], llm: ModuleProfile,
                    enc_stages: int, llm_stages: int, *,
                    frozen_aware: bool) -> PipelineGraph:
    """Encoders fused into one chain of enc_stages, then LLM chain
    (Megatron-style encoders-colocated, Fig. 1c)."""
    fused_fwd = np.concatenate([e.layer_fwd for e in encoders])
    fused_bwd = np.concatenate([e.layer_bwd for e in encoders])
    fused = ModuleProfile("encoders", fused_fwd, frozen=False)
    costs = fused_fwd + fused_bwd if frozen_aware else fused_fwd
    bounds = partition_layers(costs, enc_stages)
    stages = [Stage("encoders", float(fused_fwd[a:b].sum()),
                    float(fused_bwd[a:b].sum()), (a, b))
              for a, b in bounds]
    stages += partition_module(llm, llm_stages, frozen_aware=frozen_aware)
    return chain_graph(stages)


def build_replicated(encoders: Sequence[ModuleProfile], llm: ModuleProfile,
                     llm_stages: int, *, frozen_aware: bool
                     ) -> PipelineGraph:
    """Meta-Llama style: encoders replicated into EVERY LLM stage
    (Fig. 1b) — each stage's cost includes a full encoder pass."""
    stages = partition_module(llm, llm_stages, frozen_aware=frozen_aware)
    enc_f = sum(float(e.layer_fwd.sum()) for e in encoders)
    enc_b = sum(float(e.layer_bwd.sum()) for e in encoders)
    out = [Stage(s.module, s.fwd + enc_f, s.bwd + enc_b, s.layer_range)
           for s in stages]
    return chain_graph(out)


def build_modality_parallel(encoders: Sequence[ModuleProfile],
                            llm: ModuleProfile,
                            enc_stage_counts: Sequence[int],
                            llm_stages: int, *,
                            frozen_aware: bool = True) -> PipelineGraph:
    """Cornstarch modality parallelism (Fig. 6): each encoder is its own
    chain; all encoder chains feed the first LLM stage."""
    stages: List[Stage] = []
    edges: List[Tuple[int, int]] = []
    enc_last: List[int] = []
    for e, k in zip(encoders, enc_stage_counts):
        sub = partition_module(e, k, frozen_aware=frozen_aware)
        base = len(stages)
        stages += sub
        edges += [(base + i, base + i + 1) for i in range(len(sub) - 1)]
        enc_last.append(base + len(sub) - 1)
    llm_sub = partition_module(llm, llm_stages, frozen_aware=frozen_aware)
    base = len(stages)
    stages += llm_sub
    edges += [(base + i, base + i + 1) for i in range(len(llm_sub) - 1)]
    for last in enc_last:
        edges.append((last, base))
    return PipelineGraph(stages, edges)


def build_chain_fused(modules: Sequence[ModuleProfile], total_stages: int,
                      *, frozen_aware: bool) -> PipelineGraph:
    """Fuse all modules into one layer chain and partition into
    ``total_stages`` — boundaries may fall anywhere (the paper's §6.4
    comparison: frozen-aware partitions on true fwd+bwd; the unaware
    baseline partitions on fwd alone, implicitly assuming bwd = 2·fwd).
    Simulation always uses TRUE costs; only the *partitioning objective*
    changes."""
    fwd = np.concatenate([m.layer_fwd for m in modules])
    bwd = np.concatenate([m.layer_bwd for m in modules])
    names = sum(([m.name] * len(m.layer_fwd) for m in modules), [])
    costs = (fwd + bwd) if frozen_aware else fwd
    bounds = partition_layers(costs, total_stages)
    stages = []
    for a, b in bounds:
        mod = names[a] if names[a] == names[b - 1] else \
            f"{names[a]}+{names[b - 1]}"
        stages.append(Stage(mod, float(fwd[a:b].sum()),
                            float(bwd[a:b].sum()), (a, b)))
    return chain_graph(stages)


# ---------------------------------------------------------------------------
# Algorithm 1: loosely-coupled multimodal auto-parallelization
# ---------------------------------------------------------------------------

def auto_parallelize(encoders: Sequence[ModuleProfile], llm: ModuleProfile,
                     total_devices: int, num_microbatches: int,
                     *, frozen_aware: bool = True,
                     max_llm_stages: Optional[int] = None) -> dict:
    """For each feasible LLM stage count i: partition the LLM, derive the
    per-stage time target t_i, fit each encoder to that target, simulate,
    return the best combination (paper Algorithm 1)."""
    best = None
    max_llm = max_llm_stages or min(len(llm.layer_fwd),
                                    total_devices - len(encoders))
    for i in range(1, max_llm + 1):
        llm_sub = partition_module(llm, i, frozen_aware=frozen_aware)
        t_i = max(s.total for s in llm_sub)
        enc_counts = []
        for e in encoders:
            tot = float((e.layer_fwd + e.layer_bwd).sum()) if frozen_aware \
                else float(e.layer_fwd.sum() * 3)
            k = max(1, int(np.ceil(tot / max(t_i, 1e-9))))
            k = min(k, len(e.layer_fwd),
                    max(1, total_devices - i - (len(encoders) - 1)))
            enc_counts.append(k)
        if i + sum(enc_counts) > total_devices:
            continue
        g = build_modality_parallel(encoders, llm, enc_counts, i,
                                    frozen_aware=frozen_aware)
        sim = simulate_1f1b(g, num_microbatches)
        cand = {"llm_stages": i, "encoder_stages": enc_counts,
                "graph": g, **sim,
                "devices": i + sum(enc_counts),
                "tput_per_device": num_microbatches /
                (sim["iteration_time"] * (i + sum(enc_counts)))}
        if best is None or cand["tput_per_device"] > \
                best["tput_per_device"]:
            best = cand
    assert best is not None, "no feasible configuration"
    return best
