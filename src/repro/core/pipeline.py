"""Frozen-status-aware pipeline parallelism (Cornstarch §4.2, Alg. 1).

The paper's key observation: the rule of thumb "backward ≈ 2× forward"
breaks for MLLMs with frozen constituents. The corrected per-module rule

    T_bwd = 0·T_fwd   frozen, no trainable module upstream (forward order)
            1·T_fwd   frozen, trainable module upstream (input grads only)
            2·T_fwd   trainable
    (+1·T_fwd recompute when activation checkpointing is on AND the
     module has gradients to compute)

drives stage partitioning: balance **fwd+bwd** per stage, not fwd.

Backward further decomposes into an input-grad pass B (blocks the
upstream stage's backward) and a weight-grad pass W (blocks only the
optimizer step). Frozen modules have **no W at all** — the decomposition
the zero-bubble schedulers in ``core.schedule`` exploit:

    module kind                    B factor   W factor
    frozen, nothing trainable up      0          0
    frozen, trainable upstream        1          0
    trainable                         1          1
    (+1 to B for recompute when any gradient exists)

On this CPU-only container the cost oracle is the analytic per-layer
FLOPs model (validated against the dry-run roofline terms); on real
hardware the same interfaces accept measured profiles — the paper itself
profiles. The partitioning algorithm is unchanged.

Scheduling lives in ``core.schedule``: the F/B/W discrete-event
simulator and the four schedulers (1F1B / interleaved-1F1B / ZB-H1 /
ZB-V) used to reproduce Table 3 / Fig. 7, plus the simulator-vs-
executor memory validation harness. This module supplies the cost
model and the search: ``auto_parallelize`` (paper Algorithm 1)
partitions stages frozen-aware and searches (schedule, virtual-chunk
count) jointly — chunked schedules (interleaved, zb-v) fold v-times
finer partitions back onto the planned devices so every candidate is
compared at the same device budget. The graph types and
``simulate_1f1b`` are re-exported here for compatibility.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.schedule import (PipelineGraph, SCHEDULES,  # noqa: F401
                                 Stage, chain_graph, get_scheduler)


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

def layer_fwd_flops(cfg: ModelConfig, seq: int, batch: int = 1) -> float:
    """Analytic forward FLOPs of ONE transformer layer (2·m·n·k matmuls
    + attention scores)."""
    d, hd = cfg.d_model, cfg.head_dim
    t = seq * batch
    qkvo = 2 * t * d * (cfg.q_dim + 2 * cfg.kv_dim + cfg.q_dim)
    attn = 2 * 2 * batch * seq * seq * cfg.num_heads * hd  # scores + AV
    if cfg.family == "moe" and cfg.moe is not None:
        m = cfg.moe
        ff = 2 * 3 * t * d * m.d_expert * (m.top_k + m.num_shared_experts)
    else:
        n_mat = 3 if (cfg.act == "silu" or cfg.name.startswith("gemma2")) \
            else 2
        ff = 2 * n_mat * t * d * cfg.d_ff
    return float(qkvo + attn + ff)


@dataclasses.dataclass
class ModuleProfile:
    """One ModalityModule (or LLM) as seen by the partitioner."""
    name: str
    layer_fwd: np.ndarray          # per-layer forward cost (time units)
    frozen: bool
    # trainable module upstream in FORWARD order? (set by analyze_chain)
    trainable_upstream: bool = False
    recompute: bool = False        # activation checkpointing enabled

    @property
    def bwd_factor(self) -> float:
        if not self.frozen:
            f = 2.0
        elif self.trainable_upstream:
            f = 1.0
        else:
            return 0.0
        if self.recompute:
            f += 1.0
        return f

    @property
    def bwd_weight_factor(self) -> float:
        """W (weight-grad) share of bwd_factor — frozen ⇒ no W pass."""
        return 0.0 if self.frozen else 1.0

    @property
    def bwd_input_factor(self) -> float:
        """B (input-grad) share of bwd_factor; recompute time attaches
        here because recomputation must precede the grad matmuls."""
        return self.bwd_factor - self.bwd_weight_factor

    @property
    def layer_bwd(self) -> np.ndarray:
        return self.layer_fwd * self.bwd_factor

    @property
    def layer_bwd_w(self) -> np.ndarray:
        return self.layer_fwd * self.bwd_weight_factor


def profile_from_config(cfg: ModelConfig, seq: int, *, frozen: bool,
                        batch: int = 1, recompute: bool = False,
                        name: Optional[str] = None) -> ModuleProfile:
    f = np.array([layer_fwd_flops(cfg, seq, batch)] * cfg.num_layers)
    return ModuleProfile(name or cfg.name, f, frozen, recompute=recompute)


def analyze_chain(modules: Sequence[ModuleProfile],
                  projector_trainable: Sequence[bool]) -> None:
    """Set trainable_upstream flags along a forward-order chain
    (projectors sit between modules; a trainable projector upstream
    forces input-grad backward in all later modules)."""
    upstream = False
    for i, m in enumerate(modules):
        m.trainable_upstream = upstream
        if not m.frozen:
            upstream = True
        if i < len(projector_trainable) and projector_trainable[i]:
            upstream = True


# ---------------------------------------------------------------------------
# Stage partitioning (contiguous layers -> stages, minimize max stage cost)
# ---------------------------------------------------------------------------

def partition_layers(costs: np.ndarray, k: int) -> List[Tuple[int, int]]:
    """DP optimal contiguous partition of ``costs`` into k parts
    minimizing the max part-sum. Returns [(start, end), ...)."""
    n = len(costs)
    k = min(k, n)
    prefix = np.concatenate([[0.0], np.cumsum(costs)])

    def part_sum(a, b):
        return prefix[b] - prefix[a]

    INF = float("inf")
    dp = np.full((k + 1, n + 1), INF)
    cut = np.zeros((k + 1, n + 1), np.int64)
    dp[0, 0] = 0.0
    for parts in range(1, k + 1):
        for end in range(parts, n + 1):
            best, arg = INF, parts - 1
            for mid in range(parts - 1, end):
                v = max(dp[parts - 1, mid], part_sum(mid, end))
                if v < best - 1e-12:
                    best, arg = v, mid
            dp[parts, end] = best
            cut[parts, end] = arg
    bounds = []
    end = n
    for parts in range(k, 0, -1):
        start = int(cut[parts, end])
        bounds.append((start, end))
        end = start
    return bounds[::-1]


def _stages_from_bounds(name, fwd, bwd, bwd_w, bounds,
                        names: Optional[List[str]] = None) -> List[Stage]:
    out = []
    for a, b in bounds:
        if names is not None:
            mod = names[a] if names[a] == names[b - 1] else \
                f"{names[a]}+{names[b - 1]}"
        else:
            mod = name
        out.append(Stage(mod, float(fwd[a:b].sum()), float(bwd[a:b].sum()),
                         (a, b), bwd_w=float(bwd_w[a:b].sum())))
    return out


def partition_module(m: ModuleProfile, k: int, *,
                     frozen_aware: bool = True) -> List[Stage]:
    """Partition one module into k stages. frozen_aware balances
    fwd+bwd (Cornstarch); frozen_unaware balances fwd alone assuming
    bwd = 2·fwd (the baseline's broken assumption)."""
    costs = m.layer_fwd + m.layer_bwd if frozen_aware else m.layer_fwd
    bounds = partition_layers(costs, k)
    return _stages_from_bounds(m.name, m.layer_fwd, m.layer_bwd,
                               m.layer_bwd_w, bounds)


def simulate_1f1b(graph: PipelineGraph, num_microbatches: int
                  ) -> Dict[str, float]:
    """Legacy entry point: classic 1F1B (see core.schedule)."""
    return get_scheduler("1f1b").simulate(graph, num_microbatches)


def _chunk_candidates(schedule: str, virtual_chunks) -> Tuple[int, ...]:
    """Virtual-chunk counts a schedule searches over. ``virtual_chunks``
    is an int ceiling (legacy: try v, v-1, ..., 1) or an explicit
    sequence of candidates. zb-v places exactly two chunks per device,
    so its candidate set is {2, 1} (an explicit sequence can pin it to
    one of those — how ``MLLMParallelPlan.apply`` replays a recorded
    winner deterministically); the unchunked schedules pin v = 1."""
    if schedule == "zb-v":
        if isinstance(virtual_chunks, int):
            return (2, 1)
        vs = tuple(v for v in (2, 1)
                   if v in {int(x) for x in virtual_chunks})
        if not vs:
            # an explicit candidate set is a pin (MLLMParallelPlan.
            # apply replaying a recorded winner) — silently widening
            # it back to {2, 1} would execute a different placement
            # than the plan records
            raise ValueError(
                f"zb-v places two chunks per device: explicit "
                f"virtual_chunks must come from {{1, 2}}, got "
                f"{tuple(virtual_chunks)!r}")
        return vs
    if schedule != "interleaved":
        return (1,)
    if isinstance(virtual_chunks, int):
        return tuple(range(max(1, virtual_chunks), 0, -1))
    vs = tuple(int(v) for v in virtual_chunks)
    assert vs and all(v >= 1 for v in vs), "virtual_chunks must be >= 1"
    return vs


def _chunked_search(schedule: str, build_graph, feasible, virtual_chunks,
                    num_microbatches: int
                    ) -> Tuple[PipelineGraph, Dict[str, float]]:
    """Search the virtual-chunk count for a schedule, keeping the
    fastest simulation. v=1 is the one-chunk-per-device degenerate (the
    1F1B placement for interleaved, the ZB-H1 placement for zb-v) — on
    heterogeneous MLLM chains a device's chunk set mixes forward-heavy
    frozen-encoder chunks with LLM chunks and chunking can lose, so the
    degenerate v is a legitimate winner and chunked schedules are never
    scheduled worse than their unchunked selves."""
    candidates = _chunk_candidates(schedule, virtual_chunks)
    if not any(feasible(v) for v in candidates):
        # an explicit candidate tuple may be entirely infeasible for a
        # shallow module (e.g. virtual_chunks=(4,) on an 8-layer LLM
        # split 4 ways); degrade to the always-feasible v=1 placement
        # rather than dying — the documented fold-back behavior
        candidates = (1,)
    best = None
    for v in candidates:
        if not feasible(v):
            continue
        g = build_graph(v)
        kwargs = {"virtual_chunks": v} \
            if schedule in ("interleaved", "zb-v") else {}
        sim = get_scheduler(schedule, **kwargs).simulate(
            g, num_microbatches)
        if best is None or sim["iteration_time"] < \
                best[1]["iteration_time"]:
            best = (g, sim)
    assert best is not None, \
        f"{schedule}: v=1 must always be feasible"
    return best


def simulate_plan(encoders: Sequence[ModuleProfile], llm: ModuleProfile,
                  enc_counts: Sequence[int], llm_stages: int,
                  num_microbatches: int, *, schedule: str = "1f1b",
                  frozen_aware: bool = True, virtual_chunks=2
                  ) -> Tuple[PipelineGraph, Dict[str, float]]:
    """Build the modality-parallel graph for a stage plan and simulate
    it under ``schedule`` at a FIXED device budget of one device per
    planned stage (a stage count exceeding a module's layer count is
    clamped first, matching the partitioner). Chunked schedules
    (interleaved, zb-v) multiply the stage counts by v virtual chunks
    and fold the chunks back onto the same devices — round-robin for
    interleaved, V-shaped for zb-v — searching their candidate v set
    down to the v=1 degenerate, so ``sim["num_devices"]`` always equals
    the planned stage count and schedules compare apples-to-apples on
    the same hardware. ``virtual_chunks`` is an int ceiling or an
    explicit candidate sequence for the interleaved search; zb-v always
    searches {2, 1}."""
    llm_stages = min(llm_stages, len(llm.layer_fwd))
    enc_counts = [min(k, len(e.layer_fwd))
                  for e, k in zip(encoders, enc_counts)]
    return _chunked_search(
        schedule,
        lambda v: build_modality_parallel(
            encoders, llm, [k * v for k in enc_counts], llm_stages * v,
            frozen_aware=frozen_aware),
        lambda v: llm_stages * v <= len(llm.layer_fwd) and all(
            k * v <= len(e.layer_fwd)
            for e, k in zip(encoders, enc_counts)),
        virtual_chunks, num_microbatches)


# ---------------------------------------------------------------------------
# MLLM pipeline construction: colocated / replicated / modality-parallel
# ---------------------------------------------------------------------------

def build_colocated(encoders: Sequence[ModuleProfile], llm: ModuleProfile,
                    enc_stages: int, llm_stages: int, *,
                    frozen_aware: bool) -> PipelineGraph:
    """Encoders fused into one chain of enc_stages, then LLM chain
    (Megatron-style encoders-colocated, Fig. 1c)."""
    fused_fwd = np.concatenate([e.layer_fwd for e in encoders])
    fused_bwd = np.concatenate([e.layer_bwd for e in encoders])
    fused_bwd_w = np.concatenate([e.layer_bwd_w for e in encoders])
    costs = fused_fwd + fused_bwd if frozen_aware else fused_fwd
    bounds = partition_layers(costs, enc_stages)
    stages = _stages_from_bounds("encoders", fused_fwd, fused_bwd,
                                 fused_bwd_w, bounds)
    stages += partition_module(llm, llm_stages, frozen_aware=frozen_aware)
    return chain_graph(stages)


def build_replicated(encoders: Sequence[ModuleProfile], llm: ModuleProfile,
                     llm_stages: int, *, frozen_aware: bool
                     ) -> PipelineGraph:
    """Meta-Llama style: encoders replicated into EVERY LLM stage
    (Fig. 1b) — each stage's cost includes a full encoder pass."""
    stages = partition_module(llm, llm_stages, frozen_aware=frozen_aware)
    enc_f = sum(float(e.layer_fwd.sum()) for e in encoders)
    enc_b = sum(float(e.layer_bwd.sum()) for e in encoders)
    enc_w = sum(float(e.layer_bwd_w.sum()) for e in encoders)
    out = [Stage(s.module, s.fwd + enc_f, s.bwd + enc_b, s.layer_range,
                 bwd_w=s.bwd_w + enc_w)
           for s in stages]
    return chain_graph(out)


def build_modality_parallel(encoders: Sequence[ModuleProfile],
                            llm: ModuleProfile,
                            enc_stage_counts: Sequence[int],
                            llm_stages: int, *,
                            frozen_aware: bool = True) -> PipelineGraph:
    """Cornstarch modality parallelism (Fig. 6): each encoder is its own
    chain; all encoder chains feed the first LLM stage."""
    stages: List[Stage] = []
    edges: List[Tuple[int, int]] = []
    enc_last: List[int] = []
    for e, k in zip(encoders, enc_stage_counts):
        sub = partition_module(e, k, frozen_aware=frozen_aware)
        base = len(stages)
        stages += sub
        edges += [(base + i, base + i + 1) for i in range(len(sub) - 1)]
        enc_last.append(base + len(sub) - 1)
    llm_sub = partition_module(llm, llm_stages, frozen_aware=frozen_aware)
    base = len(stages)
    stages += llm_sub
    edges += [(base + i, base + i + 1) for i in range(len(llm_sub) - 1)]
    for last in enc_last:
        edges.append((last, base))
    return PipelineGraph(stages, edges)


def build_chain_fused(modules: Sequence[ModuleProfile], total_stages: int,
                      *, frozen_aware: bool) -> PipelineGraph:
    """Fuse all modules into one layer chain and partition into
    ``total_stages`` — boundaries may fall anywhere (the paper's §6.4
    comparison: frozen-aware partitions on true fwd+bwd; the unaware
    baseline partitions on fwd alone, implicitly assuming bwd = 2·fwd).
    Simulation always uses TRUE costs; only the *partitioning objective*
    changes."""
    fwd = np.concatenate([m.layer_fwd for m in modules])
    bwd = np.concatenate([m.layer_bwd for m in modules])
    bwd_w = np.concatenate([m.layer_bwd_w for m in modules])
    names = sum(([m.name] * len(m.layer_fwd) for m in modules), [])
    costs = (fwd + bwd) if frozen_aware else fwd
    bounds = partition_layers(costs, total_stages)
    return chain_graph(_stages_from_bounds(None, fwd, bwd, bwd_w, bounds,
                                           names=names))


def simulate_fused_chain(modules: Sequence[ModuleProfile],
                         total_stages: int, num_microbatches: int, *,
                         schedule: str = "1f1b",
                         frozen_aware: bool = True,
                         virtual_chunks=2
                         ) -> Tuple[PipelineGraph, Dict[str, float]]:
    """``build_chain_fused`` + schedule simulation at a fixed device
    budget of ``total_stages`` devices. Chunked schedules (interleaved,
    zb-v) partition the same chain v times finer and fold the chunks
    onto the same devices — round-robin or V-shaped — searching v down
    to the v=1 degenerate; see ``simulate_plan`` for why the degenerate
    v may win."""
    n_layers = sum(len(m.layer_fwd) for m in modules)
    total_stages = min(total_stages, n_layers)
    return _chunked_search(
        schedule,
        lambda v: build_chain_fused(modules, total_stages * v,
                                    frozen_aware=frozen_aware),
        lambda v: total_stages * v <= n_layers,
        virtual_chunks, num_microbatches)


# ---------------------------------------------------------------------------
# Algorithm 1: loosely-coupled multimodal auto-parallelization
# ---------------------------------------------------------------------------

#: candidate-ranking objectives for auto_parallelize: maximize
#: throughput per device (the paper's), or minimize time / bubble
AUTO_OBJECTIVES = ("tput_per_device", "iteration_time",
                   "bubble_fraction")


def _beats(cand: dict, best: dict, objective: str) -> bool:
    if objective == "tput_per_device":
        return cand["tput_per_device"] > best["tput_per_device"]
    return cand[objective] < best[objective]


def auto_parallelize(encoders: Sequence[ModuleProfile], llm: ModuleProfile,
                     total_devices: int, num_microbatches: int,
                     *, frozen_aware: bool = True,
                     max_llm_stages: Optional[int] = None,
                     schedules: Sequence[str] = SCHEDULES,
                     virtual_chunks: Sequence[int] = (1, 2, 4),
                     objective: str = "tput_per_device") -> dict:
    """For each feasible LLM stage count i: partition the LLM, derive the
    per-stage time target t_i, fit each encoder to that target, simulate
    every candidate (schedule, virtual-chunk count) pair, return the
    best combination (paper Algorithm 1, extended to search schedules
    and chunking jointly). ``virtual_chunks`` is the candidate v set
    for the interleaved schedule (zb-v always searches {2, 1}; 1f1b
    and zb-h1 pin v = 1). ``objective`` ranks candidates:
    ``"tput_per_device"`` (default, maximized) or ``"iteration_time"``
    / ``"bubble_fraction"`` (minimized — these spend every device the
    budget allows, where throughput/device prefers small footprints).
    The result dict carries the winning schedule name under
    ``"schedule"`` and the winning chunk count under
    ``"virtual_chunks"``."""
    if objective not in AUTO_OBJECTIVES:
        raise ValueError(f"unknown objective {objective!r}; pick from "
                         f"{AUTO_OBJECTIVES}")
    best = None
    max_llm = max_llm_stages or min(len(llm.layer_fwd),
                                    total_devices - len(encoders))
    for i in range(1, max_llm + 1):
        llm_sub = partition_module(llm, i, frozen_aware=frozen_aware)
        t_i = max(s.total for s in llm_sub)
        enc_counts = []
        for e in encoders:
            tot = float((e.layer_fwd + e.layer_bwd).sum()) if frozen_aware \
                else float(e.layer_fwd.sum() * 3)
            k = max(1, int(np.ceil(tot / max(t_i, 1e-9))))
            k = min(k, len(e.layer_fwd),
                    max(1, total_devices - i - (len(encoders) - 1)))
            enc_counts.append(k)
        if i + sum(enc_counts) > total_devices:
            continue
        def fits(v, i=i, enc_counts=enc_counts):
            return i * v <= len(llm.layer_fwd) and all(
                k * v <= len(e.layer_fwd)
                for e, k in zip(encoders, enc_counts))

        candidates = []
        for sched in schedules:
            if sched == "interleaved":
                candidates += [(sched, (v,))
                               for v in virtual_chunks if fits(v)]
            else:
                # the int sentinel means "schedule default": zb-v
                # searches its inherent {2, 1}; 1f1b/zb-h1 pin v = 1.
                # The interleaved-specific candidate tuple must not
                # leak here (e.g. (4,) would be an invalid zb-v pin)
                candidates.append((sched, 2))
        for sched, vs in candidates:
            g, sim = simulate_plan(encoders, llm, enc_counts, i,
                                   num_microbatches, schedule=sched,
                                   frozen_aware=frozen_aware,
                                   virtual_chunks=vs)
            devices = sim["num_devices"]        # == i + sum(enc_counts)
            cand = {"llm_stages": i, "encoder_stages": enc_counts,
                    "encoder_names": [e.name for e in encoders],
                    "graph": g, **sim,
                    "devices": devices,
                    "tput_per_device": num_microbatches /
                    (sim["iteration_time"] * devices)}
            if best is None or _beats(cand, best, objective):
                best = cand
    assert best is not None, "no feasible configuration"
    return best
