"""Modality parallelism + pipeline execution on TPU/JAX (Cornstarch §4.1).

Two complementary realizations of the paper's MPMD schedule in JAX's
SPMD world (DESIGN.md §2):

1. **Circular pipeline executor** (``pipeline_forward``): a single chain
   of homogeneous stages mapped onto a ``stage`` mesh axis via
   ``shard_map``. Microbatch ``m`` occupies stage ``s`` at tick
   ``t = m + s``; activations advance with ``lax.ppermute`` inside a
   ``lax.scan`` over ticks (the standard GPipe-on-TPU construction —
   1F1B's memory policy is a scheduling refinement that SPMD ticks
   subsume; bubble accounting for 1F1B / interleaved-1F1B / ZB-H1 lives
   in core/schedule's simulator, and ``split_devices`` threads the
   schedule picked by Algorithm 1 through to the executor plan).
   Autodiff through the scan gives the backward pipeline for free.

2. **Modality islands** (``ModalityIslands``): the paper's modality
   parallelism proper — each encoder is jitted onto a *disjoint device
   subset*; JAX's async dispatch overlaps their execution exactly
   because the execution DAG has no edge between them (paper C1). The
   LLM island consumes their outputs. On a real multi-pod TPU each
   island is one pjit program over its submesh.

Both are exercised by tests (subprocess, forced host device count) and
by the Fig. 9/10-style benchmark; the production dry-run proves the
shard_map executor lowers on the (16, 16) mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


# ---------------------------------------------------------------------------
# 1. Circular pipeline executor (homogeneous stages, shard_map + ppermute)
# ---------------------------------------------------------------------------

def stack_stage_params(per_stage_params: Sequence[Any]):
    """List of per-stage pytrees (identical structure) -> stage-stacked
    pytree with leading S dim (shard P("stage") over it)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_stage_params)


def pipeline_forward(mesh: Mesh, axis_name: str, stage_fn: Callable,
                     stage_params, microbatches, *, num_stages: int):
    """Run ``y_m = stage_{S-1}(... stage_0(x_m))`` for every microbatch.

    stage_fn(local_stage_params, x) -> y, with x/y of identical shape
    (the residual-stream contract all our blocks obey).
    stage_params: stage-stacked pytree (leading dim S).
    microbatches: [M, ...] (replicated; stage 0 slices its tick's mb).
    Returns [M, ...] outputs (gathered from the last stage).
    """
    M = microbatches.shape[0]
    S = num_stages
    ticks = M + S - 1

    def body(local_params, mbs):
        # local_params: leading dim 1 (this device's stage)
        lp = jax.tree.map(lambda a: a[0], local_params)
        sid = lax.axis_index(axis_name)
        x0 = jnp.zeros_like(mbs[0])
        out_buf = jnp.zeros_like(mbs)

        def tick(carry, t):
            x, out_buf = carry
            mb_in_idx = jnp.clip(t, 0, M - 1)
            fresh = lax.dynamic_index_in_dim(mbs, mb_in_idx, 0,
                                             keepdims=False)
            x = jnp.where(sid == 0, fresh, x)
            y = stage_fn(lp, x)
            # last stage writes finished microbatch t-(S-1) to the buffer
            done_idx = jnp.clip(t - (S - 1), 0, M - 1)
            write = (sid == S - 1) & (t >= S - 1)
            cur = lax.dynamic_index_in_dim(out_buf, done_idx, 0,
                                           keepdims=False)
            out_buf = lax.dynamic_update_index_in_dim(
                out_buf, jnp.where(write, y, cur), done_idx, 0)
            perm = [(i, (i + 1) % S) for i in range(S)]
            x = lax.ppermute(y, axis_name, perm)
            return (x, out_buf), None

        (x, out_buf), _ = lax.scan(tick, (x0, out_buf), jnp.arange(ticks))
        # collect the filled buffer from the last stage on all devices
        out_all = lax.all_gather(out_buf, axis_name)        # [S, M, ...]
        return out_all[S - 1]

    spec_params = jax.tree.map(
        lambda a: P(axis_name, *([None] * (a.ndim - 1))), stage_params)
    return shard_map(
        body, mesh=mesh,
        in_specs=(spec_params, P(*([None] * microbatches.ndim))),
        out_specs=P(*([None] * microbatches.ndim)),
        check_rep=False,
    )(stage_params, microbatches)


def pipeline_reference(stage_fn: Callable, stage_params, microbatches, *,
                       num_stages: int):
    """Oracle: same math, no pipeline."""
    def run_one(x):
        for s in range(num_stages):
            lp = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(lp, x)
        return x
    return jax.vmap(run_one)(microbatches)


# ---------------------------------------------------------------------------
# 2. Modality islands: encoders on disjoint device subsets
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Island:
    name: str
    devices: List[Any]               # jax devices owned by this island
    fn: Callable                     # jitted on this island's devices
    mesh: Optional[Mesh] = None


class ModalityIslands:
    """Place each encoder on its own device subset; the LLM on the rest.

    ``run(params, batch)`` dispatches every encoder island asynchronously
    (no dependency between them — the execution DAG guarantees it), then
    feeds their outputs to the LLM island. With JAX async dispatch the
    encoder computations overlap on real hardware; on CPU this verifies
    correctness + device placement.
    """

    def __init__(self, mllm, device_split: Dict[str, List[Any]]):
        from repro.models import mllm as M
        self.mllm = mllm
        self.islands: Dict[str, Island] = {}
        for name, enc in mllm.encoders.items():
            devs = device_split[name]
            sh = NamedSharding(Mesh(np.array(devs), ("d",)), P())

            def enc_fn(params, batch, enc=enc, sh=sh):
                params = jax.device_put(params, sh)
                return enc.forward(params, batch)

            self.islands[name] = Island(name, devs,
                                        jax.jit(enc_fn, static_argnums=()))
        devs = device_split["llm"]
        self.llm_sharding = NamedSharding(Mesh(np.array(devs), ("d",)), P())

        def llm_fn(params, merged, mllm=mllm):
            from repro.models import transformer as T
            return T.forward(params, mllm.llm_cfg, merged)

        self.llm_fn = jax.jit(llm_fn)

    def run(self, params, batch):
        # dispatch all encoder islands first — async, overlapping
        futures = {}
        for name, isl in self.islands.items():
            futures[name] = isl.fn(params["encoders"][name], batch)
        # cross-island transfer (the paper's encoder->LLM P2P send)
        futures = {name: jax.device_put(out, self.llm_sharding)
                   for name, out in futures.items()}
        merged = self.mllm.build_merge(
            jax.device_put(batch["text_tokens"], self.llm_sharding), futures)
        llm_p = jax.device_put(params["llm"], self.llm_sharding)
        return self.llm_fn(llm_p, merged)


def schedule_from_plan(plan: Optional[Dict[str, Any]]) -> str:
    """The pipeline schedule picked for a plan: ``auto_parallelize``
    results carry the winning name under "schedule";
    ``MultimodalParallelSpec.apply`` plans carry the simulation dict
    there and the name under "schedule_name". Defaults to classic
    1F1B."""
    plan = plan or {}
    name = plan.get("schedule")
    if not isinstance(name, str):
        name = plan.get("schedule_name")
    return name if isinstance(name, str) and name else "1f1b"


def split_devices(mllm, devices: Sequence[Any],
                  plan: Optional[Dict[str, Any]] = None) -> Dict[str, list]:
    """Assign device counts per module (default: 1 per encoder, rest to
    the LLM). ``plan`` is either {encoder_name: count} or the result
    dict of ``core.pipeline.auto_parallelize``, whose per-encoder stage
    counts are matched by the "encoder_names" it carries. The winning
    schedule travels separately — read it with ``schedule_from_plan``
    (this dict stays purely {module: device list})."""
    devices = list(devices)
    if plan and "encoder_stages" in plan:     # auto_parallelize result
        names = plan.get("encoder_names") or sorted(mllm.encoders)
        plan = dict(zip(names, plan["encoder_stages"]))
    plan = plan or {name: 1 for name in mllm.encoders}
    out: Dict[str, list] = {}
    i = 0
    for name in sorted(mllm.encoders):
        n = plan.get(name, 1)
        out[name] = devices[i:i + n]
        i += n
    out["llm"] = devices[i:]
    assert out["llm"], "no devices left for the LLM"
    return out
