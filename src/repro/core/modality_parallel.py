"""Modality parallelism + pipeline execution on TPU/JAX (Cornstarch §4.1).

Two complementary realizations of the paper's MPMD schedule in JAX's
SPMD world (DESIGN.md §2):

1. **Circular pipeline executor** (``pipeline_forward``): a single chain
   of homogeneous stages mapped onto a ``stage`` mesh axis via
   ``shard_map``. Microbatch ``m`` occupies stage ``s`` at tick
   ``t = m + s``; activations advance with ``lax.ppermute`` inside a
   ``lax.scan`` over ticks (the standard GPipe-on-TPU construction —
   1F1B's memory policy is a scheduling refinement that SPMD ticks
   subsume; bubble accounting for 1F1B / interleaved-1F1B / ZB-H1 /
   ZB-V lives in core/schedule's simulator, and ``split_devices``
   threads the schedule picked by Algorithm 1 through to the executor
   plan). Autodiff through the scan gives the backward pipeline for
   free.

2. **Modality islands** (``ModalityIslands``): the paper's modality
   parallelism proper — each encoder is jitted onto a *disjoint device
   subset*; JAX's async dispatch overlaps their execution exactly
   because the execution DAG has no edge between them (paper C1). The
   LLM island consumes their outputs. On a real multi-pod TPU each
   island is one pjit program over its submesh.

3. **Schedule-driven executor** (``execute_schedule``): replays a
   simulated F/B/W item timeline with real stage computations and real
   VJPs, holding every inter-stage activation in an instrumented store
   — the measurement side of the memory-validation harness
   (``core.schedule.memory``), which cross-checks the simulator's
   per-device peak-activation claims against execution.

All are exercised by tests (subprocess, forced host device count) and
by the Fig. 9/10-style benchmarks; the production dry-run proves the
shard_map executor lowers on the (16, 16) mesh.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


# ---------------------------------------------------------------------------
# 1. Circular pipeline executor (homogeneous stages, shard_map + ppermute)
# ---------------------------------------------------------------------------

def stack_stage_params(per_stage_params: Sequence[Any]):
    """List of per-stage pytrees (identical structure) -> stage-stacked
    pytree with leading S dim (shard P("stage") over it)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_stage_params)


def pipeline_forward(mesh: Mesh, axis_name: str, stage_fn: Callable,
                     stage_params, microbatches, *, num_stages: int):
    """Run ``y_m = stage_{S-1}(... stage_0(x_m))`` for every microbatch.

    stage_fn(local_stage_params, x) -> y, with x/y of identical shape
    (the residual-stream contract all our blocks obey).
    stage_params: stage-stacked pytree (leading dim S).
    microbatches: [M, ...] (replicated; stage 0 slices its tick's mb).
    Returns [M, ...] outputs (gathered from the last stage).
    """
    M = microbatches.shape[0]
    S = num_stages
    ticks = M + S - 1

    def body(local_params, mbs):
        # local_params: leading dim 1 (this device's stage)
        lp = jax.tree.map(lambda a: a[0], local_params)
        sid = lax.axis_index(axis_name)
        x0 = jnp.zeros_like(mbs[0])
        out_buf = jnp.zeros_like(mbs)

        def tick(carry, t):
            x, out_buf = carry
            mb_in_idx = jnp.clip(t, 0, M - 1)
            fresh = lax.dynamic_index_in_dim(mbs, mb_in_idx, 0,
                                             keepdims=False)
            x = jnp.where(sid == 0, fresh, x)
            y = stage_fn(lp, x)
            # last stage writes finished microbatch t-(S-1) to the buffer
            done_idx = jnp.clip(t - (S - 1), 0, M - 1)
            write = (sid == S - 1) & (t >= S - 1)
            cur = lax.dynamic_index_in_dim(out_buf, done_idx, 0,
                                           keepdims=False)
            out_buf = lax.dynamic_update_index_in_dim(
                out_buf, jnp.where(write, y, cur), done_idx, 0)
            perm = [(i, (i + 1) % S) for i in range(S)]
            x = lax.ppermute(y, axis_name, perm)
            return (x, out_buf), None

        (x, out_buf), _ = lax.scan(tick, (x0, out_buf), jnp.arange(ticks))
        # collect the filled buffer from the last stage on all devices
        out_all = lax.all_gather(out_buf, axis_name)        # [S, M, ...]
        return out_all[S - 1]

    spec_params = jax.tree.map(
        lambda a: P(axis_name, *([None] * (a.ndim - 1))), stage_params)
    return shard_map(
        body, mesh=mesh,
        in_specs=(spec_params, P(*([None] * microbatches.ndim))),
        out_specs=P(*([None] * microbatches.ndim)),
        check_rep=False,
    )(stage_params, microbatches)


def pipeline_reference(stage_fn: Callable, stage_params, microbatches, *,
                       num_stages: int):
    """Oracle: same math, no pipeline."""
    def run_one(x):
        for s in range(num_stages):
            lp = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(lp, x)
        return x
    return jax.vmap(run_one)(microbatches)


# ---------------------------------------------------------------------------
# 2. Modality islands: encoders on disjoint device subsets
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Island:
    name: str
    devices: List[Any]               # jax devices owned by this island
    fn: Callable                     # jitted on this island's devices
    mesh: Optional[Mesh] = None


class ModalityIslands:
    """Place each encoder on its own device subset; the LLM on the rest.

    ``run(params, batch)`` dispatches every encoder island asynchronously
    (no dependency between them — the execution DAG guarantees it), then
    feeds their outputs to the LLM island. With JAX async dispatch the
    encoder computations overlap on real hardware; on CPU this verifies
    correctness + device placement.
    """

    def __init__(self, mllm, device_split: Dict[str, List[Any]]):
        from repro.models import mllm as M
        self.mllm = mllm
        self.islands: Dict[str, Island] = {}
        for name, enc in mllm.encoders.items():
            devs = device_split[name]
            sh = NamedSharding(Mesh(np.array(devs), ("d",)), P())

            def enc_fn(params, batch, enc=enc, sh=sh):
                params = jax.device_put(params, sh)
                return enc.forward(params, batch)

            self.islands[name] = Island(name, devs,
                                        jax.jit(enc_fn, static_argnums=()))
        devs = device_split["llm"]
        self.llm_sharding = NamedSharding(Mesh(np.array(devs), ("d",)), P())

        def llm_fn(params, merged, mllm=mllm):
            from repro.models import transformer as T
            return T.forward(params, mllm.llm_cfg, merged)

        self.llm_fn = jax.jit(llm_fn)

    def run(self, params, batch):
        # dispatch all encoder islands first — async, overlapping
        futures = {}
        for name, isl in self.islands.items():
            futures[name] = isl.fn(params["encoders"][name], batch)
        # cross-island transfer (the paper's encoder->LLM P2P send)
        futures = {name: jax.device_put(out, self.llm_sharding)
                   for name, out in futures.items()}
        merged = self.mllm.build_merge(
            jax.device_put(batch["text_tokens"], self.llm_sharding), futures)
        llm_p = jax.device_put(params["llm"], self.llm_sharding)
        return self.llm_fn(llm_p, merged)


# ---------------------------------------------------------------------------
# 3. Schedule-driven executor: replay a simulated item timeline with real
#    stage computations (the memory-validation target)
# ---------------------------------------------------------------------------

def _accepts_microbatch(fn: Callable) -> bool:
    """Does ``fn`` implement the 3-arg StageFn contract
    ``fn(stage_params, x, microbatch)``?  Legacy 2-arg stage fns
    (``fn(stage_params, x)``) are still accepted everywhere."""
    import inspect
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return False
    params = list(sig.parameters.values())
    if any(p.kind == p.VAR_POSITIONAL for p in params):
        return True
    pos = [p for p in params if p.kind in
           (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)]
    return len(pos) >= 3


def normalize_stage_fns(stage_fn, num_stages: int) -> List[Callable]:
    """Normalize a stage-fn argument to a list of per-stage 3-arg
    callables (``models.stages.StageBundle.stage_fns`` passes a list;
    a single callable is replicated; 2-arg fns get the microbatch
    argument dropped)."""
    if isinstance(stage_fn, (list, tuple)):
        fns = list(stage_fn)
        if len(fns) != num_stages:
            raise ValueError(
                f"got {len(fns)} stage fns for {num_stages} stages")
    else:
        fns = [stage_fn] * num_stages
    return [f if _accepts_microbatch(f)
            else (lambda lp, x, mb, _f=f: _f(lp, x)) for f in fns]


def execute_schedule(stage_fn, stage_params, microbatches,
                     graph, sim: Dict[str, Any], *,
                     microbatch_loss: Optional[Callable] = None,
                     devices: Optional[Sequence[Any]] = None,
                     trainable: Optional[Sequence[bool]] = None
                     ) -> Dict[str, Any]:
    """Execute a simulated schedule's work-item timeline with REAL
    stage computations, instrumenting live activations per device.

    This is the executor side of the memory-validation harness
    (``core.schedule.memory``): the discrete-event simulator claims a
    per-device peak of live activations under its admission caps
    (``depth_from_end``); this function replays the exact item order
    the simulator emitted — F with a real forward, B with a real
    input-grad VJP, W with a real weight-grad VJP — while holding every
    inter-stage activation in an explicit store that is filled at F and
    drained at B. The store's peak occupancy per device is the
    measurement. Executing the timeline also *validates* it: an item
    order that violated data dependencies or freed an activation too
    early dies with a KeyError here rather than silently diverging.

    Contracts: ``stage_fn`` is one callable or a per-stage list, each
    ``fn(lp, x, microbatch) -> y`` (legacy ``fn(lp, x)`` accepted) with
    x/y of identical shape (the carrier contract — real MLLM stages
    come from ``models.stages``); ``stage_params`` stage-stacked with
    leading dim S, or a *list* of per-stage trees when stages are
    heterogeneous (param_grads then comes back as a matching list);
    ``trainable`` overrides which stages must produce weight grads —
    default ``bwd_w > 0`` per stage, but a frozen stage holding a
    trainable projector has no W cost in the schedule model yet still
    needs its grads glued at B (the paper's §6 configuration);
    ``microbatches`` [M, ...]; ``graph`` any stage DAG in topological
    order — source
    stages read the microbatch, fan-in stages consume the SUM of their
    predecessors' outputs (the modality-parallel merge: every encoder
    chain feeds the first LLM stage), fan-out stages accumulate the
    cotangents their successors send back, and the loss sums over sink
    stages. ``sim`` is any ``core.schedule`` simulation dict (``items``
    + ``device_of``), so folded placements — interleaved round-robin,
    ZB-V — execute on their simulated device map. When ``devices`` (one JAX device per
    pipeline rank) is given, each rank's params and activations are
    placed on its device; otherwise placement is logical.

    Memory accounting mirrors the simulator's model: an activation is
    live on stage s's device from the execution of F(s, m) until the
    execution of B(s, m). Two deliberate simplifications, kept
    symmetric on both sides so the comparison stays exact: (1) output
    cotangents and in-transit stage outputs are not counted (they hand
    over at the consumer's admission point, which is what the caps
    bound); (2) a trainable stage's deferred W pass moves its operands
    (input activation + output cotangent) to a separate W-residual
    store, reported as ``peak_w_residuals_per_device`` — the zero-
    bubble papers' memory-vs-bubble trade-off, measured rather than
    hidden.

    Returns dict: outputs [M, ...], loss, param_grads (stage-stacked,
    zero for stages the schedule assigns no W/B-glued weight work),
    peak_activations_per_device, peak_w_residuals_per_device.
    """
    from repro.core.schedule.simulator import item_id

    S = len(graph.stages)
    preds, succs = graph.preds, graph.succs
    M = int(microbatches.shape[0])
    items = sim["items"]
    device_of = sim["device_of"]
    D = int(sim["num_devices"])
    loss_fn = microbatch_loss or (lambda y: jnp.mean(y ** 2))
    has_w_items = any(kind == "W" for _, _, _, kind, _, _ in items)
    fns = normalize_stage_fns(stage_fn, S)
    hetero = isinstance(stage_params, (list, tuple))
    if trainable is None:
        trainable = [graph.stages[s].bwd_w > 0 for s in range(S)]
    trainable = [bool(t) for t in trainable]
    assert len(trainable) == S

    def rank_param(s):
        lp = stage_params[s] if hetero \
            else jax.tree.map(lambda a: a[s], stage_params)
        if devices is not None:
            lp = jax.device_put(lp, devices[device_of[s]])
        return lp

    params = [rank_param(s) for s in range(S)]
    grads = [jax.tree.map(jnp.zeros_like, p) for p in params]
    store: Dict[tuple, Any] = {}        # (s, m) -> input activation
    w_store: Dict[tuple, Any] = {}      # (s, m) -> (x, output cotangent)
    transit: Dict[tuple, Any] = {}      # produced, not yet admitted
    cot: Dict[tuple, Any] = {}          # (s, m) -> output cotangent
    outputs: List[Any] = [None] * M

    def accumulate(d: Dict[tuple, Any], key: tuple, val: Any) -> None:
        # fan-in merge: a consumer stage with several predecessors (or
        # a fan-out stage with several successors in the backward)
        # sums what arrives, in timeline order
        d[key] = val if key not in d else jax.tree.map(
            jnp.add, d[key], val)

    peak = [0] * D
    w_peak = [0] * D
    loss = 0.0
    # per-item measurement: (item_id, device, live activations on that
    # device AFTER the item ran) — the ids are
    # ``core.schedule.simulator.item_id`` strings, shared with
    # schedlint findings and MemoryModelMismatch diffs
    trace: List[tuple] = []
    act_nbytes = 0

    def store_count(d):
        # measure the CONTAINER, not a parallel counter: the peak is
        # however many entries the store truly holds for device d
        return sum(1 for (s_, _m) in store if device_of[s_] == d)

    for item in items:
        start, _end, dev, kind, s, m = item
        st = graph.stages[s]
        if kind == "F":
            x = transit.pop((s, m)) if preds[s] else microbatches[m]
            if devices is not None:
                x = jax.device_put(x, devices[dev])
            store[(s, m)] = x
            act_nbytes = max(act_nbytes, int(getattr(x, "nbytes", 0)))
            peak[dev] = max(peak[dev], store_count(dev))
            y = fns[s](params[s], x, microbatches[m])
            if not succs[s]:                     # sink: loss + cotangent
                outputs[m] = y if outputs[m] is None \
                    else outputs[m] + y
                loss = loss + loss_fn(y)
                accumulate(cot, (s, m), jax.grad(loss_fn)(y))
            else:
                for q in succs[s]:
                    accumulate(transit, (q, m), y)
        elif kind == "B":
            x = store.pop((s, m))
            # frozen stages with nothing trainable upstream (bwd_b = 0)
            # receive no cotangent — their B item only frees memory
            g = cot.pop((s, m), None)
            assert g is not None or (st.bwd_b == 0 and st.bwd_w == 0
                                     and not trainable[s]), \
                f"missing cotangent for B({s}, {m})"
            if st.bwd_b > 0 and preds[s]:
                _, vjp_x = jax.vjp(
                    lambda xx: fns[s](params[s], xx, microbatches[m]), x)
                (dx,) = vjp_x(g)
                for p in preds[s]:
                    accumulate(cot, (p, m), dx)
            if trainable[s]:
                # park for a deferred W item only if the schedule
                # emitted one (bwd_w > 0); a trainable stage the cost
                # model sees as weight-free glues its grads here
                if has_w_items and st.bwd_w > 0:
                    w_store[(s, m)] = (x, g)
                    w_peak[dev] = max(w_peak[dev], sum(
                        1 for (s_, _m) in w_store
                        if device_of[s_] == dev))
                else:                        # glued: weight grads now
                    _, vjp_p = jax.vjp(
                        lambda pp: fns[s](pp, x, microbatches[m]),
                        params[s])
                    (gp,) = vjp_p(g)
                    grads[s] = jax.tree.map(jnp.add, grads[s], gp)
        else:                                # W
            parked = w_store.pop((s, m), None)
            if parked is not None:           # else: trainable=False
                x, g = parked                # override — W is a no-op
                _, vjp_p = jax.vjp(
                    lambda pp: fns[s](pp, x, microbatches[m]), params[s])
                (gp,) = vjp_p(g)
                grads[s] = jax.tree.map(jnp.add, grads[s], gp)
        trace.append((item_id(item), dev, store_count(dev)))

    assert not store and not w_store and not transit, \
        "schedule left live activations behind (incomplete timeline)"
    assert all(y is not None for y in outputs)
    return {
        "outputs": jnp.stack(outputs),
        "loss": loss,
        "param_grads": grads if hetero
        else jax.tree.map(lambda *xs: jnp.stack(xs), *grads),
        "peak_activations_per_device": peak,
        "peak_w_residuals_per_device": w_peak,
        "activation_trace": trace,
        "activation_nbytes": act_nbytes,
    }


def _is_typed_plan(plan: Any) -> bool:
    from repro.parallel.plan import MLLMParallelPlan
    return isinstance(plan, MLLMParallelPlan)


def _dict_schedule_name(plan: Dict[str, Any]) -> Optional[str]:
    """The schedule name a legacy plan dict carries, if any:
    ``auto_parallelize`` results keep it under "schedule",
    ``MultimodalParallelSpec.apply`` plans keep the sim dict there and
    the name under "schedule_name"."""
    name = plan.get("schedule")
    if not isinstance(name, str):
        name = plan.get("schedule_name")
    return name if isinstance(name, str) else None


def schedule_from_plan(plan: Any) -> str:
    """DEPRECATED shim — read ``plan.schedule.name`` off an
    ``MLLMParallelPlan`` instead. Accepts the typed plan, the two
    legacy dict flavors (``auto_parallelize`` result /
    ``MultimodalParallelSpec.apply``), or None (no plan -> classic
    1F1B). A dict that carries no recognizable schedule, or a name
    outside ``core.schedule.SCHEDULES``, raises ``ValueError`` — the
    silent-1F1B default masked genuinely malformed plans."""
    import warnings
    warnings.warn(
        "schedule_from_plan is deprecated; use "
        "repro.parallel.MLLMParallelPlan and plan.schedule.name",
        DeprecationWarning, stacklevel=2)
    from repro.core.schedule import SCHEDULES
    if plan is None:
        return "1f1b"
    if _is_typed_plan(plan):
        return plan.schedule.name
    if isinstance(plan, dict):
        name = _dict_schedule_name(plan)
        if name in SCHEDULES:
            return name
        raise ValueError(
            f"plan carries no recognizable schedule (got {name!r}, "
            f"valid: {SCHEDULES}); pass an MLLMParallelPlan, an "
            "auto_parallelize result, or a MultimodalParallelSpec."
            "apply dict")
    raise ValueError(f"not a plan: {type(plan).__name__!r}")


def virtual_chunks_from_plan(plan: Any) -> int:
    """DEPRECATED shim — read ``plan.schedule.virtual_chunks`` off an
    ``MLLMParallelPlan`` instead. Same accepted flavors as
    ``schedule_from_plan``; a recognized plan without the tag (both
    legacy flavors always carry it) defaults to 1, anything malformed
    raises ``ValueError``."""
    import warnings
    warnings.warn(
        "virtual_chunks_from_plan is deprecated; use "
        "repro.parallel.MLLMParallelPlan and "
        "plan.schedule.virtual_chunks",
        DeprecationWarning, stacklevel=2)
    from repro.core.schedule import SCHEDULES
    if plan is None:
        return 1
    if _is_typed_plan(plan):
        return plan.schedule.virtual_chunks
    if isinstance(plan, dict):
        v = plan.get("virtual_chunks")
        if isinstance(v, int) and v >= 1:
            return v
        if v is None and _dict_schedule_name(plan) in SCHEDULES:
            return 1
        raise ValueError(f"plan carries no usable virtual_chunks "
                         f"(got {v!r})")
    raise ValueError(f"not a plan: {type(plan).__name__!r}")


def split_devices(mllm, devices: Sequence[Any],
                  plan: Any = None) -> Dict[str, list]:
    """Assign device counts per module (default: 1 per encoder, rest to
    the LLM). ``plan`` is an ``MLLMParallelPlan`` (the typed API), a
    plain {encoder_name: count} dict, or the legacy result dict of
    ``core.pipeline.auto_parallelize``, whose per-encoder stage counts
    are matched by the "encoder_names" it carries. The winning schedule
    travels on the typed plan (``plan.schedule``); this dict stays
    purely {module: device list}."""
    devices = list(devices)
    if _is_typed_plan(plan):
        plan = plan.stage_counts_by_name()
    elif plan and "encoder_stages" in plan:   # auto_parallelize result
        names = plan.get("encoder_names") or sorted(mllm.encoders)
        plan = dict(zip(names, plan["encoder_stages"]))
    plan = plan or {name: 1 for name in mllm.encoders}
    out: Dict[str, list] = {}
    i = 0
    for name in sorted(mllm.encoders):
        n = plan.get(name, 1)
        out[name] = devices[i:i + n]
        i += n
    out["llm"] = devices[i:]
    assert out["llm"], "no devices left for the LLM"
    return out
