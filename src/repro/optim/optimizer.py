"""AdamW with frozen-parameter masking + LR schedules.

Frozen masking is load-bearing for Cornstarch: frozen modules get NO
optimizer state and NO updates (their backward is already skipped by
stop_gradient in the forward; tests assert both). Implemented optax-free
(optax isn't in the container) as a pure pytree transformation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"    # cosine | constant


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    frac = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def _tree_zeros_like_masked(params, frozen_mask):
    """Frozen leaves get a zero-size placeholder (no optimizer memory)."""
    def z(p, frz):
        if frz:
            return jnp.zeros((0,), jnp.float32)
        return jnp.zeros_like(p, jnp.float32)
    return jax.tree.map(z, params, frozen_mask)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def init(cfg: AdamWConfig, params, frozen_mask=None):
    if frozen_mask is None:
        frozen_mask = jax.tree.map(lambda _: False, params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": _tree_zeros_like_masked(params, frozen_mask),
        "v": _tree_zeros_like_masked(params, frozen_mask),
    }


def update(cfg: AdamWConfig, grads, state, params, frozen_mask=None):
    """Returns (new_params, new_state, metrics)."""
    if frozen_mask is None:
        frozen_mask = jax.tree.map(lambda _: False, params)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, frz):
        if frz:
            return p, m, v
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    flat_f = tdef.flatten_up_to(frozen_mask)
    outs = [upd(p, g, m, v, frz)
            for p, g, m, v, frz in zip(flat_p, flat_g, flat_m, flat_v,
                                       flat_f)]
    new_p = tdef.unflatten([o[0] for o in outs])
    new_m = tdef.unflatten([o[1] for o in outs])
    new_v = tdef.unflatten([o[2] for o in outs])
    return new_p, {"step": step, "m": new_m, "v": new_v}, \
        {"grad_norm": gnorm, "lr": lr}
