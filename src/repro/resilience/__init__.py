"""Fault-tolerant training runtime (see docs/resilience.md).

Three layers over the existing training stack:

* health monitoring — ``make_resilient_train_step`` (in-jit NaN/Inf +
  grad-norm + EMA loss-spike bundle, update gated on step health) and
  ``HealthMonitor`` (host-side ``ok|skip|rollback|abort`` classifier
  with a JSONL ``EventLog``);
* atomic resumable checkpointing — ``CheckpointManager``
  (write-to-temp-then-rename, per-shard crc32, retention, one manifest
  bundling params + optimizer + EMA state + data cursor + free-form
  meta, ``latest()`` discovery);
* rollback-and-retry — ``ResilientTrainer`` + ``RetryPolicy`` +
  ``CursorStream``, with the deterministic fault-injection harness
  (``FaultPlan``/``FaultInjector``) that makes crash/rollback paths
  assertable in tier-1 tests.
"""
from repro.resilience.faults import (FAULT_KINDS, CrashInjected,
                                     DeviceLossInjected, Fault,
                                     FaultInjector, FaultPlan,
                                     corrupt_shard)
from repro.resilience.manager import CheckpointManager
from repro.resilience.monitor import (ABORT, BUNDLE_KEYS, OK, ROLLBACK,
                                      SKIP, VERDICTS, EventLog,
                                      HealthMonitor, MonitorConfig,
                                      bundle_dict, default_controls,
                                      init_health,
                                      make_resilient_train_step)
from repro.resilience.trainer import (CursorStream, ResilientTrainer,
                                      RetryPolicy, TrainingAborted)

__all__ = [
    "ABORT", "BUNDLE_KEYS", "FAULT_KINDS", "OK", "ROLLBACK", "SKIP",
    "VERDICTS", "CheckpointManager", "CrashInjected", "CursorStream",
    "DeviceLossInjected", "EventLog", "Fault", "FaultInjector",
    "FaultPlan", "HealthMonitor", "MonitorConfig", "ResilientTrainer",
    "RetryPolicy", "TrainingAborted", "bundle_dict", "corrupt_shard",
    "default_controls", "init_health", "make_resilient_train_step",
]
