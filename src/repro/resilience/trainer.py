"""Rollback-and-retry training runtime.

:class:`ResilientTrainer` owns the guarded step (see ``monitor``), a
:class:`CursorStream` over the data, a :class:`HealthMonitor`, an
optional :class:`CheckpointManager`, and an optional
:class:`FaultInjector`, and runs the loop that every verdict maps
onto:

* ``ok``       — commit the step (the in-jit gate already applied it),
  record the loss, checkpoint on the cadence.
* ``skip``     — the in-jit gate withheld the update; params/optimizer
  /EMA are bit-identical to before the step. The batch is consumed and
  the step index advances (the poisoned batch is *dropped*).
* ``rollback`` — restore the last good checkpoint (params + optimizer
  + EMA + data cursor, all from one manifest), fast-forward the stream
  to the restored cursor, shrink the retry ``clip_scale``
  (escalating grad clip), and re-run from there. Attempts are bounded
  by :class:`RetryPolicy`; exceeding them aborts.
* ``abort``    — raise :class:`TrainingAborted` (state is left at the
  last good values; the caller decides what to do with the corpse).

Injected faults ride the same paths: a ``crash`` raises out of the
loop exactly like a SIGKILL would; a new trainer constructed with
``resume=True`` over the same checkpoint root continues bit-exactly
(the resume-equivalence test in ``tests/test_resilience.py`` asserts
the loss trajectory matches an uninterrupted run). A ``device_loss``
triggers the ``on_device_loss`` hook — ``launch/train`` re-runs
``parallelize()`` over the shrunken ``ClusterSpec`` there — then
resumes from the last checkpoint (device state is gone by definition).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, Optional

import jax.numpy as jnp

from repro.resilience.faults import FaultInjector
from repro.resilience.manager import CheckpointManager
from repro.resilience.monitor import (ABORT, OK, ROLLBACK, SKIP,
                                      HealthMonitor, bundle_dict,
                                      default_controls, init_health)


class TrainingAborted(RuntimeError):
    """The monitor escalated to ``abort`` (or retries ran out)."""


class CursorStream:
    """A replayable, position-aware stream over a deterministic batch
    factory. ``factory()`` must return a fresh iterator that replays
    the same batch sequence every time (our synthetic datasets are
    seeded generators, so this is free); ``seek(n)`` fast-forwards a
    fresh iterator — how rollback and resume land on the exact batch
    the restored step would have seen."""

    def __init__(self, factory: Callable[[], Iterable]):
        self.factory = factory
        self._it = iter(factory())
        self.cursor = 0

    def next(self):
        batch = next(self._it)
        self.cursor += 1
        return batch

    def seek(self, cursor: int) -> None:
        if cursor < 0:
            raise ValueError(f"cursor must be >= 0, got {cursor}")
        self._it = iter(self.factory())
        for _ in range(cursor):
            next(self._it)
        self.cursor = cursor


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Rollback retry bounds + escalating grad clip.

    max_attempts: rollbacks allowed without an intervening successful
        checkpoint before the trainer aborts.
    clip_decay: each rollback multiplies the retry ``clip_scale`` by
        this (grads shrink, the retried step is gentler).
    recover_steps: consecutive ok steps after which ``clip_scale``
        resets to 1.0 and the attempt counter clears.
    """
    max_attempts: int = 3
    clip_decay: float = 0.5
    recover_steps: int = 25


class ResilientTrainer:
    """See module docstring. ``step_fn`` is a (jitted) guarded step
    from :func:`repro.resilience.monitor.make_resilient_train_step`."""

    def __init__(self, step_fn, params, opt_state, stream: CursorStream,
                 *, monitor: Optional[HealthMonitor] = None,
                 manager: Optional[CheckpointManager] = None,
                 injector: Optional[FaultInjector] = None,
                 policy: Optional[RetryPolicy] = None,
                 ckpt_every: int = 0, resume: bool = False,
                 meta: Optional[Dict[str, Any]] = None,
                 on_device_loss: Optional[Callable[[int], None]] = None,
                 log_every: int = 0):
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.health = init_health()
        self.stream = stream
        self.monitor = monitor or HealthMonitor()
        self.manager = manager
        self.injector = injector or FaultInjector()
        self.policy = policy or RetryPolicy()
        self.ckpt_every = ckpt_every
        self.meta = dict(meta or {})
        self.on_device_loss = on_device_loss
        self.log_every = log_every
        self.step = 0
        self.losses: Dict[int, float] = {}
        self.clip_scale = 1.0
        self._attempts = 0
        self._ok_streak = 0
        if resume:
            if manager is None:
                raise ValueError("resume=True needs a CheckpointManager")
            if manager.latest() is None:
                self.monitor.log.emit("resume-empty", 0, root=manager.root)
            else:
                self._restore("resume")

    # -- checkpoint plumbing -----------------------------------------------

    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state,
                "health": self.health}

    def save_checkpoint(self, on_entry=None) -> Optional[str]:
        if self.manager is None:
            return None
        meta = {**self.meta, "step": self.step,
                "cursor": self.stream.cursor,
                "clip_scale": self.clip_scale}
        path = self.manager.save(self.step, self._state_tree(),
                                 meta=meta, on_entry=on_entry)
        self.monitor.log.emit("checkpoint", self.step, dir=path,
                              cursor=self.stream.cursor)
        return path

    def adopt_state(self, params, opt_state, health=None, *,
                    step: int, cursor: Optional[int] = None) -> None:
        """Install externally-restored training state (cross-mode
        resume: the launcher loaded a checkpoint written under a
        DIFFERENT param layout — e.g. a replay-mode tree resumed into
        an SPMD run — converted it, and hands the result here instead
        of ``resume=True``'s like-tree restore). Seeks the stream and
        logs the adoption so the event trail shows where the state
        came from."""
        self.params = params
        self.opt_state = opt_state
        self.health = health if health is not None else init_health()
        self.step = int(step)
        self.stream.seek(int(cursor if cursor is not None else step))
        self.monitor.log.emit("adopt", self.step,
                              cursor=self.stream.cursor)

    def _restore(self, why: str) -> None:
        tree, step, meta = self.manager.restore(self._state_tree())
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.health = tree["health"]
        self.step = int(meta.get("step", step))
        self.stream.seek(int(meta.get("cursor", self.step)))
        self.monitor.log.emit("restore", self.step, why=why,
                              cursor=self.stream.cursor)

    # -- the loop ----------------------------------------------------------

    def _controls(self, inject_nan: bool):
        c = default_controls()
        c["max_grad_norm"] = jnp.float32(self.monitor.cfg.max_grad_norm)
        c["clip_scale"] = jnp.float32(self.clip_scale)
        c["inject_nan"] = jnp.float32(1.0 if inject_nan else 0.0)
        return c

    def _rollback(self, step: int, reason: str) -> None:
        self._attempts += 1
        if self.manager is None or self.manager.latest() is None:
            raise TrainingAborted(
                f"rollback requested at step {step} ({reason}) but no "
                f"checkpoint exists to roll back to — configure a "
                f"CheckpointManager and ckpt_every for rollback "
                f"coverage")
        if self._attempts > self.policy.max_attempts:
            raise TrainingAborted(
                f"rollback at step {step} ({reason}) exceeded "
                f"{self.policy.max_attempts} retry attempts")
        self.clip_scale *= self.policy.clip_decay
        self._ok_streak = 0
        self._restore(f"rollback:{reason}")
        self.monitor.log.emit("retry", self.step, reason=reason,
                              attempt=self._attempts,
                              clip_scale=self.clip_scale)

    def run(self, num_steps: int) -> Dict[str, Any]:
        """Train until ``self.step == num_steps``; returns a summary
        (losses by step, verdict counters, fired faults)."""
        while self.step < num_steps:
            step = self.step
            self.injector.check_crash(step)
            loss_ev = self.injector.check_device_loss(step)
            if loss_ev is not None:
                self.monitor.log.emit("device-loss", step,
                                      lost=loss_ev.lost)
                if self.on_device_loss is not None:
                    self.on_device_loss(loss_ev.lost)
                if self.manager is not None and \
                        self.manager.latest() is not None:
                    self._restore("device-loss")
                continue

            batch = self.stream.next()
            self.params, self.opt_state, self.health, bundle = \
                self.step_fn(self.params, self.opt_state, self.health,
                             batch, self._controls(
                                 self.injector.nan_at(step)))
            b = bundle_dict(bundle)
            verdict = self.monitor.classify(step, b)

            if verdict == ABORT:
                raise TrainingAborted(
                    f"monitor aborted training at step {step}: {b}")
            if verdict == ROLLBACK:
                self._rollback(step, "verdict")
                continue
            # ok | skip: the in-jit gate already did the right thing
            self.step += 1
            if verdict == OK:
                self.losses[step] = b["loss"]
                self._ok_streak += 1
                if self._ok_streak >= self.policy.recover_steps and \
                        self.clip_scale != 1.0:
                    self.clip_scale = 1.0
                    self._attempts = 0
                    self.monitor.log.emit("recovered", step)
                if self.log_every and step % self.log_every == 0:
                    print(f"step {step:5d} loss {b['loss']:.4f} "
                          f"gnorm {b['grad_norm']:.3f}", flush=True)
            if self.ckpt_every and verdict == OK and \
                    self.step % self.ckpt_every == 0:
                # a crash_in_save fault at this step kills the write
                # mid-shard; CrashInjected propagates like a SIGKILL
                self.save_checkpoint(
                    on_entry=self.injector.save_hook(step))
        return self.summary()

    def summary(self) -> Dict[str, Any]:
        ev = self.monitor.log
        return {
            "last_step": self.step,
            "losses": dict(self.losses),
            "rollbacks": self.monitor.rollbacks,
            "skipped": len([e for e in ev.of_kind("verdict")
                            if e.get("verdict") == SKIP]),
            "fired_faults": [dataclasses.asdict(f)
                             for f in self.injector.fired],
            "clip_scale": self.clip_scale,
        }
