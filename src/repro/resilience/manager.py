"""Atomic, resumable, retained checkpoints over ``repro.checkpoint``.

Layout under one root::

    <root>/step_00000010/     — one complete checkpoint per step
        manifest.msgpack      — shards + crc32s + bundled meta (data
                                cursor, RNG seed, plan JSON, ...)
        arr_*.npy
    <root>/LATEST             — name of the newest complete checkpoint
    <root>/.tmp-step_*        — in-flight saves (never readable)

Crash-safety is rename-based: a save writes every shard and the
manifest into a ``.tmp-`` dir, then ``os.replace``s it to its final
name and rewrites ``LATEST`` through its own temp file. A process
killed at ANY point leaves either the previous checkpoint set intact
(tmp dir is garbage, collected on the next manager construction) or
the new one fully visible — never a half-written dir that ``load``
could mistake for a checkpoint. ``latest()`` trusts ``LATEST`` but
falls back to scanning step dirs (a crash can land between the two
renames), so recovery never depends on the pointer file.

Retention keeps the newest ``keep`` checkpoints. Frozen-module shards
are hardlinked forward from the previous step's dir (``skip_frozen``
via ``checkpoint.save``'s ``prev_dir``), which makes retention safe by
construction: deleting an old dir drops a link, not the bytes.
"""
from __future__ import annotations

import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

from repro.checkpoint import checkpoint as ckpt

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _step_name(step: int) -> str:
    if step < 0:
        raise ValueError(f"checkpoint step must be >= 0, got {step}")
    return f"step_{step:08d}"


class CheckpointManager:
    """Owns one checkpoint root: atomic saves, ``latest()`` discovery,
    retention, and frozen-shard reuse across steps."""

    def __init__(self, root: str, *, keep: int = 3,
                 frozen_paths: Optional[set] = None):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.root = root
        self.keep = keep
        self.frozen_paths = frozen_paths
        os.makedirs(root, exist_ok=True)
        # collect garbage from saves a previous process died inside of
        for name in os.listdir(root):
            if name.startswith(".tmp-"):
                shutil.rmtree(os.path.join(root, name),
                              ignore_errors=True)
        self._prev: Optional[Tuple[str, dict]] = None
        last = self.latest()
        if last is not None:
            self._prev = (last, ckpt.read_manifest(last))

    # -- discovery ---------------------------------------------------------

    def steps(self) -> List[int]:
        """Steps of every complete checkpoint under the root, sorted."""
        out = []
        for name in os.listdir(self.root):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.root, name,
                                                 "manifest.msgpack")):
                out.append(int(m.group(1)))
        return sorted(out)

    def dir_for(self, step: int) -> str:
        return os.path.join(self.root, _step_name(step))

    def latest(self) -> Optional[str]:
        """Dir of the newest complete checkpoint (None if there is
        none). Reads ``LATEST`` first; falls back to a scan when the
        pointer is missing or stale (crash between the two renames)."""
        marker = os.path.join(self.root, "LATEST")
        if os.path.exists(marker):
            with open(marker, encoding="utf-8") as f:
                name = f.read().strip()
            d = os.path.join(self.root, name)
            if os.path.exists(os.path.join(d, "manifest.msgpack")):
                return d
        steps = self.steps()
        return self.dir_for(steps[-1]) if steps else None

    # -- save / restore ----------------------------------------------------

    def save(self, step: int, tree, *, meta: Optional[Dict[str, Any]]
             = None, on_entry=None) -> str:
        """Atomically persist ``tree`` (+ ``meta``) as the step's
        checkpoint; returns the final dir. ``on_entry`` forwards to
        ``checkpoint.save`` (the kill-mid-save fault hook)."""
        name = _step_name(step)
        tmp = os.path.join(self.root, f".tmp-{name}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        prev_dir, prev_man = self._prev if self._prev else (None, None)
        manifest = ckpt.save(tmp, tree, step=step, meta=meta,
                             frozen_paths=self.frozen_paths,
                             prev_manifest=prev_man, prev_dir=prev_dir,
                             on_entry=on_entry)
        final = os.path.join(self.root, name)
        if os.path.exists(final):   # re-save of the same step
            shutil.rmtree(final)
        os.replace(tmp, final)
        lat_tmp = os.path.join(self.root, ".LATEST.tmp")
        with open(lat_tmp, "w", encoding="utf-8") as f:
            f.write(name + "\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(lat_tmp, os.path.join(self.root, "LATEST"))
        self._prev = (final, manifest)
        self._retain()
        return final

    def restore(self, like=None, *, step: Optional[int] = None,
                verify: bool = True):
        """Load the newest (or a specific step's) checkpoint. Returns
        ``(tree, step, meta)``; raises ``CheckpointError`` when there
        is nothing to restore or the data fails validation."""
        d = self.dir_for(step) if step is not None else self.latest()
        if d is None:
            raise ckpt.CheckpointError(
                f"no checkpoint to restore under {self.root!r}")
        tree, got_step = ckpt.load(d, like, verify=verify)
        meta = ckpt.read_manifest(d).get("meta", {})
        return tree, got_step, meta

    def peek_meta(self) -> Dict[str, Any]:
        """Meta of the newest checkpoint WITHOUT loading any arrays
        (empty dict when there is no checkpoint). Lets a launcher
        inspect e.g. ``meta["mode"]`` / ``meta["spmd_layout"]`` before
        deciding what shape of state tree to restore into."""
        d = self.latest()
        if d is None:
            return {}
        return dict(ckpt.read_manifest(d).get("meta", {}))

    # -- retention ---------------------------------------------------------

    def _retain(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir_for(s), ignore_errors=True)
