"""Step-health monitoring: in-jit metrics bundle + host-side classifier.

Two halves, split exactly at the device/host boundary:

* :func:`make_resilient_train_step` builds the **guarded** train step.
  Inside the jitted step it computes NaN/Inf flags, the global grad
  norm, and an EMA-based loss-spike z-score, fuses them into ONE small
  f32 vector (``BUNDLE_KEYS`` names its lanes), and — crucially —
  gates the optimizer update on step health *inside* the jit: a
  non-finite or over-norm step applies **no** update (params, optimizer
  moments, and the EMA state all keep their previous values via a
  ``jnp.where`` select), so a single NaN can never poison training
  state no matter what the host does with the verdict. The host reads
  one array per step — the same sync logging already paid for — and
  per-step *policy* knobs (grad-norm ceiling, retry clip scale, fault
  injection) are traced scalars, so changing them never retraces.

* :class:`HealthMonitor` is the host-side classifier: it maps a bundle
  to an ``ok | skip | rollback | abort`` verdict under a
  :class:`MonitorConfig` policy (consecutive-skip escalation, total
  rollback budget) and writes every decision to a structured JSONL
  :class:`EventLog` — the audit trail the fault-injection tests replay.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Any, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.optim import optimizer as opt

#: verdicts, in escalation order
OK, SKIP, ROLLBACK, ABORT = "ok", "skip", "rollback", "abort"
VERDICTS = (OK, SKIP, ROLLBACK, ABORT)

#: lanes of the fused health bundle the guarded step emits, in order:
#:   loss       — this step's loss (pre-gate; may be nan/inf)
#:   grad_norm  — global grad norm (pre-clip; may be nan/inf)
#:   spike      — |loss - EMA| / sqrt(EMA-variance) z-score (0 during
#:                EMA warmup — the host applies its own warmup gate too)
#:   nonfinite  — 1.0 iff loss or grad norm is NaN/Inf
#:   applied    — 1.0 iff the in-jit gate applied the update
BUNDLE_KEYS = ("loss", "grad_norm", "spike", "nonfinite", "applied")


def init_health() -> Dict[str, Any]:
    """The EMA state threaded through the guarded step (and bundled
    into every checkpoint, so resumes keep the spike baseline)."""
    return {"ema": jnp.float32(0.0), "var": jnp.float32(0.0),
            "count": jnp.int32(0)}


def default_controls() -> Dict[str, Any]:
    """Per-step policy scalars (traced — mutate freely, no retrace):
    ``max_grad_norm`` in-jit skip ceiling, ``clip_scale`` retry grad
    shrink (<1 after a rollback), ``inject_nan`` deterministic
    NaN-grad fault switch."""
    return {"max_grad_norm": jnp.float32(np.inf),
            "clip_scale": jnp.float32(1.0),
            "inject_nan": jnp.float32(0.0)}


def make_resilient_train_step(loss_fn, ocfg: opt.AdamWConfig,
                              frozen_mask=None, *,
                              ema_decay: float = 0.98,
                              value_and_grad_fn=None):
    """``step(params, opt_state, health, batch, controls) ->
    (params, opt_state, health, bundle)`` — ``make_train_step`` with
    the health bundle fused in and the update gated on step health.

    ``loss_fn(params, batch) -> (loss, aux)`` is the same callable the
    plain step builders consume (``steps.make_loss_fn`` or
    ``make_mllm_train_step``'s second return). The bundle is one f32
    ``[len(BUNDLE_KEYS)]`` vector — a single device->host transfer
    per step, no extra syncs.

    ``value_and_grad_fn(params, batch) -> ((loss, aux), grads)``
    overrides the default ``jax.value_and_grad(loss_fn)`` — this is
    how executors that compute grads themselves (the SPMD schedule
    runner, whose backward is the schedule's B/W items, not one
    autodiff sweep) plug into the same health gate. When set,
    ``loss_fn`` may be ``None``.
    """
    if value_and_grad_fn is None:
        value_and_grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, health, batch, controls):
        (loss, _aux), grads = value_and_grad_fn(params, batch)
        # deterministic fault injection: a traced switch multiplies
        # every grad by NaN — exactly what a real overflow looks like
        # downstream, with none of the nondeterminism
        poison = jnp.where(controls["inject_nan"] > 0,
                           jnp.float32(np.nan), jnp.float32(1.0))
        grads = jax.tree.map(lambda g: g * poison.astype(g.dtype), grads)
        gnorm = opt.global_norm(grads)
        finite = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        ok = finite & (gnorm <= controls["max_grad_norm"])

        # EMA loss-spike score (computed BEFORE this step's loss is
        # folded in — a spike must not dilute its own baseline)
        warm = health["count"] > 0
        mean = jnp.where(warm, health["ema"], loss)
        dev = loss - mean
        spike = jnp.where(
            warm & finite,
            jnp.abs(dev) * jax.lax.rsqrt(health["var"] + 1e-8),
            jnp.float32(0.0))

        # the optimizer must never see non-finite grads (NaN would
        # poison the Adam moments even if params were later restored):
        # zero them, run the update, then select old vs new on `ok`
        safe_scale = jnp.where(ok, controls["clip_scale"],
                               jnp.float32(0.0))
        safe = jax.tree.map(
            lambda g: (g.astype(jnp.float32) * safe_scale).astype(g.dtype),
            grads)
        new_p, new_s, _om = opt.update(ocfg, safe, opt_state, params,
                                       frozen_mask)
        sel = lambda a, b: jnp.where(ok, a, b)           # noqa: E731
        new_p = jax.tree.map(sel, new_p, params)
        new_s = jax.tree.map(sel, new_s, opt_state)

        upd = ok  # EMA tracks only applied steps: a skipped spike must
        #           not drag the baseline toward itself
        new_health = {
            "ema": jnp.where(upd, ema_decay * mean
                             + (1 - ema_decay) * loss, health["ema"]),
            "var": jnp.where(upd, ema_decay * health["var"]
                             + (1 - ema_decay) * dev * dev,
                             health["var"]),
            "count": health["count"] + upd.astype(jnp.int32),
        }
        bundle = jnp.stack([
            loss.astype(jnp.float32), gnorm.astype(jnp.float32), spike,
            1.0 - finite.astype(jnp.float32), ok.astype(jnp.float32)])
        return new_p, new_s, new_health, bundle

    return step


def bundle_dict(bundle) -> Dict[str, float]:
    """One host sync: device bundle vector -> {key: float}."""
    vals = np.asarray(bundle, np.float32)
    return {k: float(v) for k, v in zip(BUNDLE_KEYS, vals)}


# ---------------------------------------------------------------------------
# Host side: event log + verdict classifier
# ---------------------------------------------------------------------------

class EventLog:
    """Structured JSONL event sink. Every event is one json object per
    line with at least ``{"step", "kind"}``; ``path=None`` keeps the
    log in memory only (tests). Appends are flushed per event so a
    crash cannot lose the decision trail."""

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.events: List[dict] = []
        if path:
            os.makedirs(os.path.dirname(os.path.abspath(path)),
                        exist_ok=True)

    def emit(self, kind: str, step: int, **fields) -> dict:
        ev = {"kind": kind, "step": int(step), **fields}
        self.events.append(ev)
        if self.path:
            with open(self.path, "a", encoding="utf-8") as f:
                f.write(json.dumps(ev) + "\n")
                f.flush()
        return ev

    def of_kind(self, kind: str) -> List[dict]:
        return [e for e in self.events if e["kind"] == kind]


@dataclasses.dataclass(frozen=True)
class MonitorConfig:
    """Host-side verdict policy.

    spike_sigma: EMA z-score above which a finite loss is a spike.
    spike_warmup: applied steps before the z-score is trusted (the EMA
        variance estimate is garbage early).
    max_grad_norm: grad-norm ceiling; above it a step is skipped (the
        same value should be passed as the ``max_grad_norm`` control so
        the in-jit gate withholds the update).
    skip_limit: consecutive skips tolerated before escalating to
        rollback (0 = first bad step rolls back immediately).
    max_rollbacks: total rollbacks tolerated before abort.
    """
    spike_sigma: float = 8.0
    spike_warmup: int = 20
    max_grad_norm: float = math.inf
    skip_limit: int = 2
    max_rollbacks: int = 3


class HealthMonitor:
    """Maps health bundles to verdicts and logs every decision."""

    def __init__(self, cfg: Optional[MonitorConfig] = None,
                 log: Optional[EventLog] = None):
        self.cfg = cfg or MonitorConfig()
        self.log = log if log is not None else EventLog()
        self.consecutive_skips = 0
        self.rollbacks = 0
        self.applied_steps = 0

    def classify(self, step: int, bundle: Dict[str, float]) -> str:
        """One verdict per step. Escalation is stateful: skips in a row
        beyond ``skip_limit`` become a rollback; rollbacks beyond
        ``max_rollbacks`` become an abort."""
        cfg = self.cfg
        verdict, reason = OK, None
        if bundle["nonfinite"] >= 0.5:
            verdict, reason = SKIP, "nonfinite"
        elif bundle["grad_norm"] > cfg.max_grad_norm:
            verdict, reason = SKIP, "grad-norm"
        elif (self.applied_steps >= cfg.spike_warmup
              and bundle["spike"] > cfg.spike_sigma):
            verdict, reason = ROLLBACK, "loss-spike"

        if verdict == SKIP:
            self.consecutive_skips += 1
            if self.consecutive_skips > cfg.skip_limit:
                verdict = ROLLBACK
        else:
            self.consecutive_skips = 0
        if verdict == ROLLBACK:
            self.rollbacks += 1
            self.consecutive_skips = 0
            if self.rollbacks > cfg.max_rollbacks:
                verdict = ABORT
        if verdict == OK:
            self.applied_steps += 1
        if verdict != OK:
            self.log.emit("verdict", step, verdict=verdict, reason=reason,
                          **{k: bundle[k] for k in BUNDLE_KEYS})
        return verdict
