"""Deterministic fault injection for the resilience tests/benchmarks.

A :class:`FaultPlan` is a declarative list of faults pinned to step
indices — the whole point is *reproducibility*: the same plan against
the same seed produces the same failure at the same step, every run,
so crash-safety and rollback behaviour are assertable in tier-1 tests
instead of hoped-for in production.

Fault kinds (``Fault.kind``):

* ``nan_grads``      — at step k, the guarded train step's traced
  ``inject_nan`` switch multiplies every gradient by NaN (indistin-
  guishable downstream from a real overflow).
* ``crash``          — at step k, raise :class:`CrashInjected` before
  the step runs: simulated process death. Nothing is saved; recovery
  is a fresh process resuming from the last checkpoint.
* ``crash_in_save``  — kill the checkpoint write after ``arg`` shards
  have hit the temp dir (via ``checkpoint.save``'s ``on_entry`` hook).
  Because saves are write-to-temp-then-rename, the previous
  checkpoint must stay intact and loadable — the atomicity test.
* ``corrupt_shard``  — flip bytes in shard ``arg`` of a finished
  checkpoint dir (bit rot / torn disk write). ``checkpoint.load``
  must catch it by crc32, never silently train on it.
* ``device_loss``    — at step k, raise :class:`DeviceLossInjected`
  (``arg`` = devices lost). The trainer's recovery path re-plans over
  the shrunken cluster and resumes from the last checkpoint —
  graceful degradation of the parallelization plan.

Every fault fires **once** (the injector tracks spent faults), so a
rollback that replays step k does not re-trip the same fault forever.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import List, Optional, Sequence, Tuple

FAULT_KINDS = ("nan_grads", "crash", "crash_in_save", "corrupt_shard",
               "device_loss")


class CrashInjected(RuntimeError):
    """Simulated process death (``crash`` / ``crash_in_save``)."""


class DeviceLossInjected(RuntimeError):
    """Simulated loss of ``lost`` devices at one step."""

    def __init__(self, step: int, lost: int):
        super().__init__(f"device loss injected at step {step} "
                         f"({lost} device(s) lost)")
        self.step = step
        self.lost = lost


@dataclasses.dataclass(frozen=True)
class Fault:
    kind: str
    step: int
    arg: int = 0      # shard index (crash_in_save/corrupt_shard) or
    #                   device count (device_loss); unused otherwise

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; pick "
                             f"from {FAULT_KINDS}")
        if self.step < 0:
            raise ValueError(f"fault step must be >= 0, got {self.step}")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, JSON round-trippable list of faults."""
    faults: Tuple[Fault, ...] = ()

    @classmethod
    def make(cls, faults: Sequence[Fault]) -> "FaultPlan":
        return cls(tuple(sorted(faults, key=lambda f: (f.step, f.kind))))

    def to_json(self) -> str:
        return json.dumps([dataclasses.asdict(f) for f in self.faults],
                          indent=1)

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        return cls.make([Fault(**d) for d in json.loads(s)])

    def save(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path, encoding="utf-8") as f:
            return cls.from_json(f.read())


class FaultInjector:
    """Consumes a :class:`FaultPlan` during a training run. Each fault
    fires at most once; ``fired`` records what went off (for test
    assertions)."""

    def __init__(self, plan: Optional[FaultPlan] = None):
        self.plan = plan or FaultPlan()
        self._pending: List[Fault] = list(self.plan.faults)
        self.fired: List[Fault] = []

    def take(self, kind: str, step: int) -> Optional[Fault]:
        """Pop the first unfired fault of ``kind`` scheduled at
        ``step`` (None if there is none)."""
        for f in self._pending:
            if f.kind == kind and f.step == step:
                self._pending.remove(f)
                self.fired.append(f)
                return f
        return None

    # -- per-kind conveniences ---------------------------------------------

    def nan_at(self, step: int) -> bool:
        return self.take("nan_grads", step) is not None

    def check_crash(self, step: int) -> None:
        if self.take("crash", step) is not None:
            raise CrashInjected(f"crash injected at step {step}")

    def check_device_loss(self, step: int) -> Optional[DeviceLossInjected]:
        f = self.take("device_loss", step)
        if f is not None:
            return DeviceLossInjected(step, max(f.arg, 1))
        return None

    def save_hook(self, step: int):
        """``on_entry`` callback for ``checkpoint.save`` that kills the
        save after the plan's ``arg``-th shard — or None when no
        ``crash_in_save`` fault is scheduled at this step."""
        f = self.take("crash_in_save", step)
        if f is None:
            return None

        def on_entry(i: int, path: str) -> None:
            if i >= f.arg:
                raise CrashInjected(
                    f"crash injected mid-save at step {step} after "
                    f"shard {i} ({path!r})")
        return on_entry


def corrupt_shard(ckpt_dir: str, shard_index: int) -> str:
    """Flip the last byte of ``arr_<shard_index>.npy`` in a finished
    checkpoint dir (deterministic bit rot). Returns the file path.
    ``checkpoint.load`` must detect the damage via crc32."""
    path = os.path.join(ckpt_dir, f"arr_{shard_index}.npy")
    if not os.path.exists(path):
        raise FileNotFoundError(f"no shard {shard_index} at {ckpt_dir!r}")
    with open(path, "r+b") as f:
        f.seek(-1, os.SEEK_END)
        b = f.read(1)
        f.seek(-1, os.SEEK_END)
        f.write(bytes([b[0] ^ 0xFF]))
    return path
