"""Sharded checkpointing: pytree -> (manifest.msgpack + *.npy shards).

Layout:
    <dir>/manifest.msgpack   — treedef paths, shapes, dtypes, step
    <dir>/arr_<i>.npy        — one file per leaf (memory-mapped on load)

Works for params + optimizer state; frozen modules are saved once and
skipped on subsequent saves when ``skip_frozen`` (they never change —
the Cornstarch frozen-status optimization applied to checkpoint I/O).
"""
from __future__ import annotations

import os
from typing import Any, Optional

import msgpack
import numpy as np

import jax


def _paths_and_leaves(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        out.append(("/".join(parts), leaf))
    return out


def save(ckpt_dir: str, tree, *, step: int = 0,
         frozen_paths: Optional[set] = None,
         prev_manifest: Optional[dict] = None) -> dict:
    os.makedirs(ckpt_dir, exist_ok=True)
    entries = []
    for i, (path, leaf) in enumerate(_paths_and_leaves(tree)):
        arr = np.asarray(leaf)
        fname = f"arr_{i}.npy"
        if frozen_paths and prev_manifest and \
                any(path.startswith(fp) for fp in frozen_paths):
            prev = {e["path"]: e for e in prev_manifest["entries"]}
            if path in prev and os.path.exists(
                    os.path.join(ckpt_dir, prev[path]["file"])):
                entries.append(prev[path])
                continue
        np.save(os.path.join(ckpt_dir, fname), arr)
        entries.append({"path": path, "file": fname,
                        "shape": list(arr.shape), "dtype": str(arr.dtype)})
    manifest = {"step": step, "entries": entries}
    with open(os.path.join(ckpt_dir, "manifest.msgpack"), "wb") as f:
        f.write(msgpack.packb(manifest))
    return manifest


def load(ckpt_dir: str, like=None):
    """Returns (tree, step). If ``like`` is given, restores exactly that
    structure (validating shapes); otherwise returns {path: array}."""
    with open(os.path.join(ckpt_dir, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    arrays = {}
    for e in manifest["entries"]:
        arr = np.load(os.path.join(ckpt_dir, e["file"]), mmap_mode="r")
        assert list(arr.shape) == e["shape"], (e["path"], arr.shape)
        arrays[e["path"]] = arr
    if like is None:
        return arrays, manifest["step"]
    flat = _paths_and_leaves(like)
    leaves = []
    for path, leaf in flat:
        assert path in arrays, f"missing {path} in checkpoint"
        a = np.asarray(arrays[path])
        assert a.shape == tuple(leaf.shape), (path, a.shape, leaf.shape)
        leaves.append(a.astype(leaf.dtype) if hasattr(leaf, "dtype") else a)
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]
