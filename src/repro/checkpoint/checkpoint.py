"""Sharded checkpointing: pytree -> (manifest.msgpack + *.npy shards).

Layout:
    <dir>/manifest.msgpack   — treedef paths, shapes, dtypes, crc32s,
                               step + free-form ``meta`` dict
    <dir>/arr_<i>.npy        — one file per leaf (memory-mapped on load)

Works for params + optimizer state; frozen modules are saved once and
skipped on subsequent saves when ``frozen_paths`` is given (they never
change — the Cornstarch frozen-status optimization applied to
checkpoint I/O). ``prev_dir`` lets the reuse span *directories*: the
resilience ``CheckpointManager`` keeps each step in its own dir, and a
frozen shard is hardlinked (copied as a fallback) from the previous
step's dir instead of being re-serialized.

Every shard carries a crc32 in the manifest; ``load`` verifies them by
default and raises :class:`CheckpointError` naming the offending shard
— a corrupted file is detected at load time, never silently trained
on. All validation errors are real exceptions (``CheckpointError``, a
``ValueError``), not asserts, so they survive ``python -O``.
"""
from __future__ import annotations

import os
import shutil
import zlib
from typing import Any, Callable, Optional

import msgpack
import numpy as np

import jax


class CheckpointError(ValueError):
    """A checkpoint failed validation: missing/truncated manifest,
    missing shard, shape mismatch, or checksum failure. The message
    always names the checkpoint dir and the offending path/file."""


def _paths_and_leaves(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        out.append(("/".join(parts), leaf))
    return out


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save(ckpt_dir: str, tree, *, step: int = 0,
         frozen_paths: Optional[set] = None,
         prev_manifest: Optional[dict] = None,
         prev_dir: Optional[str] = None,
         meta: Optional[dict] = None,
         on_entry: Optional[Callable[[int, str], None]] = None) -> dict:
    """Write ``tree`` under ``ckpt_dir``; returns the manifest.

    ``on_entry(i, path)`` fires after shard ``i`` hits disk — the
    fault-injection hook the crash-safety tests use to kill a save
    mid-flight (see ``repro.resilience.faults``).
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    entries = []
    for i, (path, leaf) in enumerate(_paths_and_leaves(tree)):
        arr = np.asarray(leaf)
        fname = f"arr_{i}.npy"
        if frozen_paths and prev_manifest and \
                any(path.startswith(fp) for fp in frozen_paths):
            prev = {e["path"]: e for e in prev_manifest["entries"]}
            if path in prev:
                src = os.path.join(ckpt_dir, prev[path]["file"])
                if os.path.exists(src):
                    entries.append(prev[path])
                    continue
                if prev_dir is not None:
                    src = os.path.join(prev_dir, prev[path]["file"])
                    if os.path.exists(src):
                        dst = os.path.join(ckpt_dir, prev[path]["file"])
                        try:
                            os.link(src, dst)
                        except OSError:
                            shutil.copyfile(src, dst)
                        entries.append(prev[path])
                        continue
        np.save(os.path.join(ckpt_dir, fname), arr)
        entries.append({"path": path, "file": fname,
                        "shape": list(arr.shape), "dtype": str(arr.dtype),
                        "crc32": _crc(arr)})
        if on_entry is not None:
            on_entry(i, path)
    manifest = {"step": step, "entries": entries, "meta": meta or {}}
    tmp = os.path.join(ckpt_dir, "manifest.msgpack.tmp")
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(manifest))
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(ckpt_dir, "manifest.msgpack"))
    return manifest


def read_manifest(ckpt_dir: str) -> dict:
    """Parse ``<dir>/manifest.msgpack`` or raise :class:`CheckpointError`
    (missing file, truncated/garbled msgpack) with a clear message."""
    mpath = os.path.join(ckpt_dir, "manifest.msgpack")
    if not os.path.exists(mpath):
        raise CheckpointError(
            f"no checkpoint at {ckpt_dir!r}: manifest.msgpack is missing")
    try:
        with open(mpath, "rb") as f:
            manifest = msgpack.unpackb(f.read())
    except Exception as e:  # truncated write, garbage bytes, ...
        raise CheckpointError(
            f"checkpoint manifest at {mpath!r} is corrupt or truncated: "
            f"{type(e).__name__}: {e}") from None
    if not isinstance(manifest, dict) or "entries" not in manifest:
        raise CheckpointError(
            f"checkpoint manifest at {mpath!r} has no 'entries' record "
            f"(got {type(manifest).__name__})")
    return manifest


def load(ckpt_dir: str, like=None, *, verify: bool = True):
    """Returns (tree, step). If ``like`` is given, restores exactly that
    structure (validating shapes); otherwise returns {path: array}.
    ``verify=True`` (default) checks every shard's crc32 against the
    manifest and raises :class:`CheckpointError` naming the shard on
    mismatch (manifests written before checksums existed skip the
    check for entries without a ``crc32`` field)."""
    manifest = read_manifest(ckpt_dir)
    arrays = {}
    for e in manifest["entries"]:
        fpath = os.path.join(ckpt_dir, e["file"])
        if not os.path.exists(fpath):
            raise CheckpointError(
                f"checkpoint {ckpt_dir!r}: shard {e['file']!r} for "
                f"path {e['path']!r} is missing")
        try:
            arr = np.load(fpath, mmap_mode=None if verify else "r")
        except Exception as err:
            raise CheckpointError(
                f"checkpoint {ckpt_dir!r}: shard {e['file']!r} for "
                f"path {e['path']!r} is unreadable: "
                f"{type(err).__name__}: {err}") from None
        if list(arr.shape) != list(e["shape"]):
            raise CheckpointError(
                f"checkpoint {ckpt_dir!r}: path {e['path']!r} has shape "
                f"{list(arr.shape)} on disk but the manifest says "
                f"{list(e['shape'])}")
        if verify and e.get("crc32") is not None and _crc(arr) != e["crc32"]:
            raise CheckpointError(
                f"checkpoint {ckpt_dir!r}: shard {e['file']!r} for path "
                f"{e['path']!r} failed its crc32 checksum — the file is "
                f"corrupt; restore from an older checkpoint")
        arrays[e["path"]] = arr
    if like is None:
        return arrays, manifest["step"]
    flat = _paths_and_leaves(like)
    leaves = []
    for path, leaf in flat:
        if path not in arrays:
            raise CheckpointError(
                f"checkpoint {ckpt_dir!r} is missing path {path!r} "
                f"required by the restore target structure")
        a = np.asarray(arrays[path])
        if a.shape != tuple(leaf.shape):
            raise CheckpointError(
                f"checkpoint {ckpt_dir!r}: path {path!r} has shape "
                f"{tuple(a.shape)} but the restore target expects "
                f"{tuple(leaf.shape)}")
        leaves.append(a.astype(leaf.dtype) if hasattr(leaf, "dtype") else a)
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]
