"""BAM flash attention — Pallas TPU kernels (Cornstarch C3, TPU-native).

The paper represents multimodal attention masks as 1-D per-token integer
bitfields (BAM) and materializes [T,T] masks only transiently inside the
attention op (their FlexAttention path). The TPU-native analogue built
here goes further: the mask is evaluated **in-registers inside the
kernel** from the two bitfield vectors — the [T,T] mask never exists in
HBM *or* VMEM, only a [bq,bk] tile of it lives in VREGs per grid step.

Layout / tiling (dense grid):
  grid = (B, H, Tq/bq, Tk/bk), dimension_semantics = (parallel, parallel,
  parallel, arbitrary). Online-softmax running stats (m, l) and the
  output accumulator live in VMEM scratch and persist across the
  arbitrary (k-block) grid dimension; the output tile is written at the
  last k step. bq = bk = 128 matches the MXU systolic tile.

Block sparsity, two levels (beyond-paper):
  * in-kernel skip (``block_skip``): the kernel reduces the [bq,bk]
    bitfield intersection before touching the MXU; a fully-masked tile
    skips the QK^T matmul via ``pl.when`` — but still pays its grid step
    and K/V copies.
  * grid compaction (``block_map``): a host-side
    ``repro.core.bam.build_block_map`` precomputes the active
    (q-block, k-block) tile list from the block-level bitfield
    reduction; the kernel then runs a flattened grid (B, H, n_steps)
    driven by scalar-prefetch index maps
    (``pltpu.PrefetchScalarGridSpec``), so fully-masked tiles cost
    neither a grid step nor a K/V DMA.

GQA: the K/V BlockSpec index_map folds the q-head -> kv-head mapping
(h // n_rep), so no jnp.repeat of K/V ever materializes.

Forward modes (``return_mode``):
  * ``"out"``       — normalized attention output only;
  * ``"residual"``  — (out, lse[B,H,Tq]); the per-row log-sum-exp is the
    flash-attention residual the fused backward consumes, so backward
    never re-materializes the O(Tq*Tk) logits;
  * ``"stats"``     — unnormalized partials (acc[B,Tq,H,hd] f32,
    m[B,H,Tq], l[B,H,Tq]) for cross-chunk online-softmax combination —
    what the context-parallel ring/allgather bodies consume.

Backward: ``bam_flash_attention_bwd`` is a pair of fused kernels — dQ
over a (B, H, nq, nk) grid and dK/dV over the transposed (B, H, nk, nq)
grid — that recompute the logits tile-by-tile from (q, k, lse), apply
the bitfield mask in-registers, and accumulate gradients in VMEM
scratch. Both honor ``block_skip`` and ``block_map`` exactly like the
forward. The old recompute-through-XLA path survives only as the
``impl="xla"`` fallback in ops.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bam

NEG_INF = -1e30


def _compiler_params_cls():
    """pltpu.CompilerParams was named TPUCompilerParams before jax
    0.4.38-ish; resolve whichever this JAX exposes."""
    cls = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        raise RuntimeError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; adapt repro.kernels.bam_attention to "
            f"this JAX ({jax.__version__})")
    return cls


def _mask_tile(qb, kb, qp, kp, window: int):
    """[bq],[bk] uint32 bitfields + int32 positions -> [bq,bk] bool.
    Mirrors repro.core.bam.allowed_mask (tested against it)."""
    qb = qb[:, None].astype(jnp.uint32)
    kb = kb[None, :].astype(jnp.uint32)
    qp = qp[:, None]
    kp = kp[None, :]
    nonpad = (qb != 0) & (kb != 0)
    same_doc = bam.instance_id(qb) == bam.instance_id(kb)
    bit_ok = ((bam.attends_set(qb) >> bam.own_modality(kb)) & 1) != 0
    q_text = bam.own_modality(qb) == bam.TEXT
    causal = kp <= qp
    if window:
        causal &= (qp - kp) < window
    within = bam.own_modality(kb) == bam.own_modality(qb)
    rule = jnp.where(q_text, causal, within)
    return nonpad & same_doc & bit_ok & rule


# ---------------------------------------------------------------------------
# Forward kernel bodies (shared by the dense and compacted grids)
# ---------------------------------------------------------------------------

def _fwd_accumulate(allowed, q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr,
                    softcap: float, scale: float):
    q = q_ref[0, :, 0, :].astype(jnp.float32)           # [bq, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # [bk, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(allowed, s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(allowed, p, 0.0)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    m_scr[...] = m_new


def _fwd_init(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)


def _fwd_finish(mode, out_refs, m_scr, l_scr, acc_scr):
    m = m_scr[...]
    l = l_scr[...]
    if mode == "stats":
        acc_ref, m_ref, l_ref = out_refs
        acc_ref[0, :, 0, :] = acc_scr[...].astype(acc_ref.dtype)
        m_ref[0, 0] = m
        l_ref[0, 0] = l
        return
    out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
    out = jnp.where((l > 0)[:, None], out, 0.0)
    if mode == "residual":
        o_ref, lse_ref = out_refs
        lse_ref[0, 0] = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)),
                                  NEG_INF)
    else:
        (o_ref,) = out_refs
    o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def _bam_fwd_kernel(qb_ref, kb_ref, qp_ref, kp_ref,     # bitfield meta
                    q_ref, k_ref, v_ref,                # tensors
                    *refs,                              # outputs + scratch
                    softcap: float, window: int, nk: int, scale: float,
                    block_skip: bool, mode: str):
    out_refs, (m_scr, l_scr, acc_scr) = refs[:-3], refs[-3:]
    ki = pl.program_id(3)

    pl.when(ki == 0)(lambda: _fwd_init(m_scr, l_scr, acc_scr))
    allowed = _mask_tile(qb_ref[0], kb_ref[0], qp_ref[0], kp_ref[0], window)

    def compute():
        _fwd_accumulate(allowed, q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr,
                        softcap, scale)

    if block_skip:
        # block sparsity: a fully-masked tile never touches the MXU
        pl.when(jnp.any(allowed))(compute)
    else:
        compute()

    pl.when(ki == nk - 1)(
        lambda: _fwd_finish(mode, out_refs, m_scr, l_scr, acc_scr))


def _bam_fwd_kernel_sparse(qblk_ref, kblk_ref, first_ref, last_ref,
                           active_ref,                  # scalar prefetch
                           qb_ref, kb_ref, qp_ref, kp_ref,
                           q_ref, k_ref, v_ref,
                           *refs,
                           softcap: float, window: int, scale: float,
                           block_skip: bool, mode: str):
    """Grid-compacted forward: grid (B, H, n_steps); the active-tile list
    (host-precomputed) drives the index maps, init and flush."""
    out_refs, (m_scr, l_scr, acc_scr) = refs[:-3], refs[-3:]
    t = pl.program_id(2)

    pl.when(first_ref[t] == 1)(lambda: _fwd_init(m_scr, l_scr, acc_scr))
    allowed = _mask_tile(qb_ref[0], kb_ref[0], qp_ref[0], kp_ref[0], window)
    is_active = active_ref[t] == 1

    def compute():
        _fwd_accumulate(allowed, q_ref, k_ref, v_ref, m_scr, l_scr, acc_scr,
                        softcap, scale)

    if block_skip:
        pl.when(is_active & jnp.any(allowed))(compute)
    else:
        pl.when(is_active)(compute)

    pl.when(last_ref[t] == 1)(
        lambda: _fwd_finish(mode, out_refs, m_scr, l_scr, acc_scr))


# ---------------------------------------------------------------------------
# Backward kernel bodies
# ---------------------------------------------------------------------------

def _recompute_p_ds(allowed, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    softcap: float, scale: float):
    """Recompute the probability tile from (q, k, lse) and form
    dS = P * (dP - delta), with the softcap chain rule folded in.
    Returns (p [bq,bk], ds [bq,bk], q, k, do) all f32."""
    q = q_ref[0, :, 0, :].astype(jnp.float32)           # [bq, hd]
    k = k_ref[0, :, 0, :].astype(jnp.float32)           # [bk, hd]
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    do = do_ref[0, :, 0, :].astype(jnp.float32)
    lse = lse_ref[0, 0]                                 # [bq]
    delta = delta_ref[0, 0]                             # [bq]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    p = jnp.where(allowed, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    if softcap:
        ds = ds * (1.0 - (s / softcap) ** 2)
    return p, ds, q, k, do


def _bam_bwd_dq_kernel(qb_ref, kb_ref, qp_ref, kp_ref,
                       q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                       dq_ref, dq_scr, *, softcap: float, window: int,
                       nk: int, scale: float, block_skip: bool):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    allowed = _mask_tile(qb_ref[0], kb_ref[0], qp_ref[0], kp_ref[0], window)

    def compute():
        _, ds, _, k, _ = _recompute_p_ds(
            allowed, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
            softcap, scale)
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if block_skip:
        pl.when(jnp.any(allowed))(compute)
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _finish():
        dq_ref[0, :, 0, :] = dq_scr[...].astype(dq_ref.dtype)


def _bam_bwd_dq_kernel_sparse(qblk_ref, kblk_ref, first_ref, last_ref,
                              active_ref,
                              qb_ref, kb_ref, qp_ref, kp_ref,
                              q_ref, k_ref, v_ref, do_ref, lse_ref,
                              delta_ref, dq_ref, dq_scr, *,
                              softcap: float, window: int, scale: float,
                              block_skip: bool):
    t = pl.program_id(2)

    @pl.when(first_ref[t] == 1)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    allowed = _mask_tile(qb_ref[0], kb_ref[0], qp_ref[0], kp_ref[0], window)
    is_active = active_ref[t] == 1

    def compute():
        _, ds, _, k, _ = _recompute_p_ds(
            allowed, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
            softcap, scale)
        dq_scr[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if block_skip:
        pl.when(is_active & jnp.any(allowed))(compute)
    else:
        pl.when(is_active)(compute)

    @pl.when(last_ref[t] == 1)
    def _finish():
        dq_ref[0, :, 0, :] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_accumulate(allowed, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_scr, dv_scr, softcap: float, scale: float):
    p, ds, q, _, do = _recompute_p_ds(
        allowed, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
        softcap, scale)
    dv_scr[...] += jax.lax.dot_general(
        p, do, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    dk_scr[...] += jax.lax.dot_general(
        ds, q, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32) * scale


def _bam_bwd_dkv_kernel(qb_ref, kb_ref, qp_ref, kp_ref,
                        q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                        dk_ref, dv_ref, dk_scr, dv_scr, *,
                        softcap: float, window: int, nq: int, scale: float,
                        block_skip: bool):
    """Transposed grid (B, H, nk, nq): the arbitrary dimension iterates
    q blocks; dK/dV accumulate per k block."""
    qi = pl.program_id(3)

    @pl.when(qi == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    allowed = _mask_tile(qb_ref[0], kb_ref[0], qp_ref[0], kp_ref[0], window)

    def compute():
        _dkv_accumulate(allowed, q_ref, k_ref, v_ref, do_ref, lse_ref,
                        delta_ref, dk_scr, dv_scr, softcap, scale)

    if block_skip:
        pl.when(jnp.any(allowed))(compute)
    else:
        compute()

    @pl.when(qi == nq - 1)
    def _finish():
        dk_ref[0, :, 0, :] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_scr[...].astype(dv_ref.dtype)


def _bam_bwd_dkv_kernel_sparse(qblk_ref, kblk_ref, first_ref, last_ref,
                               active_ref,
                               qb_ref, kb_ref, qp_ref, kp_ref,
                               q_ref, k_ref, v_ref, do_ref, lse_ref,
                               delta_ref, dk_ref, dv_ref, dk_scr, dv_scr, *,
                               softcap: float, window: int, scale: float,
                               block_skip: bool):
    t = pl.program_id(2)

    @pl.when(first_ref[t] == 1)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    allowed = _mask_tile(qb_ref[0], kb_ref[0], qp_ref[0], kp_ref[0], window)
    is_active = active_ref[t] == 1

    def compute():
        _dkv_accumulate(allowed, q_ref, k_ref, v_ref, do_ref, lse_ref,
                        delta_ref, dk_scr, dv_scr, softcap, scale)

    if block_skip:
        pl.when(is_active & jnp.any(allowed))(compute)
    else:
        pl.when(is_active)(compute)

    @pl.when(last_ref[t] == 1)
    def _finish():
        dk_ref[0, :, 0, :] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, :, 0, :] = dv_scr[...].astype(dv_ref.dtype)


# ---------------------------------------------------------------------------
# pallas_call wrappers
# ---------------------------------------------------------------------------

def _check_block_map(block_map, block_q, block_k, nq, nk, window):
    assert block_map.block_q == block_q and block_map.block_k == block_k, \
        ("block_map was built for different tile sizes",
         (block_map.block_q, block_map.block_k), (block_q, block_k))
    assert block_map.nq == nq and block_map.nk == nk, \
        ("block_map grid does not match the padded sequence",
         (block_map.nq, block_map.nk), (nq, nk))
    assert block_map.window == window, \
        ("block_map was built for a different sliding window — tiles "
         "valid under this window may have been pruned",
         block_map.window, window)


def _prefetch_arrays(block_map, major):
    return tuple(jnp.asarray(a) for a in block_map.arrays(major))


def _sparse_index_maps(n_rep: int):
    """Index maps for the compacted (B, H, n_steps) grids. All receive
    (b, h, t, *scalar_prefetch_refs); the step arrays address the
    blocks. Shared by forward and backward so the prefetch layout can
    only change in one place."""

    def qm(b, h, t, qblk, kblk, first, last, active):
        return (b, qblk[t])

    def km(b, h, t, qblk, kblk, first, last, active):
        return (b, kblk[t])

    def qtile(b, h, t, qblk, kblk, first, last, active):
        return (b, qblk[t], h, 0)

    def ktile(b, h, t, qblk, kblk, first, last, active):
        return (b, kblk[t], h // n_rep, 0)

    def ktile_full(b, h, t, qblk, kblk, first, last, active):
        return (b, kblk[t], h, 0)

    def qrow(b, h, t, qblk, kblk, first, last, active):
        return (b, h, qblk[t])

    return qm, km, qtile, ktile, ktile_full, qrow


def bam_flash_attention(q, k, v, q_bits, kv_bits, q_pos, kv_pos, *,
                        softcap: float = 0.0, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        block_skip: bool = True,
                        interpret: bool = False,
                        return_mode: str = "out",
                        block_map=None):
    """Pallas BAM attention forward. Shapes as in ref.py; Tq % block_q
    == 0 and Tk % block_k == 0 (ops.py pads with bits=0, pos=-1).

    return_mode: "out" | "residual" (out, lse) | "stats" (acc, m, l).
    block_map: optional ``repro.core.bam.BlockMask`` — compacted grid.
    """
    assert return_mode in ("out", "residual", "stats"), return_mode
    B, Tq, H, hd = q.shape
    _, Tk, Hkv, _ = k.shape
    assert H % Hkv == 0
    n_rep = H // Hkv
    assert Tq % block_q == 0 and Tk % block_k == 0, (Tq, Tk)
    nq, nk = Tq // block_q, Tk // block_k

    out_shapes = {
        "out": (jax.ShapeDtypeStruct((B, Tq, H, hd), q.dtype),),
        "residual": (jax.ShapeDtypeStruct((B, Tq, H, hd), q.dtype),
                     jax.ShapeDtypeStruct((B, H, Tq), jnp.float32)),
        "stats": (jax.ShapeDtypeStruct((B, Tq, H, hd), jnp.float32),
                  jax.ShapeDtypeStruct((B, H, Tq), jnp.float32),
                  jax.ShapeDtypeStruct((B, H, Tq), jnp.float32)),
    }[return_mode]
    scratch = [
        pltpu.VMEM((block_q,), jnp.float32),
        pltpu.VMEM((block_q,), jnp.float32),
        pltpu.VMEM((block_q, hd), jnp.float32),
    ]
    common = dict(softcap=softcap, window=window, scale=hd ** -0.5,
                  block_skip=block_skip, mode=return_mode)

    if block_map is None:
        kernel = functools.partial(_bam_fwd_kernel, nk=nk, **common)
        tile_specs = {
            "out": [pl.BlockSpec((1, block_q, 1, hd),
                                 lambda b, h, iq, ik: (b, iq, h, 0))],
            "residual": [pl.BlockSpec((1, block_q, 1, hd),
                                      lambda b, h, iq, ik: (b, iq, h, 0)),
                         pl.BlockSpec((1, 1, block_q),
                                      lambda b, h, iq, ik: (b, h, iq))],
            "stats": [pl.BlockSpec((1, block_q, 1, hd),
                                   lambda b, h, iq, ik: (b, iq, h, 0)),
                      pl.BlockSpec((1, 1, block_q),
                                   lambda b, h, iq, ik: (b, h, iq)),
                      pl.BlockSpec((1, 1, block_q),
                                   lambda b, h, iq, ik: (b, h, iq))],
        }[return_mode]
        outs = pl.pallas_call(
            kernel,
            grid=(B, H, nq, nk),
            in_specs=[
                pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (b, iq)),
                pl.BlockSpec((1, block_k), lambda b, h, iq, ik: (b, ik)),
                pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (b, iq)),
                pl.BlockSpec((1, block_k), lambda b, h, iq, ik: (b, ik)),
                pl.BlockSpec((1, block_q, 1, hd),
                             lambda b, h, iq, ik: (b, iq, h, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, iq, ik, n_rep=n_rep:
                             (b, ik, h // n_rep, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, iq, ik, n_rep=n_rep:
                             (b, ik, h // n_rep, 0)),
            ],
            out_specs=list(tile_specs),
            out_shape=list(out_shapes),
            scratch_shapes=scratch,
            compiler_params=_compiler_params_cls()(
                dimension_semantics=("parallel", "parallel", "parallel",
                                     "arbitrary")),
            interpret=interpret,
        )(q_bits, kv_bits, q_pos, kv_pos, q, k, v)
    else:
        _check_block_map(block_map, block_q, block_k, nq, nk, window)
        kernel = functools.partial(_bam_fwd_kernel_sparse, **common)
        qm, km, qtile, ktile, _, qrow = _sparse_index_maps(n_rep)
        tile_specs = {
            "out": [pl.BlockSpec((1, block_q, 1, hd), qtile)],
            "residual": [pl.BlockSpec((1, block_q, 1, hd), qtile),
                         pl.BlockSpec((1, 1, block_q), qrow)],
            "stats": [pl.BlockSpec((1, block_q, 1, hd), qtile),
                      pl.BlockSpec((1, 1, block_q), qrow),
                      pl.BlockSpec((1, 1, block_q), qrow)],
        }[return_mode]
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=5,
            grid=(B, H, block_map.n_steps),
            in_specs=[
                pl.BlockSpec((1, block_q), qm),
                pl.BlockSpec((1, block_k), km),
                pl.BlockSpec((1, block_q), qm),
                pl.BlockSpec((1, block_k), km),
                pl.BlockSpec((1, block_q, 1, hd), qtile),
                pl.BlockSpec((1, block_k, 1, hd), ktile),
                pl.BlockSpec((1, block_k, 1, hd), ktile),
            ],
            out_specs=list(tile_specs),
            scratch_shapes=scratch,
        )
        outs = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=list(out_shapes),
            compiler_params=_compiler_params_cls()(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(*_prefetch_arrays(block_map, "q"),
          q_bits, kv_bits, q_pos, kv_pos, q, k, v)

    outs = tuple(outs) if isinstance(outs, (list, tuple)) else (outs,)
    return outs[0] if return_mode == "out" else outs


def bam_flash_attention_bwd(q, k, v, out, do, lse, q_bits, kv_bits, q_pos,
                            kv_pos, *, softcap: float = 0.0, window: int = 0,
                            block_q: int = 128, block_k: int = 128,
                            block_skip: bool = True,
                            interpret: bool = False,
                            block_map=None):
    """Fused BAM flash-attention backward: dQ, dK, dV from the saved
    (out, lse) residuals — the O(Tq*Tk) logits are recomputed tile by
    tile in VMEM, never materialized. dK/dV are returned GQA-reduced to
    [B, Tk, Hkv, hd]."""
    B, Tq, H, hd = q.shape
    _, Tk, Hkv, _ = k.shape
    n_rep = H // Hkv
    assert Tq % block_q == 0 and Tk % block_k == 0, (Tq, Tk)
    nq, nk = Tq // block_q, Tk // block_k
    scale = hd ** -0.5

    # delta_i = sum_d dO_i·O_i — the rowwise correction term (O(T·hd))
    delta = jnp.einsum("bqhd,bqhd->bhq", out.astype(jnp.float32),
                       do.astype(jnp.float32))

    common = dict(softcap=softcap, window=window, scale=scale,
                  block_skip=block_skip)
    operands = (q_bits, kv_bits, q_pos, kv_pos, q, k, v, do, lse, delta)

    if block_map is None:
        dq = pl.pallas_call(
            functools.partial(_bam_bwd_dq_kernel, nk=nk, **common),
            grid=(B, H, nq, nk),
            in_specs=[
                pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (b, iq)),
                pl.BlockSpec((1, block_k), lambda b, h, iq, ik: (b, ik)),
                pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (b, iq)),
                pl.BlockSpec((1, block_k), lambda b, h, iq, ik: (b, ik)),
                pl.BlockSpec((1, block_q, 1, hd),
                             lambda b, h, iq, ik: (b, iq, h, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, iq, ik, n_rep=n_rep:
                             (b, ik, h // n_rep, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, iq, ik, n_rep=n_rep:
                             (b, ik, h // n_rep, 0)),
                pl.BlockSpec((1, block_q, 1, hd),
                             lambda b, h, iq, ik: (b, iq, h, 0)),
                pl.BlockSpec((1, 1, block_q),
                             lambda b, h, iq, ik: (b, h, iq)),
                pl.BlockSpec((1, 1, block_q),
                             lambda b, h, iq, ik: (b, h, iq)),
            ],
            out_specs=pl.BlockSpec((1, block_q, 1, hd),
                                   lambda b, h, iq, ik: (b, iq, h, 0)),
            out_shape=jax.ShapeDtypeStruct((B, Tq, H, hd), q.dtype),
            scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
            compiler_params=_compiler_params_cls()(
                dimension_semantics=("parallel", "parallel", "parallel",
                                     "arbitrary")),
            interpret=interpret,
        )(*operands)

        dk_h, dv_h = pl.pallas_call(
            functools.partial(_bam_bwd_dkv_kernel, nq=nq, **common),
            grid=(B, H, nk, nq),
            in_specs=[
                pl.BlockSpec((1, block_q), lambda b, h, ik, iq: (b, iq)),
                pl.BlockSpec((1, block_k), lambda b, h, ik, iq: (b, ik)),
                pl.BlockSpec((1, block_q), lambda b, h, ik, iq: (b, iq)),
                pl.BlockSpec((1, block_k), lambda b, h, ik, iq: (b, ik)),
                pl.BlockSpec((1, block_q, 1, hd),
                             lambda b, h, ik, iq: (b, iq, h, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, ik, iq, n_rep=n_rep:
                             (b, ik, h // n_rep, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, ik, iq, n_rep=n_rep:
                             (b, ik, h // n_rep, 0)),
                pl.BlockSpec((1, block_q, 1, hd),
                             lambda b, h, ik, iq: (b, iq, h, 0)),
                pl.BlockSpec((1, 1, block_q),
                             lambda b, h, ik, iq: (b, h, iq)),
                pl.BlockSpec((1, 1, block_q),
                             lambda b, h, ik, iq: (b, h, iq)),
            ],
            out_specs=[
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, ik, iq: (b, ik, h, 0)),
                pl.BlockSpec((1, block_k, 1, hd),
                             lambda b, h, ik, iq: (b, ik, h, 0)),
            ],
            out_shape=[jax.ShapeDtypeStruct((B, Tk, H, hd), jnp.float32),
                       jax.ShapeDtypeStruct((B, Tk, H, hd), jnp.float32)],
            scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                            pltpu.VMEM((block_k, hd), jnp.float32)],
            compiler_params=_compiler_params_cls()(
                dimension_semantics=("parallel", "parallel", "parallel",
                                     "arbitrary")),
            interpret=interpret,
        )(*operands)
    else:
        _check_block_map(block_map, block_q, block_k, nq, nk, window)
        qm, km, qtile, ktile, ktile_full, qrow = _sparse_index_maps(n_rep)
        in_specs = [
            pl.BlockSpec((1, block_q), qm),
            pl.BlockSpec((1, block_k), km),
            pl.BlockSpec((1, block_q), qm),
            pl.BlockSpec((1, block_k), km),
            pl.BlockSpec((1, block_q, 1, hd), qtile),
            pl.BlockSpec((1, block_k, 1, hd), ktile),
            pl.BlockSpec((1, block_k, 1, hd), ktile),
            pl.BlockSpec((1, block_q, 1, hd), qtile),
            pl.BlockSpec((1, 1, block_q), qrow),
            pl.BlockSpec((1, 1, block_q), qrow),
        ]
        dq = pl.pallas_call(
            functools.partial(_bam_bwd_dq_kernel_sparse, **common),
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=5,
                grid=(B, H, block_map.n_steps),
                in_specs=in_specs,
                out_specs=pl.BlockSpec((1, block_q, 1, hd), qtile),
                scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
            ),
            out_shape=jax.ShapeDtypeStruct((B, Tq, H, hd), q.dtype),
            compiler_params=_compiler_params_cls()(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(*_prefetch_arrays(block_map, "q"), *operands)

        dk_h, dv_h = pl.pallas_call(
            functools.partial(_bam_bwd_dkv_kernel_sparse, **common),
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=5,
                grid=(B, H, len(block_map.k_steps)),
                in_specs=in_specs,
                out_specs=[pl.BlockSpec((1, block_k, 1, hd), ktile_full),
                           pl.BlockSpec((1, block_k, 1, hd), ktile_full)],
                scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                                pltpu.VMEM((block_k, hd), jnp.float32)],
            ),
            out_shape=[jax.ShapeDtypeStruct((B, Tk, H, hd), jnp.float32),
                       jax.ShapeDtypeStruct((B, Tk, H, hd), jnp.float32)],
            compiler_params=_compiler_params_cls()(
                dimension_semantics=("parallel", "parallel", "arbitrary")),
            interpret=interpret,
        )(*_prefetch_arrays(block_map, "k"), *operands)

    # GQA: fold q-head grads back onto shared KV heads
    if n_rep > 1:
        dk_h = dk_h.reshape(B, Tk, Hkv, n_rep, hd).sum(axis=3)
        dv_h = dv_h.reshape(B, Tk, Hkv, n_rep, hd).sum(axis=3)
    return dq, dk_h.astype(k.dtype), dv_h.astype(v.dtype)
