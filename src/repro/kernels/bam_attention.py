"""BAM flash attention — Pallas TPU kernel (Cornstarch C3, TPU-native).

The paper represents multimodal attention masks as 1-D per-token integer
bitfields (BAM) and materializes [T,T] masks only transiently inside the
attention op (their FlexAttention path). The TPU-native analogue built
here goes further: the mask is evaluated **in-registers inside the
kernel** from the two bitfield vectors — the [T,T] mask never exists in
HBM *or* VMEM, only a [bq,bk] tile of it lives in VREGs per grid step.

Layout / tiling:
  grid = (B, H, Tq/bq, Tk/bk), dimension_semantics = (parallel, parallel,
  parallel, arbitrary). Online-softmax running stats (m, l) and the
  output accumulator live in VMEM scratch and persist across the
  arbitrary (k-block) grid dimension; the output tile is written at the
  last k step. bq = bk = 128 matches the MXU systolic tile.

Block sparsity (beyond-paper): before touching the MXU, the kernel
reduces the [bq,bk] bitfield intersection; a fully-masked tile skips the
QK^T matmul entirely (`pl.when`). With BAM masks this prunes ~half the
tiles for causal text and all cross-modality tiles — see EXPERIMENTS.md
§Perf.

GQA: the K/V BlockSpec index_map folds the q-head -> kv-head mapping
(h // n_rep), so no jnp.repeat of K/V ever materializes.

Backward: custom_vjp recomputes through the XLA reference path (the
paper's contribution is the mask representation, not attention math;
a fused backward kernel is a further optimization, not correctness).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bam

NEG_INF = -1e30


def _compiler_params_cls():
    """pltpu.CompilerParams was named TPUCompilerParams before jax
    0.4.38-ish; resolve whichever this JAX exposes."""
    cls = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        raise RuntimeError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; adapt repro.kernels.bam_attention to "
            f"this JAX ({jax.__version__})")
    return cls


def _mask_tile(qb, kb, qp, kp, window: int):
    """[bq],[bk] uint32 bitfields + int32 positions -> [bq,bk] bool.
    Mirrors repro.core.bam.allowed_mask (tested against it)."""
    qb = qb[:, None].astype(jnp.uint32)
    kb = kb[None, :].astype(jnp.uint32)
    qp = qp[:, None]
    kp = kp[None, :]
    nonpad = (qb != 0) & (kb != 0)
    same_doc = bam.instance_id(qb) == bam.instance_id(kb)
    bit_ok = ((bam.attends_set(qb) >> bam.own_modality(kb)) & 1) != 0
    q_text = bam.own_modality(qb) == bam.TEXT
    causal = kp <= qp
    if window:
        causal &= (qp - kp) < window
    within = bam.own_modality(kb) == bam.own_modality(qb)
    rule = jnp.where(q_text, causal, within)
    return nonpad & same_doc & bit_ok & rule


def _bam_fwd_kernel(qb_ref, kb_ref, qp_ref, kp_ref,     # prefetch-ish meta
                    q_ref, k_ref, v_ref,                # tensors
                    o_ref,                              # output
                    m_scr, l_scr, acc_scr,              # VMEM scratch
                    *, softcap: float, window: int, nk: int, scale: float,
                    block_skip: bool):
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qb = qb_ref[0]
    kb = kb_ref[0]
    qp = qp_ref[0]
    kp = kp_ref[0]
    allowed = _mask_tile(qb, kb, qp, kp, window)        # [bq, bk]

    def compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)       # [bq, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)       # [bk, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(allowed, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(allowed, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if block_skip:
        # block sparsity: a fully-masked tile never touches the MXU
        pl.when(jnp.any(allowed))(compute)
    else:
        compute()

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def bam_flash_attention(q, k, v, q_bits, kv_bits, q_pos, kv_pos, *,
                        softcap: float = 0.0, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        block_skip: bool = True,
                        interpret: bool = False):
    """Pallas BAM attention forward. Shapes as in ref.py; Tq % block_q
    == 0 and Tk % block_k == 0 (ops.py pads with bits=0)."""
    B, Tq, H, hd = q.shape
    _, Tk, Hkv, _ = k.shape
    assert H % Hkv == 0
    n_rep = H // Hkv
    assert Tq % block_q == 0 and Tk % block_k == 0, (Tq, Tk)
    nq, nk = Tq // block_q, Tk // block_k
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _bam_fwd_kernel, softcap=softcap, window=window, nk=nk,
        scale=hd ** -0.5, block_skip=block_skip)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, block_k), lambda b, h, iq, ik: (b, ik)),
            pl.BlockSpec((1, block_q), lambda b, h, iq, ik: (b, iq)),
            pl.BlockSpec((1, block_k), lambda b, h, iq, ik: (b, ik)),
            pl.BlockSpec((1, block_q, 1, hd),
                         lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, iq, ik, n_rep=n_rep:
                         (b, ik, h // n_rep, 0)),
            pl.BlockSpec((1, block_k, 1, hd),
                         lambda b, h, iq, ik, n_rep=n_rep:
                         (b, ik, h // n_rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, hd),
                               lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Tq, H, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_compiler_params_cls()(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q_bits, kv_bits, q_pos, kv_pos, q, k, v)
