"""Single-query flash-decode over a paged BAM KV cache — Pallas TPU.

Decode attention is one query token per request against that request's
resident cache pages. The kernel runs a flattened grid

    grid = (H, n_steps),  dimension_semantics = (parallel, arbitrary)

where the step axis is the host-precomputed active-page list from
``repro.serving.paged_cache.build_decode_grid``: per batch row, a
k-major sweep over only the pages its query bitfield can reach. The
five scalar-prefetch operands (``req``, ``page``, ``first``, ``last``,
``active``) drive every BlockSpec index map, so a fully-masked page —
an image's tokens while decoding a text-only document, another
modality's stream, a pruned sliding-window span — costs neither a grid
step nor a K/V page DMA. ``first``/``last`` frame each request's steps
for online-softmax scratch init/flush, the same contract as
``bam.BlockMask`` (and checked by the same kernellint coverage rules).

GQA is folded into the K/V index maps (``h // n_rep``) like the
training kernels — no head-expanded K/V ever materializes. The mask is
evaluated in-registers from the bitfields via the training kernels'
``_mask_tile`` (one [1, page_size] tile of it lives in VREGs per step).
Softcap and sliding window are static params; ``window`` constrains
text queries only, mirroring ``bam.allowed_mask``.

The step arrays are *traced* operands (lengths grow every decode step)
but their length is a static shape — callers bucket ``n_steps``
(``decode_grid_bucket``) to keep the jit cache warm; pad steps carry
``active=0`` and touch nothing.

``paged_decode_ref`` is the XLA fallback: gather each request's pages
dense via its page-table row (null-page padded) and run the reference
masked softmax. It is the serving engine's ``attn="xla"`` path and the
oracle the kernel is tested against.

Decode-only: no VJP. Shapes here are decode-shaped (one query row per
step) — correct under ``interpret=True`` anywhere, efficient on real
TPU once requests are packed to sublane multiples (a follow-up the
docstring of ``paged_decode_attention`` records).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bam_attention import (_compiler_params_cls, _mask_tile,
                                         NEG_INF)
from repro.kernels.ref import bam_attention_ref


# ---------------------------------------------------------------------------
# Kernel body
# ---------------------------------------------------------------------------

def _paged_decode_kernel(req_ref, page_ref, first_ref, last_ref, active_ref,
                         qb_ref, qp_ref, kb_ref, kp_ref,
                         q_ref, k_ref, v_ref,
                         o_ref, m_scr, l_scr, acc_scr, *,
                         softcap: float, window: int, scale: float,
                         block_skip: bool):
    t = pl.program_id(1)

    @pl.when(first_ref[t] == 1)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    allowed = _mask_tile(qb_ref[0], kb_ref[0], qp_ref[0], kp_ref[0],
                         window)                     # [1, page_size]
    is_active = active_ref[t] == 1

    def compute():
        q = q_ref[0, 0, :].astype(jnp.float32)[None, :]      # [1, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [ps, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        s = jnp.where(allowed, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(allowed, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if block_skip:
        # a page that survived grid compaction can still be fully
        # masked for THIS layer's sliding window — skip its MXU work
        pl.when(is_active & jnp.any(allowed))(compute)
    else:
        pl.when(is_active)(compute)

    @pl.when(last_ref[t] == 1)
    def _finish():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        o_ref[0, 0, :] = out[0].astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# Index maps — named defs so kernellint's arity rule can resolve them:
# grid rank 2 (h, t) + 5 scalar-prefetch refs = 7 arguments each.
# ---------------------------------------------------------------------------

def _im_qrow(h, t, req, page, first, last, active):
    return (req[t], 0)


def _im_page_meta(h, t, req, page, first, last, active):
    return (page[t], 0)


def _im_qvec(h, t, req, page, first, last, active):
    return (req[t], h, 0)


def _im_ktile(h, t, req, page, first, last, active, n_rep=1):
    return (page[t], 0, h // n_rep, 0)


# ---------------------------------------------------------------------------
# pallas_call wrapper
# ---------------------------------------------------------------------------

def paged_decode_attention(q, k_pages, v_pages, q_bits, q_pos,
                           kv_bits, kv_pos, steps, *,
                           softcap: float = 0.0, window: int = 0,
                           block_skip: bool = True,
                           interpret: bool = False):
    """Paged single-query BAM flash decode.

    q: [B, H, hd] (one token per request row);
    k_pages/v_pages: [P, page_size, Hkv, hd] (H % Hkv == 0);
    q_bits: [B, 1] uint32; q_pos: [B, 1] int32;
    kv_bits: [P, page_size] uint32; kv_pos: [P, page_size] int32;
    steps: (req, page, first, last, active) int32 [n_steps] arrays from
    ``build_decode_grid(...).arrays()`` — traced operands; their length
    is the static grid extent.

    Returns [B, H, hd]. Rows whose steps are all inactive (empty batch
    slots, fully-masked queries) come back exactly zero.

    One query row per grid step keeps the kernel shape-true to
    continuous batching (any mix of requests, any ragged lengths); on
    real TPU, packing 8 requests per sublane tile is the known
    follow-up for MXU utilization — the grid contract here doesn't
    change, only the q BlockSpec row count.
    """
    B, H, hd = q.shape
    P, page_size, Hkv, hd_k = k_pages.shape
    if hd != hd_k:
        raise ValueError(f"q head_dim {hd} != kv head_dim {hd_k}")
    if H % Hkv:
        raise ValueError(f"GQA needs H % Hkv == 0, got H={H} Hkv={Hkv}")
    n_rep = H // Hkv
    if kv_bits.shape != (P, page_size) or kv_pos.shape != (P, page_size):
        raise ValueError(
            f"kv page metadata {kv_bits.shape}/{kv_pos.shape} does not "
            f"match the page pool ({P}, {page_size})")
    if q_bits.shape != (B, 1) or q_pos.shape != (B, 1):
        raise ValueError(
            f"q_bits/q_pos must be [B, 1]=({B}, 1), got "
            f"{q_bits.shape}/{q_pos.shape}")
    req, page, first, last, active = (jnp.asarray(s, jnp.int32)
                                      for s in steps)
    n_steps = req.shape[0]
    if not all(s.shape == (n_steps,) for s in (page, first, last, active)):
        raise ValueError("decode-grid step arrays disagree on length")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(H, n_steps),
        in_specs=[
            pl.BlockSpec((1, 1), _im_qrow),
            pl.BlockSpec((1, 1), _im_qrow),
            pl.BlockSpec((1, page_size), _im_page_meta),
            pl.BlockSpec((1, page_size), _im_page_meta),
            pl.BlockSpec((1, 1, hd), _im_qvec),
            pl.BlockSpec((1, page_size, 1, hd),
                         functools.partial(_im_ktile, n_rep=n_rep)),
            pl.BlockSpec((1, page_size, 1, hd),
                         functools.partial(_im_ktile, n_rep=n_rep)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), _im_qvec),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_decode_kernel, softcap=softcap,
                          window=window, scale=hd ** -0.5,
                          block_skip=block_skip),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        compiler_params=_compiler_params_cls()(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(req, page, first, last, active,
      q_bits, q_pos, kv_bits, kv_pos, q, k_pages, v_pages)


# ---------------------------------------------------------------------------
# XLA reference / fallback
# ---------------------------------------------------------------------------

def paged_decode_ref(q, k_pages, v_pages, q_bits, q_pos, kv_bits, kv_pos,
                     page_tables, *, softcap: float = 0.0,
                     window: int = 0):
    """Dense-gather decode oracle: materialize each request's resident
    pages via its page-table row (``[B, max_pages]`` int32, padded with
    the null page, whose bits are all zero and mask out) and run the
    reference masked softmax. Same signature family as the kernel but
    addressed by table rows instead of a step list."""
    B, H, hd = q.shape
    P, page_size, Hkv, _ = k_pages.shape
    mp = page_tables.shape[1]
    pt = jnp.asarray(page_tables, jnp.int32)
    k = k_pages[pt].reshape(B, mp * page_size, Hkv, hd)
    v = v_pages[pt].reshape(B, mp * page_size, Hkv, hd)
    bits = kv_bits[pt].reshape(B, mp * page_size)
    pos = kv_pos[pt].reshape(B, mp * page_size)
    out = bam_attention_ref(q[:, None], k, v, q_bits, bits, q_pos, pos,
                            softcap=softcap, window=window)
    return out[:, 0]
