"""Pure-jnp oracle for the BAM flash-attention kernel.

Deliberately independent of the kernel code path: materializes the full
boolean mask via ``repro.core.bam.allowed_mask`` (the semantics'
single source of truth) and runs a numerically-stable masked softmax.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bam


def bam_attention_ref(q, k, v, q_bits, kv_bits, q_pos, kv_pos, *,
                      softcap: float = 0.0, window: int = 0):
    """q: [B,Tq,H,hd]; k/v: [B,Tk,Hkv,hd] (GQA: H % Hkv == 0);
    bits: uint32 [B,T*]; pos: int32 [B,T*]. Returns [B,Tq,H,hd]."""
    B, Tq, H, hd = q.shape
    Hkv = k.shape[2]
    n_rep = H // Hkv
    if n_rep > 1:
        k = jnp.repeat(k, n_rep, axis=2)
        v = jnp.repeat(v, n_rep, axis=2)

    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    logits = logits * (hd ** -0.5)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    mask = bam.allowed_mask(q_bits, kv_bits, q_pos, kv_pos, window)[:, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)   # fully-masked rows
    p = jnp.exp(logits - m)
    p = jnp.where(mask, p, 0.0)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    p = jnp.where(denom > 0, p / jnp.maximum(denom, 1e-30), 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
    return out.astype(q.dtype)
