"""jit'd public wrapper for BAM attention.

Dispatch:
  impl="xla"           — fused-XLA reference math (production dry-run
                         path on this CPU container; GSPMD-sharded)
  impl="bam_kernel"    — Pallas TPU kernels (real hardware)
  impl="bam_interpret" — Pallas kernel bodies interpreted on CPU
                         (correctness validation; what tests sweep)

Handles GQA, padding to block multiples (pad tokens get bits=0 ⇒ never
attend/attended; pad positions get -1 so debug dumps and workload stats
never alias pad tokens onto real position 0), and the custom_vjp.

Backward: for the kernel impls the forward saves (out, lse) as flash
residuals and the backward runs the fused Pallas dQ / dK/dV kernels
(``bam_flash_attention_bwd``) — no O(Tq·Tk) intermediate is ever
traced. Only impl="xla" still recomputes through the reference path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.bam_attention import (NEG_INF, bam_flash_attention,
                                         bam_flash_attention_bwd)
from repro.kernels.ref import bam_attention_ref


def _pad_axis(x, to: int, axis: int, value=0):
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg, constant_values=value)


def _pad_all(q, k, v, q_bits, kv_bits, q_pos, kv_pos, block_q, block_k):
    """Pad token axes to block multiples. bits pad with 0 (masked);
    positions pad with -1 (NOT 0 — padding onto a real position makes
    workload stats and debug dumps lie, even though bits=0 already
    masks the tokens)."""
    Tq, Tk = q.shape[1], k.shape[1]
    Tq_p = -(-Tq // block_q) * block_q
    Tk_p = -(-Tk // block_k) * block_k
    return (_pad_axis(q, Tq_p, 1), _pad_axis(k, Tk_p, 1),
            _pad_axis(v, Tk_p, 1),
            _pad_axis(q_bits, Tq_p, 1), _pad_axis(kv_bits, Tk_p, 1),
            _pad_axis(q_pos, Tq_p, 1, value=-1),
            _pad_axis(kv_pos, Tk_p, 1, value=-1))


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(7, 8, 9, 10, 11, 12))
def _bam_attention(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                   softcap, window, impl, block_q, block_k, block_map):
    out, _ = _fwd_impl(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                       softcap, window, impl, block_q, block_k, block_map)
    return out


def _fwd_impl(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
              softcap, window, impl, block_q, block_k, block_map):
    """Returns (out [B,Tq,H,hd], lse [B,H,Tq] or None for impl=xla)."""
    if impl == "xla":
        return bam_attention_ref(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                                 softcap=softcap, window=window), None
    Tq = q.shape[1]
    padded = _pad_all(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                      block_q, block_k)
    out, lse = bam_flash_attention(
        padded[0], padded[1], padded[2], padded[3], padded[4],
        padded[5], padded[6], softcap=softcap, window=window,
        block_q=block_q, block_k=block_k, return_mode="residual",
        block_map=block_map, interpret=(impl == "bam_interpret"))
    return out[:, :Tq], lse[:, :, :Tq]


def _fwd_vjp(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
             softcap, window, impl, block_q, block_k, block_map):
    out, lse = _fwd_impl(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                         softcap, window, impl, block_q, block_k, block_map)
    return out, (q, k, v, q_bits, kv_bits, q_pos, kv_pos, out, lse)


def _bwd_vjp(softcap, window, impl, block_q, block_k, block_map, res, g):
    q, k, v, q_bits, kv_bits, q_pos, kv_pos, out, lse = res

    if impl == "xla":
        # XLA fallback: recompute through the reference path and let
        # the compiler derive the VJP (materializes the [Tq,Tk] mask).
        def f(q, k, v):
            return bam_attention_ref(q, k, v, q_bits, kv_bits, q_pos,
                                     kv_pos, softcap=softcap, window=window)

        _, vjp = jax.vjp(f, q, k, v)
        dq, dk, dv = vjp(g)
        return dq, dk, dv, None, None, None, None

    # Fused kernel backward from the (out, lse) residuals.
    dq, dk, dv = bam_attention_chunk_bwd(
        q, k, v, out, g, lse, q_bits, kv_bits, q_pos, kv_pos,
        softcap=softcap, window=window, impl=impl, block_q=block_q,
        block_k=block_k, block_map=block_map)
    return dq, dk, dv, None, None, None, None


def bam_attention_chunk_bwd(q, k, v, out, g, lse, q_bits, kv_bits, q_pos,
                            kv_pos, *, softcap: float = 0.0,
                            window: int = 0, impl: str = "bam_interpret",
                            block_q: int = 128, block_k: int = 128,
                            block_map=None):
    """Fused flash backward from (out, lse) residuals — the building
    block both the single-device ``bam_attention`` VJP and the
    context-parallel chunk backwards share.

    The combining-aware property: ``(out, lse)`` need not come from
    attention over THIS ``k``/``v`` chunk alone — pass the cross-chunk
    COMBINED output and log-sum-exp (CP: derived from the merged
    ``(m, l)`` stats) and the result is this chunk's exact contribution
    to the global-softmax gradients: ``dq`` sums over chunks; ``dk``/
    ``dv`` (GQA-folded to [B, Tk, Hkv, hd]) are complete per chunk. Runs
    the fused Pallas dQ / dK-dV kernels; no O(Tq·Tk) intermediate is
    ever traced. Handles non-block-multiple lengths by bits=0 / pos=-1
    padding, like the forward."""
    assert impl in ("bam_kernel", "bam_interpret"), impl
    Tq, Tk = q.shape[1], k.shape[1]
    qp, kp_, vp, qbp, kbp, qpp, kpp = _pad_all(
        q, k, v, q_bits, kv_bits, q_pos, kv_pos, block_q, block_k)
    Tq_p = qp.shape[1]
    outp = _pad_axis(out, Tq_p, 1)
    gp = _pad_axis(g, Tq_p, 1)
    # padded q rows: lse = NEG_INF reproduces the kernel's own padding
    lsep = _pad_axis(lse, Tq_p, 2, value=NEG_INF)
    dq, dk, dv = bam_flash_attention_bwd(
        qp, kp_, vp, outp, gp, lsep, qbp, kbp, qpp, kpp,
        softcap=softcap, window=window, block_q=block_q, block_k=block_k,
        block_map=block_map, interpret=(impl == "bam_interpret"))
    return dq[:, :Tq], dk[:, :Tk], dv[:, :Tk]


_bam_attention.defvjp(_fwd_vjp, _bwd_vjp)


def _default_pos(B, T):
    return jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))


def bam_attention(q, k, v, q_bits, kv_bits, q_pos=None, kv_pos=None, *,
                  softcap: float = 0.0, window: int = 0,
                  impl: str = "xla", block_q: int = 128,
                  block_k: int = 128, block_map=None):
    """Public BAM attention. q: [B,Tq,H,hd]; k/v: [B,Tk,Hkv,hd];
    bits uint32 [B,T*]; pos default = iota.

    block_map: optional host-precomputed ``repro.core.bam.BlockMask``
    (grid compaction — active tiles only). Static: a new map retraces.
    """
    B, Tq = q.shape[:2]
    Tk = k.shape[1]
    if q_pos is None:
        q_pos = _default_pos(B, Tq)
    if kv_pos is None:
        kv_pos = _default_pos(B, Tk)
    return _bam_attention(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                          float(softcap), int(window), impl,
                          int(block_q), int(block_k), block_map)


def auto_block(T: int, cap: int = 128) -> int:
    """Tile size for short sequences: next multiple of 16, capped."""
    return min(cap, -(-T // 16) * 16)


def bam_attention_stats(q, k, v, q_bits, kv_bits, q_pos=None, kv_pos=None, *,
                        softcap: float = 0.0, window: int = 0,
                        impl: str = "bam_interpret", block_q: int = 128,
                        block_k: int = 128, block_map=None):
    """Unnormalized flash-attention partials for cross-chunk combination
    (context parallelism): returns (acc [B,H,Tq,hd] f32 = sum p·V,
    m [B,H,Tq], l [B,H,Tq]) with the bitfield mask evaluated in-kernel —
    no [B,H,Tq,Tk] logits in HBM. This op is a forward building block
    with no VJP of its own: the combine happens OUTSIDE (the CP bodies),
    so gradients are defined there — ``core.context_parallel``'s
    combining-aware custom_vjps derive (out, lse) from the merged
    (m, l) and drive ``bam_attention_chunk_bwd`` per chunk. Don't
    ``jax.grad`` through this op directly; grad through
    ``cp_attention`` (or ``bam_attention`` single-device) instead.
    """
    assert impl in ("bam_kernel", "bam_interpret"), impl
    B, Tq = q.shape[:2]
    Tk = k.shape[1]
    if q_pos is None:
        q_pos = _default_pos(B, Tq)
    if kv_pos is None:
        kv_pos = _default_pos(B, Tk)
    padded = _pad_all(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                      block_q, block_k)
    acc, m, l = bam_flash_attention(
        padded[0], padded[1], padded[2], padded[3], padded[4],
        padded[5], padded[6], softcap=softcap, window=window,
        block_q=block_q, block_k=block_k, return_mode="stats",
        block_map=block_map, interpret=(impl == "bam_interpret"))
    acc = jnp.einsum("bqhd->bhqd", acc)
    return acc[:, :, :Tq], m[:, :, :Tq], l[:, :, :Tq]
