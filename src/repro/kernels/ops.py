"""jit'd public wrapper for BAM attention.

Dispatch:
  impl="xla"           — fused-XLA reference math (production dry-run
                         path on this CPU container; GSPMD-sharded)
  impl="bam_kernel"    — Pallas TPU kernel (real hardware)
  impl="bam_interpret" — Pallas kernel body interpreted on CPU
                         (correctness validation; what tests sweep)

Handles GQA, padding to block multiples (pad tokens get bits=0 ⇒ never
attend/attended), and the custom_vjp whose backward recomputes through
the XLA path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.bam_attention import bam_flash_attention
from repro.kernels.ref import bam_attention_ref


def _pad_axis(x, to: int, axis: int, value=0):
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg, constant_values=value)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(7, 8, 9, 10, 11))
def _bam_attention(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                   softcap, window, impl, block_q, block_k):
    return _fwd_impl(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                     softcap, window, impl, block_q, block_k)


def _fwd_impl(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
              softcap, window, impl, block_q, block_k):
    if impl == "xla":
        return bam_attention_ref(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                                 softcap=softcap, window=window)
    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    Tq_p = -(-Tq // block_q) * block_q
    Tk_p = -(-Tk // block_k) * block_k
    qp = _pad_axis(q, Tq_p, 1)
    kp_ = _pad_axis(k, Tk_p, 1)
    vp = _pad_axis(v, Tk_p, 1)
    qbp = _pad_axis(q_bits, Tq_p, 1)       # bits=0 -> masked
    kbp = _pad_axis(kv_bits, Tk_p, 1)
    qpp = _pad_axis(q_pos, Tq_p, 1)
    kpp = _pad_axis(kv_pos, Tk_p, 1)
    out = bam_flash_attention(
        qp, kp_, vp, qbp, kbp, qpp, kpp, softcap=softcap, window=window,
        block_q=block_q, block_k=block_k,
        interpret=(impl == "bam_interpret"))
    return out[:, :Tq]


def _fwd_vjp(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
             softcap, window, impl, block_q, block_k):
    out = _fwd_impl(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                    softcap, window, impl, block_q, block_k)
    return out, (q, k, v, q_bits, kv_bits, q_pos, kv_pos)


def _bwd_vjp(softcap, window, impl, block_q, block_k, res, g):
    q, k, v, q_bits, kv_bits, q_pos, kv_pos = res

    def f(q, k, v):
        return bam_attention_ref(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                                 softcap=softcap, window=window)

    _, vjp = jax.vjp(f, q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None, None, None


_bam_attention.defvjp(_fwd_vjp, _bwd_vjp)


def bam_attention(q, k, v, q_bits, kv_bits, q_pos=None, kv_pos=None, *,
                  softcap: float = 0.0, window: int = 0,
                  impl: str = "xla", block_q: int = 128,
                  block_k: int = 128):
    """Public BAM attention. q: [B,Tq,H,hd]; k/v: [B,Tk,Hkv,hd];
    bits uint32 [B,T*]; pos default = iota."""
    B, Tq = q.shape[:2]
    Tk = k.shape[1]
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(Tq, dtype=jnp.int32)[None],
                                 (B, Tq))
    if kv_pos is None:
        kv_pos = jnp.broadcast_to(jnp.arange(Tk, dtype=jnp.int32)[None],
                                  (B, Tk))
    return _bam_attention(q, k, v, q_bits, kv_bits, q_pos, kv_pos,
                          float(softcap), int(window), impl,
                          int(block_q), int(block_k))
