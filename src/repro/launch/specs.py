"""ShapeDtypeStruct input specs for every (arch × input shape) pair —
the dry-run lowers against these; nothing is ever allocated.

train/prefill: tokens/labels/positions [B, T] (+ modality-stub
embeddings for audio/vlm archs, + BAM bits/M-RoPE for vlm).
decode: one new token [B, 1] + the KV/state cache of seq_len.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import api


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    B, T = shape.global_batch, shape.seq_len
    batch = {
        "tokens": _sds((B, T), jnp.int32),
        "labels": _sds((B, T), jnp.int32),
        "positions": _sds((B, T), jnp.int32),
    }
    if cfg.family == "audio":
        batch["encoder_embeds"] = _sds(
            (B, cfg.encdec.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.family == "vlm":
        batch["bits"] = _sds((B, T), jnp.uint32)
        batch["inputs_embeds"] = _sds((B, T, cfg.d_model), cfg.dtype)
        batch["embed_mask"] = _sds((B, T), jnp.bool_)
        batch["pos3"] = _sds((3, B, T), jnp.int32)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    B = shape.global_batch
    return {
        "tokens": _sds((B, 1), jnp.int32),
        "positions": _sds((B, 1), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    B, T = shape.global_batch, shape.seq_len
    return jax.eval_shape(
        lambda: api.init_cache(cfg, B, T, jnp.dtype(cfg.dtype)))


def param_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: api.init(jax.random.PRNGKey(0), cfg))


def opt_state_specs(cfg: ModelConfig, params_spec, ocfg=None):
    from repro.optim import optimizer as opt
    ocfg = ocfg or opt.AdamWConfig()
    return jax.eval_shape(lambda: opt.init(ocfg, jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), params_spec)))


def concrete_batch(cfg: ModelConfig, seq: int, batch: int, seed: int = 0,
                   kind: str = "train"):
    """Small concrete batch matching the spec layout (smoke tests /
    examples)."""
    rng = np.random.default_rng(seed)
    out = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32),
        "positions": jnp.broadcast_to(
            jnp.arange(seq, dtype=jnp.int32)[None], (batch, seq)),
    }
    if cfg.family == "audio":
        out["encoder_embeds"] = jnp.asarray(
            rng.normal(0, 1, (batch, cfg.encdec.encoder_seq, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        from repro.models import vlm as V
        n_img = cfg.mm.num_patches
        assert seq > n_img + 2, (seq, n_img)
        grid = (1, int(np.sqrt(n_img)), int(np.sqrt(n_img)))
        patch = jnp.asarray(rng.normal(0, 1, (batch, n_img, cfg.d_model)),
                            jnp.dtype(cfg.dtype))
        merged = V.make_vlm_batch(out["tokens"], patch,
                                  img_start=(seq - n_img) // 2, grid=grid,
                                  d_model=cfg.d_model)
        merged["labels"] = out["labels"]
        out = merged
    return out
