"""Trip-count-aware static analysis of partitioned HLO.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE —
for scan-over-layers models that undercounts FLOPs/bytes/collectives by
~L×. This module re-derives them from ``compiled.as_text()``:

  * computations are weighted by execution multiplicity, propagated
    through the call graph (fusion ``calls=``, while ``body=`` with the
    ``known_trip_count`` backend config or the loop-condition constant,
    ``conditional`` branches);
  * FLOPs from ``dot`` ops: 2 · numel(result) · K (K = product of the
    lhs contracting dims, resolved from the defining op's shape);
  * collective bytes from the result buffers of all-gather / all-reduce
    / reduce-scatter / all-to-all / collective-permute ops;
  * HBM byte traffic heuristic: Σ result-buffer bytes × 2 (read+write)
    over ops of non-fusion-internal computations (post-fusion HLO ≈ one
    materialized buffer per op), which upper-bounds well for
    matmul/collective-dominated programs.

This is the "profile" of the §Perf loop — no real-TPU timings exist in
this container, so the lowered IR is the measurement substrate.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8,
                "f64": 8, "s16": 2, "u16": 2, "c64": 8, "c128": 16,
                "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"?(\d+)"?\}')


def _shape_info(type_str: str) -> Tuple[int, List[int], int]:
    """First shape in the string -> (numel, dims, bytes). Tuples sum."""
    total_bytes = 0
    first = None
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",") if x]
        n = 1
        for x in d:
            n *= x
        total_bytes += n * _DTYPE_BYTES[dt]
        if first is None:
            first = (n, d)
    if first is None:
        return 0, [], 0
    return first[0], first[1], total_bytes


@dataclasses.dataclass
class Op:
    name: str
    rest: str            # everything after '='


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op]
    defs: Dict[str, str]   # op name -> type string


def _parse(hlo: str) -> List[Computation]:
    comps: List[Computation] = []
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if (line.startswith("%") or line.startswith("ENTRY")) and \
                stripped.endswith("{"):
            m = _COMP_HDR.match(stripped)
            if m:
                cur = Computation(m.group(2), bool(m.group(1)), [], {})
                comps.append(cur)
                continue
        if cur is None:
            continue
        if stripped == "}":
            cur = None
            continue
        dm = _DEF_RE.match(stripped)
        if dm:
            cur.ops.append(Op(dm.group(1), dm.group(2)))
            cur.defs[dm.group(1)] = dm.group(2)
    return comps


def _trip_count(op_rest: str, cond_comp: Optional[Computation]) -> int:
    m = _TRIP_RE.search(op_rest)
    if m:
        return int(m.group(1))
    if cond_comp is not None:
        consts = [int(x) for x in
                  re.findall(r"constant\((\d+)\)", "\n".join(
                      o.rest for o in cond_comp.ops))]
        if consts:
            return max(consts)
    return 1


_CALL_REFS = re.compile(
    r"(?:calls=|body=|condition=|to_apply=)%([\w.\-]+)")
_BRANCH_REFS = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS = re.compile(r"\(([^)]*)\)")


def analyze(hlo: str) -> dict:
    comps = _parse(hlo)
    by_name = {c.name: c for c in comps}

    # multiplicity propagation: callers appear AFTER callees in HLO text,
    # so walking computations in reverse order visits callers first.
    mult: Dict[str, float] = {c.name: 0.0 for c in comps}
    fusion_internal = set()
    for c in comps:
        if c.is_entry:
            mult[c.name] = 1.0
    for c in reversed(comps):
        w = mult[c.name]
        if w == 0:
            continue
        for op in c.ops:
            rest = op.rest
            if " while(" in rest or rest.startswith("while("):
                body = re.search(r"body=%([\w.\-]+)", rest)
                cond = re.search(r"condition=%([\w.\-]+)", rest)
                n = _trip_count(rest, by_name.get(cond.group(1))
                                if cond else None)
                if body:
                    mult[body.group(1)] += w * n
                if cond:
                    mult[cond.group(1)] += w * (n + 1)
            elif "calls=%" in rest:
                for ref in re.findall(r"calls=%([\w.\-]+)", rest):
                    mult[ref] += w
                    fusion_internal.add(ref)
            elif "branch_computations=" in rest:
                bm = _BRANCH_REFS.search(rest)
                if bm:
                    for ref in re.findall(r"%([\w.\-]+)", bm.group(1)):
                        mult[ref] += w
            elif "to_apply=%" in rest:
                # reduce/sort comparators: scalar, negligible — skip
                pass

    flops = 0.0
    coll = {k: 0.0 for k in COLLECTIVES}
    coll_counts = {k: 0 for k in COLLECTIVES}
    hbm_bytes = 0.0
    _skip_byte_ops = ("parameter(", "constant(", "get-tuple-element(",
                      "tuple(", "bitcast(", "bitcast-convert(",
                      "after-all(", "partition-id(", "copy-done(",
                      "all-gather-done(", "all-reduce-done(")

    for c in comps:
        w = mult[c.name]
        if w == 0:
            continue
        count_bytes = c.name not in fusion_internal
        for op in c.ops:
            rest = op.rest
            if " dot(" in rest or re.match(r"[a-z0-9]+\[[^\]]*\]\S*\s+dot\(",
                                           rest):
                numel, dims, _ = _shape_info(rest.split(" dot(")[0]
                                             if " dot(" in rest else rest)
                # lhs operand name
                opm = _OPERANDS.search(rest)
                lhs_k = 1
                if opm:
                    names = re.findall(r"%([\w.\-]+)", opm.group(1))
                    cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                                   rest)
                    if names and cd and names[0] in c.defs:
                        _, lhs_dims, _ = _shape_info(c.defs[names[0]])
                        for i in [int(x) for x in cd.group(1).split(",")
                                  if x]:
                            if i < len(lhs_dims):
                                lhs_k *= lhs_dims[i]
                flops += w * 2.0 * numel * lhs_k
            for kind in COLLECTIVES:
                if f" {kind}(" in rest or rest.split("(")[0].endswith(kind):
                    _, _, b = _shape_info(rest.split(f" {kind}(")[0])
                    coll[kind] += w * b
                    coll_counts[kind] += int(w)
                    break
            if count_bytes and not any(s in rest for s in _skip_byte_ops):
                _, _, b = _shape_info(rest.split("(")[0])
                hbm_bytes += w * 2.0 * b

    total_coll = sum(coll.values())
    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": {**coll, "total": total_coll},
        "collective_counts": coll_counts,
        "num_computations": len(comps),
    }


def top_collectives(hlo: str, n: int = 15):
    """The §Perf profiling view: largest collectives (bytes × execution
    multiplicity), with their jax op_name provenance."""
    comps = _parse(hlo)
    by_name = {c.name: c for c in comps}
    mult: Dict[str, float] = {c.name: 0.0 for c in comps}
    for c in comps:
        if c.is_entry:
            mult[c.name] = 1.0
    for c in reversed(comps):
        w = mult[c.name]
        if w == 0:
            continue
        for op in c.ops:
            rest = op.rest
            if " while(" in rest:
                body = re.search(r"body=%([\w.\-]+)", rest)
                cond = re.search(r"condition=%([\w.\-]+)", rest)
                t = _trip_count(rest, by_name.get(cond.group(1))
                                if cond else None)
                if body:
                    mult[body.group(1)] += w * t
                if cond:
                    mult[cond.group(1)] += w * (t + 1)
            elif "calls=%" in rest:
                for ref in re.findall(r"calls=%([\w.\-]+)", rest):
                    mult[ref] += w

    rows = []
    for c in comps:
        w = mult[c.name]
        if w == 0:
            continue
        for op in c.ops:
            rest = op.rest
            for kind in COLLECTIVES:
                if f" {kind}(" in rest:
                    _, _, b = _shape_info(rest.split(f" {kind}(")[0])
                    m = re.search(r'op_name="([^"]*)"', rest)
                    rows.append((w * b, kind, int(w), b,
                                 (m.group(1) if m else "?")[:160]))
                    break
    rows.sort(reverse=True)
    return rows[:n]
