"""Production mesh builders (TPU v5e pods).

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first
jax init; everything else sees the single real CPU device.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — roofline terms (EXPERIMENTS.md)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the locally available devices (tests/examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))
