"""Sharding rules: map model/optimizer/batch pytrees to PartitionSpecs.

Megatron-style baseline:
  * attention heads + FFN hidden + experts + vocab -> ``model`` axis
  * batch -> ``("pod", "data")`` (or ``data`` single-pod)
  * residual activations -> sequence dim over ``model`` (Megatron
    sequence parallelism; the memory-term lever in §Perf)
  * long_500k decode: KV cache sequence over ``data`` (batch=1)

jit argument shardings must divide evenly, so every rule is a
*candidate list*: the first spec whose sharded dims divide the array
(given the mesh axis sizes) wins; otherwise the next candidate (e.g.
MoE expert-parallel falls back to TP-within-expert when E % 16 != 0;
KV caches with few GQA heads fall back to sequence sharding), and
finally replication.

The active rules are process-global trace-time constants, set by the
launcher before tracing; model code calls ``constrain_residual`` which
no-ops when no rules are active (unit tests / single-device runs).
"""
from __future__ import annotations

import dataclasses
import re
from typing import List, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class Rules:
    multi_pod: bool = False
    zero_sharded_opt: bool = False     # ZeRO: optimizer state over data
    seq_parallel: bool = True          # activations: seq over model
    shard_cache_seq: bool = False      # long_500k: cache seq over data
    fsdp: bool = False                 # dense-train FSDP-style sharding

    @property
    def dp(self):
        return ("pod", "data") if self.multi_pod else ("data",)


_ACTIVE: Optional[Rules] = None


def set_rules(rules: Optional[Rules]):
    global _ACTIVE
    _ACTIVE = rules


def active() -> Optional[Rules]:
    return _ACTIVE


def constrain_residual(x):
    """[B, T, d] residual-stream constraint (sequence parallelism)."""
    r = _ACTIVE
    if r is None:
        return x
    if r.fsdp:
        ax = fsdp_axes(r)
        n = 1
        for a in ax:
            n *= _axis_len(a)
        if x.shape[0] % n == 0:
            return jax.lax.with_sharding_constraint(x, P(ax, None, None))
        return jax.lax.with_sharding_constraint(x, P(r.dp, None, None))
    if r.seq_parallel and x.shape[1] % _axis_len("model") == 0 and \
            x.shape[0] % _dp_len(r) == 0:
        return jax.lax.with_sharding_constraint(x, P(r.dp, "model", None))
    return jax.lax.with_sharding_constraint(x, P(r.dp, None, None))


def _axis_len(name: str) -> int:
    mesh = _CURRENT_MESH
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _dp_len(r: Rules) -> int:
    n = 1
    for a in r.dp:
        n *= _axis_len(a)
    return n


_CURRENT_MESH = None


def set_mesh(mesh):
    global _CURRENT_MESH
    _CURRENT_MESH = mesh


# ---------------------------------------------------------------------------
# Divisibility-aware candidate selection
# ---------------------------------------------------------------------------

def _entry_size(mesh_sizes, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for e in entry:
            n *= mesh_sizes.get(e, 1)
        return n
    return mesh_sizes.get(entry, 1)


def _spec_fits(spec: P, shape, mesh_sizes) -> bool:
    if len(spec) > len(shape):
        return False
    # right-align
    pads = len(shape) - len(spec)
    for i, entry in enumerate(spec):
        n = _entry_size(mesh_sizes, entry)
        if n > 1 and shape[pads + i] % n != 0:
            return False
    return True


def _align(spec: P, ndim: int) -> P:
    pads = ndim - len(spec)
    if pads < 0:
        return P()
    return P(*([None] * pads + list(spec)))


def pick_spec(candidates: Sequence[P], shape, mesh) -> P:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for c in candidates:
        if _spec_fits(c, shape, sizes):
            return _align(c, len(shape))
    return P()


# ---------------------------------------------------------------------------
# Parameter shardings (path-pattern rules -> candidate lists)
# ---------------------------------------------------------------------------

def _moe_or_dense_up(leaf):
    if leaf.ndim >= 4:   # [L, E, d, f]: expert-parallel, else TP-in-expert
        return [P("model", None, None), P(None, None, "model")]
    return [P(None, "model"), P("model", None)]


def _moe_or_dense_down(leaf):
    if leaf.ndim >= 4:
        return [P("model", None, None), P(None, "model", None)]
    return [P("model", None), P(None, "model")]


_PARAM_RULES = [
    (r"mlp/w_(gate|up)$", _moe_or_dense_up),
    (r"mlp/w_down$", _moe_or_dense_down),
    (r"(attn|cross)/w[qkv]$", lambda _: [P(None, "model"),
                                         P("model", None)]),
    (r"(attn|cross)/wo$", lambda _: [P("model", None), P(None, "model")]),
    (r"(attn|cross)/b[qkv]$", lambda _: [P("model")]),
    (r"(mlp|ffn|shared)/w_(up|gate)$", lambda _: [P(None, "model"),
                                                  P("model", None)]),
    (r"(mlp|ffn|shared)/w_down$", lambda _: [P("model", None),
                                             P(None, "model")]),
    (r"(^|/)embed$", lambda _: [P("model", None), P(None, "model")]),
    (r"(^|/)unembed$", lambda _: [P(None, "model"), P("model", None)]),
    (r"in_proj$", lambda _: [P(None, "model"), P("model", None)]),
    (r"out_proj$", lambda _: [P("model", None), P(None, "model")]),
    (r"conv_[wb]$", lambda _: [P()]),
    (r"w_(up|gate_up)$", lambda _: [P(None, "model"), P("model", None)]),
    (r"w(q|k|v|i|f)$", lambda _: [P(None, "model"), P("model", None)]),
    (r"w_down$", lambda _: [P("model", None), P(None, "model")]),
    (r"w_zifo$", lambda _: [P(None, "model"), P("model", None)]),
    (r"r_zifo$", lambda _: [P(None, "model", None, None),
                            P(None, None, "model", None)]),
    (r"projector/w[12]$", lambda _: [P(None, "model"), P("model", None)]),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspec(path, leaf, mesh) -> P:
    s = _path_str(path)
    for pat, builder in _PARAM_RULES:
        if re.search(pat, s):
            return pick_spec(builder(leaf), leaf.shape, mesh)
    return P()  # replicated (norms, scalars, biases)


def param_pspecs(params, mesh):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: param_pspec(p, l, mesh), params)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def batch_pspecs(rules: Rules, batch, mesh):
    dp = rules.dp

    def spec(path, leaf):
        name = _path_str(path)
        if name.endswith("pos3"):
            return pick_spec([P(None, dp, None)], leaf.shape, mesh)
        cands = {
            1: [P(dp)],
            2: [P(dp, None)],
            3: [P(dp, None, None)],
        }.get(leaf.ndim, [P()])
        return pick_spec(cands, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_pspecs(rules: Rules, cache, mesh):
    dp = rules.dp

    def spec(path, leaf):
        name = _path_str(path)
        if name.endswith("bits"):
            cands = [P(None, dp)] if rules.shard_cache_seq else \
                [P(dp, None)]
            return pick_spec(cands, leaf.shape, mesh)
        if re.search(r"(^|/)(k|v|cross_k|cross_v|attn_k|attn_v)$", name):
            # [L|G, B, T, Hkv, hd]
            if rules.shard_cache_seq:
                cands = [P(None, None, dp, "model", None),
                         P(None, None, dp, None, None)]
            else:
                cands = [P(None, dp, None, "model", None),
                         P(None, dp, "model", None, None),
                         P(None, dp, None, None, None)]
            return pick_spec(cands, leaf.shape, mesh)
        if name.endswith("ssm"):    # [L, B, nh, hd, ds]
            b = None if rules.shard_cache_seq else dp
            cands = [P(None, b, "model", None, None),
                     P(None, b, None, None, None)]
            return pick_spec(cands, leaf.shape, mesh)
        if name.endswith("conv"):   # [L, B, k, C]
            b = None if rules.shard_cache_seq else dp
            cands = [P(None, b, None, "model"), P(None, b, None, None)]
            return pick_spec(cands, leaf.shape, mesh)
        if leaf.ndim >= 2:
            b = None if rules.shard_cache_seq else dp
            cands = [P(None, b, *([None] * (leaf.ndim - 2)))]
            return pick_spec(cands, leaf.shape, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache)


def opt_state_pspecs(rules: Rules, params, mesh):
    """Adam m/v shard like params; ZeRO additionally shards the leading
    (layer-stacked) dim over data where divisible."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def spec(path, leaf):
        base = param_pspec(path, leaf, mesh)
        if not rules.zero_sharded_opt or leaf.ndim < 2:
            return base
        entries = list(base) + [None] * (leaf.ndim - len(base))
        # insert the dp axes at the first replicated dim that divides
        for i, e in enumerate(entries):
            if e is not None:
                continue
            cand = list(entries)
            cand[i] = rules.dp
            z = P(*cand)
            if _spec_fits(z, leaf.shape, sizes):
                return z
        return base

    return jax.tree_util.tree_map_with_path(spec, params)


def constrain(x, *entries):
    """Generic divisibility-checked sharding constraint for model code.
    ``entries`` align to x's dims; "dp" resolves to the active data
    axes. No-op when no rules/mesh are active (unit tests)."""
    r = _ACTIVE
    mesh = _CURRENT_MESH
    if r is None or mesh is None:
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    resolved = tuple(r.dp if e == "dp" else e for e in entries)
    spec = P(*resolved)
    if _spec_fits(spec, x.shape, sizes):
        return jax.lax.with_sharding_constraint(x, spec)
    return x


# ---------------------------------------------------------------------------
# FSDP-style rules (beyond-paper §Perf iteration for dense-arch training):
# weights shard their widest dim over ALL non-pod axes; the batch shards
# over the same axes, so GSPMD resolves the contraction conflict by
# all-gathering each layer's weights (O(params) comm per step) instead
# of Megatron-TP's O(activations)-per-layer traffic.
# ---------------------------------------------------------------------------

def fsdp_axes(rules: Rules):
    return ("data", "model")


def fsdp_param_pspec(path, leaf, mesh, rules: Rules) -> P:
    ax = fsdp_axes(rules)
    if leaf.ndim == 0:
        return P()
    # try dims widest-first
    order = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
    for i in order:
        spec = [None] * leaf.ndim
        spec[i] = ax
        p = P(*spec)
        if _spec_fits(p, leaf.shape,
                      dict(zip(mesh.axis_names, mesh.devices.shape))):
            return p
    return P()


def fsdp_param_pspecs(params, mesh, rules: Rules):
    return jax.tree_util.tree_map_with_path(
        lambda p, l: fsdp_param_pspec(p, l, mesh, rules), params)


def fsdp_batch_pspecs(rules: Rules, batch, mesh):
    ax = fsdp_axes(rules)

    def spec(path, leaf):
        name = _path_str(path)
        if name.endswith("pos3"):
            return pick_spec([P(None, ax, None), P(None, ("data",), None)],
                             leaf.shape, mesh)
        cands = {
            1: [P(ax), P(("data",))],
            2: [P(ax, None), P(("data",), None)],
            3: [P(ax, None, None), P(("data",), None, None)],
        }.get(leaf.ndim, [P()])
        return pick_spec(cands, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, batch)
