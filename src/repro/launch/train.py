"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --steps 300 --seq 128 --batch 4 [--reduced] [--mllm valm] \
        [--ckpt-dir ckpts/run0] [--ckpt-every 50] [--resume] \
        [--fault-plan faults.json] [--log-every 10]

Two modes:
  * LM mode (``--arch``): any registered architecture; synthetic LM
    stream (repro.data.synthetic.TextLMDataset).
  * MLLM mode (``--mllm vlm|alm|valm``): the Cornstarch path — frozen
    encoders + LLM, trainable projectors, multimodal batches; the
    frozen mask drives both stop_gradient and optimizer masking. The
    parallelization decision is a typed ``MLLMParallelPlan``
    (repro.parallel): load a cached one with ``--plan plan.json``, or
    let the driver search one (``--plan-devices`` / ``--cp-size`` /
    ``--microbatches``) and persist it with ``--plan-out``. Adding
    ``--spmd`` trains the SAME model distributed: the MLLM is
    partitioned into per-stage callables (repro.models.stages), the
    plan's wave/collective program is compiled and lint-gated, and
    every train step replays it under ``shard_map`` across the
    pipeline mesh. ``--resume`` works across modes — a replay-mode
    checkpoint resumes an ``--spmd`` run and vice versa (params are
    re-partitioned; optimizer moments reset).

Both modes run under the fault-tolerant runtime (repro.resilience):
the train step is health-guarded (NaN/Inf and grad-norm gated in-jit,
EMA loss-spike scored), verdicts and faults land in
``<ckpt-dir>/events.jsonl``, and ``--ckpt-dir`` names a
``CheckpointManager`` root of atomic ``step_XXXXXXXX`` checkpoints
bundling params + optimizer + health EMA + data cursor in one
manifest. ``--resume`` restarts from ``latest()`` bit-exactly — an
interrupted-and-resumed run logs the same losses as an uninterrupted
one (asserted in tests/test_resilience.py). ``--fault-plan`` replays a
deterministic ``FaultPlan`` JSON (NaN grads, crash, kill-mid-save,
device loss) against the run — the chaos-testing entry point.

Runs on whatever devices exist (data-parallel over the host mesh when
more than one); this is the driver the smoke/e2e examples call into.
"""
from __future__ import annotations

import argparse
import os
import time

import jax

from repro.configs.base import get_config
from repro.data.synthetic import MultimodalDataset, TextLMDataset
from repro.models import api
from repro.optim import optimizer as opt
from repro.training import steps


def _run_resilient(args, loss_fn, params, ocfg, *, frozen_mask=None,
                   ds_factory, frozen_ckpt_paths=None,
                   on_device_loss=None, meta=None,
                   value_and_grad_fn=None,
                   convert_checkpoint=None) -> dict:
    """The shared fault-tolerant loop both modes run: guarded step,
    monitor + JSONL events, atomic checkpoints, rollback/resume.

    ``value_and_grad_fn`` replaces the default autodiff sweep inside
    the guarded step (the SPMD path computes grads by replaying the
    schedule's B/W items). ``convert_checkpoint(manager, peek_meta) ->
    (params, step, cursor)`` handles cross-mode resume: when the
    newest checkpoint's ``meta["mode"]`` differs from this run's, the
    converter loads it under the SOURCE layout and re-partitions the
    params; optimizer moments and the health EMA are layout-bound and
    restart fresh (``ResilientTrainer.adopt_state``)."""
    from repro.resilience import (CheckpointManager, CursorStream,
                                  EventLog, FaultInjector, FaultPlan,
                                  HealthMonitor, MonitorConfig,
                                  ResilientTrainer,
                                  make_resilient_train_step)
    if args.resume and not args.ckpt_dir:
        raise SystemExit("--resume needs --ckpt-dir")
    state = opt.init(ocfg, params, frozen_mask)
    step_fn = jax.jit(
        make_resilient_train_step(loss_fn, ocfg, frozen_mask,
                                  value_and_grad_fn=value_and_grad_fn),
        donate_argnums=(0, 1, 2))
    manager = log_path = None
    if args.ckpt_dir:
        manager = CheckpointManager(args.ckpt_dir, keep=args.keep,
                                    frozen_paths=frozen_ckpt_paths)
        log_path = os.path.join(args.ckpt_dir, "events.jsonl")
    monitor = HealthMonitor(
        MonitorConfig(spike_sigma=args.spike_sigma), EventLog(log_path))
    injector = None
    if args.fault_plan:
        injector = FaultInjector(FaultPlan.load(args.fault_plan))
        print(f"fault plan armed: {len(injector.plan.faults)} fault(s) "
              f"from {args.fault_plan}")
    resume, adopted, src_mode = args.resume, None, None
    if args.resume and manager is not None \
            and convert_checkpoint is not None:
        peek = manager.peek_meta()
        src_mode = peek.get("mode")
        want = (meta or {}).get("mode")
        if peek and src_mode and want and src_mode != want:
            adopted = convert_checkpoint(manager, peek)
            resume = False  # like-tree restore can't span layouts
    trainer = ResilientTrainer(
        step_fn, params, state, CursorStream(ds_factory),
        monitor=monitor, manager=manager, injector=injector,
        ckpt_every=args.ckpt_every, resume=resume,
        meta={"seed": args.seed, **(meta or {})},
        on_device_loss=on_device_loss, log_every=args.log_every)
    if adopted is not None:
        a_params, a_step, a_cursor = adopted
        trainer.adopt_state(a_params,
                            opt.init(ocfg, a_params, frozen_mask),
                            step=a_step, cursor=a_cursor)
        print(f"cross-mode resume: converted a {src_mode!r} checkpoint "
              f"at step {a_step} into this run's layout (optimizer "
              f"moments and health EMA reset)")
    if args.resume and trainer.step:
        print(f"resumed from {manager.latest()} at step {trainer.step}")
    t0 = time.time()
    res = trainer.run(args.steps)
    took = time.time() - t0
    if manager is not None:
        trainer.save_checkpoint()
        print(f"saved checkpoint to {manager.latest()}")
    n_params = sum(x.size for x in jax.tree.leaves(params))
    losses = [v for _, v in sorted(res["losses"].items())]
    if res["rollbacks"] or res["skipped"]:
        print(f"resilience: {res['skipped']} skipped step(s), "
              f"{res['rollbacks']} rollback(s), "
              f"{len(res['fired_faults'])} fault(s) fired")
    done = max(len(losses), 1)
    print(f"trained {len(losses)} step(s) in {took:.1f}s "
          f"({took / done:.2f}s/step)")
    return {"params": n_params, "first_loss": losses[0],
            "last_loss": losses[-1], "losses": losses,
            "resilience": res}


def train_lm(args) -> dict:
    cfg = get_config(args.arch, reduced=args.reduced)
    if args.vocab:
        cfg = cfg.replace(vocab_size=args.vocab)
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 10
                                                        or 1),
                           total_steps=args.steps)

    def ds_factory():
        return TextLMDataset(cfg.vocab_size, args.seq, args.batch,
                             seed=args.seed)

    return _run_resilient(args, steps.make_loss_fn(cfg), params, ocfg,
                          ds_factory=ds_factory,
                          meta={"arch": args.arch})


def resolve_plan(mllm, args):
    """The MLLMParallelPlan this run trains under: loaded from
    ``--plan`` (a launch script's cached search) or searched fresh via
    ``parallelize`` — the single typed entrypoint for the joint
    PP x CP decision. ``--plan-out`` persists it for the next launch."""
    from repro.parallel import (ClusterSpec, MLLMParallelPlan,
                                WorkloadShape, parallelize)
    if args.plan:
        plan = MLLMParallelPlan.load(args.plan)
    else:
        # paper block size at paper lengths; on reduced sequences keep
        # at least ~2 blocks per CP rank so the balancer has choices
        block = min(128, max(8, mllm.merged_length(args.seq)
                             // (2 * args.cp_size)))
        plan = parallelize(
            mllm, ClusterSpec(num_devices=args.plan_devices,
                              cp_size=args.cp_size),
            WorkloadShape(text_len=args.seq,
                          num_microbatches=args.microbatches,
                          microbatch_size=args.batch,
                          block_size=block))
    # instantiating the plan validates it against THIS mllm (stage
    # counts vs layer counts, encoder set) before any step runs; in
    # --spmd mode the contract also carries the compiled wave/ppermute
    # program, which the lint gate below then statically validates
    mode = "spmd" if getattr(args, "spmd", False) else "replay"
    executor = plan.apply(mllm, text_len=args.seq, mode=mode)
    if getattr(args, "lint", True):
        # the schedlint gate: a plan whose timeline would race,
        # overflow the activation caps, or deadlock a ring lowering
        # must die here, not N steps into a run (--no-lint to bypass)
        from repro.analysis import (format_findings, gate,
                                    lint_executor_contract, lint_plan)
        found = lint_plan(plan) + lint_executor_contract(executor)
        if gate(found):
            raise SystemExit(format_findings(
                found, header="plan failed the schedule lint "
                              "(--no-lint to bypass):"))
        if found:
            print(format_findings(found, header="plan lint notes:"))
    if args.plan_out:
        plan.save(args.plan_out)
        print(f"saved plan to {args.plan_out}")
    return plan, executor


def shrink_plan(mllm, plan, lost: int, args):
    """Graceful degradation on device loss: re-run ``parallelize()``
    over the shrunken ``ClusterSpec`` and return the degraded plan the
    run continues under (Cornstarch's planner answers the same
    question, just for fewer devices)."""
    from repro.parallel import ClusterSpec, WorkloadShape, parallelize
    # an MLLM plan needs at least one LLM stage plus one stage per
    # encoder; losses below that floor can't be re-planned away
    floor = 1 + len(mllm.encoders)
    devices = max(floor, plan.pp_devices - lost)
    block = min(128, max(8, mllm.merged_length(args.seq)
                         // (2 * max(plan.cp_ranks, 1))))
    degraded = parallelize(
        mllm, ClusterSpec(num_devices=devices, cp_size=plan.cp_ranks),
        WorkloadShape(text_len=args.seq,
                      num_microbatches=args.microbatches,
                      microbatch_size=args.batch, block_size=block))
    print(f"device loss: re-planned {plan.pp_devices} -> "
          f"{degraded.pp_devices} pipeline devices "
          f"(bubble {degraded.schedule.bubble_fraction:.3f})")
    return degraded


def _mllm_ds_factory(args, mllm):
    """Shared multimodal stream factory — replay and SPMD modes must
    consume the identical batch sequence (the loss-parity and
    cross-mode-resume tests depend on it)."""
    def ds_factory():
        return MultimodalDataset(
            vocab_size=mllm.llm_cfg.vocab_size, text_len=args.seq,
            batch_size=args.batch,
            encoder_dims={n: e.cfg.d_model
                          for n, e in mllm.encoders.items()},
            encoder_tokens={n: e.num_tokens
                            for n, e in mllm.encoders.items()},
            modality_ids={n: e.modality_id
                          for n, e in mllm.encoders.items()},
            seed=args.seed)
    return ds_factory


def _train_mllm_spmd(args, mllm, plan, executor) -> dict:
    """Real-model distributed training: the plan's compiled wave
    program drives the MLLM's own stage partition (``models.stages``)
    through the ``shard_map`` runner every step — no toy stages
    anywhere on this path. Loss and grads are the per-microbatch sums
    rescaled by ``1/M``, which makes them numerically comparable to
    (and tested against) the single-process ``make_mllm_train_step``.
    """
    import json

    from repro.parallel.spmd import build_spmd_runner, mesh_from_plan
    from repro.resilience.monitor import init_health

    D = int(executor["schedule"]["num_devices"])
    if len(jax.devices()) < D:
        raise SystemExit(
            f"--spmd needs {D} devices for this plan but the "
            f"process has {len(jax.devices())}; relaunch with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={D}")
    bundle = executor["stage_bundle"]
    M = int(plan.schedule.num_microbatches)
    if args.batch % M != 0:
        raise SystemExit(
            f"--spmd needs --batch divisible by the plan's "
            f"{M} microbatches, got --batch {args.batch}")
    runner = build_spmd_runner(
        bundle.stage_fns, executor["sim_graph"], executor["schedule"],
        mesh=mesh_from_plan(plan, mllm, D),
        microbatch_loss=bundle.microbatch_loss,
        program=executor["spmd_program"],
        trainable=list(bundle.trainable))

    params = mllm.init(jax.random.PRNGKey(args.seed))
    stage_params = bundle.partition(params)
    frozen_mask = bundle.frozen_masks(stage_params)
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 10
                                                        or 1),
                           total_steps=args.steps)
    scale = 1.0 / M

    def value_and_grad_fn(sp, batch):
        # the schedule's B/W items ARE the backward pass — one jitted
        # shard_map core per step instead of an autodiff sweep
        mbs = bundle.encode_microbatches(batch, M)
        _out, loss, grads_repr, _occ, _wocc = runner.core(
            runner.prepare(sp), mbs, hetero=True)
        grads = jax.tree.map(lambda g: g * scale,
                             runner.finish_grads(grads_repr))
        loss = loss * scale
        return (loss, {"ce": loss}), grads

    def convert_checkpoint(manager, peek):
        # replay-mode checkpoint -> stage list: load under the
        # whole-model layout, then partition per this plan's stages
        like = {"params": params,
                "opt": opt.init(ocfg, params, mllm.frozen_mask(params)),
                "health": init_health()}
        tree, step, src = manager.restore(like)
        return (bundle.partition(tree["params"]),
                int(src.get("step", step)),
                int(src.get("cursor", src.get("step", step))))

    def on_device_loss(lost: int) -> None:
        shrink_plan(mllm, plan, lost, args)

    # frozen-shard hardlinking keys on whole-model paths; stage-list
    # checkpoints use per-stage paths, so skip the optimization here
    return _run_resilient(args, None, stage_params, ocfg,
                          frozen_mask=frozen_mask,
                          ds_factory=_mllm_ds_factory(args, mllm),
                          frozen_ckpt_paths=None,
                          on_device_loss=on_device_loss,
                          meta={"mllm": args.mllm,
                                "plan": plan.to_json(),
                                "mode": "spmd",
                                "spmd_layout":
                                    json.dumps(bundle.layout_meta)},
                          value_and_grad_fn=value_and_grad_fn,
                          convert_checkpoint=convert_checkpoint)


def train_mllm(args) -> dict:
    from repro.models.mllm import build_paper_mllm
    mllm = build_paper_mllm(args.mllm, reduced=args.reduced,
                            text_len=args.seq)
    if args.train_llm:
        # the paper's ft1 fine-tune: frozen encoders, trainable LLM —
        # the scenario where zero-bubble W passes have work to defer
        mllm.freeze("llm", module=False)
    plan, executor = resolve_plan(mllm, args)
    print(plan.describe())
    print(f"executor graph: {len(executor['graph'].stages)} stages, "
          f"simulated bubble "
          f"{executor['schedule']['bubble_fraction']:.3f}")
    if getattr(args, "spmd", False):
        return _train_mllm_spmd(args, mllm, plan, executor)
    params = mllm.init(jax.random.PRNGKey(args.seed))
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 10
                                                        or 1),
                           total_steps=args.steps)
    frozen_mask = mllm.frozen_mask(params)
    _, loss_fn = steps.make_mllm_train_step(mllm, ocfg)

    # frozen modules' shards are written once and hardlinked forward by
    # the CheckpointManager (checkpoint-I/O face of frozen awareness)
    frozen_ckpt_paths = {f"params/encoders/{n}/module"
                         for n in mllm.encoders}
    if not args.train_llm:
        frozen_ckpt_paths.add("params/llm")

    def convert_checkpoint(manager, peek):
        # spmd-mode checkpoint -> whole-model tree: rebuild the stage
        # layout the checkpoint was written under, load the stage list,
        # and concatenate it back (models.stages.StageBundle round-trip)
        import json

        from repro.models.stages import build_mllm_stages
        from repro.resilience.monitor import init_health
        bundle = build_mllm_stages(mllm, executor, text_len=args.seq)
        want = peek.get("spmd_layout")
        if want and json.loads(want) != bundle.layout_meta:
            raise SystemExit(
                "the newest checkpoint was written under a different "
                "SPMD stage layout than this plan resolves to; resume "
                "with the plan that wrote it (--plan)")
        sp0 = bundle.partition(params)
        like = {"params": sp0,
                "opt": opt.init(ocfg, sp0, bundle.frozen_masks(sp0)),
                "health": init_health()}
        tree, step, src = manager.restore(like)
        return (bundle.unpartition(tree["params"]),
                int(src.get("step", step)),
                int(src.get("cursor", src.get("step", step))))

    def on_device_loss(lost: int) -> None:
        shrink_plan(mllm, plan, lost, args)

    return _run_resilient(args, loss_fn, params, ocfg,
                          frozen_mask=frozen_mask,
                          ds_factory=_mllm_ds_factory(args, mllm),
                          frozen_ckpt_paths=frozen_ckpt_paths,
                          on_device_loss=on_device_loss,
                          meta={"mllm": args.mllm,
                                "plan": plan.to_json(),
                                "mode": "replay"},
                          convert_checkpoint=convert_checkpoint)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--mllm", default=None, choices=[None, "vlm", "alm",
                                                     "valm"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    # fault tolerance (repro.resilience)
    ap.add_argument("--ckpt-dir", default=None,
                    help="CheckpointManager root (atomic step_XXXXXXXX "
                    "checkpoints + events.jsonl)")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint cadence in steps (0 = only the "
                    "final checkpoint)")
    ap.add_argument("--keep", type=int, default=3,
                    help="checkpoints retained under --ckpt-dir")
    ap.add_argument("--resume", action="store_true",
                    help="restart from the newest checkpoint under "
                    "--ckpt-dir (bit-exact continuation)")
    ap.add_argument("--fault-plan", default=None,
                    help="FaultPlan JSON to inject deterministically "
                    "(see repro.resilience.faults)")
    ap.add_argument("--spike-sigma", type=float, default=8.0,
                    help="EMA loss-spike z-score that triggers a "
                    "rollback verdict")
    # MLLM-mode parallelization plan (repro.parallel typed API)
    ap.add_argument("--plan", default=None,
                    help="MLLMParallelPlan JSON to train under "
                    "(default: search one via parallelize())")
    ap.add_argument("--plan-out", default=None,
                    help="write the resolved plan JSON here")
    ap.add_argument("--plan-devices", type=int, default=8,
                    help="pipeline device budget for the plan search")
    ap.add_argument("--cp-size", type=int, default=1,
                    help="context-parallel ranks for the plan search")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--no-lint", dest="lint", action="store_false",
                    help="skip the schedlint gate on the resolved plan")
    ap.add_argument("--spmd", action="store_true",
                    help="MLLM mode: partition the model into pipeline "
                    "stages, compile the plan's timeline to the "
                    "shard_map executor (lint-gated), and train the "
                    "real model distributed — every step replays the "
                    "schedule's wave program across the device mesh")
    ap.add_argument("--train-llm", action="store_true",
                    help="MLLM mode: unfreeze the LLM (ft1 fine-tune)")
    args = ap.parse_args(argv)
    if (args.arch is None) == (args.mllm is None):
        raise SystemExit("pass exactly one of --arch / --mllm")
    res = train_mllm(args) if args.mllm else train_lm(args)
    print(f"done: {res['params']:,} params, "
          f"loss {res['first_loss']:.3f} -> {res['last_loss']:.3f}")
    return res


if __name__ == "__main__":
    main()
