"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --steps 300 --seq 128 --batch 4 [--reduced] [--mllm valm] \
        [--ckpt-dir ckpts/run0] [--log-every 10]

Two modes:
  * LM mode (``--arch``): any registered architecture; synthetic LM
    stream (repro.data.synthetic.TextLMDataset).
  * MLLM mode (``--mllm vlm|alm|valm``): the Cornstarch path — frozen
    encoders + LLM, trainable projectors, multimodal batches; the
    frozen mask drives both stop_gradient and optimizer masking.

Runs on whatever devices exist (data-parallel over the host mesh when
more than one); this is the driver the smoke/e2e examples call into.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import get_config
from repro.data.synthetic import MultimodalDataset, TextLMDataset
from repro.models import api
from repro.optim import optimizer as opt
from repro.training import steps


def train_lm(args) -> dict:
    cfg = get_config(args.arch, reduced=args.reduced)
    if args.vocab:
        cfg = cfg.replace(vocab_size=args.vocab)
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 10
                                                        or 1),
                           total_steps=args.steps)
    state = opt.init(ocfg, params)
    step_fn = jax.jit(steps.make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    ds = iter(TextLMDataset(cfg.vocab_size, args.seq, args.batch,
                            seed=args.seed))
    losses = []
    t0 = time.time()
    for i, batch in zip(range(args.steps), ds):
        params, state, m = step_fn(params, state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(m["loss"])
            losses.append(loss)
            print(f"step {i:5d} loss {loss:.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)", flush=True)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, {"params": params, "opt": state},
                  step=args.steps)
        print(f"saved checkpoint to {args.ckpt_dir}")
    return {"params": n_params, "first_loss": losses[0],
            "last_loss": losses[-1]}


def train_mllm(args) -> dict:
    from repro.models.mllm import build_paper_mllm
    mllm = build_paper_mllm(args.mllm, reduced=args.reduced,
                            text_len=args.seq)
    params = mllm.init(jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 10
                                                        or 1),
                           total_steps=args.steps)
    fmask = mllm.frozen_mask(params)
    state = opt.init(ocfg, params, fmask)
    step_fn, _ = steps.make_mllm_train_step(mllm, ocfg)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    ds = iter(MultimodalDataset(
        vocab_size=mllm.llm_cfg.vocab_size, text_len=args.seq,
        batch_size=args.batch,
        encoder_dims={n: e.cfg.d_model for n, e in mllm.encoders.items()},
        encoder_tokens={n: e.num_tokens for n, e in mllm.encoders.items()},
        modality_ids={n: e.modality_id for n, e in mllm.encoders.items()},
        seed=args.seed))
    losses = []
    t0 = time.time()
    for i, batch in zip(range(args.steps), ds):
        params, state, m = step_fn(params, state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(m["loss"])
            losses.append(loss)
            print(f"step {i:5d} loss {loss:.4f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)", flush=True)
    if args.ckpt_dir:
        frozen_paths = {"llm"} | {
            f"encoders/{n}/module" for n in mllm.encoders}
        ckpt.save(args.ckpt_dir, params, step=args.steps)
        print(f"saved checkpoint to {args.ckpt_dir} "
              f"(frozen paths: {sorted(frozen_paths)})")
    return {"params": n_params, "first_loss": losses[0],
            "last_loss": losses[-1]}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--mllm", default=None, choices=[None, "vlm", "alm",
                                                     "valm"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)
    assert (args.arch is None) != (args.mllm is None), \
        "pass exactly one of --arch / --mllm"
    res = train_mllm(args) if args.mllm else train_lm(args)
    print(f"done: {res['params']:,} params, "
          f"loss {res['first_loss']:.3f} -> {res['last_loss']:.3f}")
    return res


if __name__ == "__main__":
    main()
