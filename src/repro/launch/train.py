"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --steps 300 --seq 128 --batch 4 [--reduced] [--mllm valm] \
        [--ckpt-dir ckpts/run0] [--log-every 10]

Two modes:
  * LM mode (``--arch``): any registered architecture; synthetic LM
    stream (repro.data.synthetic.TextLMDataset).
  * MLLM mode (``--mllm vlm|alm|valm``): the Cornstarch path — frozen
    encoders + LLM, trainable projectors, multimodal batches; the
    frozen mask drives both stop_gradient and optimizer masking. The
    parallelization decision is a typed ``MLLMParallelPlan``
    (repro.parallel): load a cached one with ``--plan plan.json``, or
    let the driver search one (``--plan-devices`` / ``--cp-size`` /
    ``--microbatches``) and persist it with ``--plan-out``.

Runs on whatever devices exist (data-parallel over the host mesh when
more than one); this is the driver the smoke/e2e examples call into.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import get_config
from repro.data.synthetic import MultimodalDataset, TextLMDataset
from repro.models import api
from repro.optim import optimizer as opt
from repro.training import steps


def train_lm(args) -> dict:
    cfg = get_config(args.arch, reduced=args.reduced)
    if args.vocab:
        cfg = cfg.replace(vocab_size=args.vocab)
    params = api.init(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 10
                                                        or 1),
                           total_steps=args.steps)
    state = opt.init(ocfg, params)
    step_fn = jax.jit(steps.make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    ds = iter(TextLMDataset(cfg.vocab_size, args.seq, args.batch,
                            seed=args.seed))
    losses = []
    t0 = time.time()
    for i, batch in zip(range(args.steps), ds):
        params, state, m = step_fn(params, state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(m["loss"])
            losses.append(loss)
            print(f"step {i:5d} loss {loss:.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)", flush=True)
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, {"params": params, "opt": state},
                  step=args.steps)
        print(f"saved checkpoint to {args.ckpt_dir}")
    return {"params": n_params, "first_loss": losses[0],
            "last_loss": losses[-1], "losses": losses}


def resolve_plan(mllm, args):
    """The MLLMParallelPlan this run trains under: loaded from
    ``--plan`` (a launch script's cached search) or searched fresh via
    ``parallelize`` — the single typed entrypoint for the joint
    PP x CP decision. ``--plan-out`` persists it for the next launch."""
    from repro.parallel import (ClusterSpec, MLLMParallelPlan,
                                WorkloadShape, parallelize)
    if args.plan:
        plan = MLLMParallelPlan.load(args.plan)
    else:
        # paper block size at paper lengths; on reduced sequences keep
        # at least ~2 blocks per CP rank so the balancer has choices
        block = min(128, max(8, mllm.merged_length(args.seq)
                             // (2 * args.cp_size)))
        plan = parallelize(
            mllm, ClusterSpec(num_devices=args.plan_devices,
                              cp_size=args.cp_size),
            WorkloadShape(text_len=args.seq,
                          num_microbatches=args.microbatches,
                          microbatch_size=args.batch,
                          block_size=block))
    # instantiating the plan validates it against THIS mllm (stage
    # counts vs layer counts, encoder set) before any step runs; in
    # --spmd mode the contract also carries the compiled wave/ppermute
    # program, which the lint gate below then statically validates
    mode = "spmd" if getattr(args, "spmd", False) else "replay"
    executor = plan.apply(mllm, text_len=args.seq, mode=mode)
    if getattr(args, "lint", True):
        # the schedlint gate: a plan whose timeline would race,
        # overflow the activation caps, or deadlock a ring lowering
        # must die here, not N steps into a run (--no-lint to bypass)
        from repro.analysis import (format_findings, gate,
                                    lint_executor_contract, lint_plan)
        found = lint_plan(plan) + lint_executor_contract(executor)
        if gate(found):
            raise SystemExit(format_findings(
                found, header="plan failed the schedule lint "
                              "(--no-lint to bypass):"))
        if found:
            print(format_findings(found, header="plan lint notes:"))
    if args.plan_out:
        plan.save(args.plan_out)
        print(f"saved plan to {args.plan_out}")
    return plan, executor


def train_mllm(args) -> dict:
    from repro.models.mllm import build_paper_mllm
    mllm = build_paper_mllm(args.mllm, reduced=args.reduced,
                            text_len=args.seq)
    if args.train_llm:
        # the paper's ft1 fine-tune: frozen encoders, trainable LLM —
        # the scenario where zero-bubble W passes have work to defer
        mllm.freeze("llm", module=False)
    plan, executor = resolve_plan(mllm, args)
    print(plan.describe())
    print(f"executor graph: {len(executor['graph'].stages)} stages, "
          f"simulated bubble "
          f"{executor['schedule']['bubble_fraction']:.3f}")
    if getattr(args, "spmd", False):
        # prove the compiled shard_map program on THIS host's devices
        # before any training step: distributed loss/grads must match
        # the sequential replay (toy stages — the cheap parity oracle)
        from repro.parallel.spmd import spmd_parity_report
        D = int(executor["schedule"]["num_devices"])
        if len(jax.devices()) < D:
            raise SystemExit(
                f"--spmd needs {D} devices for this plan but the "
                f"process has {len(jax.devices())}; relaunch with "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={D}")
        rep = spmd_parity_report(executor)
        print(f"spmd executor: {rep['program']} "
              f"loss {rep['loss_spmd']:.6f} vs replay "
              f"{rep['loss_replay']:.6f}, max grad diff "
              f"{rep['max_grad_diff']:.2e}, peaks_match="
              f"{rep['peaks_match']}")
        if not (rep["peaks_match"] and rep["trace_match"]
                and rep["max_grad_diff"] < 1e-4):
            raise SystemExit(
                "spmd executor diverged from the sequential replay on "
                f"this plan: {rep}")
    params = mllm.init(jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    ocfg = opt.AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 10
                                                        or 1),
                           total_steps=args.steps)
    fmask = mllm.frozen_mask(params)
    state = opt.init(ocfg, params, fmask)
    step_fn, _ = steps.make_mllm_train_step(mllm, ocfg)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    ds = iter(MultimodalDataset(
        vocab_size=mllm.llm_cfg.vocab_size, text_len=args.seq,
        batch_size=args.batch,
        encoder_dims={n: e.cfg.d_model for n, e in mllm.encoders.items()},
        encoder_tokens={n: e.num_tokens for n, e in mllm.encoders.items()},
        modality_ids={n: e.modality_id for n, e in mllm.encoders.items()},
        seed=args.seed))
    losses = []
    t0 = time.time()
    for i, batch in zip(range(args.steps), ds):
        params, state, m = step_fn(params, state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(m["loss"])
            losses.append(loss)
            print(f"step {i:5d} loss {loss:.4f} "
                  f"({(time.time() - t0) / (i + 1):.2f}s/step)", flush=True)
    if args.ckpt_dir:
        frozen_paths = {f"encoders/{n}/module" for n in mllm.encoders}
        if not args.train_llm:
            frozen_paths.add("llm")
        ckpt.save(args.ckpt_dir, params, step=args.steps)
        print(f"saved checkpoint to {args.ckpt_dir} "
              f"(frozen paths: {sorted(frozen_paths)})")
    return {"params": n_params, "first_loss": losses[0],
            "last_loss": losses[-1], "losses": losses}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--mllm", default=None, choices=[None, "vlm", "alm",
                                                     "valm"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--vocab", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    # MLLM-mode parallelization plan (repro.parallel typed API)
    ap.add_argument("--plan", default=None,
                    help="MLLMParallelPlan JSON to train under "
                    "(default: search one via parallelize())")
    ap.add_argument("--plan-out", default=None,
                    help="write the resolved plan JSON here")
    ap.add_argument("--plan-devices", type=int, default=8,
                    help="pipeline device budget for the plan search")
    ap.add_argument("--cp-size", type=int, default=1,
                    help="context-parallel ranks for the plan search")
    ap.add_argument("--microbatches", type=int, default=8)
    ap.add_argument("--no-lint", dest="lint", action="store_false",
                    help="skip the schedlint gate on the resolved plan")
    ap.add_argument("--spmd", action="store_true",
                    help="MLLM mode: compile the plan's timeline to "
                    "the shard_map executor, lint the emitted ppermute "
                    "program, and verify distributed loss/grads "
                    "against the sequential replay before training")
    ap.add_argument("--train-llm", action="store_true",
                    help="MLLM mode: unfreeze the LLM (ft1 fine-tune)")
    args = ap.parse_args(argv)
    assert (args.arch is None) != (args.mllm is None), \
        "pass exactly one of --arch / --mllm"
    res = train_mllm(args) if args.mllm else train_lm(args)
    print(f"done: {res['params']:,} params, "
          f"loss {res['first_loss']:.3f} -> {res['last_loss']:.3f}")
    return res


if __name__ == "__main__":
    main()
