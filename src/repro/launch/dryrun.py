import os
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count on first
# init. Only the dry-run sees 512 placeholder devices (DESIGN.md §5).
# APPEND to any user-set XLA_FLAGS rather than clobbering them, and
# respect an explicit device-count choice (e.g. a multi-device test
# harness driving the dry-run under its own mesh size).

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes and extract memory / cost / collective stats.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k [--multipod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --plan-mllm all
        # ^ plan mode: emit MLLMParallelPlan JSONs (repro.parallel)
        #   instead of lowering — the artifacts train.py --plan loads

Per combination this produces <out>/<arch>__<shape>__<mesh>.json with:
  memory_analysis   (bytes per device: args/output/temp/code)
  cost_analysis     (HLO FLOPs, bytes accessed — per-device program)
  collectives       (bytes by kind, parsed from the partitioned HLO)
  roofline          (compute/memory/collective terms in seconds,
                     dominant term, MODEL_FLOPS ratio — §Roofline)
"""
import argparse
import json
import re
import sys
import time
import traceback

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig,
                                get_config, list_archs, pair_skip_reason)
from repro.launch import sharding as shd
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch import specs as S
from repro.models import api
from repro.optim import optimizer as opt
from repro.training import steps

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8,
                "s16": 2, "u16": 2}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def parse_collective_bytes(hlo_text: str) -> dict:
    """Sum result-buffer sizes of every collective op in the partitioned
    HLO (per-device program)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    # e.g. "%ag = bf16[16,256,4608]{2,1,0} all-gather("  (also tuples)
    pat = re.compile(
        r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]))[^=]*?\s("
        + "|".join(_COLLECTIVES) + r")\(")
    shape_pat = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for m in pat.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        nbytes = 0
        for dt, dims in shape_pat.findall(type_str):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        out[kind] += nbytes
        counts[kind] += 1
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out.update(out_counts)
    return out


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS: 6·N_active·D train / 2·N_active·D prefill /
    2·N_active·B (+ attention KV sweep) decode — global, all chips."""
    N = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * N * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * N * shape.global_batch * shape.seq_len
    # decode: one token per row + attention over the cache
    attn = 4.0 * shape.global_batch * shape.seq_len * cfg.num_layers * \
        cfg.num_heads * cfg.head_dim
    return 2.0 * N * shape.global_batch + attn


def rules_for(shape: ShapeConfig, multi_pod: bool,
              family: str = "dense") -> shd.Rules:
    if shape.kind == "train":
        # §Perf: FSDP-style sharding beats Megatron-TP by ~3.3x on the
        # collective term for non-MoE *training* at this mesh (batch 256
        # divides all 256/512 chips). Prefill (batch 32 < chips) keeps
        # Megatron-TP + sequence parallelism — FSDP regressed it 15x
        # (see EXPERIMENTS.md §Perf, refuted-hypothesis log). MoE keeps
        # TP/EP rules for its expert dims.
        fsdp = family != "moe"
        return shd.Rules(multi_pod=multi_pod, seq_parallel=not fsdp,
                         fsdp=fsdp)
    if shape.kind == "prefill":
        return shd.Rules(multi_pod=multi_pod, seq_parallel=True)
    if shape.name == "long_500k":
        return shd.Rules(multi_pod=multi_pod, seq_parallel=False,
                         shard_cache_seq=True)
    return shd.Rules(multi_pod=multi_pod, seq_parallel=False)


def config_for(arch: str, shape: ShapeConfig) -> ModelConfig:
    cfg = get_config(arch)
    if arch == "gemma2-9b" and shape.name == "long_500k":
        from repro.configs.gemma2_9b import long_context_variant
        cfg = long_context_variant()
    if shape.seq_len >= 32_768 and shape.kind in ("train", "prefill"):
        # §Perf-D: q-chunked attention — peak memory O(chunk·T), not
        # O(T^2); numerics identical (tests)
        cfg = cfg.replace(attn_q_chunk=1024)
    return cfg


def lower_pair(arch: str, shape_name: str, multi_pod: bool,
               extra_tag: str = "", cfg_override=None,
               rules_override=None) -> dict:
    shape = SHAPES[shape_name]
    cfg = cfg_override or config_for(arch, shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_override or rules_for(shape, multi_pod, cfg.family)
    shd.set_rules(rules)
    shd.set_mesh(mesh)
    t0 = time.time()
    try:
        with mesh:
            result = _lower_inner(cfg, shape, mesh, rules)
    finally:
        shd.set_rules(None)
        shd.set_mesh(None)
    result.update({
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "tag": extra_tag, "wall_s": round(time.time() - t0, 1),
    })
    return result


def _named(mesh, tree_specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


def _with_sharding(specs_tree, shardings_tree):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        specs_tree, shardings_tree)


def _lower_inner(cfg: ModelConfig, shape: ShapeConfig, mesh, rules):
    n_dev = mesh.devices.size
    if shape.kind in ("train", "prefill"):
        p_spec = S.param_specs(cfg)
        if rules.fsdp:
            p_sh = _named(mesh, shd.fsdp_param_pspecs(p_spec, mesh, rules))
            b_spec = S.train_input_specs(cfg, shape)
            b_sh = _named(mesh, shd.fsdp_batch_pspecs(rules, b_spec, mesh))
        else:
            p_sh = _named(mesh, shd.param_pspecs(p_spec, mesh))
            b_spec = S.train_input_specs(cfg, shape)
            b_sh = _named(mesh, shd.batch_pspecs(rules, b_spec, mesh))
        if shape.kind == "train":
            o_spec = S.opt_state_specs(cfg, p_spec)
            if rules.fsdp:
                o_sh = _named(mesh,
                              shd.fsdp_param_pspecs(p_spec, mesh, rules))
            else:
                o_sh = _named(mesh,
                              shd.opt_state_pspecs(rules, p_spec, mesh))
            o_sh = {"step": NamedSharding(mesh, P()), "m": o_sh, "v": o_sh}
            ocfg = opt.AdamWConfig()
            fn = steps.make_train_step(cfg, ocfg)
            jfn = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                          out_shardings=(p_sh, o_sh, None),
                          donate_argnums=(0, 1))
            args = (_with_sharding(p_spec, p_sh),
                    _with_sharding(o_spec, o_sh),
                    _with_sharding(b_spec, b_sh))
        else:
            fn = steps.make_prefill(cfg)
            jfn = jax.jit(fn, in_shardings=(p_sh, b_sh))
            args = (_with_sharding(p_spec, p_sh),
                    _with_sharding(b_spec, b_sh))
    else:
        p_spec = S.param_specs(cfg)
        p_sh = _named(mesh, shd.param_pspecs(p_spec, mesh))
        c_spec = S.cache_specs(cfg, shape)
        c_sh = _named(mesh, shd.cache_pspecs(rules, c_spec, mesh))
        b_spec = S.decode_input_specs(cfg, shape)
        b_sh = _named(mesh, shd.batch_pspecs(rules, b_spec, mesh))
        fn = steps.make_serve_step(cfg)
        jfn = jax.jit(fn, in_shardings=(p_sh, c_sh, b_sh),
                      out_shardings=(None, c_sh), donate_argnums=(1,))
        args = (_with_sharding(p_spec, p_sh),
                _with_sharding(c_spec, c_sh),
                _with_sharding(b_spec, b_sh))

    lowered = jfn.lower(*args)
    compiled = lowered.compile()

    mem = compiled.memory_analysis()
    mem_stats = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        mem_stats[k] = int(getattr(mem, k, 0) or 0)
    # live bytes per device ~ args + temp - aliased (donated) buffers
    per_dev = mem_stats["argument_size_in_bytes"] + \
        mem_stats["temp_size_in_bytes"] - mem_stats["alias_size_in_bytes"]

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    ca_flops = float(ca.get("flops", 0.0))
    ca_bytes = float(ca.get("bytes accessed", 0.0))

    # trip-count-aware static profile (cost_analysis counts while bodies
    # once -> ~num_layers× undercount for scan-over-layers models)
    from repro.launch import hlo_analysis as H
    hlo = compiled.as_text()
    prof = H.analyze(hlo)
    flops_dev = prof["flops"]
    bytes_dev = prof["hbm_bytes"]
    coll = {**prof["collective_bytes"],
            **{f"n_{k}": v for k, v in prof["collective_counts"].items()}}

    # roofline terms (seconds; per-chip program against v5e peaks)
    compute_t = flops_dev / PEAK_FLOPS_BF16
    memory_t = bytes_dev / HBM_BW
    coll_t = coll["total"] / ICI_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": coll_t}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    useful = mf / max(flops_dev * n_dev, 1.0)

    return {
        "devices": int(n_dev),
        "memory": mem_stats,
        "per_device_bytes": int(per_dev),
        "fits_16GB": bool(per_dev < 16e9),
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "xla_cost_analysis": {"flops": ca_flops, "bytes": ca_bytes,
                              "note": "while bodies counted once"},
        "collectives": coll,
        "roofline": {**terms, "dominant": dominant,
                     "model_flops_global": mf,
                     "useful_flops_ratio": useful},
    }


def emit_plans(args) -> int:
    """Plan mode: search the joint PP x CP decision for the paper
    MLLMs through the typed API and persist each winner as
    ``<out>/plan__<kind>__d<devices>__cp<ranks>.json`` — the cached-
    search artifacts ``repro.launch.train --plan`` consumes."""
    from repro.models.mllm import build_paper_mllm
    from repro.parallel import ClusterSpec, WorkloadShape, parallelize
    kinds = [args.plan_mllm] if args.plan_mllm != "all" \
        else ["vlm", "alm", "valm"]
    for kind in kinds:
        mllm = build_paper_mllm(kind)
        plan = parallelize(
            mllm, ClusterSpec(num_devices=args.plan_devices,
                              cp_size=args.cp_size),
            WorkloadShape(text_len=args.plan_text_len,
                          num_microbatches=args.plan_microbatches))
        path = os.path.join(
            args.out, f"plan__{kind}__d{args.plan_devices}"
            f"__cp{args.cp_size}.json")
        plan.save(path)
        print(f"[plan] {path}")
        print(plan.describe())
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    # plan mode: emit MLLMParallelPlan JSONs instead of lowering
    ap.add_argument("--plan-mllm", default=None,
                    choices=[None, "vlm", "alm", "valm", "all"])
    ap.add_argument("--plan-devices", type=int, default=8)
    ap.add_argument("--cp-size", type=int, default=8)
    ap.add_argument("--plan-text-len", type=int, default=1024)
    ap.add_argument("--plan-microbatches", type=int, default=8)
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    if args.plan_mllm:
        return emit_plans(args)
    if args.all:
        pairs = [(a, s) for a in list_archs() for s in SHAPES]
    else:
        assert args.arch and args.shape
        pairs = [(args.arch, args.shape)]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multipod]

    failures = 0
    for arch, shape in pairs:
        reason = pair_skip_reason(arch, shape)
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                print(f"[skip-existing] {tag}")
                continue
            if reason:
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape,
                               "mesh": "2x16x16" if mp else "16x16",
                               "skipped": reason}, f, indent=1)
                print(f"[skipped] {tag}: {reason}")
                continue
            try:
                res = lower_pair(arch, shape, mp)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                r = res["roofline"]
                print(f"[ok] {tag}: dom={r['dominant']} "
                      f"compute={r['compute_s']:.4f}s "
                      f"mem={r['memory_s']:.4f}s "
                      f"coll={r['collective_s']:.4f}s "
                      f"bytes/dev={res['per_device_bytes']/1e9:.2f}GB "
                      f"wall={res['wall_s']}s", flush=True)
            except Exception as e:
                failures += 1
                with open(path + ".err", "w") as f:
                    f.write(traceback.format_exc())
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
