"""``python -m repro.analysis``: the static-analysis gate.

Runs every named entrypoint (see :mod:`repro.analysis.entrypoints`)
and exits nonzero when the findings gate trips — ERROR findings
always, WARNING findings too under ``--strict``. ``--rule`` /
``--entrypoint`` narrow the run; ``--list`` prints the registries.
"""
from __future__ import annotations

import argparse
import sys
import traceback
from typing import List

from .entrypoints import ENTRYPOINTS
from .findings import (Finding, RULES, Severity, filter_findings, finding,
                       format_findings, gate, register_rule)

register_rule("entrypoint-crash", "cli",
              "an analysis entrypoint raised instead of returning "
              "findings")


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static analysis over the repro training stack")
    ap.add_argument("--strict", action="store_true",
                    help="fail on WARNING findings too")
    ap.add_argument("--rule", action="append", default=None,
                    metavar="RULE",
                    help="only report these rule ids (repeatable)")
    ap.add_argument("--entrypoint", action="append", default=None,
                    metavar="NAME", choices=sorted(ENTRYPOINTS),
                    help="only run these entrypoints (repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="list entrypoints and rules, then exit")
    args = ap.parse_args(argv)

    if args.list:
        print("entrypoints:")
        for name, fn in ENTRYPOINTS.items():
            doc = (fn.__doc__ or "").strip().split("\n")[0]
            print(f"  {name:<18} {doc}")
        print("rules:")
        for name, spec in sorted(RULES.items()):
            print(f"  {name:<28} [{spec.family}/"
                  f"{spec.default_severity.name.lower()}] "
                  f"{spec.description}")
        return 0

    names = args.entrypoint or list(ENTRYPOINTS)
    findings: List[Finding] = []
    for name in names:
        try:
            findings.extend(ENTRYPOINTS[name]())
        except Exception:
            findings.append(finding(
                "entrypoint-crash", name,
                traceback.format_exc(limit=8).strip()))
    if args.rule:
        try:
            findings = filter_findings(findings, args.rule)
        except KeyError as e:
            ap.error(str(e))

    gated = [f for f in findings
             if f.severity is Severity.ERROR
             or (args.strict and f.severity is Severity.WARNING)]
    info = [f for f in findings if f not in gated]
    if info:
        print(format_findings(info, header="notes (not gated):"))
    if gated:
        print(format_findings(
            gated, header=f"{len(gated)} finding(s) failed the gate:"))
        return 1
    print(f"repro.analysis: clean "
          f"({len(names)} entrypoint(s), {len(findings)} note(s), "
          f"strict={'on' if args.strict else 'off'})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
