"""repro.analysis: findings-based static analysis for the repro stack.

Three pass families over one :class:`Finding` spine:

* :mod:`repro.analysis.jaxprlint`   — traced-program invariants
  (no-quadratic-intermediate, peak-live-bytes, dtype-drift)
* :mod:`repro.analysis.schedlint`   — F/B/W timeline + plan validation
  (ordering, overlap, frozen stages, activation caps, send/recv
  deadlock, plan consistency)
* :mod:`repro.analysis.kernellint`  — Pallas kernel source checks
  (BlockSpec arity/rank, block divisibility, block-map coverage,
  scalar-prefetch staticness)

CLI: ``python -m repro.analysis [--strict] [--rule R] [--entrypoint E]``
runs every registered entrypoint and exits nonzero on gated findings.
"""
from .findings import (Finding, RuleSpec, RULES, Severity, filter_findings,
                       finding, format_findings, gate, register_rule)
from .jaxprlint import (check_dtype_drift, check_no_quadratic_intermediate,
                        check_peak_live_bytes, collect_avals, iter_jaxprs,
                        peak_live_bytes, quadratic_f32)
from .kernellint import (check_block_divisibility, check_block_map_coverage,
                         check_scalar_prefetch_static, lint_file,
                         lint_kernels, lint_source)
from .schedlint import (lint_executor_contract, lint_plan,
                        lint_spmd_program, lint_timeline)

__all__ = [
    "Finding", "RuleSpec", "RULES", "Severity", "filter_findings",
    "finding", "format_findings", "gate", "register_rule",
    "check_dtype_drift", "check_no_quadratic_intermediate",
    "check_peak_live_bytes", "collect_avals", "iter_jaxprs",
    "peak_live_bytes", "quadratic_f32",
    "check_block_divisibility", "check_block_map_coverage",
    "check_scalar_prefetch_static", "lint_file", "lint_kernels",
    "lint_source",
    "lint_executor_contract", "lint_plan", "lint_spmd_program",
    "lint_timeline",
]
