"""jaxpr lint: traced-program invariants for the kernel hot paths.

The shared traversal here is the promotion of the ``_walk_avals``
helpers that used to be copy-pasted across ``tests/test_kernels.py``
and ``tests/test_context_parallel.py``: it recurses into every
sub-jaxpr a primitive carries (pjit, scan, while, shard_map,
custom_vjp, pallas_call, ...), whether stored as a raw ``Jaxpr``, a
``ClosedJaxpr``, or a list/tuple of either.

Rules:

* ``no-quadratic-intermediate`` — the fused BAM backward must never
  materialize an O(Tq*Tk) f32 buffer; only [block_q, block_k] tiles may
  exist inside the kernels. The XLA attention path is the discriminating
  control: it *does* trace a [T, T] f32 intermediate, so the rule is
  proven non-vacuous wherever it is enforced.
* ``peak-live-bytes`` — a linear-scan liveness walk over the top-level
  eqns bounds the peak residual bytes a traced step holds at once;
  gated against a byte budget when one is given, reported as INFO
  otherwise.
* ``dtype-drift`` — large tensors silently upcast to f32 in a bf16/f16
  path (``convert_element_type`` eqns above a size threshold). Small
  upcasts (softmax stats, per-tile accumulators) are deliberate and
  stay below the threshold.
"""
from __future__ import annotations

import math
from typing import Any, Iterator, List, Optional, Tuple

from .findings import Finding, Severity, finding, register_rule

register_rule(
    "no-quadratic-intermediate", "jaxprlint",
    "kernel-path backward jaxprs must carry no O(Tq*Tk) f32 buffer")
register_rule(
    "peak-live-bytes", "jaxprlint",
    "liveness-scan peak residual bytes of a traced step must stay "
    "inside the byte budget")
register_rule(
    "dtype-drift", "jaxprlint",
    "large low-precision tensors must not silently upcast to f32",
    default_severity=Severity.WARNING)

AvalRecord = Tuple[str, Tuple[int, ...], Any]


def _as_jaxpr(obj: Any):
    """Raw ``Jaxpr`` from a Jaxpr / ClosedJaxpr / anything else."""
    inner = getattr(obj, "jaxpr", None)
    if hasattr(inner, "eqns"):
        return inner                                 # ClosedJaxpr
    if hasattr(obj, "eqns"):
        return obj                                   # raw Jaxpr
    return None


def iter_jaxprs(jaxpr: Any) -> Iterator[Any]:
    """Yield ``jaxpr`` and every sub-jaxpr reachable through eqn params
    (pjit/scan/while/shard_map/custom_vjp/pallas_call, nested to any
    depth). Accepts a Jaxpr or ClosedJaxpr."""
    top = _as_jaxpr(jaxpr)
    if top is None:
        return
    yield top
    for eqn in top.eqns:
        for val in eqn.params.values():
            vals = val if isinstance(val, (list, tuple)) else (val,)
            for item in vals:
                sub = _as_jaxpr(item)
                if sub is not None:
                    yield from iter_jaxprs(sub)


def collect_avals(jaxpr: Any) -> List[AvalRecord]:
    """Every (primitive name, shape, dtype) produced anywhere in the
    jaxpr, sub-jaxprs included — the promoted ``_walk_avals``."""
    seen: List[AvalRecord] = []
    for sub in iter_jaxprs(jaxpr):
        for eqn in sub.eqns:
            for var in eqn.outvars:
                aval = getattr(var, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    seen.append((eqn.primitive.name, tuple(aval.shape),
                                 getattr(aval, "dtype", None)))
    return seen


def quadratic_f32(jaxpr: Any, seq_len: int) -> List[AvalRecord]:
    """All f32 avals with >= 2 dims of size >= ``seq_len`` — the
    O(Tq*Tk) intermediates the fused kernels exist to avoid (the
    promoted ``_quadratic_f32`` test helper)."""
    import jax.numpy as jnp
    return [s for s in collect_avals(jaxpr)
            if s[2] == jnp.float32
            and sum(1 for d in s[1] if d >= seq_len) >= 2]


def check_no_quadratic_intermediate(jaxpr: Any, seq_len: int,
                                    location: str) -> List[Finding]:
    return [finding("no-quadratic-intermediate", location,
                    f"{prim} produces f32{list(shape)} — an O(Tq*Tk) "
                    f"intermediate at seq_len={seq_len}")
            for prim, shape, _dt in quadratic_f32(jaxpr, seq_len)]


# ---------------------------------------------------------------------------
# peak-live-bytes: linear-scan liveness over the top-level eqns
# ---------------------------------------------------------------------------

def _nbytes(aval: Any) -> int:
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    try:
        return math.prod(int(d) for d in shape) * dtype.itemsize
    except TypeError:                     # symbolic dims
        return 0


def peak_live_bytes(jaxpr: Any) -> int:
    """Peak bytes simultaneously live across the TOP-LEVEL eqns of
    ``jaxpr`` (inputs + consts counted; sub-jaxpr internals are the
    callee's business — scan/pjit bodies are already bounded by their
    own invars/outvars, which this walk does see).

    A var is live from the eqn that produces it (or from entry, for
    invars/constvars) until its last top-level use; jaxpr outvars stay
    live to the end. This is the same linear scan a register allocator
    runs — an upper bound on residual memory that is exact when XLA
    performs no rematerialization or buffer aliasing.
    """
    top = _as_jaxpr(jaxpr)
    if top is None:
        raise TypeError(f"not a jaxpr: {jaxpr!r}")
    n = len(top.eqns)
    last_use: dict = {}
    for i, eqn in enumerate(top.eqns):
        for var in eqn.invars:
            if hasattr(var, "aval") and not hasattr(var, "val"):
                last_use[var] = i
    for var in top.outvars:
        if hasattr(var, "aval") and not hasattr(var, "val"):
            last_use[var] = n
    live = 0
    frees: List[List[Any]] = [[] for _ in range(n + 1)]
    for var, i in last_use.items():
        frees[i].append(var)
    alive = set()
    for var in list(top.invars) + list(top.constvars):
        if var in last_use and var not in alive:
            alive.add(var)
            live += _nbytes(var.aval)
    peak = live
    for i, eqn in enumerate(top.eqns):
        transient = 0                    # produced but never read again
        for var in eqn.outvars:
            if var in last_use and var not in alive:
                alive.add(var)
                live += _nbytes(var.aval)
            elif var not in last_use and hasattr(var, "aval"):
                transient += _nbytes(var.aval)
        peak = max(peak, live + transient)
        for var in frees[i]:
            if var in alive:
                alive.discard(var)
                live -= _nbytes(var.aval)
    return peak


def check_peak_live_bytes(jaxpr: Any, location: str, *,
                          budget_bytes: Optional[int] = None
                          ) -> List[Finding]:
    peak = peak_live_bytes(jaxpr)
    if budget_bytes is None:
        return [finding("peak-live-bytes", location,
                        f"peak live bytes (liveness scan): {peak}",
                        severity=Severity.INFO)]
    if peak > budget_bytes:
        return [finding("peak-live-bytes", location,
                        f"peak live bytes {peak} exceed the budget "
                        f"{budget_bytes}")]
    return []


# ---------------------------------------------------------------------------
# dtype-drift: unexpected f32 upcasts of large tensors
# ---------------------------------------------------------------------------

_LOW_PRECISION = ("bfloat16", "float16")


def check_dtype_drift(jaxpr: Any, location: str, *,
                      min_elements: int = 1 << 16) -> List[Finding]:
    """Flag ``convert_element_type`` eqns that upcast a bf16/f16 tensor
    of >= ``min_elements`` elements to f32 — the silent memory doubling
    a mixed-precision path must opt into explicitly. Tile-sized
    accumulator upcasts inside kernels stay below the threshold."""
    import jax.numpy as jnp
    out: List[Finding] = []
    for sub in iter_jaxprs(jaxpr):
        for eqn in sub.eqns:
            if eqn.primitive.name != "convert_element_type":
                continue
            if not eqn.invars or not eqn.outvars:
                continue
            src = getattr(eqn.invars[0], "aval", None)
            dst = getattr(eqn.outvars[0], "aval", None)
            if src is None or dst is None:
                continue
            if str(getattr(src, "dtype", "")) not in _LOW_PRECISION:
                continue
            if getattr(dst, "dtype", None) != jnp.float32:
                continue
            elems = math.prod(int(d) for d in dst.shape) \
                if dst.shape else 1
            if elems >= min_elements:
                out.append(finding(
                    "dtype-drift", location,
                    f"{src.dtype}{list(src.shape)} upcast to "
                    f"f32 ({elems} elements >= {min_elements})"))
    return out
