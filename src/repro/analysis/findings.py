"""Findings and the rule registry — the spine of ``repro.analysis``.

Every static-analysis pass in this package reports through one shape:

    Finding(rule, severity, location, message)

``rule`` is a stable kebab-case identifier registered in :data:`RULES`
(so ``--rule`` filtering, docs, and tests all name checks the same
way), ``location`` is a human-meaningful anchor (an entrypoint name, a
timeline item id, a ``file:line``), and ``severity`` decides the CLI
exit code (errors always gate; warnings gate under ``--strict``).

Passes are plain functions returning ``List[Finding]``; the registry
only records *rules* (id -> family/severity/description), not pass
callables — the three pass families (jaxprlint / schedlint /
kernellint) take structurally different inputs, so dispatch lives in
``entrypoints`` while identity lives here.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterable, List, Optional, Sequence


class Severity(enum.Enum):
    ERROR = "error"
    WARNING = "warning"
    INFO = "info"

    def __str__(self) -> str:
        return self.value


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation (or informational note) at one location."""
    rule: str
    severity: Severity
    location: str
    message: str

    def __str__(self) -> str:
        return f"[{self.severity}] {self.rule} @ {self.location}: " \
               f"{self.message}"


@dataclasses.dataclass(frozen=True)
class RuleSpec:
    """Registry entry: what a rule id means and how severe a violation
    is by default."""
    name: str
    family: str                  # jaxprlint | schedlint | kernellint
    description: str
    default_severity: Severity = Severity.ERROR


#: the one rule registry (id -> spec); populated by the pass modules at
#: import time via :func:`register_rule`
RULES: Dict[str, RuleSpec] = {}


def register_rule(name: str, family: str, description: str,
                  default_severity: Severity = Severity.ERROR) -> RuleSpec:
    spec = RuleSpec(name, family, description, default_severity)
    if name in RULES and RULES[name] != spec:
        raise ValueError(f"rule {name!r} registered twice with "
                         f"different specs")
    RULES[name] = spec
    return spec


def finding(rule: str, location: str, message: str,
            severity: Optional[Severity] = None) -> Finding:
    """Build a Finding for a registered rule (severity defaults to the
    rule's registered default)."""
    spec = RULES.get(rule)
    if spec is None:
        raise KeyError(f"unregistered rule {rule!r}; known: "
                       f"{sorted(RULES)}")
    return Finding(rule, severity or spec.default_severity, location,
                   message)


def filter_findings(findings: Iterable[Finding],
                    rules: Optional[Sequence[str]] = None) -> List[Finding]:
    """Keep only findings for the given rule ids (None = all)."""
    fs = list(findings)
    if rules is None:
        return fs
    wanted = set(rules)
    unknown = wanted - set(RULES)
    if unknown:
        raise KeyError(f"unknown rule(s) {sorted(unknown)}; known: "
                       f"{sorted(RULES)}")
    return [f for f in fs if f.rule in wanted]


def gate(findings: Iterable[Finding], strict: bool = False) -> bool:
    """True when the findings should fail a CI gate: any ERROR, or any
    WARNING under ``--strict`` (INFO never gates)."""
    bad = {Severity.ERROR, Severity.WARNING} if strict \
        else {Severity.ERROR}
    return any(f.severity in bad for f in findings)


def format_findings(findings: Sequence[Finding],
                    header: Optional[str] = None) -> str:
    lines = [header] if header else []
    lines += [str(f) for f in findings]
    return "\n".join(lines)
