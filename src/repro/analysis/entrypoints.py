"""Named analysis entrypoints: the concrete programs the CLI lints.

Each entrypoint is a zero-argument callable returning ``List[Finding]``
for one named target the repo's correctness story depends on:

* ``kernel-bwd``        traced fused BAM backward (kernel path) —
                        jaxprlint: no-quadratic-intermediate,
                        dtype-drift, peak-live-bytes
* ``cp-allgather-bwd`` / ``cp-ring-bwd``
                        traced CP-body backwards on the kernel path
* ``train-step``        a tiny transformer train step routed through
                        the fused attention path
* ``xla-control``       the discriminating control: the XLA attention
                        path (single-device AND both CP bodies) MUST
                        trip no-quadratic-intermediate — if it stops
                        tripping, the rule has gone vacuous and THAT
                        is the finding
* ``schedulers``        all four schedulers x frozen/trainable
                        fixtures through every schedlint timeline rule
* ``auto-parallelize``  the winners ``auto_parallelize`` actually
                        emits on MLLM-shaped profile fixtures
* ``golden-plan``       the pinned 8-rank paper plan JSON: plan-level
                        consistency + its re-simulated timeline
* ``kernels``           kernellint over ``src/repro/kernels``

Controls invert the gate: an *expected* finding is success, silence is
the error. That keeps every negative rule in this package falsifiable
from the CLI itself, not just from the test suite.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, List

from .findings import Finding, Severity, finding, register_rule
from . import jaxprlint, kernellint, schedlint

register_rule(
    "control-not-discriminating", "jaxprlint",
    "a deliberately-bad control stopped tripping its rule — the rule "
    "has gone vacuous")

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))
GOLDEN_PLAN = os.path.join(REPO_ROOT, "tests", "data",
                           "paper_mllm_8rank_plan.json")

#: traced sequence length for the jaxpr entrypoints (big enough that a
#: quadratic buffer is unmistakable, small enough to trace in seconds)
_T = 64
#: generous byte budget for the tiny traced programs — they hold a few
#: MB at most; a blown budget means something quadratic leaked in
_BUDGET_BYTES = 64 << 20


def _attention_case():
    import jax.numpy as jnp
    from repro.core import bam
    bits_np, pos_np = bam.build_sample_bits(
        [("text", 0, 16), ("mod", 1, 16), ("text", 0, 32)], _T)
    bits = jnp.asarray(bits_np)[None]
    pos = jnp.asarray(pos_np)[None]
    q = jnp.zeros((1, _T, 2, 8))
    return q, bits, pos


def _attn_grad_jaxpr(impl: str):
    import jax
    import jax.numpy as jnp
    from repro.kernels.ops import bam_attention
    q, bits, pos = _attention_case()

    def loss(q, k, v):
        return jnp.sum(bam_attention(q, k, v, bits, bits, pos, pos,
                                     impl=impl, block_q=16,
                                     block_k=16) ** 2)
    return jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(q, q, q)


def _cp_grad_jaxpr(method: str, impl: str):
    import jax
    import jax.numpy as jnp
    from repro.core import context_parallel as cp
    q, bits, pos = _attention_case()
    mesh = jax.make_mesh((1,), ("cp",))

    def loss(q, k, v):
        return jnp.sum(cp.cp_attention(
            mesh, "cp", q, k, v, bits, bits, pos, pos, method=method,
            impl=impl, block_q=16, block_k=16) ** 2)
    return jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(q, q, q)


def _jaxpr_rules(jaxpr, location: str) -> List[Finding]:
    out = jaxprlint.check_no_quadratic_intermediate(jaxpr, _T, location)
    out += jaxprlint.check_dtype_drift(jaxpr, location)
    out += jaxprlint.check_peak_live_bytes(
        jaxpr, location, budget_bytes=_BUDGET_BYTES)
    return out


def kernel_bwd() -> List[Finding]:
    """Fused BAM attention backward (kernel path) through jaxprlint."""
    return _jaxpr_rules(_attn_grad_jaxpr("bam_interpret"), "kernel-bwd")


def cp_allgather_bwd() -> List[Finding]:
    """All-gather CP-body backward (kernel path) through jaxprlint."""
    return _jaxpr_rules(_cp_grad_jaxpr("allgather", "bam_interpret"),
                        "cp-allgather-bwd")


def cp_ring_bwd() -> List[Finding]:
    """Ring CP-body backward (kernel path) through jaxprlint."""
    return _jaxpr_rules(_cp_grad_jaxpr("ring", "bam_interpret"),
                        "cp-ring-bwd")


def train_step() -> List[Finding]:
    """Trace one full train-step gradient of a tiny transformer whose
    attention routes through the fused kernel path, and run every
    jaxprlint rule over it."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ModelConfig
    from repro.core import bam
    from repro.models import transformer as tf
    # every non-sequence dim stays < T, and T exceeds the kernels'
    # auto_block cap (128), so the ONLY tensors with two >= T dims are
    # genuine O(T^2) attention materializations — per-tile [block_q,
    # block_k] buffers stay below the bar
    T = 256
    cfg = ModelConfig(name="tiny-analysis", family="dense",
                      num_layers=2, d_model=32, num_heads=4,
                      num_kv_heads=2, d_ff=48, vocab_size=48,
                      dtype="float32", remat=False,
                      seq_shard_activations=False,
                      attn_impl="bam_interpret")
    bits_np, pos_np = bam.build_sample_bits(
        [("text", 0, 64), ("mod", 1, 64), ("text", 0, 128)], T)
    batch = {"tokens": jnp.zeros((1, T), jnp.int32),
             "labels": jnp.zeros((1, T), jnp.int32),
             "positions": jnp.asarray(pos_np)[None],
             "bits": jnp.asarray(bits_np)[None]}
    params = tf.init(jax.random.PRNGKey(0), cfg)

    def loss(p):
        from repro.training.steps import cross_entropy
        logits, _aux = tf.forward(p, cfg, batch)
        return cross_entropy(logits, batch["labels"])

    jaxpr = jax.make_jaxpr(jax.grad(loss))(params)
    out = jaxprlint.check_no_quadratic_intermediate(
        jaxpr, T, "train-step")
    out += jaxprlint.check_dtype_drift(jaxpr, "train-step")
    out += jaxprlint.check_peak_live_bytes(
        jaxpr, "train-step", budget_bytes=_BUDGET_BYTES)
    return out


def xla_control() -> List[Finding]:
    """The XLA attention path (single-device and both CP bodies) must
    trip no-quadratic-intermediate; if any of them traces clean the
    rule is vacuous and the CONTROL reports the error."""
    out: List[Finding] = []
    controls = [("xla-control/attn", _attn_grad_jaxpr("xla")),
                ("xla-control/cp-allgather",
                 _cp_grad_jaxpr("allgather", "xla")),
                ("xla-control/cp-ring", _cp_grad_jaxpr("ring", "xla"))]
    for loc, jaxpr in controls:
        hits = jaxprlint.quadratic_f32(jaxpr, _T)
        if not hits:
            out.append(finding(
                "control-not-discriminating", loc,
                "the XLA path traced NO O(Tq*Tk) f32 intermediate — "
                "no-quadratic-intermediate can no longer distinguish "
                "kernel from fallback"))
        else:
            out.append(finding(
                "control-not-discriminating", loc,
                f"control OK: XLA path trips with {len(hits)} "
                f"quadratic intermediates (e.g. "
                f"{hits[0][0]} f32{list(hits[0][1])})",
                severity=Severity.INFO))
    return out


# ---------------------------------------------------------------------------
# Schedule entrypoints
# ---------------------------------------------------------------------------

def _fixture_graphs():
    """MLLM-shaped schedule fixtures: (name, coarse chain) pairs
    covering trainable, frozen-encoder, and deeper frozen-heavy
    chains."""
    from repro.core import schedule as sch
    return [
        ("trainable-2", sch.chain_graph([
            sch.Stage("s0", 1.0, 2.0, bwd_w=1.0),
            sch.Stage("s1", 1.0, 2.0, bwd_w=1.0)])),
        ("frozen-head-2", sch.chain_graph([
            sch.Stage("enc", 1.0, 0.0),
            sch.Stage("llm", 1.0, 2.0, bwd_w=1.0)])),
        ("frozen-mid-4", sch.chain_graph([
            sch.Stage("enc", 0.8, 0.0),
            sch.Stage("proj", 0.2, 0.4, bwd_w=0.2),
            sch.Stage("llm0", 1.0, 2.0, bwd_w=1.0),
            sch.Stage("llm1", 1.0, 2.0, bwd_w=1.0)])),
    ]


def schedulers() -> List[Finding]:
    """Every schedule x every fixture through every schedlint timeline
    rule (chunked schedules on their refined chains)."""
    from repro.core import schedule as sch
    from repro.core.schedule.graph import refine_chain
    out: List[Finding] = []
    for fname, g in _fixture_graphs():
        for name in sch.SCHEDULES:
            if name in ("interleaved", "zb-v"):
                graph = refine_chain(g, 2)
                sim = sch.get_scheduler(name, virtual_chunks=2) \
                    .simulate(graph, 8)
            else:
                graph = g
                sim = sch.get_scheduler(name).simulate(graph, 8)
            out += schedlint.lint_timeline(
                graph, sim, location=f"schedulers/{name}/{fname}")
    return out


def auto_parallelize() -> List[Finding]:
    """The winners ``auto_parallelize`` actually emits, re-simulated
    and linted — the schedules a real launch would run."""
    import numpy as np
    from repro.core import pipeline as pp
    out: List[Finding] = []
    cases = [
        ("vlm-frozen", [pp.ModuleProfile(
            "vision", np.full(4, 1.0), frozen=True)], False),
        ("vlm-ft", [pp.ModuleProfile(
            "vision", np.full(4, 1.0), frozen=False)], True),
    ]
    for cname, encs, _ in cases:
        llm = pp.ModuleProfile("llm", np.full(8, 2.0), frozen=False)
        best = pp.auto_parallelize(encs, llm, 4, 8)
        # the winner dict IS a sim dict (items/device_of/peaks) plus
        # the chunked graph its stage indices refer to
        out += schedlint.lint_timeline(
            best["graph"], best,
            location=f"auto-parallelize/{cname}/{best['schedule']}")
    return out


def golden_plan() -> List[Finding]:
    """The pinned 8-rank paper plan: plan-level consistency, then the
    pinned (schedule, virtual_chunks) re-simulated on the paper
    profiles and linted as a timeline."""
    from repro.configs.paper_mllm import llm_config, vision_encoder_config
    from repro.core import pipeline as pp
    from repro.parallel.plan import MLLMParallelPlan
    plan = MLLMParallelPlan.load(GOLDEN_PLAN)
    out = schedlint.lint_plan(plan, location="golden-plan")
    encs = [pp.profile_from_config(
        vision_encoder_config(), 1024, frozen=True, name="vision")]
    llm = pp.profile_from_config(llm_config(), plan.text_len,
                                 frozen=False, name="llm")
    graph, sim = pp.simulate_plan(
        encs, llm, list(plan.stage.encoder_stages),
        plan.stage.llm_stages, plan.schedule.num_microbatches,
        schedule=plan.schedule.name,
        virtual_chunks=plan.schedule.virtual_chunks,
        frozen_aware=plan.stage.frozen_aware)
    out += schedlint.lint_timeline(graph, sim,
                                   location="golden-plan/timeline")
    return out


def kernels() -> List[Finding]:
    """kernellint over src/repro/kernels (AST + dynamic checks)."""
    return kernellint.lint_kernels()


#: name -> entrypoint (CLI order = reporting order)
ENTRYPOINTS: Dict[str, Callable[[], List[Finding]]] = {
    "kernels": kernels,
    "kernel-bwd": kernel_bwd,
    "cp-allgather-bwd": cp_allgather_bwd,
    "cp-ring-bwd": cp_ring_bwd,
    "train-step": train_step,
    "xla-control": xla_control,
    "schedulers": schedulers,
    "auto-parallelize": auto_parallelize,
    "golden-plan": golden_plan,
}
