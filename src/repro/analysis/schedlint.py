"""schedlint: static validation of F/B/W pipeline-schedule timelines.

The discrete-event simulator (``core.schedule.simulator``) emits its
full work-item timeline — ``(start, end, device, kind, stage,
microbatch)`` tuples. Today those timelines are validated only
*dynamically*, by replaying them on the real executor
(``core.schedule.memory``). This module checks the same invariants
*statically*, before any device runs, so a wrong schedule becomes a
lint finding instead of a silent deadlock or race under the upcoming
``shard_map`` executor:

* ``fbw-order``        F(s,m) before B(s,m) before W(s,m)
* ``missing-item``     every (stage, microbatch) has its F and B; a
                       split timeline has a W for every trainable pair
* ``handoff-order``    consumer F after producer F (+ transfer);
                       producer B after consumer B
* ``device-overlap``   items on one device never overlap in time
* ``frozen-no-w``      stages with no weight-grad work (bwd_w == 0)
                       emit zero W items
* ``activation-cap``   the timeline's per-device live-activation walk
                       stays inside ``core.schedule.memory.
                       activation_caps`` and never goes negative
* ``peak-claim``       the simulator's claimed
                       ``peak_activations_per_device`` matches the
                       timeline it shipped with
* ``send-recv-cycle``  the ring/ppermute lowering (async sends,
                       blocking recvs) of the timeline's per-device
                       program orders + cross-device handoffs must be
                       acyclic — a cycle IS a deadlock, found by
                       topological sort rather than by hanging an
                       8-rank job. ``lint_spmd_program`` extends the
                       rule from the timeline *model* to the *actual
                       emitted* ppermute program of the shard_map
                       executor (``repro.parallel.spmd``): a compute
                       item whose cross-device input is never delivered
                       by an earlier wave boundary is exactly a
                       blocking recv that never unblocks
* ``ppermute-program`` the emitted comm rounds are well-formed: each
                       round is a partial permutation (distinct
                       sources, distinct destinations, no self-sends)
                       and every round ships the buffer its sending
                       device produced in that very wave (no stale
                       sends)

plus plan-level consistency checks over serialized
:class:`~repro.parallel.plan.MLLMParallelPlan` JSONs (``lint_plan``).

Findings anchor on ``core.schedule.simulator.item_id`` strings — the
same ids ``MemoryModelMismatch``'s timeline diff uses, so a static
finding and a dynamic divergence point at the same item.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.schedule.graph import PipelineGraph
from repro.core.schedule.memory import activation_caps
from repro.core.schedule.simulator import Item, item_id

from .findings import Finding, Severity, finding, register_rule

register_rule("fbw-order", "schedlint",
              "F precedes B precedes W per (stage, microbatch)")
register_rule("missing-item", "schedlint",
              "every (stage, microbatch) has exactly one F and one B; "
              "split timelines carry a W per trainable pair")
register_rule("handoff-order", "schedlint",
              "consumer F starts after producer F ends; producer B "
              "starts after consumer B ends")
register_rule("device-overlap", "schedlint",
              "items on one device never overlap in time")
register_rule("frozen-no-w", "schedlint",
              "frozen stages (bwd_w == 0) emit zero W items")
register_rule("activation-cap", "schedlint",
              "per-device live activations stay inside the "
              "depth_from_end cap envelope and never go negative")
register_rule("peak-claim", "schedlint",
              "the simulator's claimed peak_activations_per_device "
              "matches its own timeline")
register_rule("send-recv-cycle", "schedlint",
              "the send/recv lowering of the timeline is acyclic "
              "(no ring/ppermute deadlock)")
register_rule("ppermute-program", "schedlint",
              "emitted ppermute rounds are valid partial permutations "
              "shipping the freshly produced buffer")
register_rule("plan-consistency", "schedlint",
              "a serialized plan's schedule/stage/context components "
              "agree with each other")

_EPS = 1e-9


def lint_timeline(graph: PipelineGraph, sim: Dict[str, Any], *,
                  location: str = "timeline") -> List[Finding]:
    """Run every schedlint timeline rule against one simulation dict
    (``items`` + ``device_of`` [+ ``peak_activations_per_device``]).
    ``graph`` must be the graph the items' stage indices refer to."""
    out: List[Finding] = []
    items: Sequence[Item] = sim["items"]
    device_of = list(sim["device_of"])
    S = len(graph.stages)
    loc = location

    def at(it: Item) -> str:
        return f"{loc}:{item_id(it)}"

    # -- index the timeline ------------------------------------------------
    by_key: Dict[Tuple[str, int, int], List[Item]] = defaultdict(list)
    mbs = set()
    for it in items:
        _s0, _e0, dev, kind, s, m = it
        if not (0 <= s < S):
            out.append(finding("missing-item", at(it),
                               f"stage index {s} outside the "
                               f"{S}-stage graph"))
            continue
        if dev != device_of[s]:
            out.append(finding("missing-item", at(it),
                               f"item placed on device {dev} but "
                               f"device_of[{s}] == {device_of[s]}"))
        by_key[(kind, s, m)].append(it)
        mbs.add(m)
    M = max(mbs) + 1 if mbs else 0
    has_w = any(k == "W" for (k, _s, _m) in by_key)

    # -- missing-item / duplicates ----------------------------------------
    for s in range(S):
        trainable_w = graph.stages[s].bwd_w > 0
        for m in range(M):
            for kind, required in (("F", True), ("B", True),
                                   ("W", has_w and trainable_w)):
                n = len(by_key.get((kind, s, m), ()))
                if required and n == 0:
                    out.append(finding(
                        "missing-item", f"{loc}:{kind}(s{s},m{m})",
                        f"no {kind} item for stage {s}, "
                        f"microbatch {m}"))
                elif n > 1:
                    out.append(finding(
                        "missing-item", f"{loc}:{kind}(s{s},m{m})",
                        f"{n} duplicate {kind} items"))

    # -- frozen-no-w -------------------------------------------------------
    for (kind, s, m), its in by_key.items():
        if kind == "W" and graph.stages[s].bwd_w <= 0:
            out.append(finding(
                "frozen-no-w", at(its[0]),
                f"stage {s} has bwd_w == 0 (frozen / no weight work) "
                f"but the timeline schedules a W pass"))

    def one(kind, s, m) -> Optional[Item]:
        its = by_key.get((kind, s, m), ())
        return its[0] if len(its) == 1 else None

    # -- fbw-order ---------------------------------------------------------
    for s in range(S):
        for m in range(M):
            f, b, w = one("F", s, m), one("B", s, m), one("W", s, m)
            if f and b and b[0] < f[1] - _EPS:
                out.append(finding(
                    "fbw-order", at(b),
                    f"B starts at {b[0]:g} before its F ends at "
                    f"{f[1]:g}"))
            if b and w and w[0] < b[1] - _EPS:
                out.append(finding(
                    "fbw-order", at(w),
                    f"W starts at {w[0]:g} before its B ends at "
                    f"{b[1]:g}"))

    # -- handoff-order (cross-stage data dependencies) ---------------------
    for (p, q) in graph.edges:
        for m in range(M):
            fp, fq = one("F", p, m), one("F", q, m)
            if fp and fq and fq[0] < fp[1] - _EPS:
                out.append(finding(
                    "handoff-order", at(fq),
                    f"consumer F(s{q},m{m}) starts at {fq[0]:g} "
                    f"before producer F(s{p},m{m}) ends at {fp[1]:g}"))
            bp, bq = one("B", p, m), one("B", q, m)
            if bp and bq and bp[0] < bq[1] - _EPS:
                out.append(finding(
                    "handoff-order", at(bp),
                    f"producer B(s{p},m{m}) starts at {bp[0]:g} "
                    f"before consumer B(s{q},m{m}) ends at "
                    f"{bq[1]:g}"))

    # -- device-overlap ----------------------------------------------------
    per_dev: Dict[int, List[Item]] = defaultdict(list)
    for it in items:
        per_dev[it[2]].append(it)
    for dev, its in per_dev.items():
        its = sorted(its, key=lambda it: (it[0], it[1]))
        for a, b in zip(its, its[1:]):
            if b[0] < a[1] - _EPS:
                out.append(finding(
                    "device-overlap", at(b),
                    f"overlaps {item_id(a)} on device {dev} "
                    f"([{a[0]:g},{a[1]:g}] vs [{b[0]:g},{b[1]:g}])"))

    # -- activation-cap / peak-claim ---------------------------------------
    D = max(device_of) + 1 if device_of else 0
    caps = activation_caps(graph, device_of, M or None)
    occ = [0] * D
    peak = [0] * D
    ordered = sorted(items, key=lambda it: (it[0], it[3] != "B"))
    for it in ordered:
        _s0, _e0, dev, kind, s, m = it
        if not (0 <= s < S):
            continue
        d = device_of[s]
        if kind == "F":
            occ[d] += 1
            peak[d] = max(peak[d], occ[d])
            if occ[d] > caps[d]:
                out.append(finding(
                    "activation-cap", at(it),
                    f"live activations on device {d} reach {occ[d]}, "
                    f"over the cap envelope {caps[d]}"))
        elif kind == "B":
            occ[d] -= 1
            if occ[d] < 0:
                out.append(finding(
                    "activation-cap", at(it),
                    f"device {d} frees an activation it never "
                    f"held (occupancy {occ[d]})"))
                occ[d] = 0
    claimed = sim.get("peak_activations_per_device")
    if claimed is not None and list(claimed) != peak:
        out.append(finding(
            "peak-claim", loc,
            f"claimed peak activations {list(claimed)} != the "
            f"timeline's own walk {peak}"))

    out.extend(_check_send_recv_cycle(graph, items, device_of, loc))
    return out


# ---------------------------------------------------------------------------
# send/recv deadlock: rendezvous-lowering cycle check
# ---------------------------------------------------------------------------

def _check_send_recv_cycle(graph: PipelineGraph, items: Sequence[Item],
                           device_of: List[int], loc: str
                           ) -> List[Finding]:
    """Model the timeline as the program a ring/ppermute lowering
    would run and check it for deadlock.

    The lowering semantics: each cross-stage handoff becomes an async
    send on the producer's device and a blocking recv on the
    consumer's (the zero-bubble runtime's per-node send/recv model).
    A device executes its items in program order; an item's recv
    blocks until the producing item has run. Deadlock therefore
    happens exactly when the union of

    * program-order edges: consecutive items on one device (position
      in start-time order — the order the rank's program executes),
    * data edges: F(p,m) -> F(q,m) per graph edge (p,q);
      B(q,m) -> B(p,m); F(s,m) -> B(s,m); B(s,m) -> W(s,m)

    has a cycle — e.g. device 0 waits for a cotangent device 1 only
    produces after a forward device 0 scheduled later (the classic
    cross-wait). Found by topological sort, reported with the item ids
    on the cycle rather than by hanging an 8-rank job.
    """
    S = len(graph.stages)
    idx_of: Dict[Tuple[str, int, int], int] = {}
    for i, it in enumerate(items):
        _s0, _e0, _d, kind, s, m = it
        if 0 <= s < S:
            idx_of.setdefault((kind, s, m), i)

    n = len(items)
    adj: List[List[int]] = [[] for _ in range(n)]

    # program order + successor-on-device lookup
    per_dev: Dict[int, List[int]] = defaultdict(list)
    for i, it in enumerate(items):
        per_dev[it[2]].append(i)
    for dev, idxs in per_dev.items():
        idxs = sorted(idxs, key=lambda i: (items[i][0], i))
        for a, b in zip(idxs, idxs[1:]):
            adj[a].append(b)

    def data_edge(u_key, v_key):
        u, v = idx_of.get(u_key), idx_of.get(v_key)
        if u is not None and v is not None:
            adj[u].append(v)

    mbs = sorted({it[5] for it in items})
    for m in mbs:
        for (p, q) in graph.edges:
            data_edge(("F", p, m), ("F", q, m))
            data_edge(("B", q, m), ("B", p, m))
        for s in range(S):
            data_edge(("F", s, m), ("B", s, m))
            data_edge(("B", s, m), ("W", s, m))

    # Kahn topological sort; leftovers participate in (or depend on) a
    # cycle — report a concrete cycle found by DFS among them
    indeg = [0] * n
    for u in range(n):
        for v in adj[u]:
            indeg[v] += 1
    queue = [u for u in range(n) if indeg[u] == 0]
    seen = 0
    while queue:
        u = queue.pop()
        seen += 1
        for v in adj[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                queue.append(v)
    if seen == n:
        return []
    stuck = [u for u in range(n) if indeg[u] > 0]
    cycle = _find_cycle(adj, stuck)
    ids = " -> ".join(item_id(items[i]) for i in cycle)
    return [finding(
        "send-recv-cycle", loc,
        f"send/recv lowering deadlocks; dependency cycle: "
        f"{ids} -> {item_id(items[cycle[0]])}" if cycle else
        f"send/recv lowering deadlocks "
        f"({len(stuck)} items never become runnable)")]


def _find_cycle(adj: List[List[int]], nodes: List[int]) -> List[int]:
    in_cycle = set(nodes)
    color: Dict[int, int] = {}
    stack: List[int] = []

    def dfs(u: int) -> Optional[List[int]]:
        color[u] = 1
        stack.append(u)
        for v in adj[u]:
            if v not in in_cycle:
                continue
            if color.get(v, 0) == 1:
                return stack[stack.index(v):]
            if color.get(v, 0) == 0:
                got = dfs(v)
                if got is not None:
                    return got
        color[u] = 2
        stack.pop()
        return None

    for u in nodes:
        if color.get(u, 0) == 0:
            got = dfs(u)
            if got is not None:
                return got
    return []


# ---------------------------------------------------------------------------
# Emitted SPMD program lint (repro.parallel.spmd wave/ppermute programs)
# ---------------------------------------------------------------------------

def lint_spmd_program(program: Any, *,
                      location: str = "spmd-program") -> List[Finding]:
    """Validate the *actual emitted* shard_map program — the
    wave/ppermute lowering ``repro.parallel.spmd.compile_spmd_program``
    produced — not the timeline model it came from.

    Three families of checks:

    * each comm round is a legal ``lax.ppermute`` partial permutation:
      distinct sources, distinct destinations, no self-sends
      (``ppermute-program``);
    * each round ships a FRESH buffer: the executor holds one forward
      send buffer and one cotangent send buffer per device, overwritten
      by every wave, so a round attached to wave w must ship exactly
      what its source device computed in wave w — anything else sends
      stale garbage (``ppermute-program``);
    * delivery-before-use: a compute item consuming a cross-device
      input (consumer F needing a remote predecessor's activation,
      producer B needing a remote successor's cotangent) must have that
      value delivered by a round at a STRICTLY earlier wave boundary.
      In the blocking-recv lowering this is the deadlock condition — a
      recv with no matching earlier send never unblocks
      (``send-recv-cycle``).
    """
    out: List[Finding] = []
    graph = program.graph
    device_of = program.device_of
    preds, succs = graph.preds, graph.succs
    delivered: set = set()              # (kind, dst_stage, src_stage, m)

    def produced(kind: str) -> str:
        return "F" if kind == "fwd" else "B"

    for w, wave in enumerate(program.waves):
        # -- consumers first: wave-w rounds run AFTER wave-w compute --
        for dev, (i, kind, s, _c, m) in sorted(wave.compute.items()):
            it = program.items[i]
            if kind == "F":
                needed = [("fwd", s, p, m) for p in preds[s]
                          if device_of[p] != dev]
            elif kind == "B":
                needed = [("bwd", s, q, m) for q in succs[s]
                          if device_of[q] != dev
                          and graph.stages[q].bwd_b > 0]
            else:
                needed = []
            for key in needed:
                if key not in delivered:
                    knd, dst_s, src_s, mb = key
                    what = "activation" if knd == "fwd" else "cotangent"
                    out.append(finding(
                        "send-recv-cycle",
                        f"{location}:wave{w}:{item_id(it)}",
                        f"blocking recv never satisfied: consumes the "
                        f"{what} of stage {src_s} (microbatch {mb}) "
                        f"from device {device_of[src_s]}, but no "
                        f"earlier wave boundary delivers it to device "
                        f"{dev}"))
        for r, rnd in enumerate(wave.rounds):
            at = f"{location}:wave{w}:round{r}"
            srcs = [t.src_dev for t in rnd.transfers]
            dsts = [t.dst_dev for t in rnd.transfers]
            if len(set(srcs)) != len(srcs) or len(set(dsts)) != len(dsts):
                out.append(finding(
                    "ppermute-program", at,
                    f"round is not a partial permutation: sources "
                    f"{srcs}, destinations {dsts} (duplicates)"))
            for t in rnd.transfers:
                if t.src_dev == t.dst_dev:
                    out.append(finding(
                        "ppermute-program", at,
                        f"self-send on device {t.src_dev} (stage "
                        f"{t.src_stage} -> {t.dst_stage}); local "
                        f"handoffs go through the store, not ppermute"))
                want = (produced(rnd.kind), t.src_stage, t.microbatch)
                have = wave.compute.get(t.src_dev)
                if have is None or have[1:3] + (have[4],) != want:
                    have_id = item_id(program.items[have[0]]) \
                        if have is not None else "nothing"
                    out.append(finding(
                        "ppermute-program", at,
                        f"stale send buffer: round ships "
                        f"{want[0]}(s{want[1]},m{want[2]})'s output "
                        f"from device {t.src_dev}, whose wave-{w} "
                        f"compute is {have_id}"))
                delivered.add((rnd.kind, t.dst_stage, t.src_stage,
                               t.microbatch))
    return out


# ---------------------------------------------------------------------------
# Plan-level lint (serialized MLLMParallelPlan JSONs)
# ---------------------------------------------------------------------------

def lint_plan(plan: Any, *, location: str = "plan") -> List[Finding]:
    """Consistency checks over a typed ``MLLMParallelPlan`` (no model
    needed): the components a launch script trusts must agree with each
    other before anything is instantiated against real devices."""
    out: List[Finding] = []
    sc, st, cx = plan.schedule, plan.stage, plan.context
    if len(sc.peak_activations_per_device) != sc.num_devices:
        out.append(finding(
            "plan-consistency", location,
            f"schedule claims {sc.num_devices} devices but "
            f"{len(sc.peak_activations_per_device)} peak-activation "
            f"entries"))
    if not (0.0 <= sc.bubble_fraction < 1.0):
        out.append(finding(
            "plan-consistency", location,
            f"bubble_fraction {sc.bubble_fraction} outside [0, 1)"))
    if sc.iteration_time <= 0:
        out.append(finding(
            "plan-consistency", location,
            f"non-positive iteration_time {sc.iteration_time}"))
    if sc.num_devices % st.num_devices != 0:
        out.append(finding(
            "plan-consistency", location,
            f"simulated device count {sc.num_devices} is not a "
            f"multiple of the stage plan's {st.num_devices} pipeline "
            f"ranks"))
    if cx is not None:
        ranks = set(range(cx.num_ranks))
        used = set(cx.assignment)
        if not used <= ranks:
            out.append(finding(
                "plan-consistency", location,
                f"context assignment references ranks "
                f"{sorted(used - ranks)} outside 0..{cx.num_ranks - 1}"))
        elif len(cx.assignment) >= cx.num_ranks and used != ranks:
            out.append(finding(
                "plan-consistency", location,
                f"context assignment leaves ranks "
                f"{sorted(ranks - used)} idle with "
                f"{len(cx.assignment)} blocks to hand out",
                severity=Severity.WARNING))
        if any(l < 0 for l in cx.loads):
            out.append(finding(
                "plan-consistency", location,
                f"negative context loads {list(cx.loads)}"))
    return out


def lint_executor_contract(executor: Dict[str, Any], *,
                           location: str = "executor") -> List[Finding]:
    """Lint the timeline inside an executor contract
    (``MLLMParallelPlan.apply`` / ``build_executor_plan`` output). The
    contract's ``sim_graph`` is the graph the simulation items index
    into (the folded ``graph`` can be coarser for chunked schedules)."""
    graph = executor.get("sim_graph") or executor["graph"]
    sim = executor["schedule"]
    mx = max((it[4] for it in sim["items"]), default=-1)
    if mx >= len(graph.stages):
        return [finding(
            "plan-consistency", location,
            f"executor contract carries no graph matching its "
            f"timeline (stage index {mx} vs {len(graph.stages)} "
            f"stages)")]
    out = lint_timeline(graph, sim, location=location)
    program = executor.get("spmd_program")
    if program is not None:
        # an SPMD-mode contract ships the compiled wave/ppermute
        # program — lint what will actually run, not just the model
        out += lint_spmd_program(program,
                                 location=f"{location}:spmd")
    return out
