"""kernellint: static + small-case checks over the Pallas kernels.

Pallas BlockSpec mistakes are brutal to debug at runtime (shape errors
deep inside Mosaic, or silent garbage on the interpret path), and the
grid-compaction machinery has a correctness obligation no type system
sees: the compacted grid must cover every tile the bitfield mask
allows. This module checks both *before* a kernel ever runs.

AST rules (over ``src/repro/kernels/*.py`` — or any source handed to
:func:`lint_source`):

* ``blockspec-index-arity`` — every ``pl.BlockSpec`` index map that
  appears inside a grid-bearing call (``pl.pallas_call(grid=...)`` or
  ``pltpu.PrefetchScalarGridSpec(grid=...)``) must take exactly
  ``len(grid)`` arguments, plus ``num_scalar_prefetch`` more for
  scalar-prefetch grids. Named index maps are resolved against every
  ``def`` in the module (any nesting depth).
* ``blockspec-rank-mismatch`` — a BlockSpec's block-shape tuple and
  its index map's returned tuple must have the same length.

Both rules only fire on statically decidable sites (literal grids,
literal spec lists, lambdas or resolvable names) — undecidable sites
are skipped, never guessed at.

Small-case dynamic rules (numpy-only, no kernel launch):

* ``block-map-coverage`` — exhaustive check on small shapes that
  ``bam.build_block_map`` grids cover every (q, k) pair
  ``bam.allowed_mask`` allows, in BOTH the q-major and k-major
  orderings, and that ``first``/``last`` flags frame each major
  block's steps correctly (accumulator init/flush).
* ``scalar-prefetch-static`` — ``BlockMask`` must stay hashable (it
  rides through ``jax.custom_vjp`` as a static argument) and its
  prefetch arrays must be int32.
* ``block-shape-divides`` — the kernel wrapper's padding really does
  round every sequence axis up to a block multiple (the property every
  BlockSpec shape in the file relies on).
* ``decode-grid-coverage`` — the serving decode grid
  (``serving.paged_cache.build_decode_grid``) visits every physical
  page the dense mask allows, frames each batch row exactly once,
  routes inactive/pad steps to the null page, and keeps ``pad_to``
  steps inert.
* ``page-grid-divisibility`` — page-table allocations are whole pages,
  the flat KV view is exactly page-padded, and
  ``paged_decode_attention`` rejects operands whose shapes disagree
  with the pool before any kernel is built.
"""
from __future__ import annotations

import ast
import os
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .findings import Finding, finding, register_rule

register_rule("blockspec-index-arity", "kernellint",
              "BlockSpec index maps take grid-rank (+ scalar-prefetch) "
              "arguments")
register_rule("blockspec-rank-mismatch", "kernellint",
              "BlockSpec block shapes and index-map results have the "
              "same rank")
register_rule("block-map-coverage", "kernellint",
              "build_block_map grids cover every tile the bitfield "
              "mask allows")
register_rule("scalar-prefetch-static", "kernellint",
              "scalar-prefetch operands are hashable/static")
register_rule("block-shape-divides", "kernellint",
              "kernel-wrapper padding rounds sequence axes to block "
              "multiples")
register_rule("decode-grid-coverage", "kernellint",
              "build_decode_grid visits every page the bitfield mask "
              "allows and frames each batch row exactly once")
register_rule("page-grid-divisibility", "kernellint",
              "page-table capacity, pool shapes, and the decode "
              "kernel's page blocks agree on page_size")

KERNELS_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "kernels")


# ---------------------------------------------------------------------------
# AST rules
# ---------------------------------------------------------------------------

def _call_name(node: ast.Call) -> str:
    """Trailing attribute name of the called function ('pallas_call',
    'BlockSpec', ...), however it is qualified."""
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    if isinstance(node.func, ast.Name):
        return node.func.id
    return ""


def _collect_defs(tree: ast.AST) -> Dict[str, List[ast.FunctionDef]]:
    defs: Dict[str, List[ast.FunctionDef]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            defs.setdefault(node.name, []).append(node)
    return defs


def _positional_arity(args: ast.arguments) -> int:
    """Grid-index arity of an index map: positional args minus any
    defaulted trailing ones (``lambda b, h, iq, ik, n_rep=n_rep: ...``
    is the standard closure-capture idiom — the defaulted arg is a
    captured constant, not a grid index)."""
    return len(args.posonlyargs) + len(args.args) - len(args.defaults)


def _return_tuple_len(fn: ast.AST) -> Optional[int]:
    """Length of the tuple a lambda/def returns, when statically
    known."""
    if isinstance(fn, ast.Lambda):
        body = fn.body
        return len(body.elts) if isinstance(body, ast.Tuple) else None
    if isinstance(fn, ast.FunctionDef):
        rets = [n for n in ast.walk(fn) if isinstance(n, ast.Return)]
        if len(rets) == 1 and isinstance(rets[0].value, ast.Tuple):
            return len(rets[0].value.elts)
    return None


def _iter_blockspecs(node: ast.AST):
    """Every pl.BlockSpec(...) Call lexically under ``node``."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call) and _call_name(sub) == "BlockSpec":
            yield sub


def _kw(node: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    return None


def lint_source(src: str, filename: str = "<source>") -> List[Finding]:
    """Run the AST rules over one Python source string."""
    out: List[Finding] = []
    tree = ast.parse(src, filename=filename)
    defs = _collect_defs(tree)

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _call_name(node)
        if name not in ("pallas_call", "PrefetchScalarGridSpec",
                        "GridSpec"):
            continue
        grid = _kw(node, "grid")
        if not isinstance(grid, ast.Tuple):
            continue                       # grid not a literal: skip
        rank = len(grid.elts)
        n_prefetch = 0
        if name == "PrefetchScalarGridSpec":
            pf = _kw(node, "num_scalar_prefetch")
            if isinstance(pf, ast.Constant) and \
                    isinstance(pf.value, int):
                n_prefetch = pf.value
            else:
                continue                   # undecidable prefetch count
        expected = rank + n_prefetch

        for spec in _iter_blockspecs(node):
            loc = f"{filename}:{spec.lineno}"
            if len(spec.args) < 2:
                continue                   # BlockSpec() defaults: skip
            shape, index_map = spec.args[0], spec.args[1]
            arity: Optional[int] = None
            ret_len: Optional[int] = None
            if isinstance(index_map, ast.Lambda):
                arity = _positional_arity(index_map.args)
                ret_len = _return_tuple_len(index_map)
            elif isinstance(index_map, ast.Name):
                cands = defs.get(index_map.id, [])
                arities = {_positional_arity(fn.args) for fn in cands}
                if len(arities) == 1:
                    arity = arities.pop()
                lens = {_return_tuple_len(fn) for fn in cands}
                if len(lens) == 1:
                    ret_len = lens.pop()
            if arity is not None and arity != expected:
                out.append(finding(
                    "blockspec-index-arity", loc,
                    f"index map takes {arity} args but the grid is "
                    f"rank {rank}"
                    + (f" with {n_prefetch} scalar-prefetch operands "
                       f"(expected {expected})" if n_prefetch
                       else f" (expected {expected})")))
            if ret_len is not None and isinstance(shape, ast.Tuple) \
                    and ret_len != len(shape.elts):
                out.append(finding(
                    "blockspec-rank-mismatch", loc,
                    f"block shape is rank {len(shape.elts)} but the "
                    f"index map returns {ret_len} coordinates"))
    return out


def lint_file(path: str) -> List[Finding]:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    return lint_source(src, os.path.relpath(path))


# ---------------------------------------------------------------------------
# Dynamic small-case rules
# ---------------------------------------------------------------------------

#: small multimodal layouts covering text-only, modality islands,
#: interleave, multi-doc, and pad-tail cases (kind, modality, length)
_COVERAGE_LAYOUTS: Tuple[Tuple[Tuple[str, int, int], ...], ...] = (
    (("text", 0, 12),),
    (("text", 0, 4), ("mod", 1, 6), ("text", 0, 2)),
    (("mod", 1, 5), ("mod", 2, 4), ("text", 0, 3)),
    (("text", 0, 3), ("newdoc", 0, 0), ("text", 0, 5), ("mod", 1, 3)),
    (("mod", 1, 7), ("text", 0, 2)),                    # pad tail below
)


def check_block_map_coverage(layouts=_COVERAGE_LAYOUTS,
                             block_sizes: Sequence[int] = (4, 8),
                             windows: Sequence[int] = (0, 3),
                             seq_len: int = 14) -> List[Finding]:
    """Exhaustive small-case proof obligation: every (q, k) pair the
    dense ``allowed_mask`` allows must land in an active tile of
    ``build_block_map``'s compacted grid — in both orderings — and the
    first/last flags must frame each major index's steps exactly once."""
    from repro.core import bam
    out: List[Finding] = []
    for li, segs in enumerate(layouts):
        bits, pos = bam.build_sample_bits(list(segs), seq_len)
        dense = np.asarray(bam.allowed_mask(bits[None], bits[None],
                                            pos[None], pos[None]))[0]
        for bq in block_sizes:
            for bk in block_sizes:
                for w in windows:
                    if w:
                        dense_w = np.asarray(bam.allowed_mask(
                            bits[None], bits[None], pos[None],
                            pos[None], window=w))[0]
                    else:
                        dense_w = dense
                    bm = bam.build_block_map(bits, bits, pos, pos,
                                             bq, bk, window=w)
                    loc = (f"layout{li} bq={bq} bk={bk} window={w}")
                    out += _coverage_findings(dense_w, bm, bq, bk, loc)
    return out


def _coverage_findings(dense: np.ndarray, bm, bq: int, bk: int,
                       loc: str) -> List[Finding]:
    out: List[Finding] = []
    Tq, Tk = dense.shape
    active_q = {(iq, ik) for iq, ik, _f, _l, a in bm.q_steps if a}
    active_k = {(iq, ik) for iq, ik, _f, _l, a in bm.k_steps if a}
    qs, ks = np.nonzero(dense)
    needed = {(int(q) // bq, int(k) // bk) for q, k in zip(qs, ks)}
    for tile in sorted(needed - active_q):
        out.append(finding(
            "block-map-coverage", loc,
            f"q-major grid misses active tile (q_block={tile[0]}, "
            f"k_block={tile[1]}) — allowed pairs would be dropped"))
    for tile in sorted(needed - active_k):
        out.append(finding(
            "block-map-coverage", loc,
            f"k-major grid misses active tile (q_block={tile[0]}, "
            f"k_block={tile[1]})"))
    for major, steps, pick in (("q", bm.q_steps, 0),
                               ("k", bm.k_steps, 1)):
        seen: Dict[int, List[Tuple[int, int]]] = {}
        for step in steps:
            seen.setdefault(step[pick], []).append((step[2], step[3]))
        majors = bm.nq if major == "q" else bm.nk
        for i in range(majors):
            flags = seen.get(i, [])
            if not flags:
                out.append(finding(
                    "block-map-coverage", loc,
                    f"{major}-major grid has no step for "
                    f"{major}_block={i} — its output/grad rows are "
                    f"never initialized"))
                continue
            if sum(f for f, _l in flags) != 1 or \
                    sum(l for _f, l in flags) != 1 or \
                    flags[0][0] != 1 or flags[-1][1] != 1:
                out.append(finding(
                    "block-map-coverage", loc,
                    f"{major}-major first/last flags malformed for "
                    f"{major}_block={i}: {flags}"))
    return out


def check_scalar_prefetch_static() -> List[Finding]:
    """The compacted grid rides through ``jax.custom_vjp`` as a static
    argument — it must hash, compare by value, and produce int32
    prefetch operands."""
    from repro.core import bam
    out: List[Finding] = []
    bits, pos = bam.build_sample_bits(
        [("text", 0, 4), ("mod", 1, 4)], 8)
    bm = bam.build_block_map(bits, bits, pos, pos, 4, 4)
    try:
        hash(bm)
    except TypeError as e:
        out.append(finding(
            "scalar-prefetch-static", "bam.BlockMask",
            f"BlockMask is unhashable ({e}) — it cannot be a "
            f"custom_vjp static argument"))
        return out
    bm2 = bam.build_block_map(bits, bits, pos, pos, 4, 4)
    if bm != bm2 or hash(bm) != hash(bm2):
        out.append(finding(
            "scalar-prefetch-static", "bam.BlockMask",
            "equal BlockMasks do not compare/hash equal — jit "
            "caching on the static arg would always miss"))
    for major in ("q", "k"):
        for j, arr in enumerate(bm.arrays(major)):
            if arr.dtype != np.int32:
                out.append(finding(
                    "scalar-prefetch-static", "bam.BlockMask.arrays",
                    f"{major}-major prefetch operand {j} is "
                    f"{arr.dtype}, not int32"))
    return out


def check_block_divisibility(
        cases: Sequence[Tuple[int, int, int]] = ((40, 16, 16),
                                                 (40, 16, 8),
                                                 (7, 4, 4),
                                                 (64, 16, 16))
        ) -> List[Finding]:
    """The kernel wrapper pads every sequence axis to a block multiple
    before building its grid; block shapes must divide the padded dims
    for every (T, block_q, block_k) it will meet."""
    import jax.numpy as jnp
    from repro.kernels import ops
    out: List[Finding] = []
    for T, bq, bk in cases:
        q = jnp.zeros((1, T, 2, 4))
        bits = jnp.zeros((1, T), jnp.uint32)
        pos = jnp.zeros((1, T), jnp.int32)
        padded = ops._pad_all(q, q, q, bits, bits, pos, pos, bq, bk)
        qp, kp, vp, qb, kb = padded[0], padded[1], padded[2], \
            padded[3], padded[4]
        loc = f"ops._pad_all T={T} bq={bq} bk={bk}"
        if qp.shape[1] % bq or qb.shape[1] % bq:
            out.append(finding(
                "block-shape-divides", loc,
                f"q axis padded to {qp.shape[1]} — not a multiple of "
                f"block_q={bq}"))
        if kp.shape[1] % bk or vp.shape[1] % bk or kb.shape[1] % bk:
            out.append(finding(
                "block-shape-divides", loc,
                f"k axis padded to {kp.shape[1]} — not a multiple of "
                f"block_k={bk}"))
    return out


def check_decode_grid_coverage(layouts=_COVERAGE_LAYOUTS,
                               page_sizes: Sequence[int] = (4, 8),
                               seq_len: int = 14) -> List[Finding]:
    """Serving twin of ``check_block_map_coverage``: the decode grid's
    physical-page step list must visit every page holding a KV slot the
    dense mask allows, frame each batch row's steps exactly once
    (online-softmax init/flush), route every inactive or padding step
    to the null page, and give empty batch rows a flush step."""
    from repro.core import bam
    from repro.serving.paged_cache import (NULL_PAGE, PageTable,
                                           build_decode_grid,
                                           decode_grid_bucket)
    out: List[Finding] = []
    queries = (bam.text_token(), bam.text_token((1, 2)),
               bam.modality_token(1))
    for li, segs in enumerate(layouts):
        bits, pos = bam.build_sample_bits(list(segs), seq_len)
        for ps in page_sizes:
            table = PageTable(8, ps)
            table.alloc(0, seq_len)
            table.write(0, np.arange(seq_len), bits, pos)
            pages = table.pages_of(0)
            kv_bits, kv_pos = table.kv_view(0)
            for qi, qb in enumerate(queries):
                qp = int(pos.max()) + 1
                loc = f"layout{li} ps={ps} query{qi}"
                grid = build_decode_grid(
                    table, [0, None], np.array([qb, 0], np.uint32),
                    np.array([qp, 0], np.int32))
                dense = np.asarray(bam.allowed_mask(
                    np.array([[qb]], np.uint32), kv_bits[None],
                    np.array([[qp]], np.int32), kv_pos[None]))[0, 0]
                needed = {pages[int(s) // ps] for s in
                          np.nonzero(dense)[0]}
                active = {int(p) for p, r, a in
                          zip(grid.page, grid.req, grid.active)
                          if a and r == 0}
                for page in sorted(needed - active):
                    out.append(finding(
                        "decode-grid-coverage", loc,
                        f"grid never visits page {page} though the "
                        f"mask allows slots in it — KV would be "
                        f"dropped from the decode softmax"))
                for row in (0, 1):
                    sel = grid.req == row
                    f, l = grid.first[sel], grid.last[sel]
                    if f.sum() != 1 or l.sum() != 1 or not f[0] \
                            or not l[-1]:
                        out.append(finding(
                            "decode-grid-coverage", loc,
                            f"batch row {row} is not framed exactly "
                            f"once (first={f.tolist()}, "
                            f"last={l.tolist()}) — scratch init/flush "
                            f"would misfire"))
                if (grid.page[grid.active == 0] != NULL_PAGE).any():
                    out.append(finding(
                        "decode-grid-coverage", loc,
                        "inactive step points at a real page — it "
                        "would DMA data the kernel must not read"))
                padded = build_decode_grid(
                    table, [0, None], np.array([qb, 0], np.uint32),
                    np.array([qp, 0], np.int32),
                    pad_to=decode_grid_bucket(grid.n_steps + 1))
                pad = padded.arrays()
                if padded.n_active_steps != grid.n_active_steps or \
                        pad[4][grid.n_steps:].any() or \
                        pad[2][grid.n_steps:].any() or \
                        pad[3][grid.n_steps:].any():
                    out.append(finding(
                        "decode-grid-coverage", loc,
                        "pad_to steps are not inert (active/first/"
                        "last must all be 0 past the real steps)"))
            table.free(0)
    return out


def check_page_divisibility(
        cases: Sequence[Tuple[int, int]] = ((5, 4), (9, 8), (1, 4),
                                            (16, 8), (17, 8))
        ) -> List[Finding]:
    """Page arithmetic the decode kernel's BlockSpecs rely on: every
    allocation is a whole number of pages, the flat KV view is exactly
    page-padded, and the kernel wrapper rejects metadata whose shape
    disagrees with the pool's (P, page_size)."""
    import jax.numpy as jnp
    from repro.kernels.paged_decode import paged_decode_attention
    from repro.serving.paged_cache import PageTable
    out: List[Finding] = []
    for n_tokens, ps in cases:
        table = PageTable(16, ps)
        table.alloc(0, n_tokens)
        cap = table.capacity(0)
        loc = f"PageTable n_tokens={n_tokens} page_size={ps}"
        if cap % ps or cap < n_tokens:
            out.append(finding(
                "page-grid-divisibility", loc,
                f"capacity {cap} is not a page multiple covering "
                f"{n_tokens} tokens"))
        kv_bits, kv_pos = table.kv_view(0)
        if len(kv_bits) != cap or len(kv_pos) != cap:
            out.append(finding(
                "page-grid-divisibility", loc,
                f"kv_view length {len(kv_bits)} != page-padded "
                f"capacity {cap} — the kernel's page blocks would "
                f"run off the metadata"))
    # wrapper-side validation: shape disagreements must raise before
    # any pallas_call is built
    ps = 4
    q = jnp.zeros((1, 2, 8))
    pages = jnp.zeros((3, ps, 2, 8))
    bits_ok = jnp.zeros((3, ps), jnp.uint32)
    pos_ok = jnp.zeros((3, ps), jnp.int32)
    steps = tuple(jnp.zeros(2, jnp.int32) for _ in range(5))
    bad = (
        ("kv metadata off-page", dict(kv_bits=jnp.zeros((3, ps + 1),
                                                        jnp.uint32))),
        ("GQA non-divisible", dict(q=jnp.zeros((1, 3, 8)))),
        ("q metadata shape", dict(q_bits=jnp.zeros((2, 1), jnp.uint32))),
    )
    for label, override in bad:
        kw = dict(q=q, k_pages=pages, v_pages=pages,
                  q_bits=jnp.zeros((1, 1), jnp.uint32),
                  q_pos=jnp.zeros((1, 1), jnp.int32),
                  kv_bits=bits_ok, kv_pos=pos_ok, steps=steps)
        kw.update(override)
        try:
            paged_decode_attention(**kw)
        except ValueError:
            continue
        out.append(finding(
            "page-grid-divisibility", f"paged_decode_attention {label}",
            "mismatched operand accepted — the kernel would index "
            "out of bounds at runtime"))
    return out


def lint_kernels(path: Optional[str] = None) -> List[Finding]:
    """All kernellint rules: AST rules over every ``.py`` under
    ``path`` (default: ``src/repro/kernels``) + the dynamic
    small-case rules."""
    root = path or KERNELS_DIR
    out: List[Finding] = []
    for name in sorted(os.listdir(root)):
        if name.endswith(".py"):
            out += lint_file(os.path.join(root, name))
    out += check_block_map_coverage()
    out += check_scalar_prefetch_static()
    out += check_block_divisibility()
    out += check_decode_grid_coverage()
    out += check_page_divisibility()
    return out
