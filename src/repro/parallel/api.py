"""The single user-facing parallelization entrypoint.

    plan = parallelize(mllm, ClusterSpec(num_devices=8, cp_size=8),
                       WorkloadShape(text_len=1024, num_microbatches=8))

runs Cornstarch's joint decision for one MLLM and one workload:

* **PP** — Algorithm 1 (``core.pipeline.auto_parallelize``) partitions
  every module frozen-aware and searches (stage allocation, schedule,
  virtual-chunk count) jointly over the discrete-event simulator;
* **CP** — the merged sequence's BAM block workloads (the same
  quantity all-gather CP time is proportional to) are balanced over
  the CP ranks by the chosen balancer (LPT by default, Algorithm 2).

Both halves read the same source of truth — the MLLM's module
profiles and token layout — so one call yields one composable,
serializable :class:`~repro.parallel.plan.MLLMParallelPlan` per
scenario. ``search_plan`` is the profile-level sibling for callers
(benchmarks, tests) that already hold ``ModuleProfile``s instead of a
``MultimodalModule``; ``plan_context`` builds a ContextPlan alone from
raw BAM bitfields.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import bam
from repro.core import distribution as dist
from repro.core import pipeline as pp
from repro.core.schedule import SCHEDULES

from .plan import (ClusterSpec, ContextPlan, MLLMParallelPlan,
                   SchedulePlan, StagePlan, WorkloadShape)

#: objectives auto_parallelize can rank candidates by (one source of
#: truth: core.pipeline validates against this same tuple)
OBJECTIVES = pp.AUTO_OBJECTIVES

#: balancers `cp_method="auto"` chooses among (ilp is left out: it is
#: the offline certificate, not a live planner)
_AUTO_CP_METHODS = ("lpt", "zigzag", "ring")


def plan_context(bits: np.ndarray, pos: np.ndarray, num_ranks: int, *,
                 block_size: int = 128, method: str = "lpt",
                 window: int = 0, **kw) -> ContextPlan:
    """BAM bitfields -> block workloads -> typed ContextPlan (the
    typed face of ``core.distribution.plan_tokens``). ``method="auto"``
    picks the live balancer with the smallest makespan."""
    W = bam.block_workload(bits, pos, block_size, window)
    if method == "auto":
        best = None
        for m in _AUTO_CP_METHODS:
            cand = dist.PLANNERS[m](W, num_ranks, block_size)
            if best is None or cand.makespan < best[1].makespan - 1e-12:
                best = (m, cand)
        method, core = best
    elif method in dist.PLANNERS:
        core = dist.PLANNERS[method](W, num_ranks, block_size, **kw)
    else:
        raise ValueError(f"unknown balancer {method!r}; pick from "
                         f"{sorted(dist.PLANNERS)} or 'auto'")
    return ContextPlan.from_core(core, method)


def mllm_workload_bits(mllm, text_len: int
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """The merged sequence's BAM bitfields for an MLLM's token layout
    — the same layout ``MultimodalModule.build_merge`` materializes at
    train time, rebuilt host-side for planning."""
    layout = mllm.layout or mllm.default_layout(text_len)
    segs = []
    for seg in layout:
        if seg[0] == "text":
            segs.append(("text", 0, seg[1]))
        else:
            enc = mllm.encoders[seg[0]]
            segs.append(("mod", enc.modality_id, enc.num_tokens))
    return bam.build_sample_bits(segs, mllm.merged_length(text_len))


def search_plan(encoders: Sequence[pp.ModuleProfile],
                llm: pp.ModuleProfile, cluster: ClusterSpec,
                shape: WorkloadShape, *,
                objective: str = "tput_per_device",
                schedules: Sequence[str] = SCHEDULES,
                virtual_chunks: Sequence[int] = (1, 2, 4),
                frozen_aware: bool = True,
                cp_workload: Optional[Tuple[np.ndarray, np.ndarray]]
                = None,
                cp_method: str = "lpt") -> MLLMParallelPlan:
    """Profile-level joint search: Algorithm 1 over the pipeline side,
    the chosen balancer over ``cp_workload`` (BAM ``(bits, pos)``; omit
    it for a PP-only plan with ``context=None``). Unknown objectives
    raise ``ValueError`` (validated by ``auto_parallelize``)."""
    best = pp.auto_parallelize(
        encoders, llm, cluster.num_devices, shape.num_microbatches,
        frozen_aware=frozen_aware, schedules=schedules,
        virtual_chunks=virtual_chunks, objective=objective)
    stage = StagePlan(
        encoder_names=tuple(best["encoder_names"]),
        encoder_stages=tuple(int(k) for k in best["encoder_stages"]),
        llm_stages=int(best["llm_stages"]), frozen_aware=frozen_aware)
    schedule = SchedulePlan(
        name=best["schedule"],
        virtual_chunks=int(best["virtual_chunks"]),
        num_microbatches=shape.num_microbatches,
        iteration_time=float(best["iteration_time"]),
        bubble_fraction=float(best["bubble_fraction"]),
        num_devices=int(best["num_devices"]),
        peak_activations_per_device=tuple(
            int(p) for p in best["peak_activations_per_device"]),
        tput_per_device=float(best["tput_per_device"]))
    context = None
    if cp_workload is not None:
        bits, pos = cp_workload
        context = plan_context(bits, pos, cluster.cp_size,
                               block_size=shape.block_size,
                               method=cp_method)
    return MLLMParallelPlan(stage=stage, schedule=schedule,
                            context=context, text_len=shape.text_len,
                            microbatch_size=shape.microbatch_size)


def parallelize(mllm, cluster: ClusterSpec, shape: WorkloadShape, *,
                objective: str = "tput_per_device",
                schedules: Sequence[str] = SCHEDULES,
                virtual_chunks: Sequence[int] = (1, 2, 4),
                frozen_aware: bool = True,
                cp_method: str = "lpt") -> MLLMParallelPlan:
    """THE entrypoint: one typed call -> one joint PP x CP plan.

    Derives the frozen-aware module profiles and the merged-sequence
    BAM workload from the same ``MultimodalModule`` description, then
    delegates to :func:`search_plan`. The result round-trips through
    JSON, prints via ``.describe()``, and instantiates against the
    model via ``.apply(mllm)``.
    """
    encs, llm_prof = mllm.profiles(shape.text_len,
                                   batch=shape.microbatch_size)
    bits, pos = mllm_workload_bits(mllm, shape.text_len)
    return search_plan(encs, llm_prof, cluster, shape,
                       objective=objective, schedules=schedules,
                       virtual_chunks=virtual_chunks,
                       frozen_aware=frozen_aware,
                       cp_workload=(bits, pos), cp_method=cp_method)
