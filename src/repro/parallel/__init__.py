"""repro.parallel — the single typed parallelization API surface.

    from repro.parallel import (ClusterSpec, WorkloadShape, parallelize)
    plan = parallelize(mllm, ClusterSpec(8, cp_size=8),
                       WorkloadShape(text_len=1024))
    plan.save("plan.json")              # launch scripts / cached searches
    executor = plan.apply(mllm)         # one-stage-per-device contract

See ``docs/api.md`` for the full tour. ``plan`` holds the data model
(:class:`MLLMParallelPlan` and its components), ``api`` the search
entrypoints (:func:`parallelize`, :func:`search_plan`,
:func:`plan_context`).
"""
from .plan import (ClusterSpec, ContextPlan,  # noqa: F401
                   MLLMParallelPlan, PLAN_FORMAT_VERSION, SchedulePlan,
                   StagePlan, WorkloadShape, build_executor_plan)
from .api import (OBJECTIVES, mllm_workload_bits,  # noqa: F401
                  parallelize, plan_context, search_plan)
from .spmd import (SPMDProgram, build_spmd_runner,  # noqa: F401
                   compile_spmd_program, mesh_from_plan,
                   reference_dag_loss, run_schedule_spmd,
                   spmd_parity_report, toy_stage_model)
