"""SPMD schedule executor: run a simulated F/B/W timeline on a real
device mesh under ``shard_map``.

``core.modality_parallel.execute_schedule`` replays a schedule's item
timeline sequentially in one process — real stage computations, real
VJPs, an instrumented activation store — but never crosses a device
boundary. This module is the distributed counterpart: the same
timeline, compiled to a static SPMD program and executed under
``shard_map`` on a named mesh, with every stage handoff (forward
activation, backward cotangent) carried by ``lax.ppermute``.

Compilation (``compile_spmd_program``) turns the timeline into
**waves**: a wave holds at most one work item per device (devices
whose next item is not yet dependency-ready sit the wave out — that
is the pipeline bubble, now visible as an idle branch), and each wave
boundary carries the activations/cotangents the wave just produced as
one or more ppermute **rounds** (a round is a partial permutation:
distinct sources, distinct destinations; fan-in DAGs that route two
encoder outputs to the same LLM device in one boundary simply take two
rounds). The compiled program is plain data — ``repro.analysis.
schedlint.lint_spmd_program`` statically checks the *emitted* rounds
(freshness, delivery-before-use, permutation validity) rather than the
timeline model.

Execution (``run_schedule_spmd`` / ``build_spmd_runner``) keeps a
fixed-shape local state per device — an ``[L, M]``-slot activation
store with a boolean occupancy mask (the *measured* container, exactly
like ``execute_schedule``'s dict store), an inbox accumulating fan-in
partial sums, a cotangent accumulator for fan-out stages, W-residual
slots for deferred weight-grad passes — and steps through the waves
with a steady-state rolled loop: a ``lax.fori_loop`` over a compacted
instruction table dispatching one ``lax.switch`` over *distinct*
``(kind, stage)`` branches, so compile time scales with the number of
distinct instructions rather than timeline length (the fully-unrolled
``dispatch="switch"`` baseline is kept for comparison). Stage fns may
be real-model per-stage callables (``models.stages.build_mllm_stages``
— heterogeneous params travel as a replicated list with psum-reduced
grads) or a single homogeneous callable. Loss and outputs are
``psum``-reduced over the pipeline axis; per-item occupancy is written
into a trace buffer and reassembled host-side into the same
``activation_trace`` format ``execute_schedule`` returns, so
``core.schedule.memory.validate_schedule_memory`` (and
``MemoryModelMismatch.first_divergence``) work unchanged on the
distributed path.

The mesh may carry extra axes (``cp``, ``dp``): every spec here names
only the pipeline axis, so the program replicates over the others and
composes with ``repro.training.steps.make_cp_train_step`` on a single
``("pp", "cp")`` (or ``("pp", "cp", "dp")``) mesh — one plan JSON
drives PP x CP x DP end to end.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.schedule.graph import PipelineGraph
from repro.core.schedule.simulator import Item, item_id


# ---------------------------------------------------------------------------
# Compiled program data model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Transfer:
    """One cross-device handoff: the value item (kind', src_stage, m)
    just produced, shipped src_dev -> dst_dev for stage ``dst_stage``.
    ``kind`` is "fwd" (activation, F -> consumer F) or "bwd"
    (cotangent, B -> predecessor B)."""
    kind: str
    src_dev: int
    dst_dev: int
    src_stage: int
    dst_stage: int
    microbatch: int


@dataclasses.dataclass
class CommRound:
    """One ``lax.ppermute`` call at a wave boundary. Sources and
    destinations are distinct within a round (a partial permutation —
    the ppermute contract)."""
    kind: str                        # "fwd" | "bwd"
    transfers: List[Transfer]

    @property
    def pairs(self) -> List[Tuple[int, int]]:
        return [(t.src_dev, t.dst_dev) for t in self.transfers]


@dataclasses.dataclass
class Wave:
    """At most one work item per device, then the boundary's comm
    rounds. ``compute`` maps device -> (item_index, kind, stage,
    local_chunk, microbatch)."""
    compute: Dict[int, Tuple[int, str, int, int, int]]
    rounds: List[CommRound]


@dataclasses.dataclass
class SPMDProgram:
    """A timeline compiled for ``shard_map`` execution (plain data —
    what ``schedlint.lint_spmd_program`` validates)."""
    graph: PipelineGraph
    items: List[Item]
    device_of: List[int]
    num_devices: int
    hosted: List[List[int]]          # device -> hosted stages (asc)
    chunk_of: List[int]              # stage -> local chunk slot
    max_chunks: int                  # L: store slots per device
    waves: List[Wave]
    has_w_items: bool

    def counts(self) -> Dict[str, int]:
        return {"waves": len(self.waves),
                "rounds": sum(len(w.rounds) for w in self.waves),
                "items": len(self.items),
                "devices": self.num_devices}


# ---------------------------------------------------------------------------
# Compilation: timeline -> waves + ppermute rounds
# ---------------------------------------------------------------------------

def compile_spmd_program(graph: PipelineGraph,
                         sim: Dict[str, Any]) -> SPMDProgram:
    """Compile a simulation dict (``items`` + ``device_of``) into an
    :class:`SPMDProgram`.

    Wave placement is the earliest level consistent with (a) one item
    per device per wave and (b) every dependency — producer F for a
    consumer F, consumer B (and own F) for a producer B, own B for a W
    — sitting in a strictly earlier wave, so its boundary transfer has
    already been delivered. Items are walked in timeline order, which
    the simulator guarantees is dependency-respecting; a malformed
    timeline (tested deliberately) still compiles and is caught by
    ``lint_spmd_program`` or by the executor's measured trace.
    """
    items = list(sim["items"])
    device_of = list(sim["device_of"])
    S = len(graph.stages)
    D = int(sim["num_devices"])
    preds, succs = graph.preds, graph.succs

    hosted = [[s for s in range(S) if device_of[s] == d] for d in range(D)]
    chunk_of = [hosted[device_of[s]].index(s) for s in range(S)]
    L = max(1, max((len(h) for h in hosted), default=1))

    # a stage that needs a cotangent must get one: from being a sink,
    # or from at least one successor that computes input grads — the
    # same invariant execute_schedule asserts per item, checked once
    for s in range(S):
        st = graph.stages[s]
        if st.bwd_b <= 0 and st.bwd_w <= 0:
            continue
        if succs[s] and not any(graph.stages[q].bwd_b > 0
                                for q in succs[s]):
            raise ValueError(
                f"stage {s} has backward work (bwd_b={st.bwd_b}, "
                f"bwd_w={st.bwd_w}) but no successor produces its "
                f"cotangent (all succs have bwd_b == 0)")

    waves: List[Wave] = []
    placed: Dict[Tuple[str, int, int], int] = {}
    last_wave = [-1] * D
    has_w = any(it[3] == "W" for it in items)

    def wave_at(w: int) -> Wave:
        while len(waves) <= w:
            waves.append(Wave(compute={}, rounds=[]))
        return waves[w]

    def add_transfer(w: int, t: Transfer) -> None:
        for r in wave_at(w).rounds:
            if r.kind != t.kind:
                continue
            if t.src_dev in (x.src_dev for x in r.transfers):
                continue
            if t.dst_dev in (x.dst_dev for x in r.transfers):
                continue
            r.transfers.append(t)
            return
        wave_at(w).rounds.append(CommRound(kind=t.kind, transfers=[t]))

    for i, it in enumerate(items):
        _s0, _e0, dev, kind, s, m = it
        if kind == "F":
            deps = [("F", p, m) for p in preds[s]]
        elif kind == "B":
            deps = [("F", s, m)] + [("B", q, m) for q in succs[s]]
        else:
            deps = [("B", s, m)]
        w = 1 + max([last_wave[dev]]
                    + [placed.get(k, -1) for k in deps])
        wave_at(w).compute[dev] = (i, kind, s, chunk_of[s], m)
        placed[(kind, s, m)] = w
        last_wave[dev] = w
        if kind == "F":
            for q in succs[s]:
                if device_of[q] != dev:
                    add_transfer(w, Transfer("fwd", dev, device_of[q],
                                             s, q, m))
        elif kind == "B" and graph.stages[s].bwd_b > 0:
            for p in preds[s]:
                if device_of[p] != dev:
                    add_transfer(w, Transfer("bwd", dev, device_of[p],
                                             s, p, m))

    return SPMDProgram(graph=graph, items=items, device_of=device_of,
                       num_devices=D, hosted=hosted, chunk_of=chunk_of,
                       max_chunks=L, waves=waves, has_w_items=has_w)


# ---------------------------------------------------------------------------
# Execution under shard_map
# ---------------------------------------------------------------------------

def default_mesh(num_devices: int, axis_name: str = "pp",
                 devices: Optional[Sequence[Any]] = None) -> Mesh:
    """A 1-D mesh over the first ``num_devices`` host devices. Raises
    with the XLA_FLAGS hint when the process has too few."""
    devs = list(devices if devices is not None else jax.devices())
    if len(devs) < num_devices:
        raise ValueError(
            f"SPMD program needs {num_devices} devices but the process "
            f"has {len(devs)}; set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={num_devices} (before importing jax) or run "
            f"on a larger mesh")
    return Mesh(np.array(devs[:num_devices]), (axis_name,))


def mesh_from_plan(plan: Any, mllm: Any, num_devices: int,
                   axis_name: str = "pp") -> Mesh:
    """Build the pipeline mesh for a plan using ``split_devices`` for
    stage -> device placement: physical devices are handed out per
    module (encoders in sorted order, then the LLM), and the mesh takes
    them in that order — so mesh position d is exactly the device the
    plan's stage/device split assigned to pipeline rank d."""
    from repro.core.modality_parallel import split_devices
    split = split_devices(mllm, jax.devices(), plan)
    flat = [d for name in sorted(mllm.encoders) for d in split[name]]
    flat += list(split["llm"])
    return default_mesh(num_devices, axis_name, devices=flat)


def toy_stage_model(num_stages: int, d_model: int, seed: int = 0):
    """The residual toy stage the memory-validation harness uses
    (``x + tanh(x W)``, one weight per stage) — same seeding, so SPMD
    runs are directly comparable against ``validate_schedule_memory``
    and ``execute_schedule`` fixtures."""
    key = jax.random.PRNGKey(seed)
    stage_params = {"w": jax.random.normal(
        key, (num_stages, d_model, d_model)) * 0.1}

    def stage_fn(lp, x):
        return x + jnp.tanh(x @ lp["w"])

    return stage_fn, stage_params


def _stack_local(program: SPMDProgram, stage_params: Any) -> Any:
    """Stage-stacked [S, ...] params -> device/chunk-stacked
    [D, L, ...] (devices hosting fewer than L chunks get zero pads that
    no branch ever touches)."""
    def one(a):
        rows = []
        for d in range(program.num_devices):
            row = [a[s] for s in program.hosted[d]]
            row += [jnp.zeros_like(a[0])] * (program.max_chunks - len(row))
            rows.append(jnp.stack(row))
        return jnp.stack(rows)
    return jax.tree.map(one, stage_params)


def _unstack_grads(program: SPMDProgram, grads_dl: Any) -> Any:
    """[D, L, ...] per-device grads back to stage-stacked [S, ...]."""
    S = len(program.graph.stages)

    def one(a):
        return jnp.stack([a[program.device_of[s], program.chunk_of[s]]
                          for s in range(S)])
    return jax.tree.map(one, grads_dl)


def _rolled_tables(prog: SPMDProgram):
    """Compact the wave timeline into per-wave instruction tables.

    Distinct instructions are ``(kind, stage)`` pairs — the device and
    chunk are static per stage, the microbatch and item index are
    traced table lookups — so the rolled dispatch loop traces each
    stage branch ONCE regardless of timeline length."""
    D, W = prog.num_devices, len(prog.waves)
    keys: List[Tuple[str, int]] = []
    key_of: Dict[Tuple[str, int], int] = {}
    instr = np.zeros((W, D), np.int32)       # 0 = idle
    m_tab = np.zeros((W, D), np.int32)
    item_tab = np.zeros((W, D), np.int32)
    for w, wave in enumerate(prog.waves):
        for d, (i, kind, s, _c, m) in wave.compute.items():
            k = (kind, s)
            if k not in key_of:
                key_of[k] = len(keys) + 1
                keys.append(k)
            instr[w, d] = key_of[k]
            m_tab[w, d] = m
            item_tab[w, d] = i
    R = max((len(wv.rounds) for wv in prog.waves), default=0)
    comm = None
    if R:
        on = np.zeros((W, R, D), bool)
        src = np.zeros((W, R, D), np.int32)
        c_tab = np.zeros((W, R, D), np.int32)
        m2 = np.zeros((W, R, D), np.int32)
        isb = np.zeros((W, R), bool)
        for w, wave in enumerate(prog.waves):
            for r, rnd in enumerate(wave.rounds):
                isb[w, r] = rnd.kind == "bwd"
                for t in rnd.transfers:
                    on[w, r, t.dst_dev] = True
                    src[w, r, t.dst_dev] = t.src_dev
                    c_tab[w, r, t.dst_dev] = prog.chunk_of[t.dst_stage]
                    m2[w, r, t.dst_dev] = t.microbatch
        comm = (on, src, c_tab, m2, isb)
    return keys, instr, m_tab, item_tab, R, comm


def build_spmd_runner(stage_fn, graph: PipelineGraph,
                      sim: Dict[str, Any], *,
                      mesh: Optional[Mesh] = None,
                      axis_name: str = "pp",
                      microbatch_loss: Optional[Callable] = None,
                      program: Optional[SPMDProgram] = None,
                      jit: bool = True,
                      trainable: Optional[Sequence[bool]] = None,
                      dispatch: str = "rolled") -> Callable:
    """Compile the schedule once and return
    ``runner(stage_params, microbatches) -> result dict`` with the same
    contract as ``execute_schedule`` (outputs, loss, param_grads,
    per-device peaks, activation_trace). The shard_map core is jitted
    (cached across calls) — this is what ``make_spmd_train_step``
    builds per training run.

    ``stage_fn`` follows ``execute_schedule``'s contract: one callable
    or a per-stage list, 2-arg ``fn(lp, x)`` or 3-arg
    ``fn(lp, x, microbatch)`` (``models.stages.StageBundle.stage_fns``).
    ``stage_params`` may be stage-stacked (homogeneous stages, sharded
    ``[D, L, ...]`` per device) or a list of per-stage trees
    (heterogeneous real-model stages; replicated, grads psum-reduced —
    ``param_grads`` then comes back as a matching list). ``trainable``
    has ``execute_schedule``'s semantics (stages that must produce
    weight grads even with ``bwd_w == 0``).

    ``dispatch`` selects the wave-stepping strategy:

    * ``"rolled"`` (default): a ``lax.fori_loop`` over waves indexing a
      compacted instruction table, with one ``lax.switch`` over
      *distinct* ``(kind, stage)`` branches and table-driven
      ``all_gather`` comm rounds — compile time scales with distinct
      instructions, not timeline length.
    * ``"switch"``: the original fully-unrolled one-``lax.switch``-per-
      wave program with per-round ``ppermute`` — retraces every wave;
      kept as the compile-time baseline (see
      ``benchmarks/bench_spmd_train.py``).

    Both dispatch modes execute the exact same per-item updates in the
    same order — identical loss, grads, occupancy trace, and peaks.
    """
    from repro.core.modality_parallel import normalize_stage_fns
    prog = program if program is not None else \
        compile_spmd_program(graph, sim)
    if mesh is None:
        mesh = default_mesh(prog.num_devices, axis_name)
    if mesh.shape[axis_name] != prog.num_devices:
        raise ValueError(
            f"mesh axis {axis_name!r} has {mesh.shape[axis_name]} "
            f"devices but the program was compiled for "
            f"{prog.num_devices}")
    if dispatch not in ("rolled", "switch"):
        raise ValueError(f"unknown dispatch {dispatch!r}")
    loss_fn = microbatch_loss or (lambda y: jnp.mean(y ** 2))
    S = len(graph.stages)
    D, L = prog.num_devices, prog.max_chunks
    device_of, chunk_of = prog.device_of, prog.chunk_of
    preds, succs = graph.preds, graph.succs
    n_items = len(prog.items)
    has_w = prog.has_w_items
    fns = normalize_stage_fns(stage_fn, S)
    if trainable is None:
        trainable = [graph.stages[s].bwd_w > 0 for s in range(S)]
    trainable = [bool(t) for t in trainable]
    for s in range(S):
        # same reachability invariant compile_spmd_program checks for
        # bwd-costed stages, extended to the trainable override: a
        # trainable stage must receive a cotangent from somewhere
        if trainable[s] and succs[s] and not any(
                graph.stages[q].bwd_b > 0 for q in succs[s]):
            raise ValueError(
                f"stage {s} is trainable but no successor produces its "
                f"cotangent (all succs have bwd_b == 0)")

    def core(local_params, mbs, hetero=False):
        M = mbs.shape[0]
        xshape, xdtype = mbs.shape[1:], mbs.dtype
        loss_dtype = jax.eval_shape(
            loss_fn, jax.ShapeDtypeStruct(xshape, xdtype)).dtype

        def body(local_params, mbs):
            if hetero:
                params_t = local_params          # tuple of stage trees
            else:
                lp = jax.tree.map(lambda a: a[0], local_params)  # [L,...]
            idx = lax.axis_index(axis_name)
            if hetero:
                zgrads = tuple(jax.tree.map(jnp.zeros_like, p)
                               for p in params_t)
            else:
                zgrads = jax.tree.map(jnp.zeros_like, lp)
            state = {
                "x": jnp.zeros((L, M) + xshape, xdtype),
                "used": jnp.zeros((L, M), jnp.bool_),
                "inbox": jnp.zeros((L, M) + xshape, xdtype),
                "cot": jnp.zeros((L, M) + xshape, xdtype),
                "grads": zgrads,
                "loss": jnp.zeros((), loss_dtype),
                "out": jnp.zeros((M,) + xshape, xdtype),
                "fy": jnp.zeros(xshape, xdtype),
                "bg": jnp.zeros(xshape, xdtype),
                "occ": jnp.zeros((n_items,), jnp.int32),
                "wocc": jnp.zeros((n_items,), jnp.int32),
            }
            if has_w:
                state["wx"] = jnp.zeros((L, M) + xshape, xdtype)
                state["wg"] = jnp.zeros((L, M) + xshape, xdtype)
                state["wused"] = jnp.zeros((L, M), jnp.bool_)

            def idle(st, m, i):
                return st

            def add_grads(st, s, c, gp):
                if hetero:
                    gl = list(st["grads"])
                    gl[s] = jax.tree.map(jnp.add, gl[s], gp)
                    st["grads"] = tuple(gl)
                else:
                    st["grads"] = jax.tree.map(
                        lambda G, dG: G.at[c].add(dG), st["grads"], gp)
                return st

            def make_branch(kind, s):
                # device/chunk are static per stage; the microbatch and
                # item index are traced (rolled table lookups)
                dev, c = device_of[s], chunk_of[s]
                stg = graph.stages[s]
                prs, sucs = preds[s], succs[s]

                def br(st, m, i):
                    st = dict(st)
                    if hetero:
                        lpc = params_t[s]
                    else:
                        lpc = jax.tree.map(lambda a: a[c], lp)
                    mb = mbs[m]
                    if kind == "F":
                        x = st["inbox"][c, m] if prs else mb
                        st["x"] = st["x"].at[c, m].set(x)
                        st["used"] = st["used"].at[c, m].set(True)
                        y = fns[s](lpc, x, mb)
                        if not sucs:             # sink: loss + cotangent
                            st["out"] = st["out"].at[m].add(y)
                            st["loss"] = st["loss"] + loss_fn(y)
                            st["cot"] = st["cot"].at[c, m].add(
                                jax.grad(loss_fn)(y))
                        else:
                            st["fy"] = y
                            for q in sucs:
                                if device_of[q] == dev:
                                    st["inbox"] = st["inbox"].at[
                                        chunk_of[q], m].add(y)
                    elif kind == "B":
                        x = st["x"][c, m]
                        st["used"] = st["used"].at[c, m].set(False)
                        g = st["cot"][c, m]
                        st["cot"] = st["cot"].at[c, m].set(
                            jnp.zeros(xshape, xdtype))
                        if stg.bwd_b > 0 and prs:
                            _, vjp_x = jax.vjp(
                                lambda xx: fns[s](lpc, xx, mb), x)
                            (dx,) = vjp_x(g)
                            st["bg"] = dx
                            for p in prs:
                                if device_of[p] == dev:
                                    st["cot"] = st["cot"].at[
                                        chunk_of[p], m].add(dx)
                        if trainable[s]:
                            # park for a deferred W only if the schedule
                            # emitted one; a trainable stage the cost
                            # model sees as weight-free glues here
                            if has_w and stg.bwd_w > 0:
                                st["wx"] = st["wx"].at[c, m].set(x)
                                st["wg"] = st["wg"].at[c, m].set(g)
                                st["wused"] = st["wused"].at[
                                    c, m].set(True)
                            else:                # glued: weight grads now
                                _, vjp_p = jax.vjp(
                                    lambda pw: fns[s](pw, x, mb), lpc)
                                (gp,) = vjp_p(g)
                                st = add_grads(st, s, c, gp)
                    else:                        # W
                        x = st["wx"][c, m]
                        g = st["wg"][c, m]
                        st["wused"] = st["wused"].at[c, m].set(False)
                        if trainable[s]:
                            _, vjp_p = jax.vjp(
                                lambda pw: fns[s](pw, x, mb), lpc)
                            (gp,) = vjp_p(g)
                            st = add_grads(st, s, c, gp)
                    st["occ"] = st["occ"].at[i].set(
                        jnp.sum(st["used"]).astype(jnp.int32))
                    if has_w:
                        st["wocc"] = st["wocc"].at[i].set(
                            jnp.sum(st["wused"]).astype(jnp.int32))
                    return st
                return br

            if dispatch == "rolled":
                keys, instr, m_tab, item_tab, R, comm = \
                    _rolled_tables(prog)
                branches = [idle] + [make_branch(k, s) for k, s in keys]
                instr_a = jnp.asarray(instr)
                m_a = jnp.asarray(m_tab)
                item_a = jnp.asarray(item_tab)
                if R:
                    on_t, src_t, c_t, m2_t, isb_t = comm
                    on_a, src_a = jnp.asarray(on_t), jnp.asarray(src_t)
                    c_a, m2_a = jnp.asarray(c_t), jnp.asarray(m2_t)
                    isb_a = jnp.asarray(isb_t)

                def comm_rounds(w, st):
                    def round_body(r, st):
                        st = dict(st)
                        isb = isb_a[w, r]
                        buf = jnp.where(isb, st["bg"], st["fy"])
                        gathered = lax.all_gather(buf, axis_name)
                        recv = gathered[src_a[w, r, idx]]
                        onv = on_a[w, r, idx]
                        cc, mm = c_a[w, r, idx], m2_a[w, r, idx]
                        delta = jnp.where(onv, recv,
                                          jnp.zeros_like(recv))
                        zero = jnp.zeros_like(delta)
                        st["inbox"] = st["inbox"].at[cc, mm].add(
                            jnp.where(isb, zero, delta))
                        st["cot"] = st["cot"].at[cc, mm].add(
                            jnp.where(isb, delta, zero))
                        return st
                    return lax.fori_loop(0, R, round_body, st)

                def wave_body(w, st):
                    st = lax.switch(instr_a[w, idx], branches, st,
                                    m_a[w, idx], item_a[w, idx])
                    if R:
                        st = comm_rounds(w, st)
                    return st

                state = lax.fori_loop(0, len(prog.waves), wave_body,
                                      state)
            else:                                # dispatch == "switch"
                stage_br: Dict[Tuple[str, int], Callable] = {}

                def static_branch(d, instr):
                    i, kind, s, _c, m = instr
                    if (kind, s) not in stage_br:
                        stage_br[(kind, s)] = make_branch(kind, s)
                    br = stage_br[(kind, s)]
                    return lambda st, br=br, m=m, i=i: br(
                        st, jnp.int32(m), jnp.int32(i))

                for wave in prog.waves:
                    branches = [static_branch(d, wave.compute[d])
                                if d in wave.compute
                                else (lambda st: st)
                                for d in range(D)]
                    state = lax.switch(idx, branches, state)
                    for rnd in wave.rounds:
                        buf = state["fy"] if rnd.kind == "fwd" \
                            else state["bg"]
                        recv = lax.ppermute(buf, axis_name, rnd.pairs)
                        on = [False] * D
                        cs = [0] * D
                        ms = [0] * D
                        for t in rnd.transfers:
                            on[t.dst_dev] = True
                            cs[t.dst_dev] = chunk_of[t.dst_stage]
                            ms[t.dst_dev] = t.microbatch
                        c = jnp.asarray(cs)[idx]
                        m = jnp.asarray(ms)[idx]
                        delta = jnp.where(jnp.asarray(on)[idx], recv,
                                          jnp.zeros_like(recv))
                        key = "inbox" if rnd.kind == "fwd" else "cot"
                        state[key] = state[key].at[c, m].add(delta)

            outputs = lax.psum(state["out"], axis_name)
            loss = lax.psum(state["loss"], axis_name)
            if hetero:
                grads = jax.tree.map(
                    lambda a: lax.psum(a, axis_name), state["grads"])
            else:
                grads = jax.tree.map(lambda a: a[None], state["grads"])
            return (outputs, loss, grads,
                    state["occ"][None], state["wocc"][None])

        if hetero:
            spec_p = jax.tree.map(
                lambda a: P(*([None] * a.ndim)), local_params)
            grads_spec = spec_p
        else:
            spec_p = jax.tree.map(
                lambda a: P(axis_name, *([None] * (a.ndim - 1))),
                local_params)
            grads_spec = spec_p
        return shard_map(
            body, mesh=mesh,
            in_specs=(spec_p, P(*([None] * mbs.ndim))),
            out_specs=(P(*([None] * mbs.ndim)), P(), grads_spec,
                       P(axis_name, None), P(axis_name, None)),
            check_rep=False,
        )(local_params, mbs)

    core_fn = jax.jit(core, static_argnames=("hetero",)) if jit else core

    def prepare(stage_params):
        """Raw stage params -> the representation ``core`` consumes
        (list of trees pass through; stacked trees go device-local)."""
        if isinstance(stage_params, (list, tuple)):
            return tuple(stage_params)
        return _stack_local(prog, stage_params)

    def finish_grads(grads_repr):
        """``core``'s grads output -> ``execute_schedule``'s
        ``param_grads`` shape (list for hetero, stage-stacked else)."""
        if isinstance(grads_repr, tuple):
            return list(grads_repr)
        return _unstack_grads(prog, grads_repr)

    def runner(stage_params, microbatches):
        hetero = isinstance(stage_params, (list, tuple))
        local = prepare(stage_params)
        outputs, loss, grads_repr, occ, wocc = core_fn(
            local, microbatches, hetero=hetero)
        occ_np = np.asarray(occ)
        wocc_np = np.asarray(wocc)
        trace = [(item_id(it), it[2], int(occ_np[it[2], i]))
                 for i, it in enumerate(prog.items)]
        peak = [0] * D
        w_peak = [0] * D
        for i, it in enumerate(prog.items):
            dev = it[2]
            peak[dev] = max(peak[dev], int(occ_np[dev, i]))
            w_peak[dev] = max(w_peak[dev], int(wocc_np[dev, i]))
        nbytes = int(np.prod(microbatches.shape[1:])
                     * microbatches.dtype.itemsize)
        return {
            "outputs": outputs,
            "loss": loss,
            "param_grads": finish_grads(grads_repr),
            "peak_activations_per_device": peak,
            "peak_w_residuals_per_device": w_peak,
            "activation_trace": trace,
            "activation_nbytes": nbytes,
            "program": prog,
        }

    # expose the pieces make_resilient_train_step's value_and_grad hook
    # needs to keep everything inside one outer jit
    runner.program = prog
    runner.core = core_fn
    runner.prepare = prepare
    runner.finish_grads = finish_grads
    return runner


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def _is_typed_plan(obj: Any) -> bool:
    from repro.parallel.plan import MLLMParallelPlan
    return isinstance(obj, MLLMParallelPlan)


def run_schedule_spmd(*args: Any, mesh: Optional[Mesh] = None,
                      axis_name: str = "pp",
                      microbatch_loss: Optional[Callable] = None,
                      program: Optional[SPMDProgram] = None,
                      stage_fn: Any = None,
                      stage_params: Any = None,
                      trainable: Optional[Sequence[bool]] = None,
                      dispatch: str = "rolled",
                      seed: int = 0) -> Dict[str, Any]:
    """Execute a schedule timeline distributed under ``shard_map``.

    Two call forms, mirroring ``execute_schedule``'s contract:

    * ``run_schedule_spmd(stage_fn, stage_params, microbatches, graph,
      sim)`` — the core form: explicit stage callables and a simulation
      dict (``items`` + ``device_of``).
    * ``run_schedule_spmd(plan, mllm, microbatches)`` — the plan form:
      an :class:`~repro.parallel.plan.MLLMParallelPlan` is applied to
      ``mllm`` in SPMD mode (``plan.apply(mllm, mode="spmd")``), the
      mesh is derived from ``split_devices`` placement. ``stage_fn``
      selects what runs the timeline: real stage callables (e.g.
      ``models.stages`` bundle fns, with matching ``stage_params``), or
      the explicit sentinel ``stage_fn="toy"`` for the toy residual
      stage model sized to the microbatches' feature dim (the model the
      memory-validation harness uses — module profiles are cost models,
      not callables). Passing ``stage_fn=None`` still falls back to the
      toy model but warns: real-model callers must opt in explicitly so
      they cannot accidentally verify the wrong model.

    Returns the ``execute_schedule`` result dict (outputs, loss,
    param_grads, per-device peaks, activation_trace) plus the compiled
    ``program``.
    """
    if _is_typed_plan(args[0]):
        plan, mllm, microbatches = args
        executor = plan.apply(mllm, mode="spmd")
        graph = executor["sim_graph"]
        sim = executor["schedule"]
        prog = program if program is not None \
            else executor.get("spmd_program")
        if mesh is None:
            mesh = mesh_from_plan(plan, mllm, int(sim["num_devices"]),
                                  axis_name)
        if stage_fn is None or stage_fn == "toy":
            if stage_fn is None:
                import warnings
                warnings.warn(
                    "run_schedule_spmd(plan, mllm, ...) got no "
                    "stage_fn and will run the TOY stage model, not "
                    "the MLLM; pass stage_fn=\"toy\" to silence this, "
                    "or real stage fns (models.stages.build_mllm_"
                    "stages) to execute the model", stacklevel=2)
            stage_fn, stage_params = toy_stage_model(
                len(graph.stages), int(microbatches.shape[-1]),
                seed=seed)
    else:
        stage_fn, stage_params, microbatches, graph, sim = args
        prog = program
    runner = build_spmd_runner(stage_fn, graph, sim, mesh=mesh,
                               axis_name=axis_name,
                               microbatch_loss=microbatch_loss,
                               program=prog, trainable=trainable,
                               dispatch=dispatch)
    return runner(stage_params, microbatches)


def spmd_parity_report(executor: Dict[str, Any], *, d_model: int = 16,
                       seq: int = 4, seed: int = 0,
                       mesh: Optional[Mesh] = None,
                       axis_name: str = "pp") -> Dict[str, Any]:
    """Run one executor contract's timeline on BOTH executors — the
    distributed shard_map program and the sequential replay — with the
    toy residual stage model, and report the parity: losses, the max
    elementwise grad difference, whether the measured per-device peaks
    and activation traces agree. The cheap end-to-end proof that a
    plan's compiled SPMD program computes what its timeline claims
    (the memory-validation harness and tests use it; ``launch/train
    --spmd`` itself trains the real partitioned model)."""
    from repro.core.modality_parallel import execute_schedule
    graph = executor["sim_graph"]
    sim = executor["schedule"]
    prog = executor.get("spmd_program")
    stage_fn, stage_params = toy_stage_model(
        len(graph.stages), d_model, seed=seed)
    M = max(int(it[5]) for it in sim["items"]) + 1
    microbatches = jax.random.normal(
        jax.random.fold_in(jax.random.PRNGKey(seed), 1),
        (M, 1, seq, d_model))
    got = run_schedule_spmd(stage_fn, stage_params, microbatches,
                            graph, sim, mesh=mesh, axis_name=axis_name,
                            program=prog)
    ref = execute_schedule(stage_fn, stage_params, microbatches,
                           graph, sim)
    diffs = jax.tree.leaves(jax.tree.map(
        lambda a, b: jnp.max(jnp.abs(a - b)),
        got["param_grads"], ref["param_grads"]))
    return {
        "loss_spmd": float(got["loss"]),
        "loss_replay": float(ref["loss"]),
        "max_grad_diff": max(float(d) for d in diffs),
        "peaks_match": (got["peak_activations_per_device"]
                        == ref["peak_activations_per_device"]),
        "trace_match": (got["activation_trace"]
                        == ref["activation_trace"]),
        "program": got["program"].counts(),
    }


def reference_dag_loss(stage_fn: Callable, stage_params: Any,
                       microbatches: Any, graph: PipelineGraph, *,
                       microbatch_loss: Optional[Callable] = None
                       ) -> Tuple[Any, Any]:
    """Single-device autodiff oracle for any stage DAG: compose the
    stages in topological order (sources read the microbatch, fan-in
    sums predecessor outputs, the loss sums over sinks), take
    ``jax.value_and_grad`` — the ``make_train_step``-equivalent both
    executors must match. Returns (loss, stage-stacked grads)."""
    loss_fn = microbatch_loss or (lambda y: jnp.mean(y ** 2))
    S = len(graph.stages)
    preds, succs = graph.preds, graph.succs

    def total_loss(params):
        loss = jnp.zeros((), jnp.float32)
        for m in range(microbatches.shape[0]):
            ys: Dict[int, Any] = {}
            for s in range(S):                   # stages are topo-ordered
                lp = jax.tree.map(lambda a: a[s], params)
                x = microbatches[m] if not preds[s] else \
                    sum(ys[p] for p in preds[s])
                ys[s] = stage_fn(lp, x)
            for s in range(S):
                if not succs[s]:
                    loss = loss + loss_fn(ys[s])
        return loss

    # stop_gradient semantics of frozen stages: the schedule encodes
    # them as bwd_w == 0, which the executors honor by never running a
    # weight-grad VJP; the oracle masks the autodiff grads to match
    loss, grads = jax.value_and_grad(total_loss)(stage_params)
    mask = jnp.asarray([graph.stages[s].bwd_w > 0 for s in range(S)])
    grads = jax.tree.map(
        lambda g: jnp.where(
            mask.reshape((S,) + (1,) * (g.ndim - 1)), g,
            jnp.zeros_like(g)), grads)
    return loss, grads
