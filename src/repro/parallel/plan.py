"""Typed parallelization plans: the single object every launch path
shares.

Cornstarch's user-facing contribution is ONE plugin-style API that
jointly decides frozen-aware pipeline parallelism (§4.2, Algorithm 1)
and token-workload-balanced context parallelism (§4.3) for a
heterogeneous MLLM. This module is that API's data model:

    MLLMParallelPlan
    ├── StagePlan      per-module pipeline stage/device assignment
    │                  (the Algorithm-1 partition decision)
    ├── SchedulePlan   pipeline schedule name + virtual-chunk count +
    │                  the simulator's verdict (iteration time, bubble,
    │                  per-device peak activations)
    └── ContextPlan    CP balancer choice + block->rank assignment
                       (wraps core.distribution.Plan)

plus the typed inputs (:class:`ClusterSpec`, :class:`WorkloadShape`)
consumed by :func:`repro.parallel.api.parallelize`.

Plans are *plain data*: frozen dataclasses of tuples/ints/floats/strs
that round-trip losslessly through ``to_json()`` / ``from_json()`` (for
launch scripts and cached searches) and compare by value, so a golden
plan recorded under ``tests/data/`` pins the search's behavior.

``plan.apply(mllm)`` turns a plan back into the executor contract the
runtime consumes (the role ``MultimodalParallelSpec.apply`` used to
play): it re-partitions the module profiles at the planned stage
counts, re-simulates the pinned (schedule, virtual_chunks) pair, and
returns a dict whose ``"graph"`` always has one stage per device —
chunked schedules keep their finer simulation for bubble accounting
but fold the executor graph back to the planned partition.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core import distribution as dist
from repro.core import pipeline as pp
from repro.core.schedule import SCHEDULES

PLAN_FORMAT_VERSION = 1


# ---------------------------------------------------------------------------
# Typed inputs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """The device budget a plan is searched against.

    num_devices: pipeline ranks available to Algorithm 1 (each planned
        stage occupies one).
    cp_size: context-parallel ranks the token workload is balanced
        over (1 = no CP; the ContextPlan is still computed so the
        makespan/imbalance figures are reportable).
    """
    num_devices: int
    cp_size: int = 1

    def __post_init__(self):
        assert self.num_devices >= 1 and self.cp_size >= 1, self


@dataclasses.dataclass(frozen=True)
class WorkloadShape:
    """The training workload a plan is searched for."""
    text_len: int = 1024
    num_microbatches: int = 8
    microbatch_size: int = 1
    block_size: int = 128           # CP token-block granularity

    def __post_init__(self):
        assert self.text_len >= 1 and self.num_microbatches >= 1, self
        assert self.microbatch_size >= 1 and self.block_size >= 1, self


# ---------------------------------------------------------------------------
# Plan components
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StagePlan:
    """Per-module pipeline stage counts — one device per stage
    (chunked schedules fold their virtual chunks onto these devices)."""
    encoder_names: Tuple[str, ...]
    encoder_stages: Tuple[int, ...]
    llm_stages: int
    frozen_aware: bool = True

    def __post_init__(self):
        assert len(self.encoder_names) == len(self.encoder_stages), self
        assert self.llm_stages >= 1, self
        assert all(k >= 1 for k in self.encoder_stages), self

    @property
    def num_devices(self) -> int:
        return self.llm_stages + sum(self.encoder_stages)

    def counts_by_name(self) -> Dict[str, int]:
        """{module: stage count} — the mapping ``split_devices``
        consumes."""
        return dict(zip(self.encoder_names, self.encoder_stages))


@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """The winning pipeline schedule and the simulator's verdict on
    it (the numbers Algorithm 1 compared candidates by)."""
    name: str
    virtual_chunks: int
    num_microbatches: int
    iteration_time: float
    bubble_fraction: float
    num_devices: int
    peak_activations_per_device: Tuple[int, ...]
    tput_per_device: float

    def __post_init__(self):
        assert self.name in SCHEDULES, \
            f"unknown schedule {self.name!r}; pick from {SCHEDULES}"
        assert self.virtual_chunks >= 1, self
        assert self.name != "zb-v" or self.virtual_chunks in (1, 2), \
            f"zb-v places two chunks per device; v={self.virtual_chunks}"


@dataclasses.dataclass(frozen=True)
class ContextPlan:
    """Context-parallel token distribution: the chosen balancer and
    its block -> rank assignment (a typed, serializable wrapper over
    ``core.distribution.Plan``)."""
    method: str
    num_ranks: int
    block_size: int
    assignment: Tuple[int, ...]     # block index -> CP rank
    loads: Tuple[float, ...]        # per-rank workload

    def __post_init__(self):
        assert self.method in dist.PLANNERS, \
            f"unknown balancer {self.method!r}; " \
            f"pick from {sorted(dist.PLANNERS)}"
        assert len(self.loads) == self.num_ranks, self

    @classmethod
    def from_core(cls, plan: dist.Plan, method: str) -> "ContextPlan":
        return cls(method=method, num_ranks=plan.num_ranks,
                   block_size=plan.block_size,
                   assignment=tuple(int(a) for a in plan.assignment),
                   loads=tuple(float(l) for l in plan.loads))

    def core_plan(self) -> dist.Plan:
        """The ``core.distribution.Plan`` this wraps (for the CP
        runtime: ``plan_permutation`` / ``apply_plan``)."""
        return dist.Plan(assignment=np.array(self.assignment, np.int32),
                         block_size=self.block_size,
                         num_ranks=self.num_ranks,
                         loads=np.array(self.loads, np.float64))

    @property
    def makespan(self) -> float:
        return max(self.loads)

    @property
    def imbalance(self) -> float:
        mean = sum(self.loads) / len(self.loads)
        return max(self.loads) / mean if mean > 0 else 1.0

    def rank_token_slices(self):
        """Per-rank token index arrays (plan layout)."""
        return self.core_plan().rank_token_slices()

    def apply(self, seq_len: int) -> Dict[str, Any]:
        """CP runtime layout for one merged sequence: the plan's token
        permutation (CP layout <- original; a true permutation of
        ``arange(seq_len)``), its inverse, and the rank count — exactly
        what ``repro.training.steps.make_cp_train_step`` consumes.
        Raises ``ValueError`` if the plan's blocks do not cover
        ``seq_len``."""
        from repro.core import context_parallel as cp
        perm = cp.plan_permutation(self.core_plan(), seq_len)
        return {"perm": perm, "inv_perm": cp.invert_perm(perm),
                "num_ranks": self.num_ranks,
                "block_size": self.block_size}


# ---------------------------------------------------------------------------
# Executor-contract construction (shared by MLLMParallelPlan.apply and
# the deprecated MultimodalParallelSpec.apply)
# ---------------------------------------------------------------------------

def build_executor_plan(encoders: Sequence[pp.ModuleProfile],
                        llm: pp.ModuleProfile,
                        enc_counts: Sequence[int], llm_stages: int,
                        num_microbatches: int, *,
                        schedule: str = "1f1b", virtual_chunks: Any = 2,
                        frozen_aware: bool = True) -> Dict[str, Any]:
    """Partition + simulate one stage allocation and return the
    executor contract: a dict whose ``"graph"`` always has one stage
    per simulated device. Chunked schedules (interleaved, zb-v) may
    win with a v-times finer simulation graph; its bubble accounting
    is kept under ``"schedule"`` while the executor graph folds back
    to the planned one-stage-per-device partition."""
    sim_graph, sim = pp.simulate_plan(
        encoders, llm, enc_counts, llm_stages, num_microbatches,
        schedule=schedule, frozen_aware=frozen_aware,
        virtual_chunks=virtual_chunks)
    graph = sim_graph
    if len(graph.stages) != sim["num_devices"]:
        llm_k = min(llm_stages, len(llm.layer_fwd))
        counts = [min(k, len(e.layer_fwd))
                  for e, k in zip(encoders, enc_counts)]
        graph = pp.build_modality_parallel(
            encoders, llm, counts, llm_k, frozen_aware=frozen_aware)
    return {
        "graph": graph,
        # the (possibly chunk-refined) graph the simulation items'
        # stage indices refer to — what schedlint.lint_executor_contract
        # lints the timeline against
        "sim_graph": sim_graph,
        "encoder_profiles": list(encoders),
        "llm_profile": llm,
        "schedule": sim,
        "schedule_name": sim["schedule"],
        "virtual_chunks": sim["virtual_chunks"],
        "devices": sim["num_devices"],
    }


# ---------------------------------------------------------------------------
# The composed plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLLMParallelPlan:
    """One joint PP x CP parallelization decision for one MLLM and one
    workload — the value :func:`repro.parallel.parallelize` returns
    and every launch path consumes."""
    stage: StagePlan
    schedule: SchedulePlan
    context: Optional[ContextPlan]
    text_len: int
    microbatch_size: int = 1

    # -- serialization -----------------------------------------------------
    def to_json(self, indent: Optional[int] = None) -> str:
        d = {
            "format_version": PLAN_FORMAT_VERSION,
            "stage": dataclasses.asdict(self.stage),
            "schedule": dataclasses.asdict(self.schedule),
            "context": dataclasses.asdict(self.context)
            if self.context is not None else None,
            "workload": {"text_len": self.text_len,
                         "microbatch_size": self.microbatch_size},
        }
        return json.dumps(d, indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "MLLMParallelPlan":
        d = json.loads(s)
        version = d.get("format_version")
        if version != PLAN_FORMAT_VERSION:
            raise ValueError(
                f"unsupported plan format_version {version!r} "
                f"(this build reads {PLAN_FORMAT_VERSION})")
        try:
            st = d["stage"]
            stage = StagePlan(
                encoder_names=tuple(st["encoder_names"]),
                encoder_stages=tuple(int(k) for k in st["encoder_stages"]),
                llm_stages=int(st["llm_stages"]),
                frozen_aware=bool(st["frozen_aware"]))
            sc = d["schedule"]
            schedule = SchedulePlan(
                name=sc["name"],
                virtual_chunks=int(sc["virtual_chunks"]),
                num_microbatches=int(sc["num_microbatches"]),
                iteration_time=float(sc["iteration_time"]),
                bubble_fraction=float(sc["bubble_fraction"]),
                num_devices=int(sc["num_devices"]),
                peak_activations_per_device=tuple(
                    int(p) for p in sc["peak_activations_per_device"]),
                tput_per_device=float(sc["tput_per_device"]))
            cx = d["context"]
            context = None if cx is None else ContextPlan(
                method=cx["method"], num_ranks=int(cx["num_ranks"]),
                block_size=int(cx["block_size"]),
                assignment=tuple(int(a) for a in cx["assignment"]),
                loads=tuple(float(l) for l in cx["loads"]))
            wl = d["workload"]
            return cls(stage=stage, schedule=schedule, context=context,
                       text_len=int(wl["text_len"]),
                       microbatch_size=int(wl["microbatch_size"]))
        except (KeyError, TypeError) as e:
            raise ValueError(f"malformed MLLMParallelPlan JSON: {e}") \
                from e

    def save(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as f:
            f.write(self.to_json(indent=1) + "\n")

    @classmethod
    def load(cls, path: str) -> "MLLMParallelPlan":
        with open(path, encoding="utf-8") as f:
            return cls.from_json(f.read())

    # -- derived views -----------------------------------------------------
    @property
    def pp_devices(self) -> int:
        return self.stage.num_devices

    @property
    def cp_ranks(self) -> int:
        return self.context.num_ranks if self.context is not None else 1

    @property
    def total_devices(self) -> int:
        """Pipeline ranks x CP group size (the full-mesh footprint)."""
        return self.pp_devices * self.cp_ranks

    def stage_counts_by_name(self) -> Dict[str, int]:
        """{module: pipeline stage count} — what ``split_devices``
        consumes to hand out device lists."""
        return self.stage.counts_by_name()

    # -- executor contract -------------------------------------------------
    def apply(self, mllm, text_len: Optional[int] = None, *,
              mode: str = "replay") -> Dict[str, Any]:
        """Instantiate the plan against ``mllm``: re-derive the module
        profiles, partition at the planned stage counts, re-simulate
        the PINNED (schedule, virtual_chunks) pair, and return the
        executor contract (see :func:`build_executor_plan`). Replaces
        ``MultimodalParallelSpec.apply``.

        ``mode="spmd"`` additionally compiles the simulated timeline
        into the shard_map executor's wave/ppermute program
        (:func:`repro.parallel.spmd.compile_spmd_program`) and ships it
        under ``"spmd_program"`` — the artifact
        ``run_schedule_spmd`` executes and ``schedlint.
        lint_spmd_program`` statically validates — plus the real-model
        stage partition under ``"stage_bundle"``
        (:func:`repro.models.stages.build_mllm_stages`): typed
        per-stage callables + params so ``launch/train --spmd`` trains
        the actual MLLM, not a toy stand-in."""
        if mode not in ("replay", "spmd"):
            raise ValueError(
                f"unknown executor mode {mode!r}; pick 'replay' "
                f"(sequential timeline replay) or 'spmd' (shard_map)")
        names = tuple(sorted(mllm.encoders))
        assert names == tuple(sorted(self.stage.encoder_names)), \
            (f"plan was searched for encoders "
             f"{sorted(self.stage.encoder_names)}, "
             f"mllm has {list(names)}")
        encs, llm = mllm.profiles(text_len or self.text_len,
                                  batch=self.microbatch_size)
        counts = self.stage.counts_by_name()
        out = build_executor_plan(
            encs, llm, [counts[e.name] for e in encs],
            self.stage.llm_stages, self.schedule.num_microbatches,
            schedule=self.schedule.name,
            virtual_chunks=(self.schedule.virtual_chunks,),
            frozen_aware=self.stage.frozen_aware)
        out["plan"] = self
        out["context"] = self.context
        if mode == "spmd":
            from repro.models.stages import build_mllm_stages
            from repro.parallel.spmd import compile_spmd_program
            out["spmd_program"] = compile_spmd_program(
                out["sim_graph"], out["schedule"])
            out["stage_bundle"] = build_mllm_stages(
                mllm, out, text_len=text_len or self.text_len)
        return out

    # -- human-readable dump -----------------------------------------------
    def describe(self) -> str:
        lines = [
            f"MLLMParallelPlan (text_len={self.text_len}, "
            f"microbatch_size={self.microbatch_size})",
            f"  stages : llm={self.stage.llm_stages}"
            + "".join(f", {n}={k}" for n, k in
                      zip(self.stage.encoder_names,
                          self.stage.encoder_stages))
            + f"  ({self.stage.num_devices} pipeline ranks, "
            f"frozen_aware={self.stage.frozen_aware})",
            f"  sched  : {self.schedule.name} "
            f"(v={self.schedule.virtual_chunks}, "
            f"microbatches={self.schedule.num_microbatches}) "
            f"bubble={self.schedule.bubble_fraction:.3f} "
            f"peak_act={list(self.schedule.peak_activations_per_device)}",
        ]
        if self.context is not None:
            c = self.context
            lines.append(
                f"  cp     : {c.method} over {c.num_ranks} ranks "
                f"(block={c.block_size}, blocks={len(c.assignment)}) "
                f"imbalance={c.imbalance:.3f}")
        else:
            lines.append("  cp     : none")
        lines.append(f"  devices: {self.pp_devices} pp x "
                     f"{self.cp_ranks} cp = {self.total_devices}")
        return "\n".join(lines)
