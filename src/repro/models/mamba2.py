"""Mamba2 (SSD) blocks + the zamba2-style hybrid backbone.

Mamba2 (arXiv:2405.21060 semantics; zamba2 arXiv:2411.15242 structure):
state-space recurrence per head

    h_t = a_t · h_{t-1} + dt_t · (B_t ⊗ x_t)        a_t = exp(-exp(A_log)·dt_t)
    y_t = C_t · h_t + D · x_t

Training uses the chunkwise-parallel SSD algorithm: quadratic
attention-like compute *within* chunks of length ``cfg.ssm.chunk`` and a
``lax.scan`` carrying the inter-chunk state — the standard TPU-friendly
formulation (MXU matmuls inside chunks, O(T) state flow across).

zamba2 hybrid structure: ``num_layers`` Mamba2 blocks; after every
``cfg.attn_layer_period`` blocks, one **shared** full-attention
transformer block (single weight set reused at every application —
zamba2's parameter-sharing trick) is applied. Decode keeps one KV cache
slot per shared-block *application* plus per-layer SSM/conv states —
total state is O(L·d·d_state), which is what makes the hybrid legal for
``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core import bam
from repro.models import layers as L
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _conv_channels(cfg: ModelConfig) -> int:
    s = cfg.ssm
    return s.d_inner(cfg.d_model) + 2 * s.d_state


def mamba_layer_init(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    ks = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * s.d_state + nh   # z, x, B, C, dt
    return {
        "ln": L.norm_init(cfg, d, dtype),
        "in_proj": L.dense_init(ks[0], d, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, _conv_channels(cfg)))
                   * 0.02).astype(dtype),
        "conv_b": jnp.zeros((_conv_channels(cfg),), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),   # a = exp(-exp(A_log)·dt)
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gate_ln": L.norm_init(cfg, di, dtype),
        "out_proj": L.dense_init(ks[2], di, d, dtype),
    }


def shared_attn_init(key, cfg: ModelConfig, dtype):
    """The zamba2 shared transformer block (attention + MLP)."""
    return T._layer_init(key, cfg, dtype)


def init(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    k_embed, k_layers, k_shared, k_out = jax.random.split(key, 4)
    params = {
        "embed": L.embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "layers": L.stacked_init(
            lambda k: mamba_layer_init(k, cfg, dtype), k_layers,
            cfg.num_layers),
        "final_ln": L.norm_init(cfg, cfg.d_model, dtype),
    }
    if cfg.attn_layer_period:
        params["shared_attn"] = shared_attn_init(k_shared, cfg, dtype)
    if not cfg.tie_embeddings:
        params["unembed"] = L.dense_init(k_out, cfg.d_model, cfg.vocab_size,
                                         dtype)
    return params


# ---------------------------------------------------------------------------
# Core SSD ops
# ---------------------------------------------------------------------------

def _causal_depthwise_conv(x, w, b):
    """x: [B,T,C]; w: [k,C] depthwise causal conv; silu activation."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # gather-free formulation: sum of shifted slices (k is tiny, 4)
    T_ = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + T_, :].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def _ssm_inputs(p, cfg: ModelConfig, x):
    """Project + conv; returns z, xh [B,T,nh,hd], Bm/Cm [B,T,ds],
    dt [B,T,nh] (softplus'd), a-decay log [B,T,nh]."""
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    zxbcdt = x @ p["in_proj"]
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + s.d_state, 2 * di + 2 * s.d_state],
        axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out = _causal_depthwise_conv(conv_in, p["conv_w"], p["conv_b"])
    xin, Bm, Cm = jnp.split(conv_out, [di, di + s.d_state], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    log_a = -jnp.exp(p["A_log"]) * dt                     # [B,T,nh] (<=0)
    xh = xin.reshape(*xin.shape[:-1], nh, s.head_dim)
    return z, xh, Bm, Cm, dt, log_a


def ssd_chunked(xh, Bm, Cm, dt, log_a, chunk: int, h0=None):
    """Chunkwise-parallel SSD scan.

    xh: [B,T,nh,hd]; Bm/Cm: [B,T,ds]; dt/log_a: [B,T,nh].
    Returns (y [B,T,nh,hd], h_last [B,nh,hd,ds]).
    """
    Bsz, T_, nh, hd = xh.shape
    ds = Bm.shape[-1]
    c = chunk
    assert T_ % c == 0, (T_, c)
    nc = T_ // c
    f32 = jnp.float32

    xc = xh.reshape(Bsz, nc, c, nh, hd).astype(f32)
    Bc = Bm.reshape(Bsz, nc, c, ds).astype(f32)
    Cc = Cm.reshape(Bsz, nc, c, ds).astype(f32)
    dtc = dt.reshape(Bsz, nc, c, nh)
    lac = log_a.reshape(Bsz, nc, c, nh)
    cum = jnp.cumsum(lac, axis=2)                         # [B,nc,c,nh]

    # --- intra-chunk (quadratic within chunk, MXU matmuls) --------------
    cb = jnp.einsum("bzts,bzis->bzti", Cc, Bc)            # [B,nc,c,c]
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    tri = jnp.tril(jnp.ones((c, c), bool))
    m = cb[..., None] * decay * dtc[:, :, None, :, :]     # [B,nc,t,i,nh]
    m = jnp.where(tri[None, None, :, :, None], m, 0.0)
    y_intra = jnp.einsum("bztin,bzinh->bztnh", m, xc)

    # --- chunk summary states -------------------------------------------
    # H_z = sum_i exp(cum_last - cum_i) * dt_i * (B_i ⊗ x_i)
    w_end = jnp.exp(cum[:, :, -1:, :] - cum) * dtc        # [B,nc,c,nh]
    Hz = jnp.einsum("bzin,bzinh,bzis->bznhs", w_end, xc, Bc)
    Az = jnp.exp(cum[:, :, -1, :])                        # chunk total decay

    # --- inter-chunk scan -------------------------------------------------
    h_init = jnp.zeros((Bsz, nh, hd, ds), f32) if h0 is None \
        else h0.astype(f32)

    def step(h, inp):
        Hz_z, Az_z = inp                                  # [B,nh,hd,ds], [B,nh]
        h_out = h                                         # state BEFORE chunk
        h = Az_z[:, :, None, None] * h + Hz_z
        return h, h_out

    HzS = jnp.moveaxis(Hz, 1, 0)                          # [nc,B,nh,hd,ds]
    AzS = jnp.moveaxis(Az, 1, 0)                          # [nc,B,nh]
    h_last, h_prevs = lax.scan(step, h_init, (HzS, AzS))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                 # [B,nc,nh,hd,ds]

    # y_inter[t] = exp(cum_t) * dt-free C_t · h_prev
    y_inter = jnp.einsum("bzts,bznhs->bztnh", Cc, h_prevs) * \
        jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bsz, T_, nh, hd)
    return y, h_last


def ssd_step(xh, Bm, Cm, dt, log_a, h):
    """Single-token recurrent step. xh: [B,1,nh,hd]; h: [B,nh,hd,ds]."""
    f32 = jnp.float32
    a = jnp.exp(log_a[:, 0, :]).astype(f32)               # [B,nh]
    u = jnp.einsum("bnh,bs,bn->bnhs", xh[:, 0].astype(f32),
                   Bm[:, 0].astype(f32), dt[:, 0])
    h = a[:, :, None, None] * h + u
    y = jnp.einsum("bs,bnhs->bnh", Cm[:, 0].astype(f32), h)
    return y[:, None], h


def mamba_block(p, cfg: ModelConfig, x, *, h0=None, conv_state=None,
                step: bool = False):
    """Full Mamba2 block. Training: step=False (chunked scan).
    Decode: step=True with (h0, conv_state) from the cache.
    Returns (out, new_h, new_conv_state)."""
    s = cfg.ssm
    res = x
    xn = L.apply_norm(cfg, p["ln"], x)

    if step:
        # maintain a rolling conv window of the last d_conv inputs
        d = cfg.d_model
        di = s.d_inner(d)
        nh = s.n_heads(d)
        zxbcdt = xn @ p["in_proj"]
        z, xin, Bm, Cm, dt = jnp.split(
            zxbcdt, [di, 2 * di, 2 * di + s.d_state,
                     2 * di + 2 * s.d_state], axis=-1)
        conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)   # [B,1,C]
        window = jnp.concatenate([conv_state, conv_in], axis=1)  # [B,k,C]
        new_conv_state = window[:, 1:]
        wc = p["conv_w"].astype(jnp.float32)
        conv_out = jnp.sum(window.astype(jnp.float32) * wc[None], axis=1,
                           keepdims=True)
        conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
        conv_out = conv_out.astype(x.dtype)
        xin, Bm, Cm = jnp.split(conv_out, [di, di + s.d_state], axis=-1)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        log_a = -jnp.exp(p["A_log"]) * dt
        xh = xin.reshape(*xin.shape[:-1], nh, s.head_dim)
        y, h_new = ssd_step(xh, Bm, Cm, dt, log_a, h0)
    else:
        z, xh, Bm, Cm, dt, log_a = _ssm_inputs(p, cfg, xn)
        y, h_new = ssd_chunked(xh, Bm, Cm, dt, log_a, s.chunk, h0)
        new_conv_state = None

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*y.shape[:-2], -1).astype(x.dtype)       # [B,T,di]
    y = L.rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                  p["gate_ln"]["w"])
    return res + y @ p["out_proj"], h_new, new_conv_state


# ---------------------------------------------------------------------------
# Hybrid backbone (zamba2): groups of mamba layers + shared attention
# ---------------------------------------------------------------------------

def _group_shape(cfg: ModelConfig):
    per = cfg.attn_layer_period
    if not per:
        return 1, cfg.num_layers
    assert cfg.num_layers % per == 0, (cfg.num_layers, per)
    return cfg.num_layers // per, per


def hidden(params, cfg: ModelConfig, batch):
    x = T.embed_tokens(params, cfg, batch)
    n_groups, per = _group_shape(cfg)
    stacked = jax.tree.map(
        lambda a: a.reshape(n_groups, per, *a.shape[1:]), params["layers"])

    def group_body(x, lp_group):
        def mamba_step(x, lp):
            def blk(x):
                out, _, _ = mamba_block(lp, cfg, x)
                return out
            if cfg.remat:
                blk = jax.checkpoint(blk)
            return blk(x), None

        x, _ = lax.scan(mamba_step, x, lp_group)
        if cfg.attn_layer_period:
            def attn_blk(x):
                out, _, _ = T._block(cfg, params["shared_attn"], x, batch,
                                     jnp.int32(0), None)
                return out
            if cfg.remat:
                attn_blk = jax.checkpoint(attn_blk)
            x = attn_blk(x)
        return x, None

    x, _ = lax.scan(group_body, x, stacked)
    return L.apply_norm(cfg, params["final_ln"], x), \
        {"aux_loss": jnp.float32(0.0)}


def forward(params, cfg: ModelConfig, batch):
    h, aux = hidden(params, cfg, batch)
    return T.unembed(params, cfg, h), aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None):
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    s = cfg.ssm
    d = cfg.d_model
    nh = s.n_heads(d)
    n_groups, per = _group_shape(cfg)
    c = {
        "ssm": jnp.zeros((cfg.num_layers, batch, nh, s.head_dim, s.d_state),
                         jnp.float32),
        "conv": jnp.zeros((cfg.num_layers, batch, s.d_conv - 1,
                           _conv_channels(cfg)), dtype),
        "bits": jnp.zeros((batch, max_len), jnp.uint32),
    }
    if cfg.attn_layer_period:
        c["attn_k"] = jnp.zeros(
            (n_groups, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype)
        c["attn_v"] = jnp.zeros_like(c["attn_k"])
    return c


def decode_step(params, cfg: ModelConfig, cache, batch):
    B = batch["tokens"].shape[0]
    x = T.embed_tokens(params, cfg, batch)
    n_groups, per = _group_shape(cfg)
    stacked = jax.tree.map(
        lambda a: a.reshape(n_groups, per, *a.shape[1:]), params["layers"])
    ssm_g = cache["ssm"].reshape(n_groups, per, *cache["ssm"].shape[1:])
    conv_g = cache["conv"].reshape(n_groups, per, *cache["conv"].shape[1:])

    cur = batch["positions"][:, 0]
    idx = cur[0]
    Tmax = cache["bits"].shape[1]
    kv_pos = jnp.broadcast_to(jnp.arange(Tmax, dtype=jnp.int32)[None],
                              (B, Tmax))
    q_bits = batch.get("bits")
    if q_bits is None:
        q_bits = jnp.full((B, 1), bam.text_token(), jnp.uint32)
    cache_bits = jnp.where(
        kv_pos < cur[:, None], cache["bits"],
        jnp.where(kv_pos == cur[:, None],
                  jnp.broadcast_to(q_bits, kv_pos.shape), jnp.uint32(0)))
    mask = bam.allowed_mask(q_bits, cache_bits, batch["positions"],
                            kv_pos)[:, None]

    def group_body(x, xs):
        lp_group, ssm_gr, conv_gr, gk, gv = xs

        def mamba_step(x, inner):
            lp, h0, cs = inner
            out, h_new, cs_new = mamba_block(lp, cfg, x, h0=h0,
                                             conv_state=cs, step=True)
            return out, (h_new, cs_new)

        x, (h_new, cs_new) = lax.scan(mamba_step, x,
                                      (lp_group, ssm_gr, conv_gr))
        if cfg.attn_layer_period:
            store = {}

            def kv_override(k, v):
                nk, nv = L.cache_update(gk, gv, k, v, idx)
                store["k"], store["v"] = nk, nv
                return nk, nv

            p = params["shared_attn"]
            h = L.apply_norm(cfg, p["ln1"], x)
            attn_out, _ = L.run_attention(
                p["attn"], cfg, h, q_pos=batch["positions"], kv_pos=kv_pos,
                mask=mask, kv_override=kv_override)
            x = x + attn_out
            h = L.apply_norm(cfg, p["ln2"], x)
            out, _ = T._default_ffn(p, h, cfg)
            x = x + out
            return x, (h_new, cs_new, store["k"], store["v"])
        return x, (h_new, cs_new, gk, gv)

    x, (h_all, cs_all, k_all, v_all) = lax.scan(
        group_body, x,
        (stacked, ssm_g, conv_g,
         cache.get("attn_k", jnp.zeros((n_groups, 0))),
         cache.get("attn_v", jnp.zeros((n_groups, 0)))))

    h = L.apply_norm(cfg, params["final_ln"], x)
    logits = T.unembed(params, cfg, h)
    new_bits = cache["bits"].at[jnp.arange(B), cur].set(q_bits[:, 0])
    new_cache = {
        "ssm": h_all.reshape(cfg.num_layers, *h_all.shape[2:]),
        "conv": cs_all.reshape(cfg.num_layers, *cs_all.shape[2:]),
        "bits": new_bits,
    }
    if cfg.attn_layer_period:
        new_cache["attn_k"] = k_all
        new_cache["attn_v"] = v_all
    return logits, new_cache
